"""Static-batch baseline: the thing continuous batching is measured against.

``static_batch_serve`` is the conventional batched driver discipline: pack
the next ``slots`` queued requests into one batch, run that batch until its
*last* slot finishes, only then admit the next wave.  Converged lanes idle
while stragglers (loose-tolerance or ill-conditioned requests) run out —
exactly the head-of-line blocking that slot recycling in
:class:`~repro.serve.server.RecoveryServer` removes.  Both paths share the
same :class:`~repro.serve.engine.BatchEngine`, clocks, and request stream,
so the benchmark difference is purely the scheduling discipline.
"""

from __future__ import annotations

from typing import List, Optional

from .request import Clock, RecoveryResult, WallClock
from .server import RecoveryServer


def static_batch_serve(
    requests,
    mesh=None,
    slots: int = 8,
    round_iters: int = 32,
    clock: Optional[Clock] = None,
    server: Optional[RecoveryServer] = None,
    **engine_kw,
) -> List[RecoveryResult]:
    """Serve ``requests`` in fixed waves of ``slots`` (no recycling).

    Requests are taken in arrival order; each wave runs to completion
    (every lane inactive) before the next wave is admitted.  Deadlines are
    still honoured — an expired lane is harvested as a flagged partial —
    but a freed lane stays empty until the wave drains.

    ``server`` optionally supplies a pre-built (e.g. pre-``warmup``-ed)
    :class:`RecoveryServer` whose bucketing and engine cache are reused —
    the benchmark passes one so baseline and continuous paths share
    compiled programs and the comparison is pure scheduling discipline.
    """
    keyer = server if server is not None else RecoveryServer(
        mesh=mesh, slots=slots, round_iters=round_iters, **engine_kw
    )
    clock = clock if clock is not None else (
        keyer.clock if server is not None else WallClock()
    )
    slots = keyer.slots
    results: List[RecoveryResult] = []
    pending = sorted(requests, key=lambda r: r.arrival_time)
    if not pending:
        return results

    engines = {}

    i = 0
    while i < len(pending):
        req = pending[i]
        key = keyer.bucket_key(req)
        eng = engines.get(key)
        if eng is None:
            eng = keyer._engine_for(key, req)
            engines[key] = eng
        # fill a wave from consecutive same-bucket requests
        wave = []
        while i < len(pending) and len(wave) < slots \
                and keyer.bucket_key(pending[i]) == key:
            wave.append(pending[i])
            i += 1
        clock.advance_to(wave[-1].arrival_time)
        now = clock.now()
        for slot, r in enumerate(wave):
            eng.admit(slot, r, now)
        while eng.busy:
            eng.run_round()
            results.extend(eng.harvest(clock.now()))
    return results

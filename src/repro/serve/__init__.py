"""Recovery-as-a-service: a continuous-batching dispatcher for compressed
signals, in the LLM-serving style — requests bucketed by operator/plan,
packed into batched ``solve_until`` drivers, converged slots recycled to
queued requests mid-run."""

from .arrivals import poisson_times, synthetic_workload
from .baseline import static_batch_serve
from .engine import BatchEngine
from .request import (
    Clock,
    ManualClock,
    RecoveryRequest,
    RecoveryResult,
    WallClock,
)
from .server import RecoveryServer, operator_fingerprint, summarize

__all__ = [
    "BatchEngine",
    "Clock",
    "ManualClock",
    "RecoveryRequest",
    "RecoveryResult",
    "RecoveryServer",
    "WallClock",
    "operator_fingerprint",
    "poisson_times",
    "static_batch_serve",
    "summarize",
    "synthetic_workload",
]

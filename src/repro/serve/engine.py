"""The continuous-batching engine: one bucket's slots, recycled mid-run.

A :class:`BatchEngine` owns ``slots`` lanes of one batched solver — every
lane shares the bucket's operator, method, and execution plan, but carries
its *own* convergence contract (per-slot ``tol`` / ``min_iters`` /
``max_iters`` arrays, the contract :func:`repro.core.solvers.solve_until`
grew for exactly this).  The engine advances all lanes together in jitted
*rounds* of ``round_iters`` masked iterations, then hands control back to
the host scheduler, which

  1. **harvests** lanes that went inactive (converged, budget-exhausted, or
     deadline-expired) — their iterate rows become results, and
  2. **recycles** the freed lanes: a queued request is admitted *mid-run*
     with the slot's solver state, ``delta``, and iteration age re-armed
     (:func:`repro.core.solvers.rearm_slots`), so the batch never drains to
     its stragglers — the LLM-continuous-batching mechanism applied to
     compressed-signal recovery.

Because freezing and re-arming are pure per-slot where-selects, a recycled
lane computes exactly what a solo :func:`solve_until` run would (pinned to
1e-5 in tests/test_serve.py).  One XLA program is compiled per engine; y
and every per-slot array are traced arguments, so admission never re-jits.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core.solvers import (
    RecoveryProblem,
    make_stepper,
    rearm_slots,
    until_active,
    until_init,
    until_step,
)

from .request import RecoveryRequest, RecoveryResult


class BatchEngine:
    """``slots`` lanes of one batched solver, recycled round by round."""

    def __init__(
        self,
        op: Any,
        plan: Any,
        method: str = "cpadmm",
        slots: int = 8,
        round_iters: int = 32,
        alpha: float = 1e-4,
        rho: float = 0.1,
        sigma: float = 0.1,
        bucket: str = "",
    ):
        self.op = op
        self.plan = plan
        self.method = method
        self.slots = int(slots)
        self.round_iters = int(round_iters)
        self.alpha, self.rho, self.sigma = alpha, rho, sigma
        self.bucket = bucket

        distributed = plan is not None and getattr(plan, "is_distributed", False)
        # the drivers' measurement convention: length-m rows locally,
        # scattered full-length rows (P^T y) on a mesh — requests arrive as
        # length-m and are scattered at admission when needed
        self._y_len = op.n if distributed else op.m
        self._scatter = distributed
        dtype = jnp.asarray(getattr(getattr(op, "circ", op), "col")).dtype
        self._y = jnp.zeros((self.slots, self._y_len), dtype)

        # per-slot convergence contracts; empty slots are parked with
        # max_iters = 0, which until_active treats as never-active
        self._tol = jnp.full((self.slots,), jnp.inf, dtype)
        self._min = jnp.zeros((self.slots,), jnp.int32)
        self._max = jnp.zeros((self.slots,), jnp.int32)

        # host-side slot metadata
        self._requests: List[Optional[RecoveryRequest]] = [None] * self.slots
        self._admitted_at: List[Optional[float]] = [None] * self.slots
        self._slot_used = [False] * self.slots

        self.stats: Dict[str, int] = {
            "admitted": 0,  # requests that reached a slot
            "recycled": 0,  # admissions into a lane freed mid-run
            "rounds": 0,  # jitted round launches
            "slot_iters": 0,  # sum of per-slot iterations actually stepped
        }

        def build_stepper(y):
            return make_stepper(
                RecoveryProblem(op=op, y=y), method,
                alpha=alpha, rho=rho, sigma=sigma, plan=plan,
            )

        # the init carry: solver-state zeros + age 0 + delta inf — both the
        # engine's starting point and the value re-armed into recycled slots
        # (solver inits are y-independent, so one init serves every request)
        stepper0 = build_stepper(self._y)
        self._u, self._batch = until_init(stepper0)
        self._u_init = self._u
        self._x = stepper0.extract(self._u.state)  # (slots, n) last extract

        round_iters_ = self.round_iters
        batch = self._batch

        @jax.jit
        def round_fn(y, u, tol, mn, mx):
            # the stepper is rebuilt under the trace so y is a traced
            # argument: admitting a new measurement row never re-compiles
            stepper = build_stepper(y)

            def cond(c):
                u, k = c
                return jnp.logical_and(
                    k < round_iters_, jnp.any(until_active(u, tol, mn, mx))
                )

            def body(c):
                u, k = c
                return until_step(stepper, u, tol, mn, mx, batch), k + 1

            (u, _) = jax.lax.while_loop(cond, body, (u, jnp.int32(0)))
            return u, stepper.extract(u.state)

        @jax.jit
        def rearm_fn(u, admit):
            return rearm_slots(u, self._u_init, admit, batch)

        self._round_fn = round_fn
        self._rearm_fn = rearm_fn

    # -- occupancy ---------------------------------------------------------
    @property
    def busy(self) -> bool:
        return any(r is not None for r in self._requests)

    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self._requests) if r is None]

    # -- admission ---------------------------------------------------------
    def admit(self, slot: int, req: RecoveryRequest, now: float) -> None:
        """Place ``req`` into a free slot, re-arming that lane's state."""
        if self._requests[slot] is not None:
            raise RuntimeError(f"slot {slot} is occupied")
        y = jnp.asarray(req.y, self._y.dtype)
        if self._scatter and y.shape[-1] != self._y_len:
            y = self.op.project_back(y)
        if y.shape[-1] != self._y_len:
            raise ValueError(
                f"request {req.request_id!r}: measurement length "
                f"{y.shape[-1]} does not fit this bucket's operator "
                f"(expects {self._y_len})"
            )
        self._y = self._y.at[slot].set(y)
        self._tol = self._tol.at[slot].set(req.tol)
        self._min = self._min.at[slot].set(req.min_iters)
        self._max = self._max.at[slot].set(req.max_iters)
        admit_mask = jnp.zeros((self.slots,), bool).at[slot].set(True)
        self._u = self._rearm_fn(self._u, admit_mask)
        self._requests[slot] = req
        self._admitted_at[slot] = now
        self.stats["admitted"] += 1
        if self._slot_used[slot]:
            self.stats["recycled"] += 1
        self._slot_used[slot] = True

    def park(self, slot: int) -> None:
        """Return a harvested lane to the never-active parked state."""
        self._requests[slot] = None
        self._admitted_at[slot] = None
        self._max = self._max.at[slot].set(0)
        self._tol = self._tol.at[slot].set(jnp.inf)

    # -- the round ---------------------------------------------------------
    def run_round(self) -> None:
        """Advance every active lane up to ``round_iters`` masked iterations."""
        if not self.busy:
            return
        age_before = int(jnp.sum(self._u.age))
        self._u, self._x = self._round_fn(
            self._y, self._u, self._tol, self._min, self._max
        )
        jax.block_until_ready(self._x)
        self.stats["rounds"] += 1
        self.stats["slot_iters"] += int(jnp.sum(self._u.age)) - age_before

    # -- harvest -----------------------------------------------------------
    def harvest(self, now: float) -> List[RecoveryResult]:
        """Collect finished lanes: converged / budget-exhausted lanes, plus
        any whose deadline has passed (flagged partial results)."""
        if not self.busy:
            return []
        age = jax.device_get(self._u.age)
        delta = jax.device_get(self._u.delta)
        tol = jax.device_get(self._tol)
        mn = jax.device_get(self._min)
        mx = jax.device_get(self._max)
        out: List[RecoveryResult] = []
        x_host = None
        for i, req in enumerate(self._requests):
            if req is None:
                continue
            inactive = age[i] >= mx[i] or (age[i] >= mn[i] and delta[i] <= tol[i])
            expired = req.deadline is not None and now >= req.deadline
            if not (inactive or expired):
                continue
            if x_host is None:
                x_host = jax.device_get(self._x)
            converged = bool(delta[i] <= tol[i] and age[i] >= mn[i])
            out.append(RecoveryResult(
                request_id=req.request_id,
                x=x_host[i],
                iterations=int(age[i]),
                delta=float(delta[i]),
                converged=converged,
                deadline_expired=bool(expired and not converged),
                arrival_time=req.arrival_time,
                admitted_time=self._admitted_at[i],
                finish_time=now,
                bucket=self.bucket,
            ))
            self.park(i)
        return out

"""Request / result dataclasses and clocks for the recovery service.

A :class:`RecoveryRequest` is one compressed signal to recover, with its own
convergence contract (``tol`` / ``min_iters`` / ``max_iters``), scheduling
hints (``priority``, ``deadline``), and the sensing operator it was measured
through.  The dispatcher (:mod:`repro.serve.server`) buckets requests whose
operator + solver + plan agree and packs them into one batched driver.

Time is injectable: the server reads a :class:`Clock`, so tests drive a
:class:`ManualClock` deterministically while benchmarks and production use
the :class:`WallClock`.  All timestamps (``arrival_time``, ``deadline``,
result times) are seconds on that clock.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional


class Clock:
    """The server's notion of time (seconds, monotone)."""

    def now(self) -> float:
        raise NotImplementedError

    def advance_to(self, t: float) -> None:
        """Idle-wait until ``t`` (no-op if already past)."""
        raise NotImplementedError


class WallClock(Clock):
    """Real time, zeroed at construction; idle waits actually sleep."""

    def __init__(self):
        self._t0 = time.monotonic()

    def now(self) -> float:
        return time.monotonic() - self._t0

    def advance_to(self, t: float) -> None:
        dt = t - self.now()
        if dt > 0:
            time.sleep(dt)


class ManualClock(Clock):
    """Deterministic test clock: time moves only when told to."""

    def __init__(self, t: float = 0.0):
        self._t = float(t)

    def now(self) -> float:
        return self._t

    def advance_to(self, t: float) -> None:
        self._t = max(self._t, float(t))

    def tick(self, dt: float) -> None:
        self._t += float(dt)


@dataclasses.dataclass(frozen=True)
class RecoveryRequest:
    """One signal to recover, with its own convergence/scheduling contract.

    ``y`` is the length-``m`` measurement vector sensed through ``op`` (a
    batch of requests may — and at scale will — share one operator
    instance; the dispatcher buckets on the operator's content fingerprint,
    so distinct spectra never share a batch).  ``priority``: larger runs
    first under contention.  ``deadline``: absolute clock time after which
    the request is returned as a *flagged partial result* instead of
    iterating further (never an exception).  ``plan_config`` optionally
    pins the execution-plan knobs for this request's bucket (e.g. rfft vs
    full-complex — configs that lower differently are separate buckets by
    construction).
    """

    request_id: str
    op: Any  # RecoveryOperator (matvec/rmatvec/project_back-capable)
    y: Any  # (m,) measurements
    tol: float = 1e-6
    min_iters: int = 50
    max_iters: int = 3000
    priority: int = 0
    deadline: Optional[float] = None
    arrival_time: float = 0.0
    method: str = "cpadmm"
    plan_config: Any = None  # Optional[repro.ops.PlanConfig]
    x_true: Any = None  # ground truth, metrics only


@dataclasses.dataclass(frozen=True)
class RecoveryResult:
    """What the server returns for one request.

    ``converged`` means the relative-change test passed inside the budget;
    ``deadline_expired`` flags a partial iterate returned because the
    deadline passed (``x`` is the best iterate so far, ``iterations`` how
    far it got — a request whose deadline passes while still queued comes
    back with ``iterations == 0`` and a zero iterate).
    """

    request_id: str
    x: Any  # (n,) recovered signal (partial if flagged)
    iterations: int
    delta: float  # last relative iterate change (inf if never stepped)
    converged: bool
    deadline_expired: bool
    arrival_time: float
    admitted_time: Optional[float]  # None: never reached a slot
    finish_time: float
    bucket: str  # the bucket key this request was served under

    @property
    def latency(self) -> float:
        """Arrival-to-finish seconds — the p50/p99 benchmark quantity."""
        return self.finish_time - self.arrival_time

    @property
    def queue_wait(self) -> float:
        t = self.finish_time if self.admitted_time is None else self.admitted_time
        return t - self.arrival_time

"""The recovery dispatcher: bucket, pack, recycle.

:class:`RecoveryServer` is the serving front-end over the batched solvers:
requests stream in (:meth:`submit` or the open-loop :meth:`serve`), are
bucketed by everything their batch must agree on — operator fingerprint,
solver method and hyper-parameters, and the execution-plan config
(:meth:`repro.ops.PlanConfig.describe` — so e.g. rfft and full-complex
requests can never share a batch) — and each bucket runs a
:class:`~repro.serve.engine.BatchEngine` whose converged slots are recycled
to queued requests mid-run.  Plans come warm when the PR-6 tune cache has
seen the bucket's workload (``tune=`` forwards to ``plan(op, mesh,
tune=...)``, which hits :class:`repro.ops.tune.PlanCache` in ~ms).

Scheduling is priority-first (larger ``priority`` wins; FIFO within a
priority), deadlines come back as flagged partial results, and every clock
read goes through the injectable :class:`~repro.serve.request.Clock`.
"""

from __future__ import annotations

import hashlib
import heapq
from typing import Any, Dict, List, Optional

import numpy as np

from .engine import BatchEngine
from .request import Clock, RecoveryRequest, RecoveryResult, WallClock


def operator_fingerprint(op) -> str:
    """Content fingerprint of a sensing operator — bucket isolation.

    Two operators with the same (type, n, m) but different spectra must
    never share a batch (slots would solve against the wrong operator), so
    the bucket key hashes the stored spectrum prefix and the measurement
    index set, not just the shape signature.
    """
    h = hashlib.sha256()
    circ = getattr(op, "circ", op)
    h.update(type(op).__name__.encode())
    h.update(np.asarray(circ.col[:256]).tobytes())
    omega = getattr(op, "omega", None)
    if omega is not None:
        h.update(np.asarray(omega[:256]).tobytes())
        h.update(str(int(omega.shape[-1])).encode())
    h.update(str(int(circ.n)).encode())
    return h.hexdigest()[:16]


class RecoveryServer:
    """Continuous-batching recovery-as-a-service dispatcher."""

    def __init__(
        self,
        mesh: Any = None,
        slots: int = 8,
        round_iters: int = 32,
        alpha: float = 1e-4,
        rho: float = 0.1,
        sigma: float = 0.1,
        tune: Any = False,
        clock: Optional[Clock] = None,
    ):
        self.mesh = mesh
        self.slots = int(slots)
        self.round_iters = int(round_iters)
        self.alpha, self.rho, self.sigma = alpha, rho, sigma
        self.tune = tune
        self.clock = clock if clock is not None else WallClock()

        self.engines: Dict[str, BatchEngine] = {}
        # bucket key -> heap of (-priority, seq, request); seq keeps FIFO
        # order within a priority level (and makes the heap total-ordered)
        self._queues: Dict[str, list] = {}
        self._seq = 0
        self.results: List[RecoveryResult] = []

    # -- bucketing ---------------------------------------------------------
    def bucket_key(self, req: RecoveryRequest) -> str:
        # cfg.describe() carries every plan knob that changes the compiled
        # program — including wire_dtype (a "wire=bf16" tag when demoted),
        # so mixed-precision-wire requests never share a lane with fp32 ones
        cfg = req.plan_config
        cfg_tag = cfg.describe() if cfg is not None else f"tune={self.tune}"
        return "|".join([
            f"op={operator_fingerprint(req.op)}",
            f"n={req.op.n}", f"m={req.op.m}",
            f"method={req.method}",
            f"alpha={self.alpha}", f"rho={self.rho}", f"sigma={self.sigma}",
            f"plan[{cfg_tag}]",
        ])

    def _engine_for(self, key: str, req: RecoveryRequest) -> BatchEngine:
        eng = self.engines.get(key)
        if eng is None:
            from repro.ops import plan as plan_fn

            if req.plan_config is not None:
                pl = plan_fn(req.op, self.mesh, config=req.plan_config)
            elif self.tune and self.mesh is not None:
                # warm path: the tune cache returns the bucket's winning
                # config in ~ms once any prior run has tuned this workload
                pl = plan_fn(req.op, self.mesh, tune=self.tune,
                             batch=self.slots)
            else:
                pl = plan_fn(req.op, self.mesh)
            eng = BatchEngine(
                req.op, pl, method=req.method, slots=self.slots,
                round_iters=self.round_iters, alpha=self.alpha,
                rho=self.rho, sigma=self.sigma, bucket=key,
            )
            self.engines[key] = eng
        return eng

    def warmup(self, req: RecoveryRequest) -> None:
        """Compile ``req``'s bucket (round + re-arm programs) off the clock.

        Serves a short-budget clone of ``req`` through the bucket's engine
        and discards the result, so a timed ``serve`` run measures steady
        state rather than XLA compilation.  Stats are reset afterwards.
        """
        import dataclasses

        key = self.bucket_key(req)
        eng = self._engine_for(key, req)
        dummy = dataclasses.replace(
            req, request_id="__warmup__", deadline=None,
            max_iters=min(req.max_iters, self.round_iters), min_iters=0,
        )
        slot = eng.free_slots()[0]
        eng.admit(slot, dummy, self.clock.now())
        while eng.busy:
            eng.run_round()
            eng.harvest(self.clock.now())
        eng._slot_used[slot] = False  # not a recycling opportunity
        for k in eng.stats:
            eng.stats[k] = 0

    # -- intake ------------------------------------------------------------
    def submit(self, req: RecoveryRequest) -> str:
        """Queue one request; returns its bucket key."""
        key = self.bucket_key(req)
        self._queues.setdefault(key, [])
        heapq.heappush(self._queues[key], (-req.priority, self._seq, req))
        self._seq += 1
        return key

    @property
    def pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    @property
    def busy(self) -> bool:
        return any(e.busy for e in self.engines.values())

    # -- the scheduling round ---------------------------------------------
    def _expire_queued(self, key: str, now: float) -> None:
        """Queued requests whose deadline already passed come back as
        flagged zero-iterate results — they never reach a slot."""
        q = self._queues.get(key, [])
        live = []
        for item in q:
            req = item[2]
            if req.deadline is not None and now >= req.deadline:
                self.results.append(RecoveryResult(
                    request_id=req.request_id,
                    x=np.zeros((req.op.n,), dtype=np.asarray(req.y).dtype),
                    iterations=0,
                    delta=float("inf"),
                    converged=False,
                    deadline_expired=True,
                    arrival_time=req.arrival_time,
                    admitted_time=None,
                    finish_time=now,
                    bucket=key,
                ))
            else:
                live.append(item)
        if len(live) != len(q):
            heapq.heapify(live)
            self._queues[key] = live

    def step(self) -> List[RecoveryResult]:
        """One scheduling round: admit → iterate → harvest, every bucket.

        Returns the results harvested this round (also appended to
        ``self.results``).
        """
        now = self.clock.now()
        harvested: List[RecoveryResult] = []
        for key, q in list(self._queues.items()):
            self._expire_queued(key, now)
            q = self._queues[key]
            if not q and key not in self.engines:
                continue
            if q:
                eng = self._engine_for(key, q[0][2])
                for slot in eng.free_slots():
                    if not q:
                        break
                    _, _, req = heapq.heappop(q)
                    eng.admit(slot, req, now)
        for eng in self.engines.values():
            eng.run_round()
            got = eng.harvest(self.clock.now())
            harvested.extend(got)
        self.results.extend(harvested)
        return harvested

    # -- drivers -----------------------------------------------------------
    def drain(self) -> List[RecoveryResult]:
        """Run scheduling rounds until every queued request is resolved."""
        while self.pending or self.busy:
            self.step()
        return self.results

    def serve(self, requests) -> List[RecoveryResult]:
        """Open-loop serving: each request becomes visible at its
        ``arrival_time`` on the server clock; returns all results once the
        stream is drained."""
        pending = sorted(requests, key=lambda r: r.arrival_time)
        i = 0
        while i < len(pending) or self.pending or self.busy:
            now = self.clock.now()
            while i < len(pending) and pending[i].arrival_time <= now:
                self.submit(pending[i])
                i += 1
            if not self.pending and not self.busy and i < len(pending):
                # idle with only future arrivals: wait for the next one
                self.clock.advance_to(pending[i].arrival_time)
                continue
            self.step()
        return self.results

    # -- stats -------------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        per_bucket = {k: dict(e.stats) for k, e in self.engines.items()}
        total = {"admitted": 0, "recycled": 0, "rounds": 0, "slot_iters": 0}
        for s in per_bucket.values():
            for k in total:
                total[k] += s[k]
        return {"buckets": len(self.engines), "total": total,
                "per_bucket": per_bucket}


def summarize(results: List[RecoveryResult]) -> Dict[str, float]:
    """Headline serving metrics: signals/sec over the busy span, latency
    percentiles, convergence/expiry counts."""
    if not results:
        return {"count": 0}
    lat = np.asarray([r.latency for r in results])
    t0 = min(r.arrival_time for r in results)
    t1 = max(r.finish_time for r in results)
    span = max(t1 - t0, 1e-9)
    return {
        "count": len(results),
        "signals_per_sec": len(results) / span,
        "p50_latency_s": float(np.percentile(lat, 50)),
        "p99_latency_s": float(np.percentile(lat, 99)),
        "mean_iterations": float(np.mean([r.iterations for r in results])),
        "converged": sum(r.converged for r in results),
        "expired": sum(r.deadline_expired for r in results),
        "span_s": float(span),
    }

"""Synthetic request streams: seeded Poisson arrivals over one operator.

The paper's serving scenario is a ground-segment receiver draining a stream
of compressively-sensed signals (cheap on-board encoder, all recovery cost
at the receiver).  This module fabricates that stream deterministically: a
seeded Poisson process for arrival times and a seeded per-request signal /
convergence-contract draw, so tests can assert bit-for-bit reproducibility
and benchmarks compare dispatchers on the identical workload.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import numpy as np

from repro.data.synthetic import paper_regime, sparse_signal

from .request import RecoveryRequest


def poisson_times(seed: int, n: int, rate: float) -> np.ndarray:
    """``n`` arrival times of a rate-``rate``/s Poisson process (seeded)."""
    if rate <= 0:
        raise ValueError(f"arrival rate must be positive, got {rate}")
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate, size=n))


def synthetic_workload(
    op,
    n_requests: int,
    rate: float,
    seed: int = 0,
    tols: Sequence[float] = (1e-5, 1e-6),
    max_iters: int = 3000,
    min_iters: int = 50,
    priorities: Sequence[int] = (0,),
    deadline_slack: Optional[float] = None,
    sparsity: Optional[Tuple[int, int]] = None,
    method: str = "cpadmm",
) -> list:
    """A deterministic request stream over one sensing operator.

    Each request senses a fresh sparse signal through ``op`` and draws its
    convergence contract from ``tols`` (heterogeneous tolerances are what
    make convergence times ragged — the raggedness slot recycling exploits)
    and its ``priority`` from ``priorities``.  ``deadline_slack`` seconds,
    if given, sets each deadline to ``arrival + slack``.  ``sparsity``
    optionally bounds the support draw ``k in [lo, hi]`` (default: the
    paper-regime k for ``op.n``, exactly).
    """
    times = poisson_times(seed, n_requests, rate)
    rng = np.random.default_rng(seed + 1)
    n = op.n
    k_paper = paper_regime(n)[1]
    lo, hi = sparsity if sparsity is not None else (k_paper, k_paper)
    out = []
    for i, t in enumerate(times):
        k = int(rng.integers(lo, hi + 1))
        x = sparse_signal(jax.random.PRNGKey(seed + 1000 + i), n, k)
        y = op.matvec(x)
        out.append(RecoveryRequest(
            request_id=f"req-{i:04d}",
            op=op,
            y=y,
            x_true=x,
            tol=float(rng.choice(np.asarray(tols))),
            min_iters=min_iters,
            max_iters=max_iters,
            priority=int(rng.choice(np.asarray(priorities))),
            deadline=None if deadline_slack is None else float(t) + deadline_slack,
            arrival_time=float(t),
            method=method,
        ))
    return out

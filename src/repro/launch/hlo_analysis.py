"""Static analyzer over optimized HLO text: per-device FLOPs, HBM traffic,
and collective bytes — *with while-loop trip counts applied*.

Why not ``compiled.cost_analysis()``: XLA's entry-computation cost analysis
counts a ``while`` body exactly once, but our production steps keep the HLO
small by scanning over layers / KV chunks / loss chunks, so >95% of the real
work lives inside while bodies.  This walker:

  * splits the HLO module into computations,
  * tracks instruction result shapes (params from signatures, defs inline),
  * counts dot FLOPs from output shape x contracting dims, fft FLOPs as
    5 n log2 n, elementwise/reduce FLOPs as output sizes,
  * estimates HBM bytes at *fusion granularity* (operands + results of each
    top-level instruction; inside-fusion temporaries are free, matching how
    TPUs stream VMEM),
  * recurses into called computations (fusions only contribute their dots),
  * multiplies while bodies by the trip count recovered from the loop
    condition (canonical ``compare(iv, K), direction=LT`` pattern),
  * sums collective payload bytes by op kind with the same multipliers.

Everything is per-device: the module analyzed is the post-GSPMD partitioned
program, which is exactly the per-chip view the roofline needs.
"""

from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "c64": 8, "c128": 16,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
}

SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\](?:\{[^}]*\})?")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _split_args(text: str) -> List[str]:
    """Split an operand list on top-level commas only.

    Newer XLA prints operands with inline shapes ("f32[128,64]{1,0} %arg"),
    so naive ``split(",")`` breaks inside dims/layout brackets.
    """
    parts: List[str] = []
    depth = 0
    cur: List[str] = []
    for ch in text:
        if ch in "[{(":
            depth += 1
        elif ch in "]})":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    tail = "".join(cur).strip()
    if tail:
        parts.append(tail)
    return parts


def _operand_name(arg: str) -> str:
    """'f32[2,3]{1,0} %name' | '%name' | 'name' -> 'name'."""
    return arg.strip().split(" ")[-1].lstrip("%")


def _parse_shape(text: str) -> List[Tuple[str, Tuple[int, ...]]]:
    """'(f32[2,3], bf16[4])' or 'f32[2,3]' -> [(dtype, dims), ...]."""
    out = []
    for m in SHAPE_RE.finditer(text):
        dt, dims = m.groups()
        shape = tuple(int(d) for d in dims.split(",") if d)
        out.append((dt, shape))
    return out


def _nbytes(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * DTYPE_BYTES.get(dt, 4)
    return total


def _numel(shapes) -> int:
    total = 0
    for _, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    transcendentals: float = 0.0
    collective_bytes: Dict[str, float] = field(default_factory=dict)
    collective_counts: Dict[str, float] = field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.transcendentals += other.transcendentals * mult
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] = self.collective_bytes.get(k, 0.0) + v * mult
        for k, v in other.collective_counts.items():
            self.collective_counts[k] = self.collective_counts.get(k, 0.0) + v * mult

    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


@dataclass
class Instruction:
    name: str
    result: str  # shape text
    op: str
    body: str  # full line


@dataclass
class Computation:
    name: str
    param_shapes: Dict[str, str]
    instructions: List[Instruction]


_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
# result shape is either a tuple "(...)" (no nested parens; may contain
# /*index=N*/ comments) or a single "dtype[dims]{layout}" token
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^()]*\)|[\w\[\]\{\},]+))\s+([\w\-]+)\("
)


def parse_module(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if cur is None:
            stripped = line.strip()
            m = _COMP_HEADER.match(stripped)
            if m and stripped.endswith("{"):
                name = m.group(1)
                # params: "name: shape" pairs; shapes may be nested tuples, but
                # per-param shapes are recovered from the parameter()
                # instructions inside the body, so the signature is advisory.
                params = {}
                for pm in re.finditer(r"%?([\w.\-]+):\s*([\w\[\],{}]+)", stripped):
                    params[pm.group(1)] = pm.group(2)
                cur = Computation(name=name, param_shapes=params, instructions=[])
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if m:
            nm, result, op = m.groups()
            cur.instructions.append(Instruction(nm, result, op, line))
    return comps


def _called_comps(body: str) -> List[str]:
    names = []
    for key in ("to_apply=", "body=", "condition=", "branch_computations={",
                "called_computations={", "calls="):
        idx = body.find(key)
        if idx < 0:
            continue
        seg = body[idx + len(key):]
        if seg.startswith("{"):
            seg = seg[1 : seg.find("}")]
        else:
            seg = seg.split(",")[0].split(" ")[0]
        for tok in seg.split(","):
            tok = tok.strip().lstrip("%")
            if tok:
                names.append(tok)
    return names


def _trip_count(cond: Computation, comps: Dict[str, Computation]) -> Optional[int]:
    """Recover K from the canonical 'compare(iv, K), direction=LT' pattern.

    The compare may be fused: follow one level of fusion, mapping the fused
    computation's parameters back to the call-site operands.
    """
    consts = {}
    for ins in cond.instructions:
        m = re.search(r"=\s*[su]\d+\[\]\s*constant\((\-?\d+)\)", ins.body)
        if m:
            consts[ins.name] = int(m.group(1))

    def from_compare(body: str, operand_consts: List[Optional[int]]):
        dm = re.search(r"direction=(\w+)", body)
        if not dm:
            return None
        if dm.group(1) == "LT" and operand_consts[-1] is not None:
            return operand_consts[-1]
        if dm.group(1) == "GT" and operand_consts[0] is not None:
            return operand_consts[0]
        return None

    for ins in cond.instructions:
        if ins.op == "compare":
            m = re.search(r"compare\(([^)]*)\)", ins.body)
            if not m:
                continue
            args = [_operand_name(a) for a in _split_args(m.group(1))]
            got = from_compare(ins.body, [consts.get(a) for a in args])
            if got:
                return got
        if ins.op == "fusion":
            called = _called_comps(ins.body)
            m = re.search(r"fusion\(([^)]*)\)", ins.body)
            if not (called and m):
                continue
            args = [_operand_name(a) for a in _split_args(m.group(1))]
            arg_consts = [consts.get(a) for a in args]
            for cn in called:
                inner = comps.get(cn)
                if inner is None:
                    continue
                for iins in inner.instructions:
                    if iins.op == "compare":
                        got = from_compare(iins.body, arg_consts)
                        if got:
                            return got
    # fallback: a single scalar integer constant in the condition is the bound
    if len(consts) == 1:
        (v,) = consts.values()
        if v > 0:
            return v
    return None


_LAYOUT_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "copy", "copy-start", "copy-done", "after-all", "partition-id",
    "replica-id", "iota", "broadcast", "reshape", "transpose",
}
_CHEAP_OPS = _LAYOUT_OPS | {"slice", "dynamic-slice", "dynamic-update-slice",
                            "concatenate", "pad", "reverse", "gather", "scatter",
                            "select", "compare", "convert", "reduce", "sort", "while",
                            "conditional", "call", "custom-call", "fusion", "dot",
                            "fft", "rng", "rng-bit-generator", "map",
                            "all-gather", "all-reduce", "reduce-scatter",
                            "all-to-all", "collective-permute", "select-and-scatter",
                            "reduce-window", "convolution", "cholesky",
                            "triangular-solve", "optimization-barrier",
                            "get-dimension-size", "send", "recv", "send-done",
                            "recv-done", "infeed", "outfeed", "domain"}


def _dot_flops(ins: Instruction, shapes: Dict[str, str]) -> float:
    out = _parse_shape(ins.result)
    out_elems = _numel(out)
    m = re.search(r"dot\(([^)]*)\)", ins.body)
    lhs_contract = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.body)
    if not (m and lhs_contract):
        return 2.0 * out_elems  # degenerate
    args = _split_args(m.group(1))
    lhs_name = _operand_name(args[0]) if args else ""
    lhs_shape_text = shapes.get(lhs_name, "")
    lhs = _parse_shape(lhs_shape_text)
    if not lhs and args:
        # shape may be inline in the operand text
        lhs = _parse_shape(args[0])
    k = 1
    if lhs:
        dims = lhs[0][1]
        for ci in lhs_contract.group(1).split(","):
            if ci and int(ci) < len(dims):
                k *= dims[int(ci)]
    return 2.0 * out_elems * k


def _fft_flops(ins: Instruction) -> float:
    out = _parse_shape(ins.result)
    n = _numel(out)
    length = re.search(r"fft_length=\{([\d,]*)\}", ins.body)
    l = 1
    if length:
        for d in length.group(1).split(","):
            if d:
                l *= int(d)
    batch = n / max(l, 1)
    return 5.0 * batch * l * max(math.log2(max(l, 2)), 1.0)


class HloAnalyzer:
    def __init__(self, hlo: str):
        self.comps = parse_module(hlo)
        self.entry = self._find_entry(hlo)
        self._memo: Dict[str, Cost] = {}

    def _find_entry(self, hlo: str) -> str:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.M)
        return m.group(1) if m else next(iter(self.comps))

    def _shapes_in(self, comp: Computation) -> Dict[str, str]:
        shapes = dict(comp.param_shapes)
        for ins in comp.instructions:
            shapes[ins.name] = ins.result
            if ins.op == "parameter":
                shapes[ins.name] = ins.result
        return shapes

    def cost_of(self, comp_name: str, surface: bool = True) -> Cost:
        """surface=True: count HBM traffic at this level (entry / while body);
        surface=False: inside a fusion — only dots/ffts/transcendentals."""
        memo_key = f"{comp_name}|{surface}"
        if memo_key in self._memo:
            return self._memo[memo_key]
        comp = self.comps.get(comp_name)
        cost = Cost()
        if comp is None:
            return cost
        shapes = self._shapes_in(comp)
        for ins in comp.instructions:
            out_shapes = _parse_shape(ins.result)
            out_bytes = _nbytes(out_shapes)
            if ins.op == "dot":
                cost.flops += _dot_flops(ins, shapes)
                if surface:
                    cost.bytes += out_bytes + self._operand_bytes(ins, shapes)
            elif ins.op == "convolution":
                cost.flops += 2.0 * _numel(out_shapes) * 128  # coarse; unused here
                if surface:
                    cost.bytes += out_bytes + self._operand_bytes(ins, shapes)
            elif ins.op == "fft" or (ins.op == "custom-call" and "fft" in ins.body.lower()):
                cost.flops += _fft_flops(ins)
                if surface:
                    cost.bytes += out_bytes + self._operand_bytes(ins, shapes)
            elif ins.op == "fusion":
                inner = Cost()
                for cn in _called_comps(ins.body):
                    inner.add(self.cost_of(cn, surface=False))
                cost.add(inner)
                if surface:
                    cost.bytes += self._fusion_surface_bytes(ins, shapes, out_bytes)
                # elementwise flops at fusion granularity ~ output size
                cost.flops += _numel(out_shapes)
            elif ins.op == "while":
                body_names = _called_comps(ins.body)
                body = cond = None
                bm = re.search(r"body=%?([\w.\-]+)", ins.body)
                cm = re.search(r"condition=%?([\w.\-]+)", ins.body)
                body = bm.group(1) if bm else (body_names[0] if body_names else None)
                cond = cm.group(1) if cm else None
                trips = None
                if cond and cond in self.comps:
                    trips = _trip_count(self.comps[cond], self.comps)
                trips = trips if trips and trips > 0 else 1
                if body:
                    cost.add(self.cost_of(body, surface=True), mult=trips)
            elif ins.op == "conditional":
                branch_costs = [self.cost_of(cn, surface=True) for cn in _called_comps(ins.body)]
                if branch_costs:
                    best = max(branch_costs, key=lambda c: c.flops + c.bytes)
                    cost.add(best)
            elif ins.op in ("call", "custom-call", "map", "reduce", "sort",
                            "select-and-scatter", "reduce-window", "scatter"):
                for cn in _called_comps(ins.body):
                    cost.add(self.cost_of(cn, surface=False))
                if surface and ins.op != "call":
                    cost.bytes += out_bytes + self._operand_bytes(ins, shapes)
                if ins.op == "reduce":
                    cost.flops += _numel(out_shapes)
            elif ins.op in COLLECTIVES:
                # payload = per-device result bytes (tuple-aware)
                cost.collective_bytes[ins.op] = (
                    cost.collective_bytes.get(ins.op, 0.0) + out_bytes
                )
                cost.collective_counts[ins.op] = (
                    cost.collective_counts.get(ins.op, 0.0) + 1
                )
                if surface:
                    cost.bytes += out_bytes
            elif ins.op in ("exponential", "log", "tanh", "logistic", "rsqrt",
                            "sqrt", "power", "sine", "cosine"):
                cost.transcendentals += _numel(out_shapes)
                cost.flops += _numel(out_shapes)
                if surface:
                    cost.bytes += out_bytes + self._operand_bytes(ins, shapes)
            elif ins.op in ("slice", "dynamic-slice"):
                # reads and writes only the slice region, NOT the source
                if surface:
                    cost.bytes += 2.0 * out_bytes
            elif ins.op in ("dynamic-update-slice", "scatter"):
                # in-place region update: traffic ~ the update payload, not
                # the full destination (XLA aliases the buffer)
                if surface:
                    ops_b = self._operand_bytes_list(ins, shapes)
                    small = sum(ops_b) - max(ops_b) if ops_b else 0.0
                    cost.bytes += 2.0 * small
            elif ins.op == "gather":
                if surface:
                    cost.bytes += 2.0 * out_bytes
            elif ins.op in _LAYOUT_OPS:
                pass  # free at this granularity
            else:
                # generic elementwise at top level
                cost.flops += _numel(out_shapes)
                if surface:
                    cost.bytes += out_bytes + self._operand_bytes(ins, shapes)
        self._memo[memo_key] = cost
        return cost

    def _operand_bytes_list(self, ins: Instruction, shapes: Dict[str, str]) -> List[float]:
        m = re.search(r"\(([^)]*)\)", ins.body[ins.body.find("=") :])
        if not m:
            return []
        out = []
        for arg in _split_args(m.group(1)):
            inline = _parse_shape(arg)
            if inline and "[" in arg.split("%")[0]:
                out.append(float(_nbytes(inline)))
                continue
            name = _operand_name(arg)
            if name in shapes:
                out.append(float(_nbytes(_parse_shape(shapes[name]))))
        return out

    def _operand_bytes(self, ins: Instruction, shapes: Dict[str, str]) -> float:
        return sum(self._operand_bytes_list(ins, shapes))

    def _fusion_surface_bytes(
        self, ins: Instruction, shapes: Dict[str, str], out_bytes: float
    ) -> float:
        """Fusion traffic with structure-aware discounts:

        * a fused-body param consumed (possibly via convert/bitcast) only by a
          (dynamic-)slice/gather is charged at the slice size — the
          scan-over-stacked-layers read pattern;
        * a fused-body param that is the *destination* of a
          dynamic-update-slice, and the fusion output rooted in that DUS, are
          charged at the update size — on TPU the stacked buffer aliases in
          place (the scan ys-stash write pattern).
        """
        ops = self._operand_bytes_list(ins, shapes)
        overrides: Dict[int, float] = {}
        out_override = None
        for cn in _called_comps(ins.body):
            comp = self.comps.get(cn)
            if comp is None:
                continue
            param_idx: Dict[str, int] = {}
            defs: Dict[str, Tuple[str, str]] = {}  # name -> (op, first operand)
            inner_shapes: Dict[str, str] = {}
            for iins in comp.instructions:
                inner_shapes[iins.name] = iins.result
                if iins.op == "parameter":
                    pm = re.search(r"parameter\((\d+)\)", iins.body)
                    if pm:
                        param_idx[iins.name] = int(pm.group(1))
                am = re.search(rf"{iins.op}\(([^)]*)\)", iins.body)
                first_args = _split_args(am.group(1)) if am else []
                defs[iins.name] = (iins.op, _operand_name(first_args[0]) if first_args else "")

            def trace_to_param(name: str, hops: int = 3):
                for _ in range(hops):
                    if name in param_idx:
                        return param_idx[name]
                    op, first = defs.get(name, ("", ""))
                    if op in ("convert", "bitcast", "copy", "reshape"):
                        name = first
                    else:
                        return None
                return param_idx.get(name)

            for iins in comp.instructions:
                if iins.op in ("dynamic-slice", "slice", "gather"):
                    _, first = defs[iins.name]
                    pi = trace_to_param(first)
                    if pi is not None:
                        sliced = float(_nbytes(_parse_shape(iins.result)))
                        overrides[pi] = min(overrides.get(pi, sliced), sliced)
                elif iins.op == "dynamic-update-slice":
                    am = re.search(r"dynamic-update-slice\(([^)]*)\)", iins.body)
                    if not am:
                        continue
                    arglist = [_operand_name(a) for a in _split_args(am.group(1))]
                    if len(arglist) < 2:
                        continue
                    dest, update = arglist[0], arglist[1]
                    upd_bytes = float(_nbytes(_parse_shape(inner_shapes.get(update, ""))))
                    pi = trace_to_param(dest)
                    if pi is not None and upd_bytes:
                        overrides[pi] = min(overrides.get(pi, upd_bytes), upd_bytes)
                        out_override = upd_bytes  # in-place aliased write

        total = 0.0
        for i, b in enumerate(ops):
            total += min(overrides.get(i, b), b)
        total += out_override if out_override is not None else out_bytes
        return total

    def analyze(self) -> Cost:
        return self.cost_of(self.entry, surface=True)


def analyze_hlo(hlo: str) -> Cost:
    return HloAnalyzer(hlo).analyze()


def analyze_compiled(compiled) -> Cost:
    """Cost of a ``jax`` ``Compiled`` object — the post-GSPMD per-device
    module text (what abstract-lowered tuner candidates hand over)."""
    return analyze_hlo(compiled.as_text())

"""Production recovery launcher: batched CS recovery with checkpoint/restart.

    PYTHONPATH=src python -m repro.launch.recover --n 65536 --batch 4 \
        --method cpadmm --iters 600 --ckpt-dir artifacts/recover_ckpt

Runs the paper's workload as a restartable job: a batch of compressively
sensed signals (one shared sensing operator, ``--batch`` independent
signals) is recovered with the selected solver, checkpointing solver state
every chunk.  ``--tol`` switches from the fixed iteration budget to the
tolerance-driven driver: convergence is then tracked *per signal* (early
finishers freeze while the rest iterate) and the per-signal iteration
counts are reported.

``--mesh`` routes the same job through the execution-plan layer
(``repro.ops.plan``): each signal is sharded over the mesh's model axis via
the four-step FFT and *the same drivers* run — every ``--method`` works
distributed, tolerance-stopped, and checkpointable.  ``--mesh 8`` shards
signals over 8 devices; ``--mesh 2x4`` additionally shards the batch over a
2-way data axis.  ``--fake-devices N`` forces N XLA host devices so the
distributed path can be exercised on a CPU box.

``--deblur`` swaps the workload to the paper's flagship Sec. 7 scenario —
compressed-domain deblurring: ``--batch`` starfield frames of
``--size`` x ``--size`` are sensed through one shared joint operator
``A = P (C B)`` (order-``--blur-order`` raster blur composed with the
``--sensing`` circulant, m = n/2) and one batched solve jointly undoes
sub-sampling and blur.  The same ``--mesh`` / ``--rfft`` / ``--overlap`` /
``--tol`` / checkpointing flags apply — the deblur operator lowers through
``repro.core.deblur.build_deblur_plan``, so e.g.

    PYTHONPATH=src python -m repro.launch.recover --deblur --batch 4 \
        --size 64 --blur-order 5 --mesh 2x4 --rfft --fake-devices 8

deblurs a four-frame stack distributed over a (data, model) mesh.
Per-frame PSNR / normalized MSE are reported after the solve.
"""

from __future__ import annotations

import argparse
import os
import sys

if __name__ == "__main__":  # --fake-devices must land before jax imports
    _pre = argparse.ArgumentParser(add_help=False)
    _pre.add_argument("--fake-devices", type=int, default=0)
    _n, _ = _pre.parse_known_args()
    if _n.fake_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={_n.fake_devices}"
        )

import time

import jax
import jax.numpy as jnp

from repro.ckpt import checkpoint as ckpt
from repro.core import (
    RecoveryProblem,
    partial_gaussian_circulant,
    solve_checkpointed,
    solve_until,
)
from repro.data.synthetic import paper_regime, sparse_signal

METHODS = ("cpadmm", "ista", "fista")


def _parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        description="batched CS recovery launcher (see module docstring)"
    )
    ap.add_argument("--n", type=int, default=65536)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--method", default="cpadmm", choices=METHODS,
                    metavar=f"{{{','.join(METHODS)}}}",
                    help="solver method; every method runs on every backend")
    ap.add_argument("--iters", type=int, default=600)
    ap.add_argument("--chunk", type=int, default=100)
    ap.add_argument("--alpha", type=float, default=1e-4)
    ap.add_argument("--tol", type=float, default=0.0,
                    help="run to per-signal convergence (relative-change "
                         "tolerance) instead of a fixed --iters budget")
    ap.add_argument("--deblur", action="store_true",
                    help="compressed-domain deblurring workload (Sec. 7): "
                         "--batch starfield frames sensed through one joint "
                         "A = P (C B) operator; reports per-frame PSNR")
    ap.add_argument("--blur-order", type=float, default=5,
                    help="blur width knob (with --deblur): raster length L "
                         "for moving-average, sigma for gaussian, first-null "
                         "radius for airy")
    ap.add_argument("--blur-kind", default="moving-average",
                    choices=("moving-average", "gaussian", "airy"),
                    help="PSF family for --deblur (repro.core.circulant)")
    ap.add_argument("--size", type=int, default=64,
                    help="frame extent: n = size*size (with --deblur)")
    ap.add_argument("--sensing", default="romberg",
                    choices=("gaussian", "romberg"),
                    help="sensing circulant family (with --deblur)")
    ap.add_argument("--prior", default="l1",
                    choices=("l1", "tv", "wavelet", "nonneg-l1"),
                    help="recovery prior (repro.ops.prox): l1 is the paper's "
                         "soft threshold (fused kernels stay on); tv is "
                         "anisotropic 2-D total variation (frames must be "
                         "square: --size with --deblur, sqrt(n) otherwise); "
                         "wavelet thresholds orthogonal Haar detail "
                         "coefficients; nonneg-l1 adds a positivity "
                         "constraint")
    ap.add_argument("--mesh", default=None,
                    help="distributed plan: 'M' (model axis size) or 'DxM' "
                         "(data x model); e.g. --mesh 8 or --mesh 2x4")
    ap.add_argument("--n1", type=int, default=None,
                    help="four-step row count for --mesh (auto near sqrt(n))")
    ap.add_argument("--rfft", action="store_true",
                    help="half-spectrum distributed transforms (with --mesh)")
    ap.add_argument("--overlap", type=int, default=1,
                    help="chunked-transpose overlap factor K (with --mesh)")
    ap.add_argument("--wire-dtype", default="fp32",
                    choices=("fp32", "bf16", "fp16"),
                    help="transpose all-to-all payload precision (with "
                         "--mesh): bf16/fp16 halve the wire bytes; lossy "
                         "wires are guarded by an fp32 fallback past the "
                         "plan layer's precision bound")
    ap.add_argument("--tune", nargs="?", const="model", default=None,
                    choices=("model", "measure"),
                    help="autotune the plan config (repro.ops.tune): bare "
                         "--tune ranks candidates by the HLO cost model; "
                         "--tune measure additionally wall-clocks the top "
                         "picks.  Explicit --rfft/--overlap/--n1 become "
                         "pins; the winner is cached in "
                         "artifacts/plan_cache.json (REPRO_PLAN_CACHE)")
    ap.add_argument("--fake-devices", type=int, default=0,
                    help="force N XLA host devices (must be the first thing "
                         "jax sees; honored when run as a script)")
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint directory (default: "
                         "artifacts/recover_ckpt, or "
                         "artifacts/recover_deblur_ckpt with --deblur — kept "
                         "separate so one workload never resumes from the "
                         "other's solver state)")
    ap.add_argument("--seed", type=int, default=0)
    return ap


def parse_mesh(mesh_arg: str | None):
    """CLI mesh spec -> (mesh, batch_axis): None, 'M', or 'DxM'."""
    if mesh_arg is None:
        return None, None
    from repro.dist.compat import make_mesh

    shape = tuple(int(t) for t in mesh_arg.lower().split("x"))
    if len(shape) == 1:
        return make_mesh(shape, ("model",)), None
    if len(shape) == 2:
        return make_mesh(shape, ("data", "model")), "data"
    raise ValueError(f"--mesh must be 'M' or 'DxM', got {mesh_arg!r}")


def make_prior(prior: str, n: int, size: int | None = None):
    """CLI ``--prior`` name -> a ``repro.ops.prox`` instance (None for l1).

    l1 maps to None so the default path keeps its fused-kernel lowering and
    bit-exactness pins; tv needs a 2-D extent — ``--size`` under --deblur,
    else the signal must be square (n a perfect square).
    """
    from repro.ops.prox import NonNegL1Prox, TVProx, WaveletProx

    if prior == "l1":
        return None
    if prior == "nonneg-l1":
        return NonNegL1Prox()
    if prior == "wavelet":
        return WaveletProx()
    if prior == "tv":
        if size is not None:
            return TVProx(shape=(size, size))
        side = int(round(n ** 0.5))
        if side * side != n:
            raise SystemExit(
                f"--prior tv needs a square frame: n={n} is not a perfect "
                f"square (use --deblur --size, or a square --n)"
            )
        return TVProx(shape=(side, side))
    raise ValueError(f"unknown prior {prior!r}")


def build_plan(op, mesh_arg: str | None, n1=None, rfft=False, overlap=1,
               config=None, tune=None, batch=None, wire_dtype="fp32",
               prox=None):
    """Lower ``op`` per the CLI mesh spec: None (local) or 'M' / 'DxM'.

    ``config=`` forwards a full ``repro.ops.PlanConfig``; ``tune=`` asks the
    autotuner to pick one, with only the *explicitly set* CLI flags becoming
    pins (a default ``--overlap 1`` must leave the overlap axis open, or
    ``--tune`` could never try K > 1).
    """
    from repro.ops import plan

    mesh, batch_axis = parse_mesh(mesh_arg)
    if tune:
        pins = {}
        if rfft:
            pins["rfft"] = True
        if overlap != 1:
            pins["overlap"] = overlap
        if n1 is not None:
            pins["n1"] = n1
        if batch_axis is not None:
            pins["batch_axis"] = batch_axis
        if wire_dtype != "fp32":
            pins["wire_dtype"] = wire_dtype
        if prox is not None:
            pins["prox"] = prox
        return plan(op, mesh, config=config, tune=tune, batch=batch, **pins)
    if config is not None:
        return plan(op, mesh, config=config)
    if mesh is None:
        # the single validation site rejects --rfft/--overlap/--wire-dtype
        # without --mesh
        return plan(op, rfft=rfft, overlap=overlap, wire_dtype=wire_dtype,
                    prox=prox)
    return plan(op, mesh, n1=n1, rfft=rfft, overlap=overlap,
                batch_axis=batch_axis, wire_dtype=wire_dtype, prox=prox)


def build_deblur_workload(args):
    """The Sec. 7 workload: (problem, plan, deblur_problem) for --deblur.

    ``--batch`` starfield frames sensed through one shared A = P (C B);
    the plan comes from ``build_deblur_plan`` so the composed spectrum is
    sharded once and a 'DxM' mesh puts frames on the data axis.
    """
    from repro.core.deblur import build_deblur_plan, build_multiframe_deblur_problem
    from repro.data.synthetic import starfield

    frames = jnp.stack([
        starfield(jax.random.PRNGKey(args.seed + i), args.size, args.size,
                  density=0.05, n_blobs=2)
        for i in range(args.batch)
    ])
    dp = build_multiframe_deblur_problem(
        jax.random.PRNGKey(args.seed + 1), frames,
        blur_order=args.blur_order, subsample=0.5, sensing=args.sensing,
        blur_kind=args.blur_kind,
    )
    prob = RecoveryProblem(op=dp.op, y=dp.y,
                           x_true=frames.reshape(args.batch, -1))
    mesh, batch_axis = parse_mesh(args.mesh)
    prox = make_prior(args.prior, args.size * args.size, size=args.size)
    if args.tune:
        # pin only explicitly-set flags so the tuner keeps its search space
        pins = {}
        if args.rfft:
            pins["rfft"] = True
        if args.overlap != 1:
            pins["overlap"] = args.overlap
        if args.n1 is not None:
            pins["n1"] = args.n1
        if args.wire_dtype != "fp32":
            pins["wire_dtype"] = args.wire_dtype
        if prox is not None:
            pins["prox"] = prox
        pl = build_deblur_plan(dp, mesh, tune=args.tune, batch=args.batch,
                               **pins)
    else:
        pl = build_deblur_plan(dp, mesh, n1=args.n1,
                               rfft=args.rfft or None,
                               overlap=args.overlap if args.overlap != 1 else None,
                               batch_axis=batch_axis,
                               wire_dtype=(args.wire_dtype
                                           if args.wire_dtype != "fp32"
                                           else None),
                               prox=prox)
    return prob, pl, dp


def report_deblur(dp, x_hat) -> None:
    from repro.core.deblur import deblur_metrics

    m = deblur_metrics(dp, x_hat)
    psnr = jnp.atleast_1d(m["psnr_db"])
    nmse = jnp.atleast_1d(m["normalized_mse"])
    for f in range(psnr.shape[0]):
        print(f"  frame {f}: PSNR {float(psnr[f]):.1f} dB   "
              f"normalized MSE {float(nmse[f]):.2e}")


def main(argv=None):
    args = _parser().parse_args(argv)
    if args.ckpt_dir is None:
        args.ckpt_dir = ("artifacts/recover_deblur_ckpt" if args.deblur
                         else "artifacts/recover_ckpt")

    if args.deblur:
        n = args.size * args.size
        prob, pl, dp = build_deblur_workload(args)
        print(f"deblurring batch={args.batch} frames of "
              f"{args.size}x{args.size} (n={n}), blur L={args.blur_order}, "
              f"m={dp.op.m}, sensing={args.sensing}, method={args.method}, "
              f"prior={args.prior}"
              + (f", mesh={args.mesh} (plan API)" if args.mesh else ""))
    else:
        n = args.n
        m, k = paper_regime(n)
        dp = None
        print(f"recovering batch={args.batch} signals, n={n}, m={m}, k={k}, "
              f"method={args.method}, prior={args.prior}"
              + (f", mesh={args.mesh} (plan API)" if args.mesh else ""))

        x_true = sparse_signal(jax.random.PRNGKey(args.seed), n, k,
                               batch=(args.batch,))
        op = partial_gaussian_circulant(jax.random.PRNGKey(args.seed + 1), n, m,
                                        normalize=True)
        prob = RecoveryProblem(op=op, y=op.matvec(x_true), x_true=x_true)
        pl = build_plan(op, args.mesh, n1=args.n1, rfft=args.rfft,
                        overlap=args.overlap, tune=args.tune,
                        batch=args.batch, wire_dtype=args.wire_dtype,
                        prox=make_prior(args.prior, n))
    if args.tune:
        print(f"tuned plan [{args.tune}]: {pl.config.describe()}")
    x_true = prob.x_true

    if args.tol > 0:
        t0 = time.time()
        x_hat, iters_used = solve_until(
            prob, args.method, tol=args.tol, max_iters=args.iters,
            alpha=args.alpha, rho=0.01, sigma=0.01, plan=pl,
        )
        d = x_true - x_hat
        mse = jnp.mean(d * d, axis=-1)
        print(f"finished in {time.time()-t0:.1f}s; per-signal iterations: "
              f"{[int(v) for v in jnp.atleast_1d(iters_used)]}")
        print(f"per-signal MSE: {[f'{v:.2e}' for v in jnp.atleast_1d(mse)]}")
        if dp is not None:
            report_deblur(dp, x_hat)
        return

    restore = None
    latest = ckpt.latest_step(args.ckpt_dir)
    if latest is not None:
        # the saved tree is the solver state; rebuild shape via a fresh stepper
        from repro.core.solvers import make_stepper

        stepper = make_stepper(prob, args.method, alpha=args.alpha,
                               rho=0.01, sigma=0.01, plan=pl)
        shape = jax.eval_shape(stepper.init)
        step_no, state = ckpt.restore(args.ckpt_dir, latest, shape)
        restore = (step_no, state)
        print(f"resumed from iteration {step_no}")

    t0 = time.time()
    x_hat, mse = solve_checkpointed(
        prob,
        args.method,
        iters=args.iters,
        chunk=args.chunk,
        alpha=args.alpha,
        rho=0.01,
        sigma=0.01,
        save_cb=lambda s, st: ckpt.save(args.ckpt_dir, s, jax.device_get(st)),
        restore=restore,
        plan=pl,
    )
    print(f"finished in {time.time()-t0:.1f}s; per-signal MSE: "
          f"{[f'{v:.2e}' for v in jnp.atleast_1d(mse)]}")
    if dp is not None:
        report_deblur(dp, x_hat)


if __name__ == "__main__":
    main(sys.argv[1:])

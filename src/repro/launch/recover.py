"""Production recovery launcher: batched CS recovery with checkpoint/restart.

    PYTHONPATH=src python -m repro.launch.recover --n 65536 --batch 4 \
        --method cpadmm --iters 600 --ckpt-dir artifacts/recover_ckpt

Runs the paper's workload as a restartable job: a batch of compressively
sensed signals (one shared sensing operator, ``--batch`` independent
signals) is recovered with the selected solver, checkpointing solver state
every chunk.  ``--tol`` switches from the fixed iteration budget to the
tolerance-driven driver: convergence is then tracked *per signal* (early
finishers freeze while the rest iterate) and the per-signal iteration
counts are reported.  For within-signal model parallelism across a mesh see
examples/distributed_recovery.py and repro.dist.recovery.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.ckpt import checkpoint as ckpt
from repro.core import (
    RecoveryProblem,
    partial_gaussian_circulant,
    solve_checkpointed,
    solve_until,
)
from repro.data.synthetic import paper_regime, sparse_signal


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=65536)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--method", default="cpadmm",
                    choices=["cpadmm", "ista", "fista"])
    ap.add_argument("--iters", type=int, default=600)
    ap.add_argument("--chunk", type=int, default=100)
    ap.add_argument("--alpha", type=float, default=1e-4)
    ap.add_argument("--tol", type=float, default=0.0,
                    help="run to per-signal convergence (relative-change "
                         "tolerance) instead of a fixed --iters budget")
    ap.add_argument("--ckpt-dir", default="artifacts/recover_ckpt")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    n = args.n
    m, k = paper_regime(n)
    print(f"recovering batch={args.batch} signals, n={n}, m={m}, k={k}, "
          f"method={args.method}")

    x_true = sparse_signal(jax.random.PRNGKey(args.seed), n, k, batch=(args.batch,))
    op = partial_gaussian_circulant(jax.random.PRNGKey(args.seed + 1), n, m,
                                    normalize=True)
    prob = RecoveryProblem(op=op, y=op.matvec(x_true), x_true=x_true)

    if args.tol > 0:
        t0 = time.time()
        x_hat, iters_used = solve_until(
            prob, args.method, tol=args.tol, max_iters=args.iters,
            alpha=args.alpha, rho=0.01, sigma=0.01,
        )
        d = x_true - x_hat
        mse = jnp.mean(d * d, axis=-1)
        print(f"finished in {time.time()-t0:.1f}s; per-signal iterations: "
              f"{[int(v) for v in jnp.atleast_1d(iters_used)]}")
        print(f"per-signal MSE: {[f'{v:.2e}' for v in jnp.atleast_1d(mse)]}")
        return

    restore = None
    latest = ckpt.latest_step(args.ckpt_dir)
    if latest is not None:
        # the saved tree is the solver state; rebuild shape via a fresh stepper
        from repro.core.solvers import make_stepper

        stepper = make_stepper(prob, args.method, alpha=args.alpha,
                               rho=0.01, sigma=0.01)
        shape = jax.eval_shape(stepper.init)
        step_no, state = ckpt.restore(args.ckpt_dir, latest, shape)
        restore = (step_no, state)
        print(f"resumed from iteration {step_no}")

    t0 = time.time()
    x_hat, mse = solve_checkpointed(
        prob,
        args.method,
        iters=args.iters,
        chunk=args.chunk,
        alpha=args.alpha,
        rho=0.01,
        sigma=0.01,
        save_cb=lambda s, st: ckpt.save(args.ckpt_dir, s, jax.device_get(st)),
        restore=restore,
    )
    print(f"finished in {time.time()-t0:.1f}s; per-signal MSE: "
          f"{[f'{v:.2e}' for v in jnp.atleast_1d(mse)]}")


if __name__ == "__main__":
    main()

"""Production recovery launcher: batched CS recovery with checkpoint/restart.

    PYTHONPATH=src python -m repro.launch.recover --n 65536 --batch 4 \
        --method cpadmm --iters 600 --ckpt-dir artifacts/recover_ckpt

Runs the paper's workload as a restartable job: a batch of compressively
sensed signals (one shared sensing operator, ``--batch`` independent
signals) is recovered with the selected solver, checkpointing solver state
every chunk.  ``--tol`` switches from the fixed iteration budget to the
tolerance-driven driver: convergence is then tracked *per signal* (early
finishers freeze while the rest iterate) and the per-signal iteration
counts are reported.

``--mesh`` routes the same job through the execution-plan layer
(``repro.ops.plan``): each signal is sharded over the mesh's model axis via
the four-step FFT and *the same drivers* run — every ``--method`` works
distributed, tolerance-stopped, and checkpointable.  ``--mesh 8`` shards
signals over 8 devices; ``--mesh 2x4`` additionally shards the batch over a
2-way data axis.  ``--fake-devices N`` forces N XLA host devices so the
distributed path can be exercised on a CPU box.
"""

from __future__ import annotations

import argparse
import os
import sys

if __name__ == "__main__":  # --fake-devices must land before jax imports
    _pre = argparse.ArgumentParser(add_help=False)
    _pre.add_argument("--fake-devices", type=int, default=0)
    _n, _ = _pre.parse_known_args()
    if _n.fake_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={_n.fake_devices}"
        )

import time

import jax
import jax.numpy as jnp

from repro.ckpt import checkpoint as ckpt
from repro.core import (
    RecoveryProblem,
    partial_gaussian_circulant,
    solve_checkpointed,
    solve_until,
)
from repro.data.synthetic import paper_regime, sparse_signal

METHODS = ("cpadmm", "ista", "fista")


def _parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        description="batched CS recovery launcher (see module docstring)"
    )
    ap.add_argument("--n", type=int, default=65536)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--method", default="cpadmm", choices=METHODS,
                    metavar=f"{{{','.join(METHODS)}}}",
                    help="solver method; every method runs on every backend")
    ap.add_argument("--iters", type=int, default=600)
    ap.add_argument("--chunk", type=int, default=100)
    ap.add_argument("--alpha", type=float, default=1e-4)
    ap.add_argument("--tol", type=float, default=0.0,
                    help="run to per-signal convergence (relative-change "
                         "tolerance) instead of a fixed --iters budget")
    ap.add_argument("--mesh", default=None,
                    help="distributed plan: 'M' (model axis size) or 'DxM' "
                         "(data x model); e.g. --mesh 8 or --mesh 2x4")
    ap.add_argument("--n1", type=int, default=None,
                    help="four-step row count for --mesh (auto near sqrt(n))")
    ap.add_argument("--rfft", action="store_true",
                    help="half-spectrum distributed transforms (with --mesh)")
    ap.add_argument("--overlap", type=int, default=1,
                    help="chunked-transpose overlap factor K (with --mesh)")
    ap.add_argument("--fake-devices", type=int, default=0,
                    help="force N XLA host devices (must be the first thing "
                         "jax sees; honored when run as a script)")
    ap.add_argument("--ckpt-dir", default="artifacts/recover_ckpt")
    ap.add_argument("--seed", type=int, default=0)
    return ap


def build_plan(op, mesh_arg: str | None, n1=None, rfft=False, overlap=1):
    """Lower ``op`` per the CLI mesh spec: None (local) or 'M' / 'DxM'."""
    from repro.dist.compat import make_mesh
    from repro.ops import plan

    if mesh_arg is None:
        return plan(op)
    shape = tuple(int(t) for t in mesh_arg.lower().split("x"))
    if len(shape) == 1:
        mesh = make_mesh(shape, ("model",))
        batch_axis = None
    elif len(shape) == 2:
        mesh = make_mesh(shape, ("data", "model"))
        batch_axis = "data"
    else:
        raise ValueError(f"--mesh must be 'M' or 'DxM', got {mesh_arg!r}")
    return plan(op, mesh, n1=n1, rfft=rfft, overlap=overlap,
                batch_axis=batch_axis)


def main(argv=None):
    args = _parser().parse_args(argv)

    n = args.n
    m, k = paper_regime(n)
    print(f"recovering batch={args.batch} signals, n={n}, m={m}, k={k}, "
          f"method={args.method}"
          + (f", mesh={args.mesh} (plan API)" if args.mesh else ""))

    x_true = sparse_signal(jax.random.PRNGKey(args.seed), n, k, batch=(args.batch,))
    op = partial_gaussian_circulant(jax.random.PRNGKey(args.seed + 1), n, m,
                                    normalize=True)
    prob = RecoveryProblem(op=op, y=op.matvec(x_true), x_true=x_true)
    pl = build_plan(op, args.mesh, n1=args.n1, rfft=args.rfft,
                    overlap=args.overlap)

    if args.tol > 0:
        t0 = time.time()
        x_hat, iters_used = solve_until(
            prob, args.method, tol=args.tol, max_iters=args.iters,
            alpha=args.alpha, rho=0.01, sigma=0.01, plan=pl,
        )
        d = x_true - x_hat
        mse = jnp.mean(d * d, axis=-1)
        print(f"finished in {time.time()-t0:.1f}s; per-signal iterations: "
              f"{[int(v) for v in jnp.atleast_1d(iters_used)]}")
        print(f"per-signal MSE: {[f'{v:.2e}' for v in jnp.atleast_1d(mse)]}")
        return

    restore = None
    latest = ckpt.latest_step(args.ckpt_dir)
    if latest is not None:
        # the saved tree is the solver state; rebuild shape via a fresh stepper
        from repro.core.solvers import make_stepper

        stepper = make_stepper(prob, args.method, alpha=args.alpha,
                               rho=0.01, sigma=0.01, plan=pl)
        shape = jax.eval_shape(stepper.init)
        step_no, state = ckpt.restore(args.ckpt_dir, latest, shape)
        restore = (step_no, state)
        print(f"resumed from iteration {step_no}")

    t0 = time.time()
    x_hat, mse = solve_checkpointed(
        prob,
        args.method,
        iters=args.iters,
        chunk=args.chunk,
        alpha=args.alpha,
        rho=0.01,
        sigma=0.01,
        save_cb=lambda s, st: ckpt.save(args.ckpt_dir, s, jax.device_get(st)),
        restore=restore,
        plan=pl,
    )
    print(f"finished in {time.time()-t0:.1f}s; per-signal MSE: "
          f"{[f'{v:.2e}' for v in jnp.atleast_1d(mse)]}")


if __name__ == "__main__":
    main(sys.argv[1:])

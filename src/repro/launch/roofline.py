"""Roofline derivation from the dry-run artifacts (assignment §ROOFLINE).

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

    compute term    = flops_per_device / PEAK_FLOPS
    memory term     = hbm_bytes_per_device / HBM_BW
    collective term = wire_bytes_per_device / ICI_BW

flops/bytes come from the trip-count-aware HLO walk (launch/hlo_analysis.py —
XLA's own cost_analysis counts while bodies once, see that module's header).
Wire bytes apply per-op multipliers for ring algorithms: all-reduce moves
2(d-1)/d ~ 2x its payload, all-gather/reduce-scatter/all-to-all ~ 1x, with
the result-shape payload parsed per op.  Payload bytes use the operand's
*own* dtype itemsize (hlo_analysis.DTYPE_BYTES), so wire-compressed
collectives (``wire_dtype='bf16'``/``'fp16'`` plans, whose transpose
payloads cross as 2-byte planes) are modeled at their true wire size with
no special-casing here.

The collective term is two-tier: bytes that cross a host boundary ride the
datacenter network at ``DCN_BW`` instead of ICI, so callers pass the
cross-host fraction as ``model_block_times(..., dcn_bytes=...)`` and the
term splits into ``ici_collective_s + dcn_collective_s``.  Hierarchical
plans (``hier_axes=``, repro.dist.fft) put exactly the inter-host hop into
``collective-permute`` ops, so their DCN bytes are read straight off the
HLO walk; a flat all-to-all spanning hosts charges its whole payload to
DCN.  With ``dcn_bytes=0`` (the default) the model reduces bit-for-bit to
the single-fabric numbers, keeping pre-split tune-cache entries and
``baseline_smoke.json`` valid until regenerated.

    python -m repro.launch.roofline [--dir artifacts/dryrun] [--md]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import List

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link
# Datacenter network between hosts.  ~100 Gb/s NIC per chip pair on a v5e
# pod slice boundary -> 12.5 GB/s, derated 2x for the a2a incast pattern.
# Well under ICI_BW / H for small host counts, which is the regime where the
# two-stage hierarchical exchange (1/H of the bytes on DCN) wins.
DCN_BW = 6.25e9  # B/s per link

WIRE_MULT = {
    "all-reduce": 2.0,  # ring: reduce-scatter + all-gather
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

# tokens per cell for MODEL_FLOPS = 6 N D (D = tokens processed per step)
from repro.configs.registry import SHAPES  # noqa: E402


def model_block_times(cost, overlap: int = 1, dcn_bytes: float = 0.0) -> dict:
    """Roofline terms + the hidden-collective overlap model for one compiled
    block, from a :class:`repro.launch.hlo_analysis.Cost`.

    The shared scoring core of ``launch/cs_dryrun.py`` (the dry-run tables)
    and ``ops/tune.py`` (candidate ranking) — one cost model, two callers.

    ``dcn_bytes`` is the portion of the wire bytes that crosses a host
    boundary and therefore rides ``DCN_BW`` instead of ``ICI_BW`` (clamped
    to the total — a caller can pass raw HLO collective-permute bytes
    without worrying about multipliers).  The default 0.0 subtracts and
    adds exact float zeros, so single-fabric scores are reproduced
    bit-for-bit.

    Overlap model: with the transpose split into K chunks, chunk i's
    collective flies while chunk i+1's first-stage FFT+twiddle runs, so at
    most (K-1)/K of the wire time can hide — and never more than the
    first-stage local-work window itself (~half the per-iteration local
    time; the column FFT after the transpose is the other half and cannot
    overlap its own transform's collective).  Local FFTs lower to custom
    calls whose flops XLA's cost walk cannot see, but at production shapes
    they are HBM-bound anyway, so the window is bounded by the larger of
    the compute and memory terms.
    """
    wire = sum(
        WIRE_MULT.get(op, 1.0) * b for op, b in cost.collective_bytes.items()
    )
    compute_s = cost.flops / PEAK_FLOPS
    memory_s = cost.bytes / HBM_BW
    dcn_wire = min(float(dcn_bytes), wire)
    ici_s = (wire - dcn_wire) / ICI_BW
    dcn_s = dcn_wire / DCN_BW
    collective_s = ici_s + dcn_s
    local_s = max(compute_s, memory_s)
    hidden_s = min((overlap - 1) / overlap * collective_s, 0.5 * local_s)
    effective_s = collective_s - hidden_s
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "ici_collective_s": ici_s,
        "dcn_collective_s": dcn_s,
        "dcn_bytes": dcn_wire,
        "overlap": overlap,
        "hidden_collective_s": hidden_s,
        "hidden_collective_frac": hidden_s / collective_s if collective_s else 0.0,
        "effective_collective_s": effective_s,
        "modeled_total_s": local_s + effective_s,
    }


def model_flops(rec: dict) -> float:
    seq, batch, kind = SHAPES[rec["shape"]]
    n_active = rec["params"]["active"]
    if kind == "train":
        tokens = seq * batch
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = seq * batch
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * batch


def derive(rec: dict) -> dict:
    w = rec["hlo_walk"]
    n_dev = rec["n_devices"]
    compute_s = w["flops"] / PEAK_FLOPS
    memory_s = w["bytes"] / HBM_BW
    wire = sum(
        WIRE_MULT.get(op, 1.0) * b for op, b in w["collective_bytes"].items()
    )
    collective_s = wire / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(rec)
    hlo_global = w["flops"] * n_dev
    mem = rec.get("memory_analysis", {})
    hbm_need = (
        mem.get("argument_size_in_bytes", 0)
        + mem.get("temp_size_in_bytes", 0)
        + mem.get("output_size_in_bytes", 0)
        - mem.get("alias_size_in_bytes", 0)
    )
    return {
        **{k: rec[k] for k in ("arch", "shape", "mesh", "kind", "n_devices")},
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "bottleneck": bottleneck,
        "step_s_bound": max(terms.values()),
        "roofline_fraction": compute_s / max(terms.values()),
        "model_flops": mf,
        "hlo_flops_global": hlo_global,
        "useful_ratio": mf / hlo_global if hlo_global else 0.0,
        "hbm_need_bytes": hbm_need,
        "fits_16g": hbm_need <= 16e9,
        "collective_detail": w["collective_bytes"],
    }


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--mesh", default="single", help="single|multipod|all")
    ap.add_argument("--json-out", default="artifacts/roofline.json")
    args = ap.parse_args()

    rows: List[dict] = []
    for path in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        rec = json.load(open(path))
        if not rec.get("ok"):
            rows.append({k: rec.get(k) for k in ("arch", "shape", "mesh")} | {"error": True})
            continue
        if args.mesh != "all" and rec["mesh"] != args.mesh:
            continue
        rows.append(derive(rec))

    rows.sort(key=lambda r: (r.get("arch", ""), r.get("shape", "")))
    os.makedirs(os.path.dirname(args.json_out), exist_ok=True)
    with open(args.json_out, "w") as f:
        json.dump(rows, f, indent=1)

    hdr = (
        "| arch | shape | compute | memory | collective | bound | roofline frac "
        "| useful (6ND/HLO) | HBM need/dev | fits 16G |"
    )
    print(hdr)
    print("|" + "---|" * 10)
    for r in rows:
        if r.get("error"):
            print(f"| {r['arch']} | {r['shape']} | ERROR |")
            continue
        print(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
            f"**{r['bottleneck']}** | {r['roofline_fraction']*100:.0f}% | "
            f"{min(r['useful_ratio'],99):.2f} | {r['hbm_need_bytes']/1e9:.1f}GB | "
            f"{'Y' if r['fits_16g'] else 'N'} |"
        )


if __name__ == "__main__":
    main()

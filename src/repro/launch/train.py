"""Production training launcher: mesh + shardings + checkpoint/restart.

    PYTHONPATH=src python -m repro.launch.train --arch gemma-7b \
        --steps 100 --batch 16 --seq 256 --mesh host [--smoke]

``--mesh host`` builds a mesh over the visible devices (tests/CI);
``--mesh single|multipod`` builds the production meshes (requires the
512-placeholder-device environment of dryrun.py, or real hardware).
On real multi-host TPU the same code runs under `jax.distributed.initialize`
— host-sharded batches come from the deterministic (seed, step, host) data
pipeline, so restart after preemption resumes exactly.
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.ckpt import checkpoint as ckpt
from repro.configs.registry import full_config, smoke_config
from repro.data.synthetic import token_batch
from repro.dist.sharding import activate_rules, rules_for_arch
from repro.launch import partition
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import steps as steps_mod
from repro.optim.adamw import AdamWConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--mesh", default="host", choices=["host", "single", "multipod"])
    ap.add_argument("--model-parallel", type=int, default=1, help="host mesh TP size")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="artifacts/train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else full_config(args.arch)
    if args.mesh == "host":
        mesh = make_host_mesh(model=args.model_parallel)
    else:
        mesh = make_production_mesh(multi_pod=(args.mesh == "multipod"))
    rules = rules_for_arch(cfg, mesh)
    opt_cfg = AdamWConfig(lr_peak=args.lr, warmup_steps=min(20, args.steps // 5 + 1),
                          total_steps=args.steps)

    state_shape = jax.eval_shape(
        lambda: steps_mod.init_train_state(jax.random.PRNGKey(args.seed), cfg, opt_cfg)
    )
    state_sh = partition.train_state_shardings(mesh, state_shape, rules)

    with activate_rules(rules, mesh):
        init = jax.jit(
            lambda key: steps_mod.init_train_state(key, cfg, opt_cfg),
            out_shardings=state_sh,
        )
        state = init(jax.random.PRNGKey(args.seed))
        start = 0
        latest = ckpt.latest_step(args.ckpt_dir)
        if latest is not None:
            start, state = ckpt.restore(args.ckpt_dir, latest, state_shape, state_sh)
            print(f"resumed from step {start} (elastic re-shard onto {mesh.shape})")

        batch0 = {"tokens": token_batch(args.seed, 0, 0, args.batch, args.seq, cfg.vocab)}
        batch_sh = partition.batch_shardings(mesh, jax.eval_shape(lambda: batch0), rules)
        train_step = jax.jit(
            steps_mod.make_train_step(cfg, opt_cfg, microbatches=args.microbatches),
            in_shardings=(state_sh, batch_sh),
            donate_argnums=0,
        )

        t0 = time.time()
        for step in range(start, args.steps):
            batch = {
                "tokens": token_batch(args.seed, step, 0, args.batch, args.seq, cfg.vocab)
            }
            state, metrics = train_step(state, batch)
            if (step + 1) % 10 == 0:
                print(
                    f"step {step+1:5d}  loss {float(metrics['loss']):.3f}  "
                    f"acc {float(metrics['acc']):.3f}  "
                    f"gnorm {float(metrics['grad_norm']):.2f}  "
                    f"({(step+1-start)*args.batch*args.seq/(time.time()-t0):.0f} tok/s)",
                    flush=True,
                )
            if (step + 1) % args.ckpt_every == 0:
                ckpt.save(args.ckpt_dir, step + 1, jax.device_get(state))
        print("done")


if __name__ == "__main__":
    main()

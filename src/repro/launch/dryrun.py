import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first — jax locks the device count on first
init, and the production meshes need 512 placeholder devices.  Run as

    PYTHONPATH=src python -m repro.launch.dryrun --arch codeqwen1.5-7b \
        --shape train_4k --mesh single            # one cell
    PYTHONPATH=src python -m repro.launch.dryrun --all --jobs 8  # everything

Each cell records, into artifacts/dryrun/<arch>__<shape>__<mesh>.json:
    * compiled.memory_analysis()   (bytes per device — "proves it fits")
    * compiled.cost_analysis()     (FLOPs / bytes for §Roofline)
    * per-collective byte counts parsed from the optimized HLO
    * the sharding-rule fallbacks that were applied
Cells are independent; --all fans them out over worker subprocesses.
"""

import argparse
import json
import re
import subprocess
import sys
import time
from typing import Dict

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts", "dryrun")

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "c64": 8, "c128": 16,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_RE = re.compile(
    r"(\w[\w.-]*)\s*=\s*(\w+)\[\]?.*?\b"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)


def _shape_bytes(shape_str: str) -> int:
    """'f32[16,128]{1,0}' -> byte count (0 for tuples handled by caller)."""
    m = re.match(r"(\w+)\[([\d,]*)\]", shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    nbytes = DTYPE_BYTES.get(dt, 4)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * nbytes


def parse_collective_bytes(hlo: str) -> Dict[str, int]:
    """Sum output-shape bytes of every collective op in optimized HLO text.

    Uses the *result* shape of each collective instruction (per-device
    payload).  Tuples (e.g. fused all-reduces) are expanded element-wise.
    """
    out: Dict[str, int] = {}
    for line in hlo.splitlines():
        line = line.strip()
        m = re.match(r"^(?:ROOT\s+)?%?[\w.-]+\s*=\s*(.+?)\s+(all-gather|all-reduce|"
                     r"reduce-scatter|all-to-all|collective-permute)", line)
        if not m:
            continue
        shape_str, op = m.groups()
        total = sum(_shape_bytes(s) for s in re.findall(r"\w+\[[\d,]*\]", shape_str))
        out[op] = out.get(op, 0) + total
        out.setdefault(f"{op}_count", 0)
        out[f"{op}_count"] += 1
    return out


def run_cell(arch: str, shape: str, mesh_kind: str, out_path: str) -> dict:
    import jax

    from repro.configs.registry import full_config
    from repro.dist.sharding import DEFAULT_RULES, activate_rules, rules_for_arch
    from repro.launch import partition
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import cell_specs

    t0 = time.time()
    cfg = full_config(arch)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    rules = rules_for_arch(cfg, mesh)

    kind, fn, args = cell_specs(cfg, shape)
    if kind == "train":
        state_specs, batch_specs = args
        in_sh = (
            partition.train_state_shardings(mesh, state_specs, rules),
            partition.batch_shardings(mesh, batch_specs, rules),
        )
    elif kind == "prefill":
        params_specs_, batch_specs = args
        in_sh = (
            partition.param_shardings(mesh, params_specs_, rules),
            partition.batch_shardings(mesh, batch_specs, rules),
        )
    else:  # decode
        params_specs_, tok_specs, state_specs = args
        in_sh = (
            partition.param_shardings(mesh, params_specs_, rules),
            partition.batch_shardings(mesh, tok_specs, rules),
            partition.cache_shardings(mesh, state_specs, rules),
        )

    with activate_rules(rules, mesh):
        jitted = jax.jit(fn, in_shardings=in_sh)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    mem_dict = {}
    for field in (
        "generated_code_size_in_bytes",
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
    ):
        v = getattr(mem, field, None)
        if v is not None:
            mem_dict[field] = int(v)

    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # jax 0.4.x: one dict per module
        cost = cost[0] if cost else {}
    cost_dict = {
        k: float(v)
        for k, v in cost.items()
        if isinstance(v, (int, float)) and k in ("flops", "bytes accessed", "transcendentals", "optimal_seconds")
        or str(k).startswith("bytes accessed")
    }

    hlo = compiled.as_text()
    coll = parse_collective_bytes(hlo)  # raw, trip-count-naive (debug)

    from repro.launch.hlo_analysis import analyze_hlo

    walked = analyze_hlo(hlo)  # trip-count-aware per-device cost

    from repro.models.config import count_params

    result = {
        "arch": arch,
        "shape": shape,
        "mesh": mesh_kind,
        "mesh_shape": list(mesh.devices.shape),
        "n_devices": int(mesh.devices.size),
        "kind": kind,
        "rules_fallbacks": {
            k: v for k, v in rules.items() if v != DEFAULT_RULES.get(k)
        },
        "memory_analysis": mem_dict,
        "cost_analysis": cost_dict,
        "hlo_walk": {
            "flops": walked.flops,
            "bytes": walked.bytes,
            "transcendentals": walked.transcendentals,
            "collective_bytes": walked.collective_bytes,
            "collective_counts": walked.collective_counts,
        },
        "collectives_raw": coll,
        "params": count_params(cfg),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "ok": True,
    }
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(result, f, indent=1)
    return result


def all_cells():
    from repro.configs.registry import all_arch_ids, cells_for

    for arch in all_arch_ids():
        for shape in cells_for(arch):
            for mesh_kind in ("single", "multipod"):
                yield arch, shape, mesh_kind


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multipod"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=6)
    ap.add_argument("--out-dir", default=os.path.abspath(ARTIFACT_DIR))
    ap.add_argument("--only-missing", action="store_true")
    args = ap.parse_args()

    if not args.all:
        assert args.arch and args.shape
        out = os.path.join(args.out_dir, f"{args.arch}__{args.shape}__{args.mesh}.json")
        try:
            res = run_cell(args.arch, args.shape, args.mesh, out)
            print(json.dumps(res, indent=1))
        except Exception as e:  # record the failure for the aggregate table
            os.makedirs(args.out_dir, exist_ok=True)
            with open(out, "w") as f:
                json.dump(
                    {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
                     "ok": False, "error": repr(e)[:2000]},
                    f,
                )
            print(f"FAILED {args.arch} {args.shape} {args.mesh}: {e}", file=sys.stderr)
            sys.exit(1)
        return

    # fan out over subprocesses (each gets its own 512-device jax runtime)
    cells = list(all_cells())
    if args.only_missing:
        cells = [
            c
            for c in cells
            if not os.path.exists(os.path.join(args.out_dir, f"{c[0]}__{c[1]}__{c[2]}.json"))
        ]
    print(f"{len(cells)} cells to run, {args.jobs} workers")
    procs: list = []
    done = 0
    while cells or procs:
        while cells and len(procs) < args.jobs:
            arch, shape, mesh_kind = cells.pop(0)
            cmd = [
                sys.executable, "-m", "repro.launch.dryrun",
                "--arch", arch, "--shape", shape, "--mesh", mesh_kind,
                "--out-dir", args.out_dir,
            ]
            p = subprocess.Popen(
                cmd, stdout=subprocess.DEVNULL, stderr=subprocess.PIPE, text=True
            )
            p._cell = (arch, shape, mesh_kind)  # type: ignore
            procs.append(p)
        for p in list(procs):
            if p.poll() is not None:
                procs.remove(p)
                done += 1
                status = "ok" if p.returncode == 0 else "FAIL"
                print(f"[{done}] {p._cell}: {status}", flush=True)
                if p.returncode != 0:
                    err = p.stderr.read()
                    print(err[-1500:], flush=True)
        time.sleep(2)
    print("dry-run sweep complete")


if __name__ == "__main__":
    main()

"""input_specs(): ShapeDtypeStruct stand-ins for every (arch x shape) cell.

No device allocation anywhere — the dry-run lowers against these shapes
(assignment MULTI-POD DRY-RUN step 2).  Modality frontends are stubs per the
assignment: whisper gets post-conv frame embeddings, pixtral gets patch
embeddings, both as inputs.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.registry import SHAPES
from repro.models import lm, steps
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig

SDS = jax.ShapeDtypeStruct

WHISPER_TEXT_LEN = 448  # whisper's decoder horizon (teacher forcing)
WHISPER_CROSS_LEN = 4096  # encoder memory length carried into decode cells


def opt_config() -> AdamWConfig:
    return AdamWConfig()


def train_batch_specs(cfg: ModelConfig, seq_len: int, batch: int) -> Dict[str, Any]:
    if cfg.is_encdec:
        return {
            "tokens": SDS((batch, WHISPER_TEXT_LEN + 1), jnp.int32),
            "frames": SDS((batch, seq_len, cfg.d_model), jnp.bfloat16),
        }
    if cfg.n_img_tokens:
        text = seq_len - cfg.n_img_tokens
        return {
            "tokens": SDS((batch, text + 1), jnp.int32),
            "img_embeds": SDS((batch, cfg.n_img_tokens, cfg.d_model), jnp.bfloat16),
        }
    return {"tokens": SDS((batch, seq_len + 1), jnp.int32)}


def prefill_batch_specs(cfg: ModelConfig, seq_len: int, batch: int) -> Dict[str, Any]:
    if cfg.is_encdec:
        return {
            "tokens": SDS((batch, WHISPER_TEXT_LEN), jnp.int32),
            "frames": SDS((batch, seq_len, cfg.d_model), jnp.bfloat16),
        }
    if cfg.n_img_tokens:
        return {
            "tokens": SDS((batch, seq_len - cfg.n_img_tokens), jnp.int32),
            "img_embeds": SDS((batch, cfg.n_img_tokens, cfg.d_model), jnp.bfloat16),
        }
    return {"tokens": SDS((batch, seq_len), jnp.int32)}


def train_state_specs(cfg: ModelConfig) -> Any:
    return jax.eval_shape(
        lambda: steps.init_train_state(jax.random.PRNGKey(0), cfg, opt_config())
    )


def params_specs(cfg: ModelConfig) -> Any:
    return jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0), cfg))


def decode_state_specs(cfg: ModelConfig, batch: int, max_len: int) -> Any:
    if cfg.is_encdec:
        ck = SDS((batch, WHISPER_CROSS_LEN, cfg.d_model), jnp.bfloat16)
        return jax.eval_shape(
            lambda c: lm.init_decode_state(cfg, batch, max_len, cross_kv=c), ck
        )
    return jax.eval_shape(lambda: lm.init_decode_state(cfg, batch, max_len))


def cell_specs(cfg: ModelConfig, shape_name: str) -> Tuple[str, Callable, Tuple]:
    """-> (step_kind, step_fn, arg-specs tuple for .lower())."""
    seq_len, batch, kind = SHAPES[shape_name]
    if kind == "train":
        fn = steps.make_train_step(cfg, opt_config())
        args = (train_state_specs(cfg), train_batch_specs(cfg, seq_len, batch))
        return "train", fn, args
    if kind == "prefill":
        fn = steps.make_prefill_step(cfg)
        args = (params_specs(cfg), prefill_batch_specs(cfg, seq_len, batch))
        return "prefill", fn, args
    # decode: one token against a seq_len-deep cache
    fn = steps.make_decode_step(cfg)
    args = (
        params_specs(cfg),
        SDS((batch, 1), jnp.int32),
        decode_state_specs(cfg, batch, max_len=seq_len),
    )
    return "decode", fn, args

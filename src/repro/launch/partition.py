"""Parameter / state / batch PartitionSpecs for the production meshes.

Name-based rules over the param-tree paths: every leaf gets a PartitionSpec
derived from what the tensor *is* (attention projection, expert weight,
vocab table, ...), resolved against the active per-arch sharding rules
(repro.dist.sharding.rules_for_arch handles non-divisible fallbacks).

Conventions (leading ``L`` is the stacked-layer axis from segment scanning):
    embed/table        (V, D)              vocab-sharded rows
    attn wq/wk/wv      (L, D, H*hd)        TP on the head-flat output dim
    attn wo            (L, H*hd, D)        TP on the head-flat input dim
    mlp w_gate/up      (L, D, F)           TP on F
    mlp w_down         (L, F, D)           TP on F
    moe w_*            (L, E, D, F)        EP on E + FSDP on D (the 671B case)
    mamba/xlstm projs  (L, D, K)           FSDP/TP on K when divisible
Optimizer moments mirror their parameter's spec.  Batch: tokens shard over
(pod, data); caches shard batch and kv-heads.
"""

from __future__ import annotations

import re
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


# (regex on path, logical axes for the trailing dims). Leading unmatched dims
# (e.g. the stacked-layer axis) are replicated.  First match wins.
PARAM_RULES = [
    (r"embed/table$", ("vocab", None)),
    (r"embed/unembed$", (None, "vocab")),
    (r"attn/wq$", (None, "heads")),
    (r"attn/wk$", (None, "kv_heads")),
    (r"attn/wv$", (None, "kv_heads")),
    (r"attn/wo$", ("heads", None)),
    (r"attn/w_dq$", (None, None)),
    (r"attn/w_uq$", (None, "heads")),
    (r"attn/w_dkv$", (None, None)),
    (r"attn/w_krope$", (None, None)),
    (r"attn/w_uk$", (None, "heads")),
    (r"attn/w_uv$", (None, "heads")),
    (r"attn/w_q$", (None, "heads")),
    (r"mlp/w_gate$", (None, "mlp")),
    (r"mlp/w_up$", (None, "mlp")),
    (r"mlp/w_down$", ("mlp", None)),
    (r"shared/w_gate$", (None, "mlp")),
    (r"shared/w_up$", (None, "mlp")),
    (r"shared/w_down$", ("mlp", None)),
    (r"moe/router$", (None, None)),
    (r"moe/router_bias$", (None,)),
    (r"moe/w_gate$", ("experts", "fsdp", None)),
    (r"moe/w_up$", ("experts", "fsdp", None)),
    (r"moe/w_down$", ("experts", None, "fsdp")),
    (r"mamba/in_proj$", ("fsdp", None)),
    (r"mamba/out_proj$", (None, "fsdp")),
    (r"mamba/conv_[wb]$", None),  # tiny: replicate
    (r"(mlstm|slstm)/w_(up|q|k|v|o|x|h)$", (None, "ssm_inner")),
    (r"(mlstm|slstm)/w_down$", ("ssm_inner", None)),
    (r"(mlstm|slstm)/w_[ifb]$", None),
]


def _resolve(logical: Optional[str], rules: Dict[str, Any], names: Tuple[str, ...]):
    if logical is None:
        return None
    phys = rules.get(logical)
    if phys is None:
        return None
    if isinstance(phys, tuple):
        present = tuple(a for a in phys if a in names)
        return present if len(present) > 1 else (present[0] if present else None)
    return phys if phys in names else None


def spec_for_param(path: str, ndim: int, rules, names) -> P:
    for pat, axes in PARAM_RULES:
        if re.search(pat, path):
            if axes is None:
                return P()
            resolved = tuple(_resolve(a, rules, names) for a in axes)
            lead = (None,) * (ndim - len(resolved))
            return P(*(lead + resolved))
    return P()  # norms, biases, scalars: replicated


def param_shardings(mesh: Mesh, params_shape, rules) -> Any:
    """NamedSharding tree matching a params ShapeDtypeStruct tree."""
    names = tuple(mesh.axis_names)

    def leaf(path, leaf_shape):
        spec = spec_for_param(_path_str(path), len(leaf_shape.shape), rules, names)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(leaf, params_shape)


def _axes_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if isinstance(axes, tuple):
        n = 1
        for a in axes:
            n *= sizes.get(a, 1)
        return n
    return sizes.get(axes, 1)


def batch_shardings(mesh: Mesh, batch_shape, rules) -> Any:
    """tokens (B, S): batch over (pod, data); embeds (B, N, D) likewise.

    Batch dims that don't divide the DP extent (e.g. long_500k's batch=1)
    stay replicated — correct, just without data parallelism for that cell."""
    names = tuple(mesh.axis_names)
    dp = _resolve("batch", rules, names)
    dp_size = _axes_size(mesh, dp)

    def leaf(leaf_shape):
        nd = len(leaf_shape.shape)
        b = leaf_shape.shape[0] if nd else 0
        use_dp = dp if (nd and b % max(dp_size, 1) == 0) else None
        return NamedSharding(mesh, P(*((use_dp,) + (None,) * (nd - 1))))

    return jax.tree_util.tree_map(leaf, batch_shape)


def cache_shardings(mesh: Mesh, state_shape, rules) -> Any:
    """DecodeState: shard the batch dim; KV head dim over model when present.

    Cache layouts (leading L = stacked layer axis within a segment):
        KVCache.k/v      (L, B, S, K, hd)
        MLACache.c_kv    (L, B, S, R)
        Mamba2Cache.*    (L, B, ...)
        length           (L, B)
        cross_kv         (B, S_enc, D)  (no leading L)
    """
    names = tuple(mesh.axis_names)
    dp = _resolve("batch", rules, names)
    kvh = _resolve("kv_heads", rules, names)
    dp_size = _axes_size(mesh, dp)
    kvh_size = _axes_size(mesh, kvh)

    def leaf(path, leaf_shape):
        nd = len(leaf_shape.shape)
        shape = leaf_shape.shape
        name = _path_str(path)

        def dp_for(dim_idx):
            return dp if shape[dim_idx] % max(dp_size, 1) == 0 else None

        def kvh_for(dim_idx):
            return kvh if shape[dim_idx] % max(kvh_size, 1) == 0 else None

        if re.search(r"(^|/)(k|v)$", name) and nd == 5:  # stacked (L,B,S,K,hd)
            return NamedSharding(mesh, P(None, dp_for(1), None, kvh_for(3), None))
        if re.search(r"(^|/)(k|v)$", name) and nd == 4:  # shared block (B,S,K,hd)
            return NamedSharding(mesh, P(dp_for(0), None, kvh_for(2), None))
        if "cross_kv" in name and nd == 3:
            return NamedSharding(mesh, P(dp_for(0), None, None))
        if nd >= 2:
            return NamedSharding(mesh, P(None, dp_for(1), *(None,) * (nd - 2)))
        return NamedSharding(mesh, P(None))

    return jax.tree_util.tree_map_with_path(leaf, state_shape)


def train_state_shardings(mesh: Mesh, state_shape, rules) -> Any:
    """TrainState(params, opt(mu, nu, count), step): moments mirror params."""
    names = tuple(mesh.axis_names)

    def leaf(path, leaf_shape):
        name = _path_str(path)
        # strip TrainState/Adam prefixes so PARAM_RULES regexes match
        stripped = re.sub(r"^(params|opt/mu|opt/nu)/", "", name)
        if stripped in ("step", "count") or name.endswith(("/count", "step")):
            return NamedSharding(mesh, P())
        spec = spec_for_param(stripped, len(leaf_shape.shape), rules, names)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(leaf, state_shape)

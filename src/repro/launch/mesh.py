"""Production mesh construction (assignment MULTI-POD DRY-RUN step 1).

A FUNCTION, not a module-level constant — importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before any jax import)."""

from __future__ import annotations

import jax
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(model: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    assert n % model == 0, (n, model)
    return jax.make_mesh(
        (n // model, model), ("data", "model"), axis_types=(AxisType.Auto, AxisType.Auto)
    )

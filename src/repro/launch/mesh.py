"""Production mesh construction (assignment MULTI-POD DRY-RUN step 1).

A FUNCTION, not a module-level constant — importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before any jax import)."""

from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro.dist.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    assert n % model == 0, (n, model)
    return make_mesh((n // model, model), ("data", "model"))

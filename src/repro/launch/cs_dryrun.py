import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Dry-run + roofline for the paper's own workload on the production mesh.

Lowers one CPADMM iteration-block (50 iterations, as the recovery launcher
runs it) for a batch of large signals: each signal sharded over the model
axis, the batch sharded over (pod) x data — the cluster-job form of the
paper's Sec. 7 deblurring.  Four variants of the iteration are compared:

    baseline    paper-faithful 6-transform iteration, full complex spectra
                (6 all-to-alls per iteration)
    fused       frequency-domain x-update + stacked transforms
                (2 all-to-alls per iteration, see dist/recovery.py)
    fused_rfft  fused + half-spectrum (rfft) transforms: same all-to-all
                count, ~2x lower local FFT flops and all-to-all wire bytes
                per signal (see dist/fft.py)
    overlap     fused_rfft with overlap=K chunked transposes: each
                transform's all-to-all is split into K chunk collectives
                issued as their first-stage FFT finishes, so up to
                (K-1)/K of the wire time hides behind local compute
                (same payload on the wire, zero-padded to equal chunks when
                K does not divide the chunked extent — the win is latency,
                reported as
                the hidden-collective fraction / effective collective time)
    wire_bf16   overlap with wire_dtype='bf16': every chunk payload demoted
                to split-complex bf16 planes right before its collective
                (dist/fft wire packing), halving the bytes that actually
                cross the wire — the modeled collective bytes come from the
                compiled HLO, so the table reflects the true wire dtype

A second, multi-host section compares the same best-lever iteration on a
``data x host x device`` mesh (compat.make_hier_mesh), where the transform
axis spans hosts and every cross-host byte rides DCN instead of ICI:

    mh_flat     wire_bf16 lowered over the factored (host, device) axis as
                one monolithic all-to-all — every transpose byte crosses
                the host boundary and is charged at DCN_BW
    mh_hier     the two-stage hierarchical exchange (hier_axes=(H, D),
                dist/fft): full payload intra-host on ICI, only the
                (H-1)/H cross-boundary fraction on DCN as collective-
                permutes, with its own inter_wire_dtype

    per-tier bytes are read off the compiled HLO (collective-permute = the
    DCN hop), and the two-tier model (roofline.DCN_BW) scores both.

This is the §Perf hillclimb cell for the paper's technique: the printed
per-signal FFT-flop and wire-byte ratios are the measured value of each
lever, and the JSON artifact pins them per push.

    PYTHONPATH=src python -m repro.launch.cs_dryrun [--n1 4096 --n2 4096]
"""

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.dist.compat import make_hier_mesh
from repro.dist.fft import padded_rfft_len
from repro.dist.recovery import DistCpadmmState
from repro.launch.hlo_analysis import analyze_compiled
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import model_block_times
from repro.ops import plan_from_parts
from repro.ops.plan import _transform_extent

SDS = jax.ShapeDtypeStruct

VARIANTS = (  # (tag, fused, rfft, overlap, wire_dtype)
    ("baseline", False, False, 1, "fp32"),
    ("fused", True, False, 1, "fp32"),
    ("fused_rfft", True, True, 1, "fp32"),
    ("overlap", True, True, 4, "fp32"),
    ("wire_bf16", True, True, 4, "bf16"),
)


def lower_variant(
    mesh, n1, n2, batch, iters, fused, rfft=False, overlap=1,
    wire_dtype="fp32", axis_name="model", hier_axes=None,
    inter_wire_dtype="fp32",
):
    """Lower one iteration block through the plan API's abstract entry point
    (``ExecutionPlan.cpadmm_block``): the batch rides (pod x) data, each
    signal's transforms shard over the model axis (or the factored
    ``(host, device)`` pair) — the same lowering the unified drivers
    execute, here compiled from ShapeDtypeStructs only."""
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    pl = plan_from_parts(
        mesh, n1=n1, n2=n2, rfft=rfft, overlap=overlap, fused=fused,
        batch_axis=dp, axis_name=axis_name, wire_dtype=wire_dtype,
        hier_axes=hier_axes, inter_wire_dtype=inter_wire_dtype,
    )
    block = pl.cpadmm_block(iters)
    model_size = _transform_extent(mesh, pl.axis_name)
    ncols = padded_rfft_len(n2, model_size) if rfft else n2
    spec_s = SDS((n1, ncols), jnp.complex64)
    diag_s = SDS((n1, n2), jnp.float32)
    real_b = SDS((batch, n1, n2), jnp.float32)
    state_s = DistCpadmmState(*(real_b,) * 5)
    return block.lower(spec_s, spec_s, diag_s, real_b, state_s).compile()


def analyze(compiled, iters, batch, overlap=1, dcn="none"):
    # The roofline terms and the hidden-collective overlap model live in
    # launch/roofline.model_block_times — shared with the autotuner's
    # candidate scoring (ops/tune.py) so the dry-run tables and the tuner
    # can never drift apart.  ``dcn`` names which collective crosses hosts
    # (tune._dcn_bytes policy): "permute" for hierarchical plans (exactly
    # the inter-host hop), "all" for a flat exchange spanning hosts (every
    # transpose byte), "none" for single-fabric meshes.
    c = analyze_compiled(compiled)
    a2a_bytes = c.collective_bytes.get("all-to-all", 0)
    cp_bytes = c.collective_bytes.get("collective-permute", 0)
    dcn_bytes = {"none": 0.0, "permute": float(cp_bytes),
                 "all": float(a2a_bytes)}[dcn]
    times = model_block_times(c, overlap, dcn_bytes=dcn_bytes)
    return {
        "flops_per_dev": c.flops,
        "bytes_per_dev": c.bytes,
        "collective_bytes_per_dev": c.collective_bytes,
        "collective_counts": {k: v for k, v in c.collective_counts.items()},
        **times,
        "per_iter_a2a": c.collective_counts.get("all-to-all", 0) / iters,
        "flops_per_signal": c.flops / batch,
        "a2a_bytes_per_signal": a2a_bytes / batch,
        "cp_bytes_per_signal": cp_bytes / batch,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n1", type=int, default=4096)
    ap.add_argument("--n2", type=int, default=4096)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--iters", type=int, default=50)
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--hosts", type=int, default=2,
                    help="host tier extent H of the multi-host section")
    ap.add_argument("--devices-per-host", type=int, default=8,
                    help="device tier extent D of the multi-host section")
    ap.add_argument("--no-hier", action="store_true",
                    help="skip the multi-host flat-vs-hier section")
    ap.add_argument("--out", default="artifacts/cs_dryrun.json")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multipod)
    results = {}
    for tag, fused, rfft, overlap, wire in VARIANTS:
        t0 = time.time()
        compiled = lower_variant(
            mesh, args.n1, args.n2, args.batch, args.iters, fused, rfft,
            overlap, wire,
        )
        res = analyze(compiled, args.iters, args.batch, overlap)
        res["wire_dtype"] = wire
        mem = compiled.memory_analysis()
        res["hbm_need_gb"] = (
            getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "temp_size_in_bytes", 0)
        ) / 1e9
        res["compile_s"] = round(time.time() - t0, 1)
        results[tag] = res
        dom = max(
            ("compute_s", "memory_s", "effective_collective_s"),
            key=lambda k: res[k],
        )
        print(
            f"{tag:10s} n={args.n1*args.n2} batch={args.batch}: "
            f"compute {res['compute_s']*1e3:.1f}ms  memory {res['memory_s']*1e3:.1f}ms  "
            f"collective {res['collective_s']*1e3:.1f}ms "
            f"(hidden {res['hidden_collective_frac']*100:.0f}% -> eff "
            f"{res['effective_collective_s']*1e3:.1f}ms)  bound={dom}  "
            f"a2a/iter={res['per_iter_a2a']:.1f}  HBM {res['hbm_need_gb']:.1f}GB"
        )
    b, f, r = results["baseline"], results["fused"], results["fused_rfft"]
    o, w = results["overlap"], results["wire_bf16"]
    print(
        f"fused vs baseline: collective {b['collective_s']/max(f['collective_s'],1e-12):.2f}x down, "
        f"flops {b['flops_per_dev']/max(f['flops_per_dev'],1):.2f}x down, "
        f"bytes {b['bytes_per_dev']/max(f['bytes_per_dev'],1):.2f}x down"
    )
    print(
        f"rfft vs full-complex (fused): per-signal total flops "
        f"{f['flops_per_signal']/max(r['flops_per_signal'],1):.2f}x down "
        f"(FFT-only ~2x; the elementwise tail dilutes the total), "
        f"per-signal all-to-all bytes "
        f"{f['a2a_bytes_per_signal']/max(r['a2a_bytes_per_signal'],1):.2f}x down"
    )
    print(
        f"overlap(K={o['overlap']}) vs fused_rfft: same "
        f"{o['a2a_bytes_per_signal']/1e6:.1f}MB/signal on the wire in "
        f"{o['per_iter_a2a']:.0f} chunk-collectives/iter "
        f"(was {r['per_iter_a2a']:.0f}); hidden-collective fraction "
        f"{o['hidden_collective_frac']*100:.0f}% -> effective collective "
        f"{r['collective_s']*1e3:.1f}ms -> {o['effective_collective_s']*1e3:.1f}ms "
        f"per {args.iters}-iter block"
    )
    print(
        f"wire_bf16 vs overlap(fp32 wire): per-signal all-to-all bytes "
        f"{o['a2a_bytes_per_signal']/max(w['a2a_bytes_per_signal'],1):.2f}x "
        f"down (split-complex bf16 planes, same chunk schedule); vs "
        f"fused_rfft "
        f"{r['a2a_bytes_per_signal']/max(w['a2a_bytes_per_signal'],1):.2f}x"
    )
    # a2a bytes come from the compiled HLO's operand dtypes (hlo_analysis
    # DTYPE_BYTES) — the wire dtype's true itemsize, not the spectrum dtype's
    per_sig = {
        t: {
            "flops_per_signal": results[t]["flops_per_signal"],
            "a2a_bytes_per_signal": results[t]["a2a_bytes_per_signal"],
            "effective_collective_s": results[t]["effective_collective_s"],
            "wire_dtype": results[t]["wire_dtype"],
        }
        for t, *_ in VARIANTS
    }
    print("per-signal wire/flop table:")
    for t, row in per_sig.items():
        print(
            f"  {t:10s} flops {row['flops_per_signal']/1e9:8.2f}G  "
            f"a2a {row['a2a_bytes_per_signal']/1e6:7.1f}MB  "
            f"eff-collective {row['effective_collective_s']*1e3:6.1f}ms  "
            f"wire={row['wire_dtype']}"
        )

    if not args.no_hier:
        # multi-host section: same best-lever iteration (fused rfft, K=4,
        # bf16 wires), transform axis factored over (host, device) so the
        # flat exchange pays DCN for every byte and the hierarchical one
        # only for the cross-boundary (H-1)/H fraction
        H, D = args.hosts, args.devices_per_host
        data = args.batch  # one data shard per signal, as in production
        mesh_h = make_hier_mesh(data, H, D)
        mh = [
            ("mh_flat", None, "fp32", "all"),
            ("mh_hier", (H, D), "bf16", "permute"),
        ]
        for tag, hier, iw, dcn in mh:
            t0 = time.time()
            compiled = lower_variant(
                mesh_h, args.n1, args.n2, args.batch, args.iters,
                fused=True, rfft=True, overlap=4, wire_dtype="bf16",
                axis_name=("host", "device"), hier_axes=hier,
                inter_wire_dtype=iw,
            )
            res = analyze(compiled, args.iters, args.batch, 4, dcn=dcn)
            res["wire_dtype"] = "bf16"
            res["inter_wire_dtype"] = iw
            res["hier_axes"] = list(hier) if hier else None
            res["compile_s"] = round(time.time() - t0, 1)
            results[tag] = res
            print(
                f"{tag:10s} mesh=data{data} x host{H} x device{D}: "
                f"ICI {res['ici_collective_s']*1e3:.1f}ms + DCN "
                f"{res['dcn_collective_s']*1e3:.1f}ms = collective "
                f"{res['collective_s']*1e3:.1f}ms  per-signal a2a "
                f"{res['a2a_bytes_per_signal']/1e6:.1f}MB / inter-host "
                f"{(res['dcn_bytes']/args.batch)/1e6:.1f}MB"
            )
        fl, hi = results["mh_flat"], results["mh_hier"]
        print(
            f"hier vs flat over {H} hosts: inter-host bytes "
            f"{fl['dcn_bytes']/max(hi['dcn_bytes'],1):.2f}x down "
            f"((H-1)/H of the payload crosses, demoted to "
            f"{hi['inter_wire_dtype']}), modeled collective "
            f"{fl['collective_s']/max(hi['collective_s'],1e-12):.2f}x down, "
            f"modeled block "
            f"{fl['modeled_total_s']/max(hi['modeled_total_s'],1e-12):.2f}x down"
        )

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    json.dump(
        {"n1": args.n1, "n2": args.n2, "batch": args.batch,
         "mesh": "multipod" if args.multipod else "single", **results},
        open(args.out, "w"), indent=1,
    )


if __name__ == "__main__":
    main()

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Dry-run + roofline for the paper's own workload on the production mesh.

Lowers one CPADMM iteration-block (50 iterations, as the recovery launcher
runs it) for a large signal sharded over the model axis, with a batch of
signals over (pod) x data — the cluster-job form of the paper's Sec. 7
deblurring.  Compares the paper-faithful 6-transform iteration (6 all-to-alls)
against the fused variant (2 batched transforms -> 2 all-to-alls, see
dist/recovery.py) — this is the §Perf hillclimb cell for the paper's
technique.

    PYTHONPATH=src python -m repro.launch.cs_dryrun [--n1 4096 --n2 4096]
"""

import argparse
import json
import time

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.compat import shard_map

from repro.dist.recovery import (
    DistCpadmmParams,
    DistCpadmmState,
    dist_cpadmm_step,
    dist_cpadmm_step_fused,
)
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import HBM_BW, ICI_BW, PEAK_FLOPS, WIRE_MULT

SDS = jax.ShapeDtypeStruct


def lower_variant(mesh, n1, n2, batch, iters, fused, axis_name="model"):
    step = dist_cpadmm_step_fused if fused else dist_cpadmm_step
    row = P(None, axis_name, None)  # (batch, n1, n2) rows sharded
    col = P(None, None, axis_name)
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    row_b = P(dp, axis_name, None)
    col_b = P(dp, None, axis_name)

    def block(spec, b_spec, d_diag, pty, state):
        p = DistCpadmmParams(*(jnp.float32(v) for v in (1e-4, 0.01, 0.01, 1.0, 1.0)))

        def body(s, _):
            return step(spec, b_spec, d_diag, pty, s, p, axis_name), None

        state, _ = jax.lax.scan(body, state, None, length=iters)
        return state

    sm = shard_map(
        block,
        mesh=mesh,
        in_specs=(col_b, col_b, row_b, row_b, DistCpadmmState(*(row_b,) * 5)),
        out_specs=DistCpadmmState(*(row_b,) * 5),
        check_vma=False,
    )
    spec_s = SDS((batch, n1, n2), jnp.complex64)
    real_s = SDS((batch, n1, n2), jnp.float32)
    state_s = DistCpadmmState(*(real_s,) * 5)
    jitted = jax.jit(sm)  # shardings come from shard_map specs
    lowered = jitted.lower(spec_s, spec_s, real_s, real_s, state_s)
    compiled = lowered.compile()
    return compiled


def analyze(compiled, iters):
    hlo = compiled.as_text()
    c = analyze_hlo(hlo)
    wire = sum(WIRE_MULT.get(op, 1.0) * b for op, b in c.collective_bytes.items())
    return {
        "flops_per_dev": c.flops,
        "bytes_per_dev": c.bytes,
        "collective_bytes_per_dev": c.collective_bytes,
        "collective_counts": {k: v for k, v in c.collective_counts.items()},
        "compute_s": c.flops / PEAK_FLOPS,
        "memory_s": c.bytes / HBM_BW,
        "collective_s": wire / ICI_BW,
        "per_iter_a2a": c.collective_counts.get("all-to-all", 0) / iters,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n1", type=int, default=4096)
    ap.add_argument("--n2", type=int, default=4096)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--iters", type=int, default=50)
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--out", default="artifacts/cs_dryrun.json")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multipod)
    results = {}
    for fused in (False, True):
        tag = "fused" if fused else "baseline"
        t0 = time.time()
        compiled = lower_variant(mesh, args.n1, args.n2, args.batch, args.iters, fused)
        res = analyze(compiled, args.iters)
        mem = compiled.memory_analysis()
        res["hbm_need_gb"] = (
            getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "temp_size_in_bytes", 0)
        ) / 1e9
        res["compile_s"] = round(time.time() - t0, 1)
        results[tag] = res
        dom = max(
            ("compute_s", "memory_s", "collective_s"), key=lambda k: res[k]
        )
        print(
            f"{tag:9s} n={args.n1*args.n2} batch={args.batch}: "
            f"compute {res['compute_s']*1e3:.1f}ms  memory {res['memory_s']*1e3:.1f}ms  "
            f"collective {res['collective_s']*1e3:.1f}ms  bound={dom}  "
            f"a2a/iter={res['per_iter_a2a']:.1f}  HBM {res['hbm_need_gb']:.1f}GB"
        )
    b, f = results["baseline"], results["fused"]
    print(
        f"fused vs baseline: collective {b['collective_s']/max(f['collective_s'],1e-12):.2f}x down, "
        f"flops {b['flops_per_dev']/max(f['flops_per_dev'],1):.2f}x down, "
        f"bytes {b['bytes_per_dev']/max(f['bytes_per_dev'],1):.2f}x down"
    )
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    json.dump(
        {"n1": args.n1, "n2": args.n2, "batch": args.batch,
         "mesh": "multipod" if args.multipod else "single", **results},
        open(args.out, "w"), indent=1,
    )


if __name__ == "__main__":
    main()

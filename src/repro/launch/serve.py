"""Recovery-as-a-service launcher: serve a stream of compressed signals.

    PYTHONPATH=src python -m repro.launch.serve --n 16384 --requests 32 \
        --rate 200 --slots 8

Stands up a :class:`repro.serve.RecoveryServer` — the continuous-batching
dispatcher — and drives it with a seeded synthetic Poisson stream of
heterogeneous recovery requests (mixed tolerances, optional priorities and
deadlines) over one sensing operator.  Converged slots are recycled to
queued requests mid-run, so the batch never drains to its stragglers;
``--compare-static`` additionally serves the identical stream through the
fixed-wave baseline and reports the throughput ratio.

``--mesh`` routes every bucket's engine through the execution-plan layer
(``repro.ops.plan``), same specs as ``repro.launch.recover``: ``--mesh 8``
shards each signal over 8 model-axis devices; ``--fake-devices N`` forces N
XLA host devices so the distributed path runs on a CPU box.  ``--tune``
asks the plan autotuner for each bucket's config — warm runs hit the plan
cache in microseconds.

Reports signals/sec, p50/p99 latency, convergence/expiry counts, and the
recycling statistics per bucket.
"""

from __future__ import annotations

import argparse
import os
import sys

if __name__ == "__main__":  # --fake-devices must land before jax imports
    _pre = argparse.ArgumentParser(add_help=False)
    _pre.add_argument("--fake-devices", type=int, default=0)
    _n, _ = _pre.parse_known_args()
    if _n.fake_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={_n.fake_devices}"
        )

import jax

METHODS = ("cpadmm", "ista", "fista")


def _parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        description="continuous-batching recovery server (see module docstring)"
    )
    ap.add_argument("--n", type=int, default=16384)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--rate", type=float, default=200.0,
                    help="Poisson arrival rate (requests/second)")
    ap.add_argument("--slots", type=int, default=8,
                    help="batch lanes per bucket engine")
    ap.add_argument("--round-iters", type=int, default=32,
                    help="solver iterations per scheduling round")
    ap.add_argument("--method", default="cpadmm", choices=METHODS,
                    metavar=f"{{{','.join(METHODS)}}}")
    ap.add_argument("--tols", type=float, nargs="+",
                    default=[1e-3, 1e-3, 1e-3, 1e-6],
                    help="per-request tolerance draw (repeat a value to "
                         "weight it; the default is the ragged 3:1 mix)")
    ap.add_argument("--max-iters", type=int, default=2000)
    ap.add_argument("--min-iters", type=int, default=50)
    ap.add_argument("--priorities", type=int, nargs="+", default=[0],
                    help="per-request priority draw (larger runs first)")
    ap.add_argument("--deadline-slack", type=float, default=None,
                    help="per-request deadline = arrival + slack seconds "
                         "(expired requests return flagged partials)")
    ap.add_argument("--alpha", type=float, default=1e-4)
    ap.add_argument("--rho", type=float, default=0.01)
    ap.add_argument("--sigma", type=float, default=0.01)
    ap.add_argument("--compare-static", action="store_true",
                    help="also serve the identical stream through the "
                         "fixed-wave static baseline and report the ratio")
    ap.add_argument("--mesh", default=None,
                    help="distributed engines: 'M' (model axis) or 'DxM'")
    ap.add_argument("--rfft", action="store_true")
    ap.add_argument("--overlap", type=int, default=1)
    ap.add_argument("--n1", type=int, default=None)
    ap.add_argument("--tune", nargs="?", const="model", default=None,
                    choices=("model", "measure"),
                    help="autotune each bucket's plan (warm runs hit the "
                         "plan cache)")
    ap.add_argument("--fake-devices", type=int, default=0,
                    help="force N XLA host devices (honored when run as a "
                         "script; must precede jax import)")
    ap.add_argument("--seed", type=int, default=0)
    return ap


def main(argv=None):
    args = _parser().parse_args(argv)

    from repro.core.circulant import partial_gaussian_circulant
    from repro.data.synthetic import paper_regime
    from repro.launch.recover import parse_mesh
    from repro.serve import (
        RecoveryServer,
        WallClock,
        static_batch_serve,
        summarize,
        synthetic_workload,
    )

    mesh, _ = parse_mesh(args.mesh)
    m, k = paper_regime(args.n)
    op = partial_gaussian_circulant(jax.random.PRNGKey(args.seed + 1),
                                    args.n, m, normalize=True)
    reqs = synthetic_workload(
        op, args.requests, rate=args.rate, seed=args.seed, tols=args.tols,
        max_iters=args.max_iters, min_iters=args.min_iters,
        priorities=args.priorities, deadline_slack=args.deadline_slack,
        method=args.method,
    )
    print(f"serving {args.requests} requests, n={args.n}, m={m}, k={k}, "
          f"rate={args.rate}/s, slots={args.slots}, method={args.method}"
          + (f", mesh={args.mesh} (plan API)" if args.mesh else ""))

    tune = args.tune if args.tune else False
    srv = RecoveryServer(mesh=mesh, slots=args.slots,
                         round_iters=args.round_iters, alpha=args.alpha,
                         rho=args.rho, sigma=args.sigma, tune=tune,
                         clock=WallClock())
    srv.warmup(reqs[0])
    srv.clock = WallClock()
    results = srv.serve(reqs)
    s = summarize(results)
    stats = srv.stats()

    print(f"continuous: {s['signals_per_sec']:.2f} signals/s, "
          f"p50 {s['p50_latency_s']:.3f}s, p99 {s['p99_latency_s']:.3f}s, "
          f"converged {s['converged']}/{s['count']}, "
          f"expired {s['expired']}")
    t = stats["total"]
    print(f"  buckets {stats['buckets']}, admitted {t['admitted']}, "
          f"recycled {t['recycled']}, rounds {t['rounds']}, "
          f"slot-iterations {t['slot_iters']}")

    if args.compare_static:
        b = summarize(static_batch_serve(reqs, server=srv,
                                         clock=WallClock()))
        ratio = s["signals_per_sec"] / b["signals_per_sec"]
        print(f"static baseline: {b['signals_per_sec']:.2f} signals/s, "
              f"p50 {b['p50_latency_s']:.3f}s, "
              f"p99 {b['p99_latency_s']:.3f}s")
        print(f"continuous vs static: {ratio:.2f}x signals/s")


if __name__ == "__main__":
    main(sys.argv[1:])

"""Pallas TPU kernel: banded circulant matvec (circular FIR / blur apply).

The Sec. 7 blur matrix is an order-L circulant (L ~ 5): only L of the n
"sensing vector" entries are nonzero.  For such matrices the time-domain
product is O(nL) — far below the O(n log n) FFT — and is a pure stencil:

    y[i] = sum_{t=0}^{L-1} w[t] * x[(i + t) mod n]        (first-row taps)

Each grid step owns a length-B output tile and DMAs the (B + L - 1)-element
halo window of x; taps sit in SMEM-like small VMEM block.  The loop over L
is unrolled (L is static and small) — each iteration is one shifted VPU
multiply-add, the canonical TPU stencil pattern.

This kernel is also the building block for the *distributed* blur apply:
shard x over the model axis and the halo exchange is a 1-hop
collective-permute of L - 1 elements (see repro/dist/fft.py notes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 1024


def _kernel(xw_ref, taps_ref, o_ref, *, block: int, order: int):
    i = pl.program_id(0)
    window = xw_ref[pl.ds(i * block, block + order - 1)]
    acc = jnp.zeros((block,), o_ref.dtype)
    for t in range(order):  # static unroll: order is small (paper L = 5)
        acc += taps_ref[t] * jax.lax.dynamic_slice_in_dim(window, t, block)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("order", "block", "interpret"))
def banded_circulant_matvec(
    taps: jax.Array,  # (order,) first-row taps w[0..L-1]
    x: jax.Array,  # (n,)
    *,
    order: int,
    block: int = DEFAULT_BLOCK,
    interpret: bool = True,
) -> jax.Array:
    """y[i] = sum_t taps[t] x[(i+t) mod n] — the paper's blur (A = first-row
    circulant with taps [1/L]*L gives the Sec. 7 moving average)."""
    n = x.shape[-1]
    assert n % block == 0, (n, block)
    assert taps.shape[-1] >= order
    # circular halo: append the first (order-1) elements
    xw = jnp.concatenate([x, x[: order - 1]]) if order > 1 else x
    kern = functools.partial(_kernel, block=block, order=order)
    return pl.pallas_call(
        kern,
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((xw.shape[0],), lambda i: 0),  # windowed source
            pl.BlockSpec((taps.shape[0],), lambda i: 0),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: i),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=interpret,
    )(xw, taps)

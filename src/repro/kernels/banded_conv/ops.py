"""Jit'd wrapper for the banded circulant matvec (pads n to the block size)."""

from __future__ import annotations

import functools

import jax

from .kernel import DEFAULT_BLOCK, banded_circulant_matvec
from .ref import banded_circulant_matvec_ref


@functools.partial(jax.jit, static_argnames=("order", "interpret"))
def blur_apply(taps, x, *, order: int, interpret: bool = True):
    """Apply an order-L first-row circulant (e.g. the Sec. 7 blur)."""
    n = x.shape[-1]
    if n % DEFAULT_BLOCK != 0:
        # circular padding would change semantics; fall back to the oracle
        return banded_circulant_matvec_ref(taps, x, order=order)
    return banded_circulant_matvec(taps, x, order=order, interpret=interpret)

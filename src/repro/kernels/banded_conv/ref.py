"""Pure-jnp oracle for the banded circulant (blur) matvec."""

from __future__ import annotations

import jax.numpy as jnp


def banded_circulant_matvec_ref(taps, x, *, order: int):
    """y[i] = sum_t taps[t] x[(i+t) mod n] via explicit rolls."""
    y = jnp.zeros_like(x)
    for t in range(order):
        y = y + taps[t] * jnp.roll(x, -t, axis=-1)
    return y

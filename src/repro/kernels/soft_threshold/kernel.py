"""Pallas TPU kernel: fused soft-threshold + state update (paper Eq. 4).

CPISTA's Alg. 8 fuses the gradient update and the threshold in one GPU
kernel so the pre-threshold vector never round-trips through global memory;
this is the TPU equivalent.  Two fusions are provided:

    ista:   x_new = eta_gamma(x + delta)               (Alg. 1 line 5 + Alg. 8)
    admm:   z    = eta_gamma(x + nu)
            nu'  = nu + tau2 * (x - z)                  (Alg. 3 lines 5-6 fused)

Pure VPU elementwise work tiled in (8, 128)-aligned 1-D blocks; one HBM read
per operand and one write per output instead of three round-trips.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 1024


def _eta(v, gamma):
    return jnp.sign(v) * jnp.maximum(jnp.abs(v) - gamma, 0.0)


def _ista_kernel(x_ref, d_ref, gamma_ref, o_ref):
    o_ref[...] = _eta(x_ref[...] + d_ref[...], gamma_ref[0])


def _admm_kernel(x_ref, nu_ref, gamma_ref, tau_ref, z_ref, nu_out_ref):
    z = _eta(x_ref[...] + nu_ref[...], gamma_ref[0])
    z_ref[...] = z
    nu_out_ref[...] = nu_ref[...] + tau_ref[0] * (x_ref[...] - z)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def ista_threshold_update(
    x: jax.Array,
    delta: jax.Array,
    gamma: jax.Array,
    *,
    block: int = DEFAULT_BLOCK,
    interpret: bool = True,
) -> jax.Array:
    """eta_gamma(x + delta), fused."""
    n = x.shape[-1]
    assert n % block == 0, (n, block)
    gamma = jnp.broadcast_to(jnp.asarray(gamma, x.dtype), (1,))
    return pl.pallas_call(
        _ista_kernel,
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: i),
            pl.BlockSpec((block,), lambda i: i),
            pl.BlockSpec((1,), lambda i: 0),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: i),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=interpret,
    )(x, delta, gamma)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def admm_threshold_dual_update(
    x: jax.Array,
    nu: jax.Array,
    gamma: jax.Array,
    tau2: jax.Array,
    *,
    block: int = DEFAULT_BLOCK,
    interpret: bool = True,
):
    """(z, nu') = (eta_gamma(x + nu), nu + tau2 (x - z)), fused."""
    n = x.shape[-1]
    assert n % block == 0, (n, block)
    gamma = jnp.broadcast_to(jnp.asarray(gamma, x.dtype), (1,))
    tau2 = jnp.broadcast_to(jnp.asarray(tau2, x.dtype), (1,))
    return pl.pallas_call(
        _admm_kernel,
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: i),
            pl.BlockSpec((block,), lambda i: i),
            pl.BlockSpec((1,), lambda i: 0),
            pl.BlockSpec((1,), lambda i: 0),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: i),
            pl.BlockSpec((block,), lambda i: i),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), x.dtype),
            jax.ShapeDtypeStruct((n,), x.dtype),
        ],
        interpret=interpret,
    )(x, nu, gamma, tau2)

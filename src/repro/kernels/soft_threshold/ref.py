"""Pure-jnp oracle for the fused soft-threshold kernels."""

from __future__ import annotations

import jax.numpy as jnp


def eta_ref(v, gamma):
    return jnp.sign(v) * jnp.maximum(jnp.abs(v) - gamma, 0.0)


def ista_threshold_update_ref(x, delta, gamma):
    return eta_ref(x + delta, gamma)


def admm_threshold_dual_update_ref(x, nu, gamma, tau2):
    z = eta_ref(x + nu, gamma)
    return z, nu + tau2 * (x - z)

"""Jit'd wrappers (pad-to-block + reshape) for the soft-threshold kernels."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import (
    DEFAULT_BLOCK,
    admm_threshold_dual_update,
    ista_threshold_update,
)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_ista_update(x, delta, gamma, *, interpret: bool = True):
    n = x.shape[-1]
    pad = (-n) % DEFAULT_BLOCK
    if pad:
        x = jnp.pad(x, (0, pad))
        delta = jnp.pad(delta, (0, pad))
    out = ista_threshold_update(x, delta, gamma, interpret=interpret)
    return out[:n]


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_admm_update(x, nu, gamma, tau2, *, interpret: bool = True):
    n = x.shape[-1]
    pad = (-n) % DEFAULT_BLOCK
    if pad:
        x = jnp.pad(x, (0, pad))
        nu = jnp.pad(nu, (0, pad))
    z, nu_new = admm_threshold_dual_update(x, nu, gamma, tau2, interpret=interpret)
    return z[:n], nu_new[:n]

"""Wire pack/unpack entry points: shape plumbing + substrate dispatch.

``pack_wire`` / ``unpack_wire`` are what the distributed transforms call
around every transpose all-to-all (repro.dist.fft._fwd_transpose /
_inv_transpose).  The payload is an arbitrary-rank complex chunk; packing
stacks demoted (re, im) planes on a new leading axis so the collective's
split/concat axes (trailing) shift by one and nothing else changes.

Substrates:

    'jnp'     pure-jnp cast path (XLA fuses it into the chunk producer)
    'pallas'  the kernels in kernel.py — one fused VMEM pass per direction
    'auto'    'pallas' compiled on TPU, 'jnp' elsewhere (interpret-mode
              Pallas inside every collective would be pure overhead on the
              CPU test path; the kernel parity tests force 'pallas')
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .kernel import pack_wire_pallas, unpack_wire_pallas
from .ref import pack_wire_ref, unpack_wire_ref

# the wire_dtype= plan-knob vocabulary — THE mapping every layer shares
# (PlanConfig.validate, dist.fft, tune's candidate space, the CLI flag)
WIRE_DTYPES = {
    "fp32": jnp.float32,
    "bf16": jnp.bfloat16,
    "fp16": jnp.float16,
}


def wire_itemsize(wire_dtype: str) -> int:
    """Bytes per real wire element (a complex payload element is 2x this)."""
    return jnp.dtype(WIRE_DTYPES[wire_dtype]).itemsize


def interpret_default() -> bool:
    """Pallas execution-mode default (repo-wide kernel convention):
    compiled for real on TPU, interpret mode elsewhere."""
    return jax.default_backend() != "tpu"


def _resolve(substrate: str) -> str:
    if substrate == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    if substrate not in ("jnp", "pallas"):
        raise ValueError(
            f"wire pack substrate must be 'auto', 'jnp' or 'pallas', "
            f"got {substrate!r}"
        )
    return substrate


def pack_wire(z, wire_dtype: str, substrate: str = "auto", interpret=None):
    """Complex payload (...,) -> (2, ...) split-complex wire planes.

    ``wire_dtype`` is a :data:`WIRE_DTYPES` key; 'fp32' still packs (the
    collective needs the real layout either way the caller chose this path)
    but demotes nothing.
    """
    dt = WIRE_DTYPES[wire_dtype]
    if _resolve(substrate) == "jnp":
        return pack_wire_ref(z, dt)
    shape = z.shape
    L = 1
    for s in shape:
        L *= s
    re = jnp.real(z).astype(jnp.float32).reshape(L)
    im = jnp.imag(z).astype(jnp.float32).reshape(L)
    w = pack_wire_pallas(
        re, im, wire_dtype=dt,
        interpret=interpret_default() if interpret is None else interpret,
    )
    return w.reshape((2,) + shape)


def unpack_wire(w, out_dtype=jnp.complex64, substrate: str = "auto",
                interpret=None):
    """(2, ...) wire planes -> complex payload, promoted via float32."""
    if _resolve(substrate) == "jnp":
        return unpack_wire_ref(w, out_dtype)
    shape = w.shape[1:]
    L = 1
    for s in shape:
        L *= s
    re, im = unpack_wire_pallas(
        w.reshape(2, L),
        interpret=interpret_default() if interpret is None else interpret,
    )
    return lax.complex(re, im).astype(out_dtype).reshape(shape)

"""Pure-jnp oracle for the wire pack/unpack pair.

Split-complex packing for the transpose all-to-all: a complex payload is
demoted to a real wire dtype as two stacked planes (re, im) on a new
*leading* axis, so the trailing axes the collective splits/concats over are
untouched and each plane stays contiguous on the wire.  Unpack promotes
back to float32 parts and recombines — quantization error enters exactly
once per collective, never compounding through twiddles or accumulation
(those stay fp32 locally; see repro.dist.fft).
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def pack_wire_ref(z, wire_dtype):
    """Complex (...,) -> real (2, ...) planes demoted to ``wire_dtype``."""
    return jnp.stack([jnp.real(z), jnp.imag(z)]).astype(wire_dtype)


def unpack_wire_ref(w, out_dtype=jnp.complex64):
    """Real (2, ...) wire planes -> complex (...,) promoted via float32."""
    u = w.astype(jnp.float32)
    return lax.complex(u[0], u[1]).astype(out_dtype)

"""Pallas TPU kernels: demote-pack / promote-unpack for wire-compressed
collectives.

The four-step transpose all-to-all (repro.dist.fft) moves complex chunk
payloads between devices.  With ``wire_dtype='bf16'``/``'fp16'`` the payload
is demoted right before the collective and promoted right after — these
kernels are that cast, fused into the chunk pipeline as one VMEM pass per
direction instead of separate real/imag/stack/cast XLA ops:

    pack    re, im float32 tiles -> one (2, block) wire-dtype tile
    unpack  one (2, block) wire-dtype tile -> re, im float32 tiles

Split-complex layout (separate re/im planes on a new leading axis) keeps
the trailing axes — the ones the all-to-all splits and concats over —
contiguous and untouched, so the collective treats the plane axis like a
batch axis.  Tiling mirrors kernels/cpadmm_tail: 1-D tiles over the
flattened payload, padded to a block multiple and sliced back after.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 1024


def _pack_kernel(re_ref, im_ref, out_ref):
    dt = out_ref.dtype
    out_ref[0, :] = re_ref[...].astype(dt)
    out_ref[1, :] = im_ref[...].astype(dt)


def _unpack_kernel(w_ref, re_ref, im_ref):
    re_ref[...] = w_ref[0, :].astype(jnp.float32)
    im_ref[...] = w_ref[1, :].astype(jnp.float32)


@functools.partial(
    jax.jit, static_argnames=("wire_dtype", "block", "interpret")
)
def pack_wire_pallas(
    re: jax.Array,  # (L,) float32
    im: jax.Array,  # (L,) float32
    *,
    wire_dtype,
    block: int = DEFAULT_BLOCK,
    interpret: bool = True,
):
    """-> (2, L) wire-dtype planes: row 0 = re, row 1 = im, demoted."""
    L = re.shape[-1]
    pad = (-L) % block
    if pad:
        re = jnp.pad(re, (0, pad))
        im = jnp.pad(im, (0, pad))
    n = re.shape[-1]
    tile = pl.BlockSpec((block,), lambda i: i)
    out = pl.pallas_call(
        _pack_kernel,
        grid=(n // block,),
        in_specs=[tile, tile],
        out_specs=pl.BlockSpec((2, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((2, n), jnp.dtype(wire_dtype)),
        interpret=interpret,
    )(re, im)
    return out[:, :L]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def unpack_wire_pallas(
    w: jax.Array,  # (2, L) wire dtype
    *,
    block: int = DEFAULT_BLOCK,
    interpret: bool = True,
):
    """-> (re, im) float32 (L,) planes promoted from the wire payload."""
    L = w.shape[-1]
    pad = (-L) % block
    if pad:
        w = jnp.pad(w, ((0, 0), (0, pad)))
    n = w.shape[-1]
    tile = pl.BlockSpec((block,), lambda i: i)
    re, im = pl.pallas_call(
        _unpack_kernel,
        grid=(n // block,),
        in_specs=[pl.BlockSpec((2, block), lambda i: (0, i))],
        out_specs=[tile, tile],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.float32)] * 2,
        interpret=interpret,
    )(w)
    return re[:L], im[:L]

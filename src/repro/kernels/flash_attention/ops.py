"""Public wrapper: (B, S, H, D) GQA layout -> folded-head flash kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import DEFAULT_BLK_K, DEFAULT_BLK_Q, flash_attention_pallas


@functools.partial(jax.jit, static_argnames=("causal", "interpret"))
def flash_attention(
    q: jax.Array,  # (B, Sq, H, D)
    k: jax.Array,  # (B, Sk, KH, D)
    v: jax.Array,  # (B, Sk, KH, D)
    *,
    causal: bool = True,
    interpret: bool = True,
) -> jax.Array:
    """GQA-aware wrapper: repeats KV heads to match H, folds (B, H) into the
    kernel grid.  Pads Sq/Sk to the block size (masked by causality or
    discarded)."""
    b, sq, h, d = q.shape
    kh = k.shape[2]
    g = h // kh
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)

    blk_q = min(DEFAULT_BLK_Q, sq) if sq % DEFAULT_BLK_Q else DEFAULT_BLK_Q
    blk_k = min(DEFAULT_BLK_K, k.shape[1]) if k.shape[1] % DEFAULT_BLK_K else DEFAULT_BLK_K
    assert sq % blk_q == 0 and k.shape[1] % blk_k == 0, "pad upstream"

    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, k.shape[1], d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, v.shape[1], d)
    out = flash_attention_pallas(
        qf, kf, vf, causal=causal, blk_q=blk_q, blk_k=blk_k, interpret=interpret
    )
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)


def flash_hbm_bytes(b, sq, sk, h, d, bytes_per_el=2) -> int:
    """Analytic HBM traffic of the fused kernel (q+k+v reads + out write) —
    used by the roofline accounting when the kernel replaces the pure-JAX
    attention (EXPERIMENTS.md §Perf)."""
    return bytes_per_el * (b * h * (sq * d * 2 + sk * d * 2))

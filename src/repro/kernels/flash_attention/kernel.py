"""Pallas TPU kernel: fused flash attention (forward).

The §Roofline analysis attributes ~60-70% of dense-train HBM traffic to the
score-tile round-trips of the pure-JAX online-softmax attention (each XLA
fusion boundary materializes a (blk_q, S) fp32 tile).  This kernel runs the
whole q-tile pipeline — scores, mask, online softmax, PV accumulation — in
VMEM: HBM traffic collapses to q/k/v reads + one output write.

Layout: heads are folded into the grid's first axis; grid = (B*H, Sq/blk_q).
K/V for one (batch, head) ride in VMEM for the whole q-tile pass (S*Dh*2
floats: 4 MiB at S=4096, Dh=128 — fits v5e VMEM; the streamed-DMA variant
for longer S is the documented follow-up).  The KV loop is a fori_loop over
blk_k tiles with running (m, l, acc) in registers — the textbook flash
schedule on the MXU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30

DEFAULT_BLK_Q = 256
DEFAULT_BLK_K = 256


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, blk_q: int, blk_k: int,
                  causal: bool, scale: float):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale  # (blk_q, d)
    s_len = k_ref.shape[1]
    nk = s_len // blk_k
    q_pos = qi * blk_q + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)

    def body(j, carry):
        m, l, acc = carry
        k = k_ref[0, pl.ds(j * blk_k, blk_k), :].astype(jnp.float32)
        v = v_ref[0, pl.ds(j * blk_k, blk_k), :].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)
        if causal:
            kv_pos = j * blk_k + jax.lax.broadcasted_iota(
                jnp.int32, (blk_q, blk_k), 1
            )
            s = jnp.where(q_pos >= kv_pos, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        return m_new, l_new, acc_new

    m0 = jnp.full((blk_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((blk_q,), jnp.float32)
    a0 = jnp.zeros((blk_q, q_ref.shape[-1]), jnp.float32)
    # causal: kv tiles strictly above the diagonal never contribute — skip them
    upper = nk if not causal else jnp.minimum(
        nk, (qi + 1) * blk_q // blk_k + (1 if blk_q % blk_k else 0)
    )
    m, l, acc = jax.lax.fori_loop(0, upper, body, (m0, l0, a0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "blk_q", "blk_k", "interpret")
)
def flash_attention_pallas(
    q: jax.Array,  # (BH, Sq, D)
    k: jax.Array,  # (BH, Sk, D)
    v: jax.Array,  # (BH, Sk, D)
    *,
    causal: bool = True,
    blk_q: int = DEFAULT_BLK_Q,
    blk_k: int = DEFAULT_BLK_K,
    interpret: bool = True,
) -> jax.Array:
    bh, sq, d = q.shape
    sk = k.shape[1]
    assert sq % blk_q == 0 and sk % blk_k == 0, (sq, sk, blk_q, blk_k)
    scale = d**-0.5
    kern = functools.partial(
        _flash_kernel, blk_q=blk_q, blk_k=blk_k, causal=causal, scale=scale
    )
    return pl.pallas_call(
        kern,
        grid=(bh, sq // blk_q),
        in_specs=[
            pl.BlockSpec((1, blk_q, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, sk, d), lambda b, i: (b, 0, 0)),  # KV resident
            pl.BlockSpec((1, sk, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        interpret=interpret,
    )(q, k, v)

"""Pure-jnp oracle for the flash attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """q,k,v: (BH, S, D) -> naive softmax attention."""
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * (d**-0.5)
    if causal:
        sq, sk = s.shape[-2], s.shape[-1]
        mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)

"""Jit'd public wrapper for the circulant matvec kernel.

Dispatch policy (recorded in EXPERIMENTS.md §Perf):
  * n below ``FFT_CROSSOVER``: direct Pallas kernel — O(n^2) FLOPs but MXU-
    dense and HBM-light (the paper's Fig. 7 regime where the structured
    direct scheme beats generic GEMM).
  * larger n: FFT path — O(n log n) wins regardless of constant factors.
On this CPU container the Pallas kernel runs in interpret mode (slow,
correctness only); `interpret=False` is the real-TPU configuration.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import DEFAULT_BLOCK, circulant_matvec_pallas
from .ref import circulant_matvec_fft_ref

FFT_CROSSOVER = 1 << 15


def _pad_to_multiple(v, block):
    n = v.shape[-1]
    pad = (-n) % block
    return (jnp.pad(v, (0, pad)), n) if pad else (v, n)


@functools.partial(jax.jit, static_argnames=("transpose", "block", "interpret", "force"))
def circulant_matvec(
    col: jax.Array,
    x: jax.Array,
    *,
    transpose: bool = False,
    block: int = DEFAULT_BLOCK,
    interpret: bool = True,
    force: str | None = None,
) -> jax.Array:
    """y = C @ x, C[i, j] = col[(i - j) mod n].  force in {None,'direct','fft'}."""
    n = col.shape[-1]
    use_direct = force == "direct" or (force is None and n < FFT_CROSSOVER and n % block == 0)
    if use_direct:
        return circulant_matvec_pallas(
            col, x, transpose=transpose, block=block, interpret=interpret
        )
    return circulant_matvec_fft_ref(col, x, transpose=transpose)

"""Pallas TPU kernel: direct (time-domain) circulant matvec.

TPU adaptation of the paper's CPISTA/CPADMM GPU kernels (Algs. 4-8).  The
GPU version gives each work-item one output row and modular reads of the
shared sensing vector, relying on L2 to de-duplicate traffic.  The TPU
version makes that de-duplication *structural*:

  * grid = (row-tiles, col-tiles); each step owns a (BI, BJ) tile of the
    implicit matrix ``C[i, j] = col[(i - j) mod n]``.
  * the whole doubled vector ``colx = concat(col, col)`` lives in VMEM; the
    kernel slices the length ``BI + BJ - 1`` *window* that generates the
    tile — O(BI + BJ) unique elements instead of O(BI * BJ): the same
    O(n^2) -> O(n) traffic reduction the paper gets from GPU caching
    (DESIGN.md Sec. 2), but guaranteed by the block schedule rather than by
    a cache heuristic.
  * the Toeplitz tile is materialized on-chip from the window with an
    iota-gather and fed to the MXU as a (BI, BJ) x (BJ,) product;
    accumulation over col-tiles happens in the output VMEM block
    (revisited across the inner grid dimension).

Memory budget per step: BI*BJ (tile) + 2n (colx) + BJ (x) + BI (out) floats.
With BI = BJ = 256 and n <= 2^20 this is well under a 16 MiB VMEM (the tile
itself is 256 KiB); for larger n the FFT path takes over (see ops.py).

The iota-gather (``jnp.take`` of a 1-D VMEM window) lowers on current Mosaic
toolchains; an equivalent formulation via BJ unrolled dynamic slices is kept
in ``_tile_via_slices`` for older toolchains and is covered by the same
tests.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

DEFAULT_BLOCK = 128


def _toeplitz_tile_gather(window: Array, bi: int, bj: int) -> Array:
    """tile[a, b] = window[(bj - 1) + a - b]; window has length bi + bj - 1."""
    a = jax.lax.broadcasted_iota(jnp.int32, (bi, bj), 0)
    b = jax.lax.broadcasted_iota(jnp.int32, (bi, bj), 1)
    return jnp.take(window, (bj - 1) + a - b, axis=0)


def _tile_via_slices(window: Array, bi: int, bj: int) -> Array:
    """Gather-free alternative: bj static slices (columns of the tile)."""
    cols = [
        jax.lax.dynamic_slice_in_dim(window, bj - 1 - b, bi) for b in range(bj)
    ]
    return jnp.stack(cols, axis=1)


def _matvec_kernel(colx_ref, x_ref, o_ref, *, n: int, bi: int, bj: int, transpose: bool, use_gather: bool):
    gi = pl.program_id(0)
    gj = pl.program_id(1)

    # Window generating tile (gi, gj) of C (or C^T).
    #   C   [i, j] = col[(i - j) mod n]        -> base = gi*bi - gj*bj - (bj-1)
    #   C^T [i, j] = col[(j - i) mod n]        -> reversed window direction
    if not transpose:
        base = gi * bi - gj * bj - (bj - 1)
    else:
        # C^T tile[a, b] = col[(gj*bj + b) - (gi*bi + a) mod n]
        #              = colrev window; reuse gather with swapped roles:
        # define window w[t] = col[(gj*bj - gi*bi - (bi - 1) + t) mod n],
        # then tile[a, b] = w[(bi - 1) + b - a] ... we fold by reading the
        # forward window of the *transposed* index arithmetic below.
        base = gj * bj - gi * bi - (bi - 1)

    base = jax.lax.rem(base, n) + n  # positive index into doubled colx
    if not transpose:
        w_len = bi + bj - 1
        window = colx_ref[pl.ds(base, w_len)]
        if use_gather:
            tile = _toeplitz_tile_gather(window, bi, bj)
        else:
            tile = _tile_via_slices(window, bi, bj)
    else:
        w_len = bi + bj - 1
        window = colx_ref[pl.ds(base, w_len)]
        # tile[a, b] = window[(bi - 1) + b - a] == gather with swapped iotas
        a = jax.lax.broadcasted_iota(jnp.int32, (bi, bj), 0)
        b = jax.lax.broadcasted_iota(jnp.int32, (bi, bj), 1)
        if use_gather:
            tile = jnp.take(window, (bi - 1) + b - a, axis=0)
        else:
            rows = [
                jax.lax.dynamic_slice_in_dim(window, bi - 1 - aa, bj)
                for aa in range(bi)
            ]
            tile = jnp.stack(rows, axis=0)

    acc = jnp.dot(tile, x_ref[...], preferred_element_type=jnp.float32)

    @pl.when(gj == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += acc.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("transpose", "block", "use_gather", "interpret")
)
def circulant_matvec_pallas(
    col: Array,
    x: Array,
    *,
    transpose: bool = False,
    block: int = DEFAULT_BLOCK,
    use_gather: bool = True,
    interpret: bool = True,
) -> Array:
    """y = C @ x (or C^T @ x) with C[i, j] = col[(i - j) mod n].

    ``n`` must be a multiple of ``block`` (ops.py pads otherwise).
    """
    n = col.shape[-1]
    assert n % block == 0, (n, block)
    assert x.shape[-1] == n
    colx = jnp.concatenate([col, col, col[: 2 * block]])  # headroom for windows
    grid = (n // block, n // block)
    kern = functools.partial(
        _matvec_kernel,
        n=n,
        bi=block,
        bj=block,
        transpose=transpose,
        use_gather=use_gather,
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((colx.shape[0],), lambda i, j: 0),  # resident window pool
            pl.BlockSpec((block,), lambda i, j: j),  # x tile
        ],
        out_specs=pl.BlockSpec((block,), lambda i, j: i),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=interpret,
    )(colx, x)

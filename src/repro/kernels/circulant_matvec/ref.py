"""Pure-jnp oracle for the direct circulant matvec kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def circulant_dense(col: Array) -> Array:
    n = col.shape[-1]
    i = jnp.arange(n)[:, None]
    j = jnp.arange(n)[None, :]
    return col[(i - j) % n]


def circulant_matvec_ref(col: Array, x: Array, *, transpose: bool = False) -> Array:
    """O(n^2) dense oracle: y = C @ x with C[i, j] = col[(i - j) mod n]."""
    C = circulant_dense(col)
    if transpose:
        C = C.T
    return C @ x


def circulant_matvec_fft_ref(col: Array, x: Array, *, transpose: bool = False) -> Array:
    """O(n log n) FFT oracle (the convolution-theorem path)."""
    n = col.shape[-1]
    spec = jnp.fft.rfft(col)
    if transpose:
        spec = jnp.conj(spec)
    return jnp.fft.irfft(spec * jnp.fft.rfft(x), n=n)

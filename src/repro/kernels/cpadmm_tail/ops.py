"""Jit'd wrapper: shape plumbing (flatten/pad/reshape) around the tail kernel.

The signal layout is whatever trails ``d_diag`` — a flat ``(n,)`` vector on
the single-device path, an ``(n1/p, n2)`` four-step block on the sharded
path.  Per-signal streams may carry leading batch axes; ``pty`` follows the
signal if it is batched (per-signal measurements) and the operator if not
(one P^T y shared by the batch, kept resident like ``d_diag``).
"""

from __future__ import annotations

import functools

import jax

from .kernel import cpadmm_tail_pallas


def interpret_default() -> bool:
    """Pallas execution-mode default shared by every tail='pallas' call
    site (core.solvers, dist.recovery): compiled for real on TPU, interpret
    mode elsewhere (CPU tests) — the repo-wide kernel convention."""
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_cpadmm_tail(
    x, cx, d_diag, pty, mu, nu, rho, gamma, tau1, tau2, *, interpret: bool = True
):
    """(v, z, mu', nu') = fused Alg. 3 tail; shapes follow ``x``.

    ``d_diag`` defines the signal block shape S (its full shape); ``x``,
    ``cx``, ``mu``, ``nu`` are ``batch + S``; ``pty`` is either S (shared)
    or ``batch + S`` (per-signal).  ``gamma`` is alpha / sigma.
    """
    sig_shape = d_diag.shape
    batch = x.shape[: x.ndim - len(sig_shape)]
    L = 1
    for s in sig_shape:
        L *= s
    flat_sig = (-1, L) if batch else (L,)
    pty_batched = pty.ndim > len(sig_shape)
    v, z, mu_new, nu_new = cpadmm_tail_pallas(
        d_diag.reshape(L),
        pty.reshape(flat_sig if pty_batched else (L,)),
        x.reshape(flat_sig),
        cx.reshape(flat_sig),
        mu.reshape(flat_sig),
        nu.reshape(flat_sig),
        rho,
        gamma,
        tau1,
        tau2,
        pty_batched=pty_batched,
        interpret=interpret,
    )
    back = lambda a: a.reshape(batch + sig_shape)
    return back(v), back(z), back(mu_new), back(nu_new)

"""Pallas TPU kernel: fused CPADMM iteration tail (one VMEM-resident pass).

After the two circulant applies of an iteration (x and Cx), everything left
in Alg. 3 is elementwise:

    v   = d * (pty + rho * (cx - mu))
    z   = eta_gamma(x + nu)
    mu' = mu + tau1 * (v - cx)
    nu' = nu + tau2 * (x - z)

Run as separate XLA ops this is 4 kernel launches reading ~10 operand
streams from HBM; the paper's Sec. 5 motivation for merging GPU kernels
applies unchanged, so here the whole tail is one Pallas pass: six input
streams tiled through VMEM once, four outputs written once, all
intermediates (v, z) living only in registers/VMEM.

Layout mirrors ``spectral_pointwise``: 1-D tiles over the flattened signal
block, a leading batch axis (B signals through one operator) as the outer
grid dimension.  The *operator* streams — d_diag always, pty when it is
shared across the batch (one measurement mask, B signals) — stay resident
per column-tile while the per-signal streams sweep past them.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 1024


def _eta(v, gamma):
    return jnp.sign(v) * jnp.maximum(jnp.abs(v) - gamma, 0.0)


def _kernel(
    d_ref, pty_ref, x_ref, cx_ref, mu_ref, nu_ref,
    rho_ref, gam_ref, t1_ref, t2_ref,
    v_ref, z_ref, mu_out_ref, nu_out_ref,
):
    x, cx = x_ref[...], cx_ref[...]
    mu, nu = mu_ref[...], nu_ref[...]
    v = d_ref[...] * (pty_ref[...] + rho_ref[0] * (cx - mu))
    z = _eta(x + nu, gam_ref[0])
    v_ref[...] = v
    z_ref[...] = z
    mu_out_ref[...] = mu + t1_ref[0] * (v - cx)
    nu_out_ref[...] = nu + t2_ref[0] * (x - z)


@functools.partial(jax.jit, static_argnames=("pty_batched", "block", "interpret"))
def cpadmm_tail_pallas(
    d_diag: jax.Array,  # (L,) operator stream, shared across the batch
    pty: jax.Array,  # (L,) shared or (B, L) per-signal (see pty_batched)
    x: jax.Array,  # (B, L) or (L,) per-signal streams
    cx: jax.Array,
    mu: jax.Array,
    nu: jax.Array,
    rho: jax.Array,
    gamma: jax.Array,  # alpha / sigma
    tau1: jax.Array,
    tau2: jax.Array,
    *,
    pty_batched: bool = False,
    block: int = DEFAULT_BLOCK,
    interpret: bool = True,
):
    """-> (v, z, mu', nu') with the shape of ``x``.

    Streams are 1-D (flattened signal block) with an optional leading batch
    axis on the per-signal streams; ``d_diag`` (and ``pty`` unless
    ``pty_batched``) are length-L operator vectors reused across the batch.
    """
    L = x.shape[-1]
    pad = (-L) % block
    if pad:
        pads = lambda a: jnp.pad(a, [(0, 0)] * (a.ndim - 1) + [(0, pad)])
        d_diag, pty = pads(d_diag), pads(pty)
        x, cx, mu, nu = pads(x), pads(cx), pads(mu), pads(nu)
    n = x.shape[-1]
    dt = x.dtype
    scal = lambda s: jnp.broadcast_to(jnp.asarray(s, dt), (1,))
    rho, gamma, tau1, tau2 = scal(rho), scal(gamma), scal(tau1), scal(tau2)
    batched = x.ndim == 2
    if batched:
        bsz = x.shape[0]
        grid = (bsz, n // block)
        # operator streams: resident per column-tile, reused across the batch
        tile_op = pl.BlockSpec((block,), lambda b, i: i)
        tile_sig = pl.BlockSpec((1, block), lambda b, i: (b, i))
        scalar = pl.BlockSpec((1,), lambda b, i: 0)
        out_shape = (bsz, n)
    else:
        grid = (n // block,)
        tile_op = pl.BlockSpec((block,), lambda i: i)
        tile_sig = tile_op
        scalar = pl.BlockSpec((1,), lambda i: 0)
        out_shape = (n,)
    tile_pty = tile_sig if pty_batched else tile_op
    outs = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[tile_op, tile_pty] + [tile_sig] * 4 + [scalar] * 4,
        out_specs=[tile_sig] * 4,
        out_shape=[jax.ShapeDtypeStruct(out_shape, dt)] * 4,
        interpret=interpret,
    )(d_diag, pty, x, cx, mu, nu, rho, gamma, tau1, tau2)
    return tuple(o[..., :L] for o in outs)

"""Pure-jnp oracle for the fused CPADMM iteration tail.

Same math as ``repro.core.admm.cpadmm_tail`` with the scalars unpacked, so
the kernel parity tests don't need a params tuple.
"""

from __future__ import annotations

import jax.numpy as jnp


def _eta(v, gamma):
    return jnp.sign(v) * jnp.maximum(jnp.abs(v) - gamma, 0.0)


def cpadmm_tail_ref(x, cx, d_diag, pty, mu, nu, rho, gamma, tau1, tau2):
    """(v, z, mu', nu') — the Alg. 3 elementwise tail after x and Cx.

    v   = D (P^T y + rho (Cx - mu))
    z   = eta_gamma(x + nu)           with gamma = alpha / sigma
    mu' = mu + tau1 (v - Cx)
    nu' = nu + tau2 (x - z)
    """
    v = d_diag * (pty + rho * (cx - mu))
    z = _eta(x + nu, gamma)
    mu_new = mu + tau1 * (v - cx)
    nu_new = nu + tau2 * (x - z)
    return v, z, mu_new, nu_new

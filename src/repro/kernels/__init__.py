"""Pallas TPU kernels (TPU target; interpret=True validated on CPU).

Paper hot spots: circulant_matvec (Algs. 4-8), soft_threshold (Eq. 4 fused),
spectral_pointwise (CPADMM freq-domain update), cpadmm_tail (the whole
elementwise iteration tail in one VMEM pass), banded_conv (Sec. 7 blur).
LM substrate: flash_attention (identified by the roofline analysis).
Each subpackage: kernel.py (pallas_call + BlockSpec) + ops.py + ref.py.
"""

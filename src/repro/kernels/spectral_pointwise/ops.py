"""Jit'd wrapper: complex <-> (real, imag) plane plumbing around the kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import cpadmm_spectral_update


@functools.partial(jax.jit, static_argnames=("interpret",))
def spectral_update(c_spec, b_spec, vm_spec, zn_spec, rho, sigma, *, interpret=True):
    """Complex-typed public API; internally runs the plane-split Pallas kernel."""
    xr, xi = cpadmm_spectral_update(
        jnp.real(c_spec),
        jnp.imag(c_spec),
        jnp.real(b_spec).astype(jnp.real(c_spec).dtype),
        jnp.real(vm_spec),
        jnp.imag(vm_spec),
        jnp.real(zn_spec),
        jnp.imag(zn_spec),
        rho,
        sigma,
        interpret=interpret,
    )
    return jax.lax.complex(xr, xi)

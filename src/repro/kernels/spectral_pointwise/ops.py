"""Jit'd wrapper: complex <-> (real, imag) plane plumbing around the kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import cpadmm_spectral_update


@functools.partial(jax.jit, static_argnames=("interpret",))
def spectral_update(c_spec, b_spec, vm_spec, zn_spec, rho, sigma, *, interpret=True):
    """Complex-typed public API; internally runs the plane-split Pallas kernel.

    ``c_spec`` / ``b_spec`` are the shared operator spectra (length nf, any
    half-spectrum length — n//2+1, odd n, ...); ``vm_spec`` / ``zn_spec``
    may carry leading batch axes (B signals through one operator), which map
    to the kernel's outer grid dimension.
    """
    batch = vm_spec.shape[:-1]
    nf = vm_spec.shape[-1]
    vm = vm_spec.reshape((-1, nf) if batch else (nf,))
    zn = zn_spec.reshape((-1, nf) if batch else (nf,))
    xr, xi = cpadmm_spectral_update(
        jnp.real(c_spec),
        jnp.imag(c_spec),
        jnp.real(b_spec).astype(jnp.real(c_spec).dtype),
        jnp.real(vm),
        jnp.imag(vm),
        jnp.real(zn),
        jnp.imag(zn),
        rho,
        sigma,
        interpret=interpret,
    )
    out = jax.lax.complex(xr, xi)
    return out.reshape(batch + (nf,))

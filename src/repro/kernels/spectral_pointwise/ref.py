"""Pure-jnp oracle for the fused CPADMM spectral update."""

from __future__ import annotations

import jax.numpy as jnp


def cpadmm_spectral_update_ref(c_spec, b_spec, vm_spec, zn_spec, rho, sigma):
    """Complex-typed reference: X = b * (rho * conj(c) * VM + sigma * ZN)."""
    return b_spec * (rho * jnp.conj(c_spec) * vm_spec + sigma * zn_spec)

"""Pallas TPU kernel: fused frequency-domain pointwise stage of CPADMM.

The CPADMM x-update is x = B (rho C^T (v + mu) + sigma (z - nu)) with both B
and C^T diagonal in the Fourier basis (paper Sec. 4.3).  Between one forward
and one inverse rFFT, the *entire* update is a pointwise complex program:

    X(f) = b(f) * ( rho * conj(c(f)) * VM(f) + sigma * ZN(f) )

where VM = rfft(v + mu), ZN = rfft(z - nu), c = spec(C), b = spec(B) (real).
Fusing it keeps five operand streams in VMEM for a single pass instead of
launching 4 separate elementwise ops over HBM (the paper's motivation for
merging GPU kernels, Sec. 5).

TPU has no complex dtype in Pallas: complex arrays travel as separate
real/imag planes.  All blocks are 1-D tiles of the half-spectrum; a leading
batch axis (B signals through one operator — the batched recovery pipeline)
becomes the outer grid dimension, with the operator spectra c and b staying
resident per column-tile while the per-signal streams sweep past them.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 512


def _kernel(
    cr_ref, ci_ref, b_ref, vmr_ref, vmi_ref, znr_ref, zni_ref, rho_ref, sig_ref,
    or_ref, oi_ref,
):
    # conj(c) * vm  (complex multiply with conjugated first operand)
    cr, ci = cr_ref[...], ci_ref[...]
    vr, vi = vmr_ref[...], vmi_ref[...]
    rho, sig = rho_ref[0], sig_ref[0]
    tr = cr * vr + ci * vi  # Re(conj(c) vm)
    ti = cr * vi - ci * vr  # Im(conj(c) vm)
    xr = rho * tr + sig * znr_ref[...]
    xi = rho * ti + sig * zni_ref[...]
    b = b_ref[...]
    or_ref[...] = b * xr
    oi_ref[...] = b * xi


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def cpadmm_spectral_update(
    c_spec_r: jax.Array,
    c_spec_i: jax.Array,
    b_spec: jax.Array,  # real spectrum of B = (rho |c|^2 + sigma)^{-1}
    vm_r: jax.Array,
    vm_i: jax.Array,
    zn_r: jax.Array,
    zn_i: jax.Array,
    rho: jax.Array,
    sigma: jax.Array,
    *,
    block: int = DEFAULT_BLOCK,
    interpret: bool = True,
):
    """-> (X_r, X_i): spectrum of the updated x.

    Operator spectra (c, b) are length-nf vectors; the per-signal streams
    (vm, zn) are (nf,) or batched (B, nf) — one shared operator, B signals.
    """
    nf = c_spec_r.shape[-1]
    pad = (-nf) % block
    if pad:
        pads = lambda a: jnp.pad(a, [(0, 0)] * (a.ndim - 1) + [(0, pad)])
        c_spec_r, c_spec_i, b_spec = pads(c_spec_r), pads(c_spec_i), pads(b_spec)
        vm_r, vm_i, zn_r, zn_i = pads(vm_r), pads(vm_i), pads(zn_r), pads(zn_i)
    n = c_spec_r.shape[-1]
    rho = jnp.broadcast_to(jnp.asarray(rho, b_spec.dtype), (1,))
    sigma = jnp.broadcast_to(jnp.asarray(sigma, b_spec.dtype), (1,))
    batched = vm_r.ndim == 2
    if batched:
        bsz = vm_r.shape[0]
        grid = (bsz, n // block)
        # operator spectra: resident per column-tile, reused across the batch
        tile_op = pl.BlockSpec((block,), lambda b, i: i)
        tile_sig = pl.BlockSpec((1, block), lambda b, i: (b, i))
        scalar = pl.BlockSpec((1,), lambda b, i: 0)
        out_shape = (bsz, n)
    else:
        grid = (n // block,)
        tile_op = pl.BlockSpec((block,), lambda i: i)
        tile_sig = tile_op
        scalar = pl.BlockSpec((1,), lambda i: 0)
        out_shape = (n,)
    out_r, out_i = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[tile_op] * 3 + [tile_sig] * 4 + [scalar, scalar],
        out_specs=[tile_sig, tile_sig],
        out_shape=[
            jax.ShapeDtypeStruct(out_shape, b_spec.dtype),
            jax.ShapeDtypeStruct(out_shape, b_spec.dtype),
        ],
        interpret=interpret,
    )(c_spec_r, c_spec_i, b_spec, vm_r, vm_i, zn_r, zn_i, rho, sigma)
    return out_r[..., :nf], out_i[..., :nf]

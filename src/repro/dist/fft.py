"""Distributed four-step FFT: one transpose-collective per transform.

The length-``n`` DFT of the paper's circulant operators is decomposed over
``n = n1 x n2`` (Bailey's four-step algorithm), laid out as an ``(n1, n2)``
matrix ``A[j1, j2] = x[j1 + n1*j2]`` and sharded *row-wise* over the mesh's
model axis.  One forward transform is then

    1. local FFT of length n2 along the rows (axis -1),
    2. local twiddle multiply  W_n^{j1*k2},
    3. one all-to-all transpose-collective (rows -> columns), and
    4. local FFT of length n1 along the columns (axis -2),

yielding the full spectrum ``F[k1, k2] = X[n2*k1 + k2]`` sharded
*column-wise*.  This is the layout contract used across ``repro.dist``:

    time / signal domain   (..., n1, n2) real     P(model, None)   "rows"
    frequency domain       (..., n1, n2) complex  P(None, model)   "cols"

A distributed circulant matvec (paper Sec. 4: ``C x = F^H diag(spec) F x``)
is therefore forward FFT -> *local* pointwise spectrum multiply -> inverse
FFT: exactly two transpose-collectives and zero other communication, the
property the per-device hot path of the paper's GPU kernels needs to survive
sharding (see kernels/banded_conv/kernel.py for the O(nL) banded variant).

Half-spectrum (rfft) variant
----------------------------
Every operator in the paper is real, so the full complex spectrum is
redundant: ``X[n - k] = conj(X[k])``.  In the (n1, n2) layout that symmetry
pairs ``F[k1, k2]`` with ``conj(F[n1-1-k1, n2-k2])`` (k2 >= 1), which means
the column block ``k2 in [0, n2//2]`` determines everything.  The rfft
four-step path (:func:`rfft2_local` / :func:`irfft2_local`) therefore

    1. takes a *real* rfft of length n2 along the rows (half the flops),
    2. twiddles only the kept ``nf = n2//2 + 1`` columns,
    3. moves only those columns through the all-to-all (half the wire
       bytes; the column count is zero-padded to a multiple of the mesh
       size so any device count works), and
    4. runs the length-n1 column FFT on half as many columns.

The half spectrum lives as ``(..., n1, pad(nf))`` complex, column-sharded —
same sharding contract as the full path, half the frequency axis.  All the
Hermitian bookkeeping (which bins are kept, how the discarded half is
reconstructed) is done here once: :func:`half_to_full` materializes the full
spectrum for verification, and the pointwise-multiply identity "Hermitian x
Hermitian = Hermitian" is what lets solvers stay in the half layout
end to end.

Everything operates on the trailing two axes and broadcasts over leading
batch axes — a leading batch axis sharded over the mesh's *data* axis rides
the same single all-to-all per transform, so B signals share one collective
(see make_distributed_rfft / repro.dist.recovery.make_dist_cpadmm).

Overlapped chunked transpose (``overlap=K``)
--------------------------------------------
The monolithic transform serializes [local FFT+twiddle] -> [all-to-all] ->
[local FFT]: the wire sits idle while the flops run and vice versa.  With
``overlap=K`` the *non-split* axis of the transpose is cut into K chunks and
each chunk's all-to-all is issued as soon as that chunk's first-stage
FFT+twiddle is done — chunk i's collective is in flight while chunk i+1's
local stage runs, so XLA's async collective scheduler can hide up to
(K-1)/K of the wire time behind the first-stage compute.

The chunk axis is deliberately the axis the all-to-all does *not* split
(rows for the forward transform, spectrum columns for the inverse): every
chunk's collective then delivers bytes to the same device it would land on
monolithically, and reassembling the K chunk outputs into the monolithic
layout is a purely local reshape/transpose (``_gather_fwd_chunks`` /
``_gather_inv_chunks``).  Chunks are zero-padded to equal size so any K
works on odd extents; the pad rows/columns are sliced off locally before
the second-stage FFT, so ``overlap=K`` is numerically identical to
``overlap=1`` (same flops on the same data, reordered).

Wire-compressed collectives (``wire_dtype=``)
---------------------------------------------
After rfft's ~2x byte cut the next lever is fewer bytes *per element* on
the wire: with ``wire_dtype='bf16'`` (or ``'fp16'``) every transpose
all-to-all's complex chunk payload is demoted to the wire dtype immediately
before the collective and promoted back to float32 on arrival
(:func:`_wire_all_to_all`).  Packing is split-complex — demoted (re, im)
planes stacked on a new *leading* axis (``repro.kernels.wire_pack``), so
the trailing split/concat axes of the collective are untouched and each
plane stays contiguous on the wire.  All

    twiddle multiplies, FFT stages, and accumulation stay float32 locally,

so quantization error enters exactly once per collective and never
compounds across the K overlap chunks; ``wire_dtype='fp32'`` is the
bit-exact legacy path (no pack at all).  The plan layer guards the lossy
dtypes with an error-controlled fp32 fallback (repro.ops.plan).

Hierarchical two-stage transpose (``axis_name=(host, device)``, ``hier=``)
--------------------------------------------------------------------------
On a multi-host mesh the transform axis factors as p = H x D over a
``(host, device)`` mesh-axis pair (``repro.dist.compat.make_hier_mesh``):
the slow DCN links sit between hosts, the fast ICI tier within one.  A flat
all-to-all over the factored axis (``hier=False``) pushes the *entire*
block through the host boundary; the two-stage exchange (``hier=True``)
restructures the same permutation so only the cross-boundary fraction ever
touches DCN:

    1. intra-host all-to-all over the device tier (full block bytes, fast
       ICI only),
    2. a purely local reshuffle ordering the received sub-blocks by their
       absolute source rank, and
    3. H-1 rotation ``ppermute`` hops over the host tier, each carrying
       exactly 1/H of the flat payload — the sub-block destined for the
       local host never enters a collective at all.

Total inter-host bytes are (H-1)/H of the flat collective's (1/2 at H=2),
and the result is bit-identical to the flat exchange — the two stages
compose the same global permutation, so every downstream consumer (overlap
chunk gathering, rfft padding, the solver steps) is unchanged.  The
transform axis is sharded *device-major* (``P((device, host))``: device
(h, d) holds global block r = d*H + h), which is what makes the
intra-host-first ordering correct; :func:`shard_axes` owns that convention.

Per-tier wire precision: ``wire_dtype`` demotes the intra-host all-to-all
payloads exactly as on a flat mesh, and the new ``inter_wire_dtype``
independently demotes the DCN ``ppermute`` hops (e.g. fp32 intra + bf16
inter halves exactly the bytes on the slow tier).  Both default to the
bit-exact ``'fp32'``.
"""

from __future__ import annotations

import functools
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

# split-complex demote/promote around the transpose collectives; WIRE_DTYPES
# is re-exported because this module defines the collective those dtypes
# compress (plan validation and the tuner's candidate space import it here)
from repro.kernels.wire_pack.ops import WIRE_DTYPES, pack_wire, unpack_wire  # noqa: F401

# Hermitian bookkeeping shared with the core circulant algebra — one
# definition in repro.ops.spectral, re-exported here because this module
# defines the (n1, n2) layout those helpers are used against.
from repro.ops.spectral import half_to_full, padded_rfft_len, rfft_len  # noqa: F401

from .compat import shard_map

Array = jax.Array

MODEL_AXIS = "model"  # default mesh axis the signal is sharded over
HOST_AXIS = "host"  # slow-tier (DCN) axis of a hierarchical mesh
DEVICE_AXIS = "device"  # fast-tier (ICI) axis of a hierarchical mesh

# ``axis_name`` across this module is either one mesh-axis name (flat
# transform axis) or a ``(host_axis, device_axis)`` pair (factored
# hierarchical axis, p = H x D).


def shard_axes(axis_name):
    """Mesh axes the transform dimension shards over, major axis first.

    The hierarchical ``(host, device)`` pair shards *device-major* (device
    (h, d) holds global block ``r = d*H + h``): that is the order in which
    an intra-host all-to-all is the correct first stage of the two-stage
    transpose, and the order a flat ``lax.all_to_all`` over the pair must
    use to produce the same result as a single fused axis.
    """
    if isinstance(axis_name, str):
        return axis_name
    host, dev = axis_name
    return (dev, host)


def _axis_size(axis_name) -> int:
    return lax.psum(1, shard_axes(axis_name))


def _axis_rank(axis_name):
    """Global rank of this shard on the (possibly factored) transform axis."""
    if isinstance(axis_name, str):
        return lax.axis_index(axis_name)
    host, dev = axis_name
    return lax.axis_index(dev) * lax.psum(1, host) + lax.axis_index(host)


# --------------------------------------------------------------------------
# layout: flat <-> (n1, n2)
# --------------------------------------------------------------------------


def layout_2d(x: Array, n1: int, n2: int) -> Array:
    """Flat signal (..., n) -> four-step layout (..., n1, n2).

    ``A[j1, j2] = x[j1 + n1*j2]``: consecutive samples run down the columns,
    so row-sharding A gives every device a strided 1/p subset of the signal.
    """
    a = x.reshape(x.shape[:-1] + (n2, n1))
    return jnp.swapaxes(a, -1, -2)


def unlayout_2d(a: Array) -> Array:
    """Inverse of :func:`layout_2d`: (..., n1, n2) -> (..., n)."""
    n1, n2 = a.shape[-2], a.shape[-1]
    return jnp.swapaxes(a, -1, -2).reshape(a.shape[:-2] + (n1 * n2,))


def freq_flat(F: Array) -> Array:
    """Spectrum layout -> natural DFT order: ``X[n2*k1 + k2] = F[k1, k2]``.

    For the four-step output this is a plain row-major reshape.
    """
    return F.reshape(F.shape[:-2] + (F.shape[-2] * F.shape[-1],))


# --------------------------------------------------------------------------
# per-shard transforms (call inside shard_map; `axis_name` is the mesh axis)
# --------------------------------------------------------------------------


def _phase(num: Array, n) -> Array:
    """exp(-2*pi*i * num / n) with the integer exponent reduced mod n first
    (keeps float32 phase accurate for large n1*n2 products)."""
    ang = (-2.0 * jnp.pi) * ((num % n).astype(jnp.float32) / n)
    return lax.complex(jnp.cos(ang), jnp.sin(ang))


def _chunk_grid(extent: int, overlap: int) -> Tuple[int, int]:
    """(chunk_size, n_chunks) cutting ``extent`` items into ~``overlap``
    equal chunks (the last one zero-padded up to chunk_size by the caller).
    """
    k = max(1, min(int(overlap), extent))
    cs = -(-extent // k)
    return cs, -(-extent // cs)


def _pad_to(x: Array, size: int, axis: int) -> Array:
    if x.shape[axis] == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, size - x.shape[axis])
    return jnp.pad(x, pads)


def _wire_all_to_all(
    t: Array, axis_name: str, split_off: int, concat_off: int, wire_dtype: str
) -> Array:
    """One transpose all-to-all with the payload demoted to the wire dtype.

    ``split_off``/``concat_off`` index from the *end* (1 = trailing axis):
    packing adds a leading (re, im) plane axis, so end-relative axes are the
    same for the packed and unpacked payloads and the plane axis rides the
    collective like a batch axis.  ``'fp32'`` is the bit-exact direct send.

    The demoted planes cross the wire *bitcast to uint16*: backends without
    native 16-bit-float support (e.g. CPU) run a float-normalization pass
    that silently promotes bf16/fp16 collectives back to f32 — an integer
    payload is never touched, so the 2-byte wire survives on every backend
    (and the bitcast is free where bf16 is native).
    """
    if wire_dtype == "fp32":
        return lax.all_to_all(
            t, axis_name, split_axis=t.ndim - split_off,
            concat_axis=t.ndim - concat_off, tiled=True,
        )
    w = pack_wire(t, wire_dtype)
    u = lax.bitcast_convert_type(w, jnp.uint16)
    u = lax.all_to_all(
        u, axis_name, split_axis=u.ndim - split_off,
        concat_axis=u.ndim - concat_off, tiled=True,
    )
    w = lax.bitcast_convert_type(u, WIRE_DTYPES[wire_dtype])
    return unpack_wire(w, t.dtype)


def _wire_ppermute(t: Array, axis_name: str, perm, wire_dtype: str) -> Array:
    """One inter-host ``ppermute`` hop with the payload demoted to the wire
    dtype — the ppermute twin of :func:`_wire_all_to_all` (same split-complex
    pack, same uint16 bitcast so the 2-byte wire survives XLA:CPU float
    normalization; ``'fp32'`` is the bit-exact direct send)."""
    if wire_dtype == "fp32":
        return lax.ppermute(t, axis_name, perm)
    w = pack_wire(t, wire_dtype)
    u = lax.bitcast_convert_type(w, jnp.uint16)
    u = lax.ppermute(u, axis_name, perm)
    w = lax.bitcast_convert_type(u, WIRE_DTYPES[wire_dtype])
    return unpack_wire(w, t.dtype)


def _hier_reorder(pieces, h):
    """Order received hop pieces by absolute source host and stack them.

    ``pieces[k]`` came from host ``(h - k) % H`` (k = 0 is the local
    sub-block).  A static flip (``R'[j] = R[(-j) % H]``) followed by a roll
    by the traced host index ``h`` yields source-host order — jnp.roll is
    the one reindexing primitive that takes a traced shift.
    """
    st = jnp.stack(pieces, axis=-3)  # (..., H (hop k), rows, cols)
    flip = jnp.concatenate([st[..., :1, :, :], st[..., :0:-1, :, :]], axis=-3)
    return jnp.roll(flip, h, axis=-3)  # (..., H (source host h'), rows, cols)


def _hier_fwd_exchange(
    t: Array, axis_name, wire_dtype: str, inter_wire_dtype: str
) -> Array:
    """Two-stage forward transpose: (..., cs, W) -> (..., p*cs, W/p), equal
    bit-for-bit (at fp32 wires) to the flat all-to-all over the factored
    axis.  Stage 1 is a full intra-host all-to-all on the device tier;
    stage 2 sends only the H-1 cross-host sub-blocks, each 1/H of the flat
    payload, as rotation ppermutes on the host tier (module docstring)."""
    host, dev = axis_name
    H = lax.psum(1, host)
    D = lax.psum(1, dev)
    h = lax.axis_index(host)
    a = _wire_all_to_all(t, dev, 1, 2, wire_dtype)  # (..., D*cs, W/D)
    wsub = a.shape[-1] // H
    # the sub-block staying on this host is sliced out locally — never wired
    pieces = [lax.dynamic_slice_in_dim(a, h * wsub, wsub, axis=-1)]
    for k in range(1, H):
        send = lax.dynamic_slice_in_dim(a, ((h + k) % H) * wsub, wsub, axis=-1)
        perm = [(s, (s + k) % H) for s in range(H)]
        pieces.append(_wire_ppermute(send, host, perm, inter_wire_dtype))
    T = _hier_reorder(pieces, h)  # (..., H, D*cs, wsub)
    Dcs, w = T.shape[-2], T.shape[-1]
    cs = Dcs // D
    T = T.reshape(T.shape[:-3] + (H, D, cs, w))
    T = jnp.swapaxes(T, -4, -3)  # (..., D, H, cs, w): flat rank r = d*H + h
    return T.reshape(T.shape[:-4] + (D * H * cs, w))


def _hier_inv_exchange(
    t: Array, axis_name, wire_dtype: str, inter_wire_dtype: str
) -> Array:
    """Two-stage inverse transpose: (..., n1, cs) -> (..., n1/p, p*cs); the
    mirror of :func:`_hier_fwd_exchange` with the roles of the split and
    concat axes swapped (rows cross the wire, columns concatenate)."""
    host, dev = axis_name
    H = lax.psum(1, host)
    D = lax.psum(1, dev)
    h = lax.axis_index(host)
    a = _wire_all_to_all(t, dev, 2, 1, wire_dtype)  # (..., n1/D, D*cs)
    rsub = a.shape[-2] // H
    pieces = [lax.dynamic_slice_in_dim(a, h * rsub, rsub, axis=-2)]
    for k in range(1, H):
        send = lax.dynamic_slice_in_dim(a, ((h + k) % H) * rsub, rsub, axis=-2)
        perm = [(s, (s + k) % H) for s in range(H)]
        pieces.append(_wire_ppermute(send, host, perm, inter_wire_dtype))
    T = _hier_reorder(pieces, h)  # (..., H, n1/p, D*cs)
    r, Dcs = T.shape[-2], T.shape[-1]
    cs = Dcs // D
    T = T.reshape(T.shape[:-1] + (D, cs))  # (..., H, r, D, cs)
    T = jnp.moveaxis(T, -4, -2)  # (..., r, D, H, cs): columns rank-ordered
    return T.reshape(T.shape[:-3] + (D * H * cs,))


def _fwd_exchange(
    t: Array, axis_name, wire_dtype: str, hier: bool, inter_wire_dtype: str
) -> Array:
    if hier and not isinstance(axis_name, str):
        return _hier_fwd_exchange(t, axis_name, wire_dtype, inter_wire_dtype)
    return _wire_all_to_all(t, shard_axes(axis_name), 1, 2, wire_dtype)


def _inv_exchange(
    t: Array, axis_name, wire_dtype: str, hier: bool, inter_wire_dtype: str
) -> Array:
    if hier and not isinstance(axis_name, str):
        return _hier_inv_exchange(t, axis_name, wire_dtype, inter_wire_dtype)
    return _wire_all_to_all(t, shard_axes(axis_name), 2, 1, wire_dtype)


def _fwd_transpose(
    stage1, a: Array, overlap: int, axis_name: str, wire_dtype: str = "fp32",
    hier: bool = False, inter_wire_dtype: str = "fp32",
) -> Array:
    """Chunked forward transpose-collective with the row axis (-2) chunked.

    ``stage1(chunk, r0)`` maps a row chunk (rows [r0, r0+cs) of the local
    block ``a``) to its twiddled first-stage output (..., cs, W) with W
    divisible by the axis size.  Returns the assembled (..., p*n1_loc, W/p)
    block, identical to the monolithic all-to-all output.  Each chunk's
    collective depends only on that chunk's stage-1 compute, so chunk i's
    all-to-all can fly while chunk i+1's FFT+twiddle runs.  ``wire_dtype``
    selects the payload precision of every chunk collective.
    """
    n1_loc = a.shape[-2]
    if overlap <= 1:
        b = stage1(a, 0)
        return _fwd_exchange(b, axis_name, wire_dtype, hier, inter_wire_dtype)
    p = _axis_size(axis_name)
    cs, nch = _chunk_grid(n1_loc, overlap)
    outs = []
    for i in range(nch):
        chunk = _pad_to(a[..., i * cs : min((i + 1) * cs, n1_loc), :], cs, -2)
        t = stage1(chunk, i * cs)  # pad rows are zero; twiddle keeps them zero
        outs.append(
            _fwd_exchange(t, axis_name, wire_dtype, hier, inter_wire_dtype)
        )
    return _gather_fwd_chunks(outs, p, cs, n1_loc)


def _gather_fwd_chunks(outs, p: int, cs: int, n1_loc: int) -> Array:
    """Local reassembly of forward chunk outputs into the monolithic layout.

    Chunk i's all-to-all output (..., p*cs, W/p) holds rows ordered
    device-major (peer d's rows [i*cs, (i+1)*cs) of its local block); the
    monolithic output orders rows device-major over the *full* local row
    range.  Interleave the chunks per device and drop the pad rows.
    """
    w = outs[0].shape[-1]
    st = jnp.stack(outs, axis=-3)  # (..., K, p*cs, w)
    st = st.reshape(st.shape[:-2] + (p, cs, w))  # (..., K, p, cs, w)
    st = jnp.swapaxes(st, -4, -3)  # (..., p, K, cs, w)
    st = st.reshape(st.shape[:-3] + (st.shape[-3] * cs,) + (w,))  # (..., p, K*cs, w)
    st = st[..., :n1_loc, :]  # drop the zero-pad rows (per device)
    return st.reshape(st.shape[:-3] + (p * n1_loc, w))


def _inv_transpose(
    stage1, F: Array, overlap: int, axis_name: str, wire_dtype: str = "fp32",
    hier: bool = False, inter_wire_dtype: str = "fp32",
) -> Array:
    """Chunked inverse transpose-collective with the column axis (-1) chunked.

    ``stage1(chunk, c0)`` maps a column chunk (columns [c0, c0+cs) of the
    local spectrum block ``F``) to its twiddled first-stage output
    (..., n1, cs) with n1 divisible by the axis size.  Returns the assembled
    (..., n1/p, p*C_loc) block, identical to the monolithic output.
    ``wire_dtype`` selects the payload precision of every chunk collective.
    """
    c_loc = F.shape[-1]
    if overlap <= 1:
        b = stage1(F, 0)
        return _inv_exchange(b, axis_name, wire_dtype, hier, inter_wire_dtype)
    p = _axis_size(axis_name)
    cs, nch = _chunk_grid(c_loc, overlap)
    outs = []
    for i in range(nch):
        chunk = _pad_to(F[..., :, i * cs : min((i + 1) * cs, c_loc)], cs, -1)
        t = stage1(chunk, i * cs)  # pad columns are zero and stay zero
        outs.append(
            _inv_exchange(t, axis_name, wire_dtype, hier, inter_wire_dtype)
        )
    return _gather_inv_chunks(outs, p, cs, c_loc)


def _gather_inv_chunks(outs, p: int, cs: int, c_loc: int) -> Array:
    """Local reassembly of inverse chunk outputs into the monolithic layout.

    Chunk i's output (..., n1/p, p*cs) holds columns ordered peer-major
    (peer j's spectrum columns [i*cs, (i+1)*cs)); the monolithic output
    orders columns peer-major over the full local column range.
    """
    st = jnp.stack(outs, axis=-2)  # (..., R, K, p*cs)
    st = st.reshape(st.shape[:-1] + (p, cs))  # (..., R, K, p, cs)
    st = jnp.swapaxes(st, -3, -2)  # (..., R, p, K, cs)
    st = st.reshape(st.shape[:-2] + (st.shape[-2] * cs,))  # (..., R, p, K*cs)
    st = st[..., :c_loc]  # drop the zero-pad columns (per peer)
    return st.reshape(st.shape[:-2] + (p * c_loc,))


def fft2_local(
    a: Array, axis_name: str = MODEL_AXIS, overlap: int = 1,
    wire_dtype: str = "fp32", hier: bool = False,
    inter_wire_dtype: str = "fp32",
) -> Array:
    """Forward four-step FFT of a row-sharded block.

    a: (..., n1/p, n2) complex, rows j1 sharded over ``axis_name`` (one mesh
    axis, or a (host, device) pair — device-major, see :func:`shard_axes`).
    Returns (..., n1, n2/p): the column-sharded spectrum block.
    ``overlap=K`` cuts the rows into K chunks whose transpose-collectives
    overlap the first-stage FFT+twiddle (numerically identical output).
    ``wire_dtype`` demotes the collective payload; ``hier=True`` runs the
    two-stage hierarchical transpose with ``inter_wire_dtype`` on the
    inter-host hops (module docstring).
    """
    p = _axis_size(axis_name)
    idx = _axis_rank(axis_name)
    n1_loc, n2 = a.shape[-2], a.shape[-1]
    n = n1_loc * p * n2

    def stage1(chunk: Array, r0: int) -> Array:
        b = jnp.fft.fft(chunk, axis=-1)  # over j2 (full locally)
        j1 = idx * n1_loc + r0 + jnp.arange(chunk.shape[-2])  # global rows
        k2 = jnp.arange(n2)
        return b * _phase(j1[:, None] * k2[None, :], n)

    b = _fwd_transpose(
        stage1, a, overlap, axis_name, wire_dtype, hier, inter_wire_dtype
    )
    return jnp.fft.fft(b, axis=-2)  # over j1 (full after the transpose)


def ifft2_local(
    F: Array, axis_name: str = MODEL_AXIS, overlap: int = 1,
    wire_dtype: str = "fp32", hier: bool = False,
    inter_wire_dtype: str = "fp32",
) -> Array:
    """Inverse four-step FFT of a column-sharded spectrum block.

    F: (..., n1, n2/p) complex, columns k2 sharded over ``axis_name``.
    Returns (..., n1/p, n2): the row-sharded time-domain block (complex;
    take the real part for real signals).  ``overlap=K`` chunks the columns.
    ``hier``/``inter_wire_dtype`` as in :func:`fft2_local`.
    """
    p = _axis_size(axis_name)
    idx = _axis_rank(axis_name)
    n1, n2_loc = F.shape[-2], F.shape[-1]
    n = n1 * n2_loc * p

    def stage1(chunk: Array, c0: int) -> Array:
        b = jnp.fft.ifft(chunk, axis=-2)  # over k1 (full locally)
        j1 = jnp.arange(n1)
        k2 = idx * n2_loc + c0 + jnp.arange(chunk.shape[-1])  # global columns
        return b * _phase(-(j1[:, None] * k2[None, :]), n)  # conjugate twiddle

    b = _inv_transpose(
        stage1, F, overlap, axis_name, wire_dtype, hier, inter_wire_dtype
    )
    return jnp.fft.ifft(b, axis=-1)  # over k2 (full after the transpose)


def rfft2_local(
    a: Array, axis_name: str = MODEL_AXIS, overlap: int = 1,
    wire_dtype: str = "fp32", hier: bool = False,
    inter_wire_dtype: str = "fp32",
) -> Array:
    """Forward four-step rfft of a row-sharded *real* block.

    a: (..., n1/p, n2) real, rows j1 sharded over ``axis_name``.
    Returns (..., n1, pad(nf)/p) complex: the column-sharded half spectrum
    (kept columns k2 in [0, n2//2], zero-padded to a multiple of p).
    ``overlap=K`` chunks the rows as in :func:`fft2_local`;
    ``hier``/``inter_wire_dtype`` select the two-stage transpose likewise.
    """
    p = _axis_size(axis_name)
    idx = _axis_rank(axis_name)
    n1_loc, n2 = a.shape[-2], a.shape[-1]
    n = n1_loc * p * n2
    nf, nf_pad = rfft_len(n2), padded_rfft_len(n2, p)

    def stage1(chunk: Array, r0: int) -> Array:
        b = jnp.fft.rfft(chunk, axis=-1)  # over j2: real input, half the flops
        j1 = idx * n1_loc + r0 + jnp.arange(chunk.shape[-2])  # global rows
        k2 = jnp.arange(nf)
        b = b * _phase(j1[:, None] * k2[None, :], n)
        return _pad_to(b, nf_pad, -1)

    # transpose-collective on half as many columns: half the wire bytes
    b = _fwd_transpose(
        stage1, a, overlap, axis_name, wire_dtype, hier, inter_wire_dtype
    )
    return jnp.fft.fft(b, axis=-2)  # over j1, on half as many columns


def irfft2_local(
    F: Array, n2: int, axis_name: str = MODEL_AXIS, overlap: int = 1,
    wire_dtype: str = "fp32", hier: bool = False,
    inter_wire_dtype: str = "fp32",
) -> Array:
    """Inverse four-step rfft of a column-sharded half-spectrum block.

    F: (..., n1, pad(nf)/p) complex, kept columns k2 sharded over
    ``axis_name``.  ``n2`` is the full signal column count (static — it is
    not recoverable from the half-spectrum shape).  Returns the row-sharded
    *real* block (..., n1/p, n2).  ``overlap=K`` chunks the kept columns.
    """
    idx = _axis_rank(axis_name)
    n1, nfp_loc = F.shape[-2], F.shape[-1]
    n = n1 * n2
    nf = rfft_len(n2)

    def stage1(chunk: Array, c0: int) -> Array:
        b = jnp.fft.ifft(chunk, axis=-2)  # over k1 (full locally)
        j1 = jnp.arange(n1)
        k2 = idx * nfp_loc + c0 + jnp.arange(chunk.shape[-1])  # global columns
        return b * _phase(-(j1[:, None] * k2[None, :]), n)  # conjugate twiddle

    b = _inv_transpose(
        stage1, F, overlap, axis_name, wire_dtype, hier, inter_wire_dtype
    )
    return jnp.fft.irfft(b[..., :nf], n=n2, axis=-1)  # drop pad, real out


def matvec_local(
    spec: Array,
    x: Array,
    axis_name: str = MODEL_AXIS,
    transpose: bool = False,
    overlap: int = 1,
    wire_dtype: str = "fp32",
    hier: bool = False,
    inter_wire_dtype: str = "fp32",
) -> Array:
    """Sharded circulant matvec on local blocks: irfft(spec * fft(x)).

    spec: column-sharded spectrum block (..., n1, n2/p) — from fft2_local of
    the circulant's first column.  x: row-sharded real block (..., n1/p, n2).
    ``transpose=True`` applies C^T (conjugate spectrum, real circulant).
    """
    f = fft2_local(
        x.astype(spec.dtype), axis_name, overlap, wire_dtype, hier,
        inter_wire_dtype,
    )
    s = jnp.conj(spec) if transpose else spec
    return jnp.real(ifft2_local(
        s * f, axis_name, overlap, wire_dtype, hier, inter_wire_dtype
    ))


def rmatvec_local(
    spec_h: Array,
    x: Array,
    axis_name: str = MODEL_AXIS,
    transpose: bool = False,
    overlap: int = 1,
    wire_dtype: str = "fp32",
    hier: bool = False,
    inter_wire_dtype: str = "fp32",
) -> Array:
    """Half-spectrum circulant matvec: same contract as :func:`matvec_local`
    with ``spec_h`` the column-sharded *half* spectrum from rfft2_local.

    Correct because both operands are spectra of real signals: the pointwise
    product of Hermitian spectra is Hermitian, so the half layout closes
    under the multiply and the inverse transform returns the real result.
    """
    n2 = x.shape[-1]
    f = rfft2_local(x, axis_name, overlap, wire_dtype, hier, inter_wire_dtype)
    s = jnp.conj(spec_h) if transpose else spec_h
    return irfft2_local(
        s * f, n2, axis_name, overlap, wire_dtype, hier, inter_wire_dtype
    )


# --------------------------------------------------------------------------
# global entry points (jitted shard_map wrappers over a concrete mesh)
# --------------------------------------------------------------------------


def row_spec(axis_name=MODEL_AXIS, batch_axis: str | None = None) -> P:
    """Signal-domain spec; with ``batch_axis`` the arrays carry a leading
    batch dimension sharded over the mesh's data axis.  A (host, device)
    ``axis_name`` shards the row axis over both tiers device-major
    (:func:`shard_axes`)."""
    ax = shard_axes(axis_name)
    if batch_axis is not None:
        return P(batch_axis, ax, None)
    return P(ax, None)


def col_spec(axis_name=MODEL_AXIS, batch_axis: str | None = None) -> P:
    ax = shard_axes(axis_name)
    if batch_axis is not None:
        return P(batch_axis, None, ax)
    return P(None, ax)


def make_distributed_fft(
    mesh,
    n1: int,
    n2: int,
    axis_name=MODEL_AXIS,
    batch_axis: str | None = None,
    overlap: int = 1,
    wire_dtype: str = "fp32",
    hier: bool = False,
    inter_wire_dtype: str = "fp32",
) -> Tuple[Callable[[Array], Array], Callable[[Array], Array]]:
    """(fft2d, ifft2d) over global (n1, n2) arrays on ``mesh``.

    fft2d maps a row-sharded layout_2d array to its column-sharded spectrum;
    ifft2d inverts it.  Each costs exactly one all-to-all (``overlap=K``
    splits it into K chunked collectives that overlap the first local FFT
    stage; same payload modulo chunk zero-padding, same result).
    With ``batch_axis`` the arrays are
    (B, n1, n2) with B sharded over that mesh axis — the whole batch shares
    the one collective.  ``wire_dtype`` demotes the collective payload
    (module docstring; 'fp32' is bit-exact).  A (host, device) ``axis_name``
    with ``hier=True`` runs the two-stage hierarchical transpose;
    ``inter_wire_dtype`` demotes only its DCN hops.
    """
    del n1, n2  # shapes are taken from the traced operands

    fwd = jax.jit(
        shard_map(
            functools.partial(
                fft2_local, axis_name=axis_name, overlap=overlap,
                wire_dtype=wire_dtype, hier=hier,
                inter_wire_dtype=inter_wire_dtype,
            ),
            mesh=mesh,
            in_specs=(row_spec(axis_name, batch_axis),),
            out_specs=col_spec(axis_name, batch_axis),
            check_vma=False,
        )
    )
    inv = jax.jit(
        shard_map(
            functools.partial(
                ifft2_local, axis_name=axis_name, overlap=overlap,
                wire_dtype=wire_dtype, hier=hier,
                inter_wire_dtype=inter_wire_dtype,
            ),
            mesh=mesh,
            in_specs=(col_spec(axis_name, batch_axis),),
            out_specs=row_spec(axis_name, batch_axis),
            check_vma=False,
        )
    )
    return fwd, inv


def make_distributed_rfft(
    mesh,
    n1: int,
    n2: int,
    axis_name=MODEL_AXIS,
    batch_axis: str | None = None,
    overlap: int = 1,
    wire_dtype: str = "fp32",
    hier: bool = False,
    inter_wire_dtype: str = "fp32",
) -> Tuple[Callable[[Array], Array], Callable[[Array], Array]]:
    """(rfft2d, irfft2d): half-spectrum transforms over real (n1, n2) arrays.

    rfft2d maps a row-sharded real layout_2d array to its column-sharded
    half spectrum (n1, padded_rfft_len(n2, p)); irfft2d inverts it back to
    the real signal layout.  Same single all-to-all as the full path, at
    half the wire bytes and half the local FFT flops; ``overlap=K`` chunks
    that collective to overlap it with the first FFT stage, ``wire_dtype``
    demotes its payload for another ~2x byte cut.  ``hier=True`` (with a
    (host, device) ``axis_name``) runs the two-stage transpose with
    ``inter_wire_dtype`` on the inter-host hops.
    """
    del n1  # taken from the traced operands; n2 is needed by the inverse

    rfwd = jax.jit(
        shard_map(
            functools.partial(
                rfft2_local, axis_name=axis_name, overlap=overlap,
                wire_dtype=wire_dtype, hier=hier,
                inter_wire_dtype=inter_wire_dtype,
            ),
            mesh=mesh,
            in_specs=(row_spec(axis_name, batch_axis),),
            out_specs=col_spec(axis_name, batch_axis),
            check_vma=False,
        )
    )
    rinv = jax.jit(
        shard_map(
            functools.partial(
                irfft2_local, n2=n2, axis_name=axis_name, overlap=overlap,
                wire_dtype=wire_dtype, hier=hier,
                inter_wire_dtype=inter_wire_dtype,
            ),
            mesh=mesh,
            in_specs=(col_spec(axis_name, batch_axis),),
            out_specs=row_spec(axis_name, batch_axis),
            check_vma=False,
        )
    )
    return rfwd, rinv


def make_distributed_matvec(
    mesh,
    axis_name=MODEL_AXIS,
    rfft: bool = False,
    batch_axis: str | None = None,
    overlap: int = 1,
    wire_dtype: str = "fp32",
    hier: bool = False,
    inter_wire_dtype: str = "fp32",
):
    """Jitted ``mv(spec2d, x2d, transpose=False)`` over global arrays.

    Two all-to-alls per call (forward + inverse transform); the spectrum
    multiply is purely local.  ``rfft=True`` takes the half-spectrum path:
    ``spec2d`` is then the (n1, pad(nf)) half spectrum from
    :func:`make_distributed_rfft`'s forward transform.  ``overlap=K`` runs
    both transforms with the chunked overlapped transpose; ``wire_dtype``
    demotes both collectives' payloads.  ``mv.lower(...)``
    exposes the compiled HLO for the collective-structure assertions in
    tests/dist_progs/fft_prog.py.
    """
    local = rmatvec_local if rfft else matvec_local

    @functools.partial(jax.jit, static_argnums=2)
    def mv(spec2d: Array, x2d: Array, transpose: bool = False) -> Array:
        fn = shard_map(
            functools.partial(
                local, axis_name=axis_name, transpose=transpose,
                overlap=overlap, wire_dtype=wire_dtype, hier=hier,
                inter_wire_dtype=inter_wire_dtype,
            ),
            mesh=mesh,
            in_specs=(col_spec(axis_name), row_spec(axis_name, batch_axis)),
            out_specs=row_spec(axis_name, batch_axis),
            check_vma=False,
        )
        return fn(spec2d, x2d)

    return mv

"""Distributed four-step FFT: one transpose-collective per transform.

The length-``n`` DFT of the paper's circulant operators is decomposed over
``n = n1 x n2`` (Bailey's four-step algorithm), laid out as an ``(n1, n2)``
matrix ``A[j1, j2] = x[j1 + n1*j2]`` and sharded *row-wise* over the mesh's
model axis.  One forward transform is then

    1. local FFT of length n2 along the rows (axis -1),
    2. local twiddle multiply  W_n^{j1*k2},
    3. one all-to-all transpose-collective (rows -> columns), and
    4. local FFT of length n1 along the columns (axis -2),

yielding the full spectrum ``F[k1, k2] = X[n2*k1 + k2]`` sharded
*column-wise*.  This is the layout contract used across ``repro.dist``:

    time / signal domain   (..., n1, n2) real     P(model, None)   "rows"
    frequency domain       (..., n1, n2) complex  P(None, model)   "cols"

A distributed circulant matvec (paper Sec. 4: ``C x = F^H diag(spec) F x``)
is therefore forward FFT -> *local* pointwise spectrum multiply -> inverse
FFT: exactly two transpose-collectives and zero other communication, the
property the per-device hot path of the paper's GPU kernels needs to survive
sharding (see kernels/banded_conv/kernel.py for the O(nL) banded variant).

Everything operates on the trailing two axes and broadcasts over leading
batch axes, so the same step functions serve the single-signal test programs
and the batched production dry-run.
"""

from __future__ import annotations

import functools
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from .compat import shard_map

Array = jax.Array

MODEL_AXIS = "model"  # default mesh axis the signal is sharded over


# --------------------------------------------------------------------------
# layout: flat <-> (n1, n2)
# --------------------------------------------------------------------------


def layout_2d(x: Array, n1: int, n2: int) -> Array:
    """Flat signal (..., n) -> four-step layout (..., n1, n2).

    ``A[j1, j2] = x[j1 + n1*j2]``: consecutive samples run down the columns,
    so row-sharding A gives every device a strided 1/p subset of the signal.
    """
    a = x.reshape(x.shape[:-1] + (n2, n1))
    return jnp.swapaxes(a, -1, -2)


def unlayout_2d(a: Array) -> Array:
    """Inverse of :func:`layout_2d`: (..., n1, n2) -> (..., n)."""
    n1, n2 = a.shape[-2], a.shape[-1]
    return jnp.swapaxes(a, -1, -2).reshape(a.shape[:-2] + (n1 * n2,))


def freq_flat(F: Array) -> Array:
    """Spectrum layout -> natural DFT order: ``X[n2*k1 + k2] = F[k1, k2]``.

    For the four-step output this is a plain row-major reshape.
    """
    return F.reshape(F.shape[:-2] + (F.shape[-2] * F.shape[-1],))


# --------------------------------------------------------------------------
# per-shard transforms (call inside shard_map; `axis_name` is the mesh axis)
# --------------------------------------------------------------------------


def _phase(num: Array, n) -> Array:
    """exp(-2*pi*i * num / n) with the integer exponent reduced mod n first
    (keeps float32 phase accurate for large n1*n2 products)."""
    ang = (-2.0 * jnp.pi) * ((num % n).astype(jnp.float32) / n)
    return lax.complex(jnp.cos(ang), jnp.sin(ang))


def fft2_local(a: Array, axis_name: str = MODEL_AXIS) -> Array:
    """Forward four-step FFT of a row-sharded block.

    a: (..., n1/p, n2) complex, rows j1 sharded over ``axis_name``.
    Returns (..., n1, n2/p): the column-sharded spectrum block.
    """
    p = lax.psum(1, axis_name)
    idx = lax.axis_index(axis_name)
    n1_loc, n2 = a.shape[-2], a.shape[-1]
    n = n1_loc * p * n2

    b = jnp.fft.fft(a, axis=-1)  # over j2 (full locally)
    j1 = idx * n1_loc + jnp.arange(n1_loc)  # global row indices
    k2 = jnp.arange(n2)
    b = b * _phase(j1[:, None] * k2[None, :], n)
    # transpose-collective: split columns, gather rows -> (..., n1, n2/p)
    b = lax.all_to_all(
        b, axis_name, split_axis=b.ndim - 1, concat_axis=b.ndim - 2, tiled=True
    )
    return jnp.fft.fft(b, axis=-2)  # over j1 (full after the transpose)


def ifft2_local(F: Array, axis_name: str = MODEL_AXIS) -> Array:
    """Inverse four-step FFT of a column-sharded spectrum block.

    F: (..., n1, n2/p) complex, columns k2 sharded over ``axis_name``.
    Returns (..., n1/p, n2): the row-sharded time-domain block (complex;
    take the real part for real signals).
    """
    p = lax.psum(1, axis_name)
    idx = lax.axis_index(axis_name)
    n1, n2_loc = F.shape[-2], F.shape[-1]
    n = n1 * n2_loc * p

    b = jnp.fft.ifft(F, axis=-2)  # over k1 (full locally)
    j1 = jnp.arange(n1)
    k2 = idx * n2_loc + jnp.arange(n2_loc)  # global column indices
    b = b * _phase(-(j1[:, None] * k2[None, :]), n)  # conjugate twiddle
    b = lax.all_to_all(
        b, axis_name, split_axis=b.ndim - 2, concat_axis=b.ndim - 1, tiled=True
    )
    return jnp.fft.ifft(b, axis=-1)  # over k2 (full after the transpose)


def matvec_local(
    spec: Array, x: Array, axis_name: str = MODEL_AXIS, transpose: bool = False
) -> Array:
    """Sharded circulant matvec on local blocks: irfft(spec * fft(x)).

    spec: column-sharded spectrum block (..., n1, n2/p) — from fft2_local of
    the circulant's first column.  x: row-sharded real block (..., n1/p, n2).
    ``transpose=True`` applies C^T (conjugate spectrum, real circulant).
    """
    f = fft2_local(x.astype(spec.dtype), axis_name)
    s = jnp.conj(spec) if transpose else spec
    return jnp.real(ifft2_local(s * f, axis_name))


# --------------------------------------------------------------------------
# global entry points (jitted shard_map wrappers over a concrete mesh)
# --------------------------------------------------------------------------


def row_spec(axis_name: str = MODEL_AXIS) -> P:
    return P(axis_name, None)


def col_spec(axis_name: str = MODEL_AXIS) -> P:
    return P(None, axis_name)


def make_distributed_fft(
    mesh, n1: int, n2: int, axis_name: str = MODEL_AXIS
) -> Tuple[Callable[[Array], Array], Callable[[Array], Array]]:
    """(fft2d, ifft2d) over global (n1, n2) arrays on ``mesh``.

    fft2d maps a row-sharded layout_2d array to its column-sharded spectrum;
    ifft2d inverts it.  Each costs exactly one all-to-all.
    """
    del n1, n2  # shapes are taken from the traced operands

    fwd = jax.jit(
        shard_map(
            functools.partial(fft2_local, axis_name=axis_name),
            mesh=mesh,
            in_specs=(row_spec(axis_name),),
            out_specs=col_spec(axis_name),
            check_vma=False,
        )
    )
    inv = jax.jit(
        shard_map(
            functools.partial(ifft2_local, axis_name=axis_name),
            mesh=mesh,
            in_specs=(col_spec(axis_name),),
            out_specs=row_spec(axis_name),
            check_vma=False,
        )
    )
    return fwd, inv


def make_distributed_matvec(mesh, axis_name: str = MODEL_AXIS):
    """Jitted ``mv(spec2d, x2d, transpose=False)`` over global arrays.

    Two all-to-alls per call (forward + inverse transform); the spectrum
    multiply is purely local.  ``mv.lower(...)`` exposes the compiled HLO for
    the collective-structure assertions in tests/dist_progs/fft_prog.py.
    """

    @functools.partial(jax.jit, static_argnums=2)
    def mv(spec2d: Array, x2d: Array, transpose: bool = False) -> Array:
        fn = shard_map(
            functools.partial(matvec_local, axis_name=axis_name, transpose=transpose),
            mesh=mesh,
            in_specs=(col_spec(axis_name), row_spec(axis_name)),
            out_specs=row_spec(axis_name),
            check_vma=False,
        )
        return fn(spec2d, x2d)

    return mv

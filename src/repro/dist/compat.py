"""Version-portable wrappers for the jax sharding API surface we use.

The distributed layer targets the modern spelling (``jax.shard_map`` with
``check_vma``, ``jax.make_mesh(..., axis_types=...)``) but must also run on
jax 0.4.x, where ``shard_map`` lives in ``jax.experimental.shard_map`` with a
``check_rep`` keyword and meshes have no axis types.  Everything that builds
a mesh or wraps a function in shard_map goes through this module so the rest
of ``repro.dist`` (and the subprocess test programs) stays version-agnostic.
"""

from __future__ import annotations

import jax

try:  # jax >= 0.6: top-level export, `check_vma` keyword
    from jax import shard_map as _shard_map

    _KW = "check_vma"
except ImportError:  # jax 0.4.x: experimental module, `check_rep` keyword
    from jax.experimental.shard_map import shard_map as _shard_map

    _KW = "check_rep"

try:  # explicit/auto axis types exist only on newer jax
    from jax.sharding import AxisType  # noqa: F401

    _HAS_AXIS_TYPES = True
except ImportError:
    AxisType = None
    _HAS_AXIS_TYPES = False


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` with the replication-check keyword normalized.

    We default our call sites to ``check_vma=False``: the FFT layer uses
    ``axis_index``-dependent twiddles, which the replication checker cannot
    prove anything useful about.
    """
    kw = {_KW: check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    if _HAS_AXIS_TYPES:
        return jax.make_mesh(
            axis_shapes, axis_names, axis_types=(AxisType.Auto,) * len(axis_names)
        )
    return jax.make_mesh(axis_shapes, axis_names)


def make_hier_mesh(data: int, host: int, device: int):
    """``data x host x device`` mesh for the hierarchical two-stage transpose.

    The transform axis of ``repro.dist.fft`` factors over the
    ``("host", "device")`` pair (p = host * device, device-major sharding —
    see ``fft.shard_axes``); a leading batch of signals shards over
    ``"data"`` exactly as on a flat mesh.  Axis order follows jax's
    convention that later mesh axes are nearer neighbors: the device tier
    (fast ICI) is innermost, hosts (slow DCN) outside it, so the
    ``host * device`` consecutive devices of one data slice group into
    ``host`` contiguous fast-tier islands.
    """
    return make_mesh((data, host, device), ("data", "host", "device"))

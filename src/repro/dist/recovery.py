"""Planned CPADMM step functions (paper Alg. 3) over the sharded four-step FFT.

This module holds the *per-iteration math* of distributed CPADMM and
nothing else: the solver drivers live in ``repro.core.solvers`` and reach
these steps through an execution plan (``repro.ops.plan(op, mesh)``), which
is also how distributed CPISTA/FISTA run — same drivers, planned matvecs.
``make_dist_cpadmm`` remains only as a deprecation shim over that API.

The single-device solver (``repro.core.admm.cpadmm_step``) does per
iteration three circulant applications — C^T, B = (rho C^T C + sigma I)^{-1}
and C — i.e. six length-n transforms, plus elementwise work.  Here the same
iteration runs with every array sharded in the :mod:`repro.dist.fft` layout:

    spectra  (spec of C, spec of B)      column-sharded  P(None, model)
    iterates (x, v, z, mu, nu), d_diag,
    P^T y                                row-sharded     P(model, None)

The Woodbury/spectral inverse B never leaves the frequency domain: its
spectrum is elementwise ``1 / (rho |spec|^2 + sigma)`` computed on the local
column block, so the x-update's "inversion" stays a pointwise multiply per
device — Andrecut-style: the per-device hot path is pointwise spectral ops,
all cross-device traffic is the FFT transpose-collective.

Two step variants:

    dist_cpadmm_step        paper-faithful: 3 separate circulant applies,
                            6 transforms = 6 all-to-alls per iteration.
    dist_cpadmm_step_fused  the x-update is formed directly in the frequency
                            domain (B and C^T fuse into one local spectral
                            multiply — Alg. 3 line 2 never materializes
                            C^T(v+mu) in the time domain) and the remaining
                            transforms are batched: one stacked forward FFT
                            (v+mu, z-nu) and one stacked inverse FFT
                            (x, Cx), so an iteration costs 2 all-to-alls
                            instead of 6.  The soft-threshold and both dual
                            updates collapse into a single elementwise pass.

Both steps take ``rfft=True`` to run on the half-spectrum transforms of
:mod:`repro.dist.fft` (real iterates, Hermitian spectra): half the local FFT
flops and half the all-to-all wire bytes per iteration, same all-to-all
count.  The spectra (``spec``, ``b_spec``) must then be in the half layout
(from ``make_dist_spectrum(..., rfft=True)``).

Batching over the data axis: every step broadcasts over leading batch axes,
and ``make_dist_cpadmm(..., batch_axis='data')`` shards a leading batch of B
signals over the mesh's data axis while the model axis keeps the within-
signal FFT sharding — all B signals share each transform's single
all-to-all, which is the Andrecut-style many-signals-at-once form of the
paper's workload.

Two iteration-critical-path knobs ride every step:

    overlap=K   each transform's transpose-collective is split into K
                chunked all-to-alls overlapped with the first local FFT
                stage (repro.dist.fft docstring) — same payload (pad bytes
                only when K does not divide the chunk axis), same result,
                up to (K-1)/K of the wire hidden behind compute.
    tail        'jnp' (default) keeps the elementwise tail as XLA-fused
                jnp ops; 'pallas' routes it through the fused
                kernels/cpadmm_tail VMEM-resident kernel (one pass for the
                v-update, soft-threshold, and both dual updates).

Both agree with the single-device solver to float32 roundoff on the same
problem (tests/test_dist_equiv.py, tests/dist_progs/recovery_prog.py,
tests/dist_progs/batched_recovery_prog.py).
"""

from __future__ import annotations

import warnings
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.admm import cpadmm_tail

from .compat import shard_map
from .fft import (
    MODEL_AXIS,
    col_spec,
    fft2_local,
    ifft2_local,
    irfft2_local,
    layout_2d,
    rfft2_local,
    row_spec,
    unlayout_2d,
)

Array = jax.Array


def _transforms(
    rfft: bool, n2: int, cdtype, axis_name: str, overlap: int = 1,
    wire_dtype: str = "fp32", hier: bool = False,
    inter_wire_dtype: str = "fp32",
):
    """(forward, inverse) local transform pair: real block <-> spectrum block.

    The full-complex pair casts to the spectrum dtype and takes the real
    part on the way back; the rfft pair stays real-in/real-out in the half
    layout (``n2`` is the full column count the half spectrum unfolds to).
    ``overlap`` selects the chunked overlapped transpose in both directions;
    ``wire_dtype`` demotes each transpose's all-to-all payload on the wire
    (twiddles and accumulation stay fp32 locally — repro.dist.fft).
    ``hier`` (with a (host, device) ``axis_name``) runs each transpose as
    the two-stage hierarchical exchange, ``inter_wire_dtype`` demoting only
    its inter-host hops.
    """
    if rfft:
        fwd = lambda r: rfft2_local(
            r, axis_name, overlap, wire_dtype, hier, inter_wire_dtype
        )
        inv = lambda F: irfft2_local(
            F, n2, axis_name, overlap, wire_dtype, hier, inter_wire_dtype
        )
    else:
        fwd = lambda r: fft2_local(
            r.astype(cdtype), axis_name, overlap, wire_dtype, hier,
            inter_wire_dtype,
        )
        inv = lambda F: jnp.real(ifft2_local(
            F, axis_name, overlap, wire_dtype, hier, inter_wire_dtype
        ))
    return fwd, inv


def _tail(tail: str, prox=None):
    """Elementwise-tail dispatch: pure-jnp math or the fused Pallas kernel.

    The Pallas path compiles for real on TPU and falls back to interpret
    mode elsewhere (CPU tests), mirroring the repo-wide kernel convention.
    The fused kernel bakes in the l1 soft threshold, so it is only taken
    when ``is_l1(prox)``; any other elementwise prior composes through the
    shared jnp tail (``core.admm.cpadmm_tail``) with the prox threaded in.
    (Non-elementwise priors never reach here — the plan layer runs them at
    the global level via :func:`dist_cpadmm_core`.)
    """
    from repro.ops.prox import is_l1

    if tail == "pallas" and is_l1(prox):
        from repro.kernels.cpadmm_tail.ops import fused_cpadmm_tail, interpret_default

        interpret = interpret_default()

        def run(x, cx, d_diag, pty, mu, nu, p):
            return fused_cpadmm_tail(
                x, cx, d_diag, pty, mu, nu,
                p.rho, p.alpha / p.sigma, p.tau1, p.tau2,
                interpret=interpret,
            )

        return run
    if tail not in ("jnp", "pallas"):
        raise ValueError(f"tail must be 'jnp' or 'pallas', got {tail!r}")
    if prox is None:
        return cpadmm_tail

    def run(x, cx, d_diag, pty, mu, nu, p):
        return cpadmm_tail(x, cx, d_diag, pty, mu, nu, p, prox=prox)

    return run


class DistCpadmmParams(NamedTuple):
    """Alg. 3 hyperparameters (same meaning as core.admm.CpadmmParams)."""

    alpha: Array  # l1 weight
    rho: Array  # splitting weight for v = C x
    sigma: Array  # splitting weight for z = x
    tau1: Array  # dual step for mu
    tau2: Array  # dual step for nu


class DistCpadmmState(NamedTuple):
    """Row-sharded iterates, all in the (..., n1, n2) signal layout."""

    x: Array  # primal estimate
    v: Array  # splitting variable, v ~= C x
    z: Array  # l1 auxiliary (the recovered signal)
    mu: Array  # scaled dual for v = C x
    nu: Array  # scaled dual for z = x


def dist_cpadmm_step(
    spec: Array,
    b_spec: Array,
    d_diag: Array,
    pty: Array,
    state: DistCpadmmState,
    p: DistCpadmmParams,
    axis_name: str = MODEL_AXIS,
    rfft: bool = False,
    overlap: int = 1,
    tail: str = "jnp",
    wire_dtype: str = "fp32",
    hier: bool = False,
    inter_wire_dtype: str = "fp32",
    prox=None,
) -> DistCpadmmState:
    """One paper-faithful Alg. 3 iteration on local shard blocks.

    spec / b_spec: column-sharded spectra of C and B (half layout when
    ``rfft``).  d_diag: row-sharded diagonal of (P^T P + rho I)^{-1}.
    pty: row-sharded P^T y.  Mirrors ``core.admm.cpadmm_step`` line for
    line; broadcasts over leading batch axes.  ``prox`` must be elementwise
    (this step runs whole inside a shard_map — see :func:`_tail`).
    """
    fwd, inv = _transforms(
        rfft, state.x.shape[-1], spec.dtype, axis_name, overlap, wire_dtype,
        hier, inter_wire_dtype,
    )
    tail_fn = _tail(tail, prox)

    def apply(s: Array, r: Array) -> Array:
        return inv(s * fwd(r))

    # x-update: B (rho C^T (v + mu) + sigma (z - nu))
    rhs = p.rho * apply(jnp.conj(spec), state.v + state.mu) + p.sigma * (
        state.z - state.nu
    )
    x = apply(b_spec, rhs)
    cx = apply(spec, x)
    # elementwise tail: v-update, threshold, both dual updates
    v, z, mu, nu = tail_fn(x, cx, d_diag, pty, state.mu, state.nu, p)
    return DistCpadmmState(x=x, v=v, z=z, mu=mu, nu=nu)


def dist_cpadmm_step_fused(
    spec: Array,
    b_spec: Array,
    d_diag: Array,
    pty: Array,
    state: DistCpadmmState,
    p: DistCpadmmParams,
    axis_name: str = MODEL_AXIS,
    rfft: bool = False,
    overlap: int = 1,
    tail: str = "jnp",
    wire_dtype: str = "fp32",
    hier: bool = False,
    inter_wire_dtype: str = "fp32",
    prox=None,
) -> DistCpadmmState:
    """Fused Alg. 3 iteration: 2 all-to-alls, one elementwise tail.

    The two forward transforms (of v+mu and z-nu) ride one stacked FFT; the
    x-update happens entirely in the frequency domain (B and C^T fuse to one
    local multiply); x and Cx come back through one stacked inverse FFT; the
    threshold and both dual updates are a single elementwise pass (the
    fused Pallas kernel when ``tail='pallas'``).  With ``rfft`` the stacked
    transforms run in the half layout — the x-update multiply is closed
    there because every factor is a Hermitian spectrum.  ``overlap=K``
    chunks both stacked transposes.  Broadcasts over leading batch axes
    (the stack axis leads them).  ``prox`` must be elementwise (see
    :func:`_tail`).
    """
    x, cx = dist_cpadmm_core(
        spec, b_spec, state.v + state.mu, state.z - state.nu, p,
        axis_name, rfft, overlap, wire_dtype, hier, inter_wire_dtype,
    )
    tail_fn = _tail(tail, prox)
    # fused elementwise tail: v-update, threshold, both dual updates
    v, z, mu, nu = tail_fn(x, cx, d_diag, pty, state.mu, state.nu, p)
    return DistCpadmmState(x=x, v=v, z=z, mu=mu, nu=nu)


def dist_cpadmm_core(
    spec: Array,
    b_spec: Array,
    vmu: Array,
    znu: Array,
    p: DistCpadmmParams,
    axis_name: str = MODEL_AXIS,
    rfft: bool = False,
    overlap: int = 1,
    wire_dtype: str = "fp32",
    hier: bool = False,
    inter_wire_dtype: str = "fp32",
) -> tuple:
    """The fused step's transform core: ``(v + mu, z - nu) -> (x, C x)``.

    Exactly the frequency-domain x-update of :func:`dist_cpadmm_step_fused`
    (which calls this, so the two can never drift): one stacked forward
    FFT, the fused local B·C^T multiply, one stacked inverse FFT.  Split
    out so the plan layer can shard_map *only* the transforms when the
    prior is non-elementwise (TV/wavelet) — the tail then runs at the
    global jit level where the prox sees whole signals.
    """
    fwd_t, inv_t = _transforms(
        rfft, vmu.shape[-1], spec.dtype, axis_name, overlap, wire_dtype,
        hier, inter_wire_dtype,
    )
    fwd = fwd_t(jnp.stack([vmu, znu]))
    w, zf = fwd[0], fwd[1]
    xf = b_spec * (p.rho * jnp.conj(spec) * w + p.sigma * zf)  # spectrum of x
    inv = inv_t(jnp.stack([xf, spec * xf]))
    return inv[0], inv[1]


# --------------------------------------------------------------------------
# global drivers
# --------------------------------------------------------------------------


def make_dist_spectrum(mesh, axis_name: str = MODEL_AXIS, rfft: bool = False):
    """Jitted: row-sharded layout_2d(first column) -> column-sharded spectrum.

    ``rfft=True`` yields the half-spectrum layout (n1, padded nf columns)
    that the rfft solver path consumes.
    """

    def to_spec(col2d: Array) -> Array:
        if rfft:
            return rfft2_local(col2d, axis_name)
        dt = jnp.complex128 if col2d.dtype == jnp.float64 else jnp.complex64
        return fft2_local(col2d.astype(dt), axis_name)

    return jax.jit(
        shard_map(
            to_spec,
            mesh=mesh,
            in_specs=(row_spec(axis_name),),
            out_specs=col_spec(axis_name),
            check_vma=False,
        )
    )


def make_dist_cpadmm(
    mesh,
    n1: int,
    n2: int,
    iters: int,
    fused: bool = False,
    axis_name: str = MODEL_AXIS,
    rfft: bool = False,
    batch_axis: str | None = None,
    overlap: int = 1,
    tail: str = "jnp",
    wire_dtype: str = "fp32",
):
    """DEPRECATED shim: jitted solver(spec2d, mask2d, y2d, alpha, rho, sigma).

    .. deprecated:: 0.1.0
        Will be **removed in repro 0.2.0**.  Not re-exported from
        ``repro.dist`` — reachable only by this full path until removal.

    The bespoke distributed driver this factory used to build is gone — the
    unified path is::

        pl = repro.ops.plan(op, mesh, rfft=..., overlap=..., tail=...)
        z, trace = repro.core.solvers.solve(problem, 'cpadmm', plan=pl)

    which also unlocks solve_until / solve_checkpointed / metric traces on
    the mesh.  This shim keeps the old call signature working by building a
    plan from the pre-sharded parts and running the same ``solve`` driver;
    output is pinned identical to the plan route (tests/test_plan.py).
    """
    warnings.warn(
        "make_dist_cpadmm is deprecated and will be removed in repro 0.2.0: "
        "build a repro.ops.plan and call repro.core.solvers.solve(..., "
        "method='cpadmm', plan=...) instead",
        DeprecationWarning,
        stacklevel=2,
    )
    if batch_axis is not None and batch_axis not in mesh.axis_names:
        raise ValueError(f"batch_axis {batch_axis!r} not in mesh axes {mesh.axis_names}")

    def run(spec2d, mask2d, y2d, alpha, rho, sigma):
        from repro.core.solvers import RecoveryProblem, solve
        from repro.ops import plan_from_parts

        pl = plan_from_parts(
            mesh, spec2d, mask2d,
            n1=n1, n2=n2, rfft=rfft, overlap=overlap, tail=tail, fused=fused,
            batch_axis=batch_axis, axis_name=axis_name, wire_dtype=wire_dtype,
        )
        prob = RecoveryProblem(op=pl.operator, y=unlayout_2d(y2d))
        z, _ = solve(
            prob, "cpadmm", iters=iters, record_every=iters,
            alpha=alpha, rho=rho, sigma=sigma, plan=pl,
        )
        return layout_2d(z, n1, n2)

    return jax.jit(run)

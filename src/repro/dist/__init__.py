"""repro.dist — the multi-device decomposition of the paper's recovery stack.

Module map (paper references are to "GPU-Accelerated Algorithms for
Compressed Signals Recovery with Application to Astronomical Imagery
Deblurring", arXiv:1707.02244):

    compat     version-portable shard_map / mesh constructors (jax 0.4.x
               through current), used by every entry point below and by the
               subprocess test programs.
    sharding   logical->physical named-axis sharding rules for the model
               stack (DEFAULT_RULES, rules_for_arch, activate_rules,
               constrain, grad_reduce_boundary).  This is the GSPMD side:
               transformer training shards by annotation.
    fft        the four-step n = n1 x n2 decomposed FFT (paper Sec. 4's
               C = F^H diag(spec) F identity, made multi-device): layout_2d /
               unlayout_2d / freq_flat define the sharded layout; a circulant
               matvec costs exactly two transpose-collectives
               (make_distributed_fft, make_distributed_matvec).  ``overlap=K``
               splits each transpose into K chunked all-to-alls overlapped
               with the first local FFT stage (same payload modulo chunk
               zero-padding, same result).
    recovery   the *planned step functions* of CPADMM, paper Alg. 3, over
               that layout: the spectral inverse B = (rho C^T C + sigma
               I)^{-1} stays sharded in the frequency domain;
               dist_cpadmm_step is the paper-faithful 6-transform iteration,
               dist_cpadmm_step_fused batches it down to two all-to-alls per
               iteration; ``tail='pallas'`` runs the elementwise tail as the
               fused kernels/cpadmm_tail VMEM pass.  There is no driver
               here: ``repro.ops.plan(op, mesh)`` lowers an operator onto
               these steps (and onto planned CPISTA/FISTA matvecs), and the
               ``repro.core.solvers`` drivers run it — make_dist_cpadmm
               survives only as a deprecation shim over that API.

The solvers here must agree with the single-device ``repro.core`` paths —
tests/test_dist_equiv.py and tests/test_plan.py pin the distributed-vs-core
match for every method, and tests/dist_progs/*.py exercise every module on
8 fake devices.
"""

from . import compat, fft, recovery, sharding  # noqa: F401

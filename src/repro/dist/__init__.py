"""repro.dist — the multi-device decomposition of the paper's recovery stack.

Module map (paper references are to "GPU-Accelerated Algorithms for
Compressed Signals Recovery with Application to Astronomical Imagery
Deblurring", arXiv:1707.02244):

    compat     version-portable shard_map / mesh constructors (jax 0.4.x
               through current), used by every entry point below and by the
               subprocess test programs.
    sharding   logical->physical named-axis sharding rules for the model
               stack (DEFAULT_RULES, rules_for_arch, activate_rules,
               constrain, grad_reduce_boundary).  This is the GSPMD side:
               transformer training shards by annotation.
    fft        the four-step n = n1 x n2 decomposed FFT (paper Sec. 4's
               C = F^H diag(spec) F identity, made multi-device): layout_2d /
               unlayout_2d / freq_flat define the sharded layout; a circulant
               matvec costs exactly two transpose-collectives
               (make_distributed_fft, make_distributed_matvec).  ``overlap=K``
               splits each transpose into K chunked all-to-alls overlapped
               with the first local FFT stage (same payload modulo chunk
               zero-padding, same result).
    recovery   the *planned step functions* of CPADMM, paper Alg. 3, over
               that layout: the spectral inverse B = (rho C^T C + sigma
               I)^{-1} stays sharded in the frequency domain;
               dist_cpadmm_step is the paper-faithful 6-transform iteration,
               dist_cpadmm_step_fused batches it down to two all-to-alls per
               iteration; ``tail='pallas'`` runs the elementwise tail as the
               fused kernels/cpadmm_tail VMEM pass.  There is no driver
               here: ``repro.ops.plan(op, mesh)`` lowers an operator onto
               these steps (and onto planned CPISTA/FISTA matvecs), and the
               ``repro.core.solvers`` drivers run it — make_dist_cpadmm
               survives only as a deprecation shim over that API (removed
               in repro 0.2.0; deliberately not re-exported here).

The solvers here must agree with the single-device ``repro.core`` paths —
tests/test_dist_equiv.py and tests/test_plan.py pin the distributed-vs-core
match for every method, and tests/dist_progs/*.py exercise every module on
8 fake devices.
"""

_LAZY_MODULES = ("compat", "fft", "recovery", "sharding")

# Package-level symbol re-exports (PEP 562 lazy, like repro.ops).
# ``make_dist_cpadmm`` is deliberately NOT here and NOT in ``__all__``: the
# shim is deprecated (removal in repro 0.2.0) and stays reachable only by
# its full path ``repro.dist.recovery.make_dist_cpadmm`` until then.
_LAZY_SYMBOLS = {
    "make_mesh": "compat",
    "shard_map": "compat",
    "MODEL_AXIS": "fft",
    "layout_2d": "fft",
    "unlayout_2d": "fft",
    "freq_flat": "fft",
    "make_distributed_fft": "fft",
    "make_distributed_rfft": "fft",
    "make_distributed_matvec": "fft",
    "DistCpadmmParams": "recovery",
    "DistCpadmmState": "recovery",
    "dist_cpadmm_step": "recovery",
    "dist_cpadmm_step_fused": "recovery",
    "make_dist_spectrum": "recovery",
    "rules_for_arch": "sharding",
    "activate_rules": "sharding",
    "constrain": "sharding",
    "grad_reduce_boundary": "sharding",
}

__all__ = sorted(_LAZY_MODULES) + sorted(_LAZY_SYMBOLS)


def __getattr__(name: str):
    import importlib

    if name in _LAZY_MODULES:
        mod = importlib.import_module(f".{name}", __name__)
        globals()[name] = mod
        return mod
    if name in _LAZY_SYMBOLS:
        mod = importlib.import_module(f".{_LAZY_SYMBOLS[name]}", __name__)
        # bind every symbol that module provides at once: importing the
        # submodule also sets the package attribute of the module's own
        # name, which must not shadow later symbol lookups
        for other, modname in _LAZY_SYMBOLS.items():
            if modname == _LAZY_SYMBOLS[name]:
                globals()[other] = getattr(mod, other)
        return globals()[name]
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(list(globals()) + list(__all__)))

"""Named-axis sharding rules: logical model axes -> physical mesh axes.

The models (``repro.models.*``) annotate activations with *logical* axis
names ("batch", "heads", "mlp", ...).  This module owns the mapping from
those names to the physical mesh axes ("pod", "data", "model") and exposes:

    DEFAULT_RULES        the production mapping (TP on "model", DP over
                         ("pod", "data"), FSDP for the MoE expert case)
    rules_for_arch       per-arch copy of DEFAULT_RULES with non-divisible
                         shardings dropped (a 4-kv-head model on a 16-way
                         model axis falls back to replication, recorded by
                         the dry-run as a rule fallback)
    activate_rules       context manager that makes (rules, mesh) current;
                         while active, ``constrain`` emits real
                         with_sharding_constraint ops
    constrain            logical-axis sharding constraint; identity when no
                         rules are active so single-device smoke tests and
                         kernel oracles are untouched
    grad_reduce_boundary identity in the forward pass; in the backward pass
                         re-constrains the activation cotangent at the layer
                         boundary so GSPMD materializes the gradient
                         all-reduce there (once per layer) instead of
                         deferring it into the optimizer

Nothing here imports the FFT/recovery layer; ``repro.launch.partition``
builds parameter/batch/cache NamedShardings on top of these rules.
"""

from __future__ import annotations

import contextlib
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Logical axis -> physical mesh axes.  Tuples are resolved against the axes
# actually present in the mesh (so ("pod", "data") degrades to ("data",) on a
# single-pod mesh).  ``None`` = replicated.
DEFAULT_RULES: Dict[str, Any] = {
    "batch": ("pod", "data"),  # data parallelism over pod x data
    "seq": None,  # sequence parallelism off by default
    "embed": None,  # activations replicated along d_model
    "vocab": "model",  # embedding/unembedding rows (Megatron-style)
    "heads": "model",  # attention TP on the head-flat dim
    "kv_heads": "model",
    "mlp": "model",  # feed-forward TP on d_ff
    "experts": "model",  # expert parallelism on the expert dim
    "fsdp": "data",  # MoE weight FSDP on d_model (the 671B case)
    "ssm_inner": "model",  # mamba/xlstm inner projections
}

# Logical axes whose shardability depends on a model dimension, and the
# config field that dimension comes from (see ``rules_for_arch``).
_DIVISIBILITY = (
    ("vocab", lambda cfg: cfg.vocab_padded),
    ("heads", lambda cfg: cfg.n_heads),
    ("kv_heads", lambda cfg: cfg.n_kv_heads),
    ("mlp", lambda cfg: cfg.d_ff),
    ("experts", lambda cfg: cfg.n_experts),
    ("fsdp", lambda cfg: cfg.d_model),
    ("ssm_inner", lambda cfg: cfg.d_ssm_inner),
)


def _mesh_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _extent(mesh: Mesh, phys) -> int:
    """Total device count behind a physical-axis assignment (present axes only)."""
    if phys is None:
        return 1
    sizes = _mesh_sizes(mesh)
    if isinstance(phys, tuple):
        n = 1
        for a in phys:
            n *= sizes.get(a, 1)
        return n
    return sizes.get(phys, 1)


def rules_for_arch(cfg, mesh: Mesh) -> Dict[str, Any]:
    """DEFAULT_RULES specialized to one architecture on one mesh.

    Any logical axis whose model dimension does not divide the mesh extent it
    would shard over falls back to replication (``None``).  The dry-run
    records exactly these fallbacks by diffing against DEFAULT_RULES.
    """
    rules = dict(DEFAULT_RULES)
    for logical, dim_of in _DIVISIBILITY:
        phys = rules.get(logical)
        extent = _extent(mesh, phys)
        dim = dim_of(cfg)
        if extent > 1 and (dim == 0 or dim % extent != 0):
            rules[logical] = None
    return rules


def resolve_axis(logical: Optional[str], rules: Dict[str, Any], names: Tuple[str, ...]):
    """Logical name -> physical axis (or tuple) restricted to present axes."""
    if logical is None:
        return None
    phys = rules.get(logical)
    if phys is None:
        return None
    if isinstance(phys, tuple):
        present = tuple(a for a in phys if a in names)
        return present if len(present) > 1 else (present[0] if present else None)
    return phys if phys in names else None


# --------------------------------------------------------------------------
# active-rules context
# --------------------------------------------------------------------------

_ACTIVE: list = []  # stack of (rules, mesh)


@contextlib.contextmanager
def activate_rules(rules: Dict[str, Any], mesh: Mesh):
    """Make (rules, mesh) current for ``constrain``/``grad_reduce_boundary``.

    Tracing (jit/lower) must happen inside this context for the constraints
    to be emitted; outside it every annotation is the identity.
    """
    _ACTIVE.append((rules, mesh))
    try:
        yield
    finally:
        _ACTIVE.pop()


def current_rules() -> Tuple[Optional[Dict[str, Any]], Optional[Mesh]]:
    return _ACTIVE[-1] if _ACTIVE else (None, None)


def constrain(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Constrain ``x`` (one logical name per dimension) under the active rules.

    Identity when no rules are active, when the rank disagrees (defensive:
    callers annotate the common layout), or when every axis resolves to
    replicated.
    """
    rules, mesh = current_rules()
    if rules is None or mesh is None or len(logical_axes) != x.ndim:
        return x
    names = tuple(mesh.axis_names)
    resolved = tuple(resolve_axis(a, rules, names) for a in logical_axes)
    if all(r is None for r in resolved):
        return x
    # drop shardings that do not divide the dimension (uneven GSPMD sharding
    # is legal but wasteful; replicating matches rules_for_arch's policy)
    sizes = _mesh_sizes(mesh)

    def ext(r):
        if r is None:
            return 1
        axes = r if isinstance(r, tuple) else (r,)
        n = 1
        for a in axes:
            n *= sizes.get(a, 1)
        return n

    resolved = tuple(
        r if r is not None and x.shape[i] % ext(r) == 0 else None
        for i, r in enumerate(resolved)
    )
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*resolved)))


@jax.custom_vjp
def grad_reduce_boundary(x: jax.Array) -> jax.Array:
    """Identity marking a layer boundary for gradient reduction.

    With rules active, the backward pass constrains the cotangent to the
    activation layout, forcing GSPMD to finish the TP partial-sum all-reduce
    at the boundary (in the layer's compute dtype) rather than accumulating
    unreduced partials across the scanned stack.
    """
    return x


def _grb_fwd(x):
    return x, None


def _grb_bwd(_, g):
    return (constrain(g, "batch", "seq", "embed"),)


grad_reduce_boundary.defvjp(_grb_fwd, _grb_bwd)

"""Whisper-large-v3 [arXiv:2212.04356]: enc-dec, 32+32L d1280 20H (kv=20)
d_ff=5120 vocab=51866.  The conv1d mel frontend is a STUB per the
assignment: input_specs provides post-conv frame embeddings (B, S, d)
directly; sinusoidal encoder positions; no RoPE (learned/sinusoidal-style
absolute positions).  Note 20 heads do not divide the 16-wide model axis;
TP falls back to mlp+vocab for this arch (dist/sharding.py)."""

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,  # decoder
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51866,
    mlp_variant="plain",
    is_encdec=True,
    n_enc_layers=32,
    norm_type="layernorm",
    act="gelu",
    use_rope=False,
)

SMOKE = ModelConfig(
    name="whisper-smoke",
    family="audio",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=256,
    mlp_variant="plain",
    is_encdec=True,
    n_enc_layers=2,
    norm_type="layernorm",
    act="gelu",
    use_rope=False,
    loss_chunk=16,
)

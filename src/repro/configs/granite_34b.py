"""Granite-34B-Code [arXiv:2405.04324]: 88L d6144 48H MQA (kv=1)
d_ff=24576 vocab=49152 — llama-arch code model with multi-query attention."""

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="granite-34b",
    family="dense",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,  # MQA
    d_ff=24576,
    vocab=49152,
    mlp_variant="plain",
    rope_theta=1e4,
    act="silu",
)

SMOKE = ModelConfig(
    name="granite-34b-smoke",
    family="dense",
    n_layers=3,
    d_model=96,
    n_heads=6,
    n_kv_heads=1,
    d_ff=256,
    vocab=384,
    mlp_variant="plain",
    act="silu",
    loss_chunk=16,
)

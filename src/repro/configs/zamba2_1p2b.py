"""Zamba2-1.2B [arXiv:2411.15242]: 38 Mamba2 layers d2048 (ssm_state=64)
with a single *shared* attention+MLP block (32H, kv=32, d_ff=8192) invoked
every 6th layer, vocab=32000.  (Zamba2's per-invocation LoRA deltas on the
shared block are omitted — simplification noted in DESIGN.md.)"""

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,  # shared block MLP
    vocab=32000,
    block_type="mamba2",
    ssm_state=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_head_dim=64,
    ssm_groups=8,
    attn_every=6,
    rope_theta=1e4,
    act="silu",
)

SMOKE = ModelConfig(
    name="zamba2-smoke",
    family="hybrid",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=256,
    block_type="mamba2",
    ssm_state=16,
    ssm_expand=2,
    ssm_conv=4,
    ssm_head_dim=16,
    ssm_groups=2,
    attn_every=2,
    act="silu",
    loss_chunk=16,
)

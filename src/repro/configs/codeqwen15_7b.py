"""CodeQwen1.5-7B [hf:Qwen/CodeQwen1.5-7B]: 32L d4096 32H (GQA kv=32)
d_ff=13440 vocab=92416 — qwen1.5 arch (full MHA, SwiGLU, RoPE theta 1e6)."""

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=13440,
    vocab=92416,
    rope_theta=1e6,
    act="silu",
)

SMOKE = ModelConfig(
    name="codeqwen1.5-7b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=160,
    vocab=256,
    rope_theta=1e6,
    act="silu",
    loss_chunk=16,
)

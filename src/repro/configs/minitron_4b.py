"""Minitron-4B [arXiv:2407.14679]: pruned Nemotron — 32L d3072 24H (GQA kv=8)
d_ff=9216 vocab=256000.  Note 24 heads / 8 kv-heads do not divide the 16-wide
model axis; TP falls back to mlp+vocab only for this arch (dist/sharding.py)."""

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="minitron-4b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=9216,
    vocab=256000,
    mlp_variant="plain",
    rope_theta=1e4,
    act="silu",  # nemotron uses squared-relu; silu kept for GLU-family uniformity
)

SMOKE = ModelConfig(
    name="minitron-4b-smoke",
    family="dense",
    n_layers=2,
    d_model=48,
    n_heads=6,
    n_kv_heads=2,
    d_ff=128,
    vocab=512,
    mlp_variant="plain",
    act="silu",
    loss_chunk=16,
)

"""Pixtral-12B [hf:mistralai/Pixtral-12B-2409]: 40L d5120 32H (GQA kv=8)
d_ff=14336 vocab=131072 — mistral-nemo backbone; the pixtral ViT frontend is
a STUB per the assignment (input_specs provides precomputed patch embeddings
that are prepended to the text stream)."""

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=131072,
    n_img_tokens=1024,  # 1024 patch embeddings per example (stub frontend)
    rope_theta=1e6,
    act="silu",
)

SMOKE = ModelConfig(
    name="pixtral-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=160,
    vocab=256,
    n_img_tokens=8,
    act="silu",
    loss_chunk=16,
)

"""--arch registry: full (assigned) configs + reduced smoke configs."""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

ARCH_IDS: List[str] = [
    "codeqwen15_7b",
    "granite_34b",
    "minitron_4b",
    "gemma_7b",
    "deepseek_v3_671b",
    "moonshot_v1_16b_a3b",
    "zamba2_1p2b",
    "pixtral_12b",
    "xlstm_350m",
    "whisper_large_v3",
]

# external ids (assignment spelling) -> module names
ALIASES: Dict[str, str] = {
    "codeqwen1.5-7b": "codeqwen15_7b",
    "granite-34b": "granite_34b",
    "minitron-4b": "minitron_4b",
    "gemma-7b": "gemma_7b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "zamba2-1.2b": "zamba2_1p2b",
    "pixtral-12b": "pixtral_12b",
    "xlstm-350m": "xlstm_350m",
    "whisper-large-v3": "whisper_large_v3",
}


def _module(arch: str):
    name = ALIASES.get(arch, arch.replace("-", "_").replace(".", "p"))
    return importlib.import_module(f"repro.configs.{name}")


def full_config(arch: str) -> ModelConfig:
    return _module(arch).FULL.validate()


def smoke_config(arch: str) -> ModelConfig:
    return _module(arch).SMOKE.validate()


def all_arch_ids() -> List[str]:
    return list(ARCH_IDS)


# Shape cells (assignment): name -> (seq_len, global_batch, kind)
SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}

# long_500k runs only for sub-quadratic (SSM/hybrid) archs per the assignment.
LONG_CONTEXT_ARCHS = {"zamba2_1p2b", "xlstm_350m"}


def cells_for(arch: str):
    """The (shape_name, ...) cells assigned to this arch."""
    name = ALIASES.get(arch, arch)
    out = []
    for shape in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
        if shape == "long_500k" and name not in LONG_CONTEXT_ARCHS:
            continue  # full-attention archs skip 500k (DESIGN.md §Arch-applicability)
        out.append(shape)
    return out

"""Moonlight-16B-A3B [hf:moonshotai/Moonlight-16B-A3B]: 48L d2048 16H
(kv=16), MoE 64 routed top-6 + 2 shared, expert d_ff=1408, first layer
dense (d_ff=11264), vocab=163840 — deepseek-v3-style arch at 16B scale."""

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=11264,  # first dense layer
    vocab=163840,
    n_experts=64,
    top_k=6,
    n_shared_experts=2,
    d_ff_expert=1408,
    first_k_dense=1,
    router_aux_free_bias=True,
    rope_theta=5e4,
    act="silu",
)

SMOKE = ModelConfig(
    name="moonshot-smoke",
    family="moe",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=192,
    vocab=512,
    n_experts=8,
    top_k=2,
    n_shared_experts=2,
    d_ff_expert=48,
    first_k_dense=1,
    act="silu",
    loss_chunk=16,
)

"""DeepSeek-V3-671B [arXiv:2412.19437]: 61L d7168 128H MLA, MoE 256 routed
(top-8) + 1 shared expert, expert d_ff=2048, first 3 layers dense
(d_ff=18432), vocab=129280.  MLA: q_lora 1536, kv_lora 512, nope 128,
rope 64, v 128.  (MTP head omitted — see DESIGN.md §Arch-applicability.)"""

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,  # nominal; MLA replaces classic KV heads
    d_ff=18432,  # the 3 dense layers
    vocab=129280,
    attn_type="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    rope_head_dim=64,
    nope_head_dim=128,
    v_head_dim=128,
    n_experts=256,
    top_k=8,
    n_shared_experts=1,
    d_ff_expert=2048,
    first_k_dense=3,
    router_aux_free_bias=True,
    rope_theta=1e4,
    act="silu",
)

SMOKE = ModelConfig(
    name="deepseek-v3-smoke",
    family="moe",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=192,
    vocab=512,
    attn_type="mla",
    q_lora_rank=32,
    kv_lora_rank=16,
    rope_head_dim=8,
    nope_head_dim=16,
    v_head_dim=16,
    n_experts=8,
    top_k=2,
    n_shared_experts=1,
    d_ff_expert=48,
    first_k_dense=1,
    act="silu",
    loss_chunk=16,
)

"""Gemma-7B [arXiv:2403.08295]: 28L d3072 16H (kv=16) head_dim=256, GeGLU
d_ff=24576, vocab=256000, tied embeddings, embed scaling sqrt(d)."""

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,  # q/k/v heads are 256-wide (16*256 = 4096 != d_model)
    d_ff=24576,
    vocab=256000,
    rope_theta=1e4,
    act="gelu",  # GeGLU
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="gemma-7b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=32,
    d_ff=192,
    vocab=512,
    act="gelu",
    tie_embeddings=True,
    loss_chunk=16,
)

"""xLSTM-350M [arXiv:2405.04517]: 24 blocks d1024 4H vocab=50304, mLSTM
blocks with an sLSTM block every 8th (the paper's x:1 interleave), no
separate FFN (d_ff=0 — projections live inside the xLSTM blocks)."""

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    block_type="xlstm",
    slstm_every=8,
    act="gelu",
)

SMOKE = ModelConfig(
    name="xlstm-smoke",
    family="ssm",
    n_layers=4,
    d_model=64,
    n_heads=2,
    n_kv_heads=2,
    d_ff=0,
    vocab=256,
    block_type="xlstm",
    slstm_every=2,
    act="gelu",
    loss_chunk=16,
)

"""Deterministic synthetic data: sparse signals, starfield images, token streams.

Everything is generated from explicit PRNG keys so that (a) every test is
reproducible and (b) multi-host pipelines can derive non-overlapping shards
from (seed, host_id, step) without coordination — the restart story never
needs to replay data (DESIGN.md Sec. 4).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# Paper Sec. 6: k-sparse Gaussian test signals
# ---------------------------------------------------------------------------


def sparse_signal(
    key: Array, n: int, k: int, batch: Tuple[int, ...] = (), dtype=jnp.float32
) -> Array:
    """x* with exactly k nonzeros, values ~ N(0,1) (paper Sec. 6 setup)."""
    kv, kp = jax.random.split(key)
    vals = jax.random.normal(kv, batch + (n,), dtype)

    def one_mask(k_perm):
        idx = jax.random.permutation(k_perm, n)[:k]
        return jnp.zeros((n,), dtype).at[idx].set(1.0)

    nb = 1
    for b in batch:
        nb *= b
    masks = jax.vmap(one_mask)(jax.random.split(kp, nb)).reshape(batch + (n,))
    return vals * masks


def paper_regime(n: int) -> Tuple[int, int]:
    """Paper Sec. 6: m = n/2 measurements, k ~= n/10 nonzeros."""
    return n // 2, max(1, n // 10)


# ---------------------------------------------------------------------------
# Paper Sec. 7: synthetic astronomical starfield (Abell-2744 stand-in)
# ---------------------------------------------------------------------------


def starfield(
    key: Array,
    h: int = 256,
    w: int = 256,
    density: float = 0.10,
    n_blobs: int = 12,
    dtype=jnp.float32,
) -> Array:
    """Sparse night-sky image: point sources (~``density`` of pixels lit,
    matching the paper's "sparsity about 10% of the signal size") plus a few
    soft elliptical blobs standing in for cluster galaxies.  Intensities in
    [0, 1]."""
    k_pts, k_int, k_blob = jax.random.split(key, 3)

    # Point sources.
    lit = jax.random.bernoulli(k_pts, density, (h, w))
    intensity = jax.random.uniform(k_int, (h, w), dtype, 0.2, 1.0)
    img = jnp.where(lit, intensity, 0.0)

    # Extended sources: sum of anisotropic Gaussians.
    yy = jnp.arange(h, dtype=dtype)[:, None]
    xx = jnp.arange(w, dtype=dtype)[None, :]
    params = jax.random.uniform(k_blob, (n_blobs, 5), dtype)  # cy cx sy sx amp

    def blob(img, p):
        cy, cx = p[0] * h, p[1] * w
        sy = 1.5 + p[2] * (h / 40.0)
        sx = 1.5 + p[3] * (w / 40.0)
        amp = 0.3 + 0.7 * p[4]
        g = amp * jnp.exp(-(((yy - cy) / sy) ** 2 + ((xx - cx) / sx) ** 2))
        return img + g, None

    img, _ = jax.lax.scan(blob, img, params)
    img = jnp.clip(img, 0.0, 1.0)
    # Kill sub-perceptual blob tails so the image stays genuinely sparse
    # (the paper's premise: most night-sky pixels are black).
    return jnp.where(img < 0.02, 0.0, img)


def extended_emission(
    key: Array,
    h: int = 256,
    w: int = 256,
    n_sources: int = 3,
    background: float = 0.05,
    dtype=jnp.float32,
) -> Array:
    """Piecewise-constant extended-emission map (Herschel-style dust/cloud
    field): ``n_sources`` flat-topped disks of random center/radius/intensity
    over a faint uniform background.  The complement of :func:`starfield` —
    almost nowhere zero but gradient-sparse, which is the regime where the
    TV prior (``repro.ops.prox.TVProx``) beats the paper's l1 threshold
    (``repro.core.mapmaking`` / tests pin the gap).  Intensities in [0, 1].
    """
    yy = jnp.arange(h, dtype=dtype)[:, None]
    xx = jnp.arange(w, dtype=dtype)[None, :]
    params = jax.random.uniform(key, (n_sources, 4), dtype)  # cy cx r amp

    def disk(img, p):
        cy, cx = p[0] * h, p[1] * w
        r = (0.10 + 0.18 * p[2]) * min(h, w)
        amp = 0.4 + 0.6 * p[3]
        inside = (yy - cy) ** 2 + (xx - cx) ** 2 <= r * r
        return jnp.where(inside, jnp.maximum(img, amp), img), None

    img, _ = jax.lax.scan(disk, jnp.full((h, w), background, dtype), params)
    return jnp.clip(img, 0.0, 1.0)


# ---------------------------------------------------------------------------
# LM substrate: deterministic token streams
# ---------------------------------------------------------------------------


def token_batch(
    seed: int, step: int, host: int, batch: int, seq_len: int, vocab: int
) -> Array:
    """(batch, seq_len+1) int32 tokens, unique per (seed, step, host).

    A Zipf-ish marginal (mixture of a low-id head and a uniform tail) so the
    loss curve is non-degenerate; fully deterministic => a restarted run
    consumes exactly the missed batches and no others."""
    key = jax.random.fold_in(jax.random.fold_in(jax.random.PRNGKey(seed), step), host)
    k1, k2, k3 = jax.random.split(key, 3)
    head = jax.random.randint(k1, (batch, seq_len + 1), 0, max(2, vocab // 64))
    tail = jax.random.randint(k2, (batch, seq_len + 1), 0, vocab)
    pick_head = jax.random.bernoulli(k3, 0.8, (batch, seq_len + 1))
    return jnp.where(pick_head, head, tail).astype(jnp.int32)

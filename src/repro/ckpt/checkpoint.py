"""Fault-tolerant checkpointing: atomic save, restart, elastic re-shard.

Design (DESIGN.md Sec. 4):
  * Atomicity: write to ``<dir>/.tmp.<step>`` then ``os.replace`` — a crash
    mid-save never corrupts the latest checkpoint; restart always finds a
    complete one.
  * Integrity: metadata carries a content checksum per leaf and the config
    hash; mismatches fail loudly at restore.
  * Elasticity: arrays are saved *unsharded by logical name* (on multi-host
    TPU this becomes one tensorstore shard per host; the np.savez backend
    here is the single-host embodiment of the same protocol).  Restore takes
    a target mesh + sharding tree and ``jax.device_put``s each leaf — so a
    run checkpointed on a 16x16 mesh restarts on 2x16x16 (grow) or 8x8
    (shrink) without conversion: the step/data-order contract lives in the
    metadata, not the shard layout.
  * Retention: ``keep`` most-recent checkpoints are kept, older ones pruned.
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

SEP = "|"  # path-key separator inside the npz


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def _checksum(arrays: Dict[str, np.ndarray]) -> str:
    h = hashlib.sha256()
    for k in sorted(arrays):
        h.update(k.encode())
        h.update(np.ascontiguousarray(arrays[k]).tobytes()[:65536])
    return h.hexdigest()[:16]


def save(ckpt_dir: str, step: int, tree: Any, extra: Optional[dict] = None, keep: int = 3) -> str:
    """Atomically persist ``tree`` for ``step``; returns the final path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    arrays = _flatten(tree)
    meta = {
        "step": int(step),
        "checksum": _checksum(arrays),
        "extra": extra or {},
        "keys": sorted(arrays),
    }
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    tmp = tempfile.mkdtemp(prefix=".tmp.", dir=ckpt_dir)
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic publish
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    # retention never removes the step just published, even when its number
    # is below ``keep`` older checkpoints (e.g. a restart that re-saves an
    # early step after later ones already exist)
    _prune(ckpt_dir, keep, protect=int(step))
    return final


def _step_dirs(ckpt_dir: str):
    """``(step, name)`` for every step directory, ordered *numerically*.

    Directory names are parsed, not lexically sorted: a lexical sort puts
    ``step_9`` after ``step_10`` (and after every zero-padded name), which
    made ``restore(latest)`` and ``keep=`` pruning pick the wrong
    checkpoints past step 9 for any unpadded name (older layouts, hand-made
    dirs, foreign writers).  Non-numeric ``step_*`` names are ignored.
    """
    out = []
    for d in os.listdir(ckpt_dir):
        if not d.startswith("step_") or d.startswith("."):
            continue
        try:
            s = int(d.split("_", 1)[1])
        except ValueError:
            continue
        out.append((s, d))
    out.sort()
    return out


def _prune(ckpt_dir: str, keep: int, protect: Optional[int] = None) -> None:
    if keep <= 0:
        return
    steps = _step_dirs(ckpt_dir)
    for s, d in steps[:-keep]:
        if protect is not None and s == protect:
            continue  # never touch the checkpoint currently being published
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def _dir_for_step(ckpt_dir: str, step: int) -> str:
    """Resolve a step number to its on-disk directory (padded or not)."""
    padded = os.path.join(ckpt_dir, f"step_{step:010d}")
    if os.path.isdir(padded):
        return padded
    for s, d in _step_dirs(ckpt_dir):
        if s == step:
            return os.path.join(ckpt_dir, d)
    return padded  # keep the canonical name in the FileNotFoundError


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        s
        for s, d in _step_dirs(ckpt_dir)
        if os.path.exists(os.path.join(ckpt_dir, d, "meta.json"))
    ]
    return max(steps) if steps else None


def restore(
    ckpt_dir: str,
    step: Optional[int],
    like: Any,
    shardings: Any = None,
) -> Tuple[int, Any]:
    """Restore into the structure of ``like``; optionally device_put each leaf
    with the matching ``shardings`` leaf (the elastic re-shard path)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = _dir_for_step(ckpt_dir, step)
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    with np.load(os.path.join(path, "arrays.npz")) as z:
        arrays = {k: z[k] for k in z.files}
    if _checksum(arrays) != meta["checksum"]:
        raise IOError(f"checksum mismatch in {path} — corrupt checkpoint")

    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    shard_leaves = (
        jax.tree_util.tree_leaves(shardings) if shardings is not None else [None] * len(paths)
    )
    for (path_t, leaf_like), shard in zip(paths, shard_leaves):
        key = SEP.join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path_t
        )
        arr = arrays[key]
        if shard is not None:
            leaves.append(jax.device_put(arr, shard))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return meta["step"], jax.tree_util.tree_unflatten(treedef, leaves)


def solver_checkpoint_cb(ckpt_dir: str, every: int = 1):
    """save_cb for core.solvers.solve_checkpointed."""

    def cb(step, state):
        save(ckpt_dir, step, state)

    return cb

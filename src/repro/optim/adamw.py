"""AdamW + global-norm clipping + warmup-cosine schedule (optax-free).

Functional: state is a pytree (mu, nu, count); update is pure and pjit-
friendly.  Moments default to fp32; the ``moment_dtype`` knob trades
optimizer-state memory for precision on the very large models (recorded per
arch in EXPERIMENTS.md §Dry-run memory notes).
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


class AdamWState(NamedTuple):
    mu: dict
    nu: dict
    count: Array


class AdamWConfig(NamedTuple):
    lr_peak: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    lr_min_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"


def schedule(cfg: AdamWConfig, step: Array) -> Array:
    step = step.astype(jnp.float32)
    warm = cfg.lr_peak * step / jnp.maximum(1.0, cfg.warmup_steps)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        1.0, cfg.total_steps - cfg.warmup_steps
    )
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.lr_min_ratio + (1 - cfg.lr_min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr_peak * cos)


def init(params: dict, cfg: AdamWConfig) -> AdamWState:
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return AdamWState(
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
        count=jnp.zeros((), jnp.int32),
    )


def global_norm(tree) -> Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def update(
    params: dict, grads: dict, state: AdamWState, cfg: AdamWConfig
) -> Tuple[dict, AdamWState, dict]:
    """-> (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    count = state.count + 1
    lr = schedule(cfg, count)
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * cfg.b1 + g * (1 - cfg.b1)
        v32 = v.astype(jnp.float32) * cfg.b2 + g * g * (1 - cfg.b2)
        step = (m32 / c1) / (jnp.sqrt(v32 / c2) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * step
        return p_new.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(mu=new_m, nu=new_v, count=count), metrics

"""ISTA / CPISTA / FISTA for LASSO (paper Alg. 1, Sec. 5.2).

The iteration is operator-generic: pass a ``DenseOperator`` to get the
paper's circulant-agnostic PISTA baseline, or a ``PartialCirculant`` /
``Circulant`` to get CPISTA (same algorithm, O(n log n) matvecs and O(n)
memory).  FISTA is a beyond-paper acceleration (Beck & Teboulle 2009):
identical per-iteration cost, O(1/t^2) objective decay vs ISTA's O(1/t).

LASSO objective (paper Eq. 3):  ||y - A x||_2^2 + 2 alpha ||x||_1.
Convergence (paper Sec. 2.2): any tau < 2 ||A||_2^{-2}; we default to
0.99 / ||A||^2, with the exact spectral norm available in O(n) for
circulant operators (DESIGN.md Sec. 2).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .soft_threshold import ista_update

Array = jax.Array


class IstaParams(NamedTuple):
    alpha: Array  # l1 weight (paper alpha)
    tau: Array  # step size


class IstaState(NamedTuple):
    x: Array  # current estimate x(t)
    x_prev: Array  # previous estimate (FISTA momentum; unused by ISTA)
    t_mom: Array  # FISTA momentum t_k, batch-shaped (per signal; unused by ISTA)


def default_tau(op, safety: float = 0.99) -> Array:
    """tau = safety / ||A||_2^2 (paper Alg. 1 initialization)."""
    norm = op.operator_norm_bound()
    return safety / (norm**2)


def ista_init(op, y: Array, x0: Array | None = None) -> IstaState:
    n = op.n
    batch = y.shape[:-1]
    x = jnp.zeros(batch + (n,), y.dtype) if x0 is None else x0
    # the FISTA momentum is *per signal* (batch-shaped, not a shared
    # scalar): a frozen or mid-run-recycled slot then carries exactly the
    # momentum schedule a solo run would, which is what pins batched /
    # served FISTA results to the run-alone path
    return IstaState(x=x, x_prev=x, t_mom=jnp.ones(batch, y.dtype))


def ista_step(op, y: Array, state: IstaState, p: IstaParams, prox=None) -> IstaState:
    """One Alg. 1 iteration: residual -> gradient -> prox.

    ``prox=None`` is the paper's identity-basis soft threshold (line 5);
    any ``repro.ops.prox.Prox`` swaps the prior while keeping lines 3-4.
    """
    r = y - op.matvec(state.x)  # line 3: residual
    delta = p.tau * op.rmatvec(r)  # line 4: gradient step
    if prox is None:
        x_new = ista_update(state.x, delta, p.alpha * p.tau)  # line 5 (*)
    else:
        x_new = prox.apply(state.x + delta, p.alpha * p.tau)
    return IstaState(x=x_new, x_prev=state.x, t_mom=state.t_mom)


# (*) Note on the threshold level: Alg. 1 writes eta_alpha; the proximal-
# gradient derivation of LASSO (Eq. 3, with the 2*alpha weighting) gives
# eta_{alpha*tau}.  We use alpha*tau, which matches the paper's own
# convergence citation [9] (Daubechies et al.) and reduces to the paper's
# exact pseudo-code when tau is absorbed into alpha.


def fista_step(op, y: Array, state: IstaState, p: IstaParams, prox=None) -> IstaState:
    """Beyond-paper: Nesterov-accelerated ISTA, same matvec cost.

    ``t_mom`` may be batch-shaped (per-signal momentum, see
    :func:`ista_init`); the coefficient broadcasts over each signal's
    trailing signal dims.
    """
    t_next = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * state.t_mom**2))
    beta = (state.t_mom - 1.0) / t_next
    if beta.ndim:  # batched momentum: align with the leading batch axes
        beta = beta.reshape(beta.shape + (1,) * (state.x.ndim - beta.ndim))
    v = state.x + beta * (state.x - state.x_prev)  # extrapolation point
    r = y - op.matvec(v)
    delta = p.tau * op.rmatvec(r)
    if prox is None:
        x_new = ista_update(v, delta, p.alpha * p.tau)
    else:
        x_new = prox.apply(v + delta, p.alpha * p.tau)
    return IstaState(x=x_new, x_prev=state.x, t_mom=t_next)


def lasso_objective(op, y: Array, x: Array, alpha) -> Array:
    """Paper Eq. 3: ||y - Ax||^2 + 2 alpha ||x||_1 (batched over leading axes)."""
    r = y - op.matvec(x)
    return jnp.sum(r * r, axis=-1) + 2.0 * alpha * jnp.sum(jnp.abs(x), axis=-1)

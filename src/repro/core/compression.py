"""Compressive-sensing gradient compression for cross-pod all-reduce.

This is the paper's sensing/recovery pair repurposed as a *distributed-
optimization* collective (DESIGN.md Secs. 3-5): CS "lifts the encoding
complexity from the source to the receiver" — precisely the asymmetry you
want on a slow cross-pod (DCN) link, where every chip can afford an
O(n log n) rFFT but the wire cannot afford n floats.

Pipeline (per gradient leaf, per step):
    e   = g + residual               # error feedback (Karimireddy et al. '19)
    y   = P C e                      # partial-circulant projection, via rFFT
    y~  = all_reduce_mean(y)         # m = n/ratio floats on the wire
    g^  = k ISTA steps on (PC, y~)   # decode: paper Alg. 1, fixed k, jitted
    residual = e - g^                # local feedback memory

The sensing operator is derived deterministically from (seed, leaf path), so
every host builds the identical operator with zero coordination — the same
property that lets the paper's spaceborne encoder stay tiny.

Honest accounting: this is *lossy*; error feedback keeps SGD/Adam convergent
(contractive compressor + memory), and `tests/test_compression.py` checks
the end-to-end contract (compression error -> 0 on sparse gradients, train
loss still decreases on a real model).
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.ops.prox import L1Prox

from .circulant import Circulant, PartialCirculant

Array = jax.Array

_L1 = L1Prox()  # decode default: the paper's soft threshold, via the prox layer


class CompressorSpec(NamedTuple):
    """Static description (hashable; safe to close over in jit)."""

    n: int  # padded flat length
    m: int  # measurement count
    decode_iters: int  # ISTA steps at the receiver
    alpha: float  # decode threshold weight
    prox: Any = None  # decode prior (repro.ops.prox); None = l1 soft threshold


class CompressorState(NamedTuple):
    """Per-leaf operator constants + error-feedback memory."""

    col: Array  # (n,) circulant first column (normalized)
    omega: Array  # (m,) selected rows
    residual: Array  # (n,) error feedback


def _pad_to(x: Array, n: int) -> Array:
    return jnp.pad(x, (0, n - x.shape[0]))


def make_compressor(
    key: Array,
    dim: int,
    ratio: int = 8,
    decode_iters: int = 50,
    alpha: float = 3e-3,
    prox=None,
) -> Tuple[CompressorSpec, CompressorState]:
    """ratio = n/m compression factor on the wire.  ``prox=`` selects the
    decode prior (frozen Prox dataclasses are hashable, so the spec stays
    jit-closable); None is the l1 soft threshold, bit-exact with the
    pre-prox decoder."""
    n = max(8, int(2 ** jnp.ceil(jnp.log2(max(dim, 2)))))  # pad to pow2 for FFT
    n = int(n)
    m = max(1, n // ratio)
    kc, ko = jax.random.split(key)
    # Romberg unit-spectrum sensing: orthogonal rows, ISTA step tau = 1 safe.
    from .circulant import random_omega, romberg_circulant

    circ = romberg_circulant(kc, n)
    omega = random_omega(ko, n, m)
    spec = CompressorSpec(n=n, m=m, decode_iters=decode_iters, alpha=alpha, prox=prox)
    state = CompressorState(
        col=circ.col, omega=omega, residual=jnp.zeros((n,), jnp.float32)
    )
    return spec, state


def _op(state: CompressorState) -> PartialCirculant:
    return PartialCirculant(Circulant.from_first_col(state.col), state.omega)


def compress(
    spec: CompressorSpec, state: CompressorState, g: Array
) -> Tuple[Array, Array]:
    """-> (measurements y, error-feedback input e). g is flat (dim,)."""
    e = _pad_to(g.reshape(-1).astype(jnp.float32), spec.n) + state.residual
    y = _op(state).matvec(e)
    return y, e


def decode(spec: CompressorSpec, state: CompressorState, y: Array) -> Array:
    """Fixed-k FISTA decode (accelerated paper Alg. 1; tau=1 is safe since
    the Romberg operator has orthogonal rows).  Scanned — jit/pjit friendly."""
    op = _op(state)
    prox = spec.prox if spec.prox is not None else _L1

    def body(carry, _):
        x, x_prev, t = carry
        t_next = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
        v = x + ((t - 1.0) / t_next) * (x - x_prev)
        r = y - op.matvec(v)
        x_new = prox.apply(v + op.rmatvec(r), spec.alpha)
        return (x_new, x, t_next), None

    x0 = jnp.zeros((spec.n,), jnp.float32)
    (x, _, _), _ = jax.lax.scan(
        body, (x0, x0, jnp.ones((), jnp.float32)), None, length=spec.decode_iters
    )
    return x


def update_residual(
    state: CompressorState, e: Array, g_hat: Array
) -> CompressorState:
    return state._replace(residual=e - g_hat)


def compressed_mean(
    spec: CompressorSpec,
    state: CompressorState,
    g: Array,
    axis_name: str | Tuple[str, ...],
) -> Tuple[Array, CompressorState]:
    """Drop-in replacement for ``jax.lax.pmean(g, axis_name)`` over a slow
    axis: wire cost m floats instead of n.  Must run inside shard_map/pmap
    with ``axis_name`` bound.  Returns (decoded mean gradient, new state)."""
    dim = g.reshape(-1).shape[0]
    y, e = compress(spec, state, g)
    y = jax.lax.pmean(y, axis_name)
    g_hat = decode(spec, state, y)
    new_state = update_residual(state, e, g_hat)
    return g_hat[:dim].reshape(g.shape).astype(g.dtype), new_state


def compression_wire_bytes(spec: CompressorSpec) -> int:
    return spec.m * 4


def identity_wire_bytes(dim: int) -> int:
    return dim * 4

"""Soft-thresholding operator eta_gamma (paper Eq. 4).

``eta_gamma(x) = sign(x) * max(|x| - gamma, 0)``

The fused-update variants below mirror how the paper's GPU kernels fuse the
threshold with the state update that produces its input (CPISTA Alg. 8,
CPADMM Alg. 6) so the intermediate never round-trips through HBM.  The
Pallas TPU kernel lives in ``repro.kernels.soft_threshold``; these are the
pure-jnp definitions used by the solvers and as kernel oracles.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def soft_threshold(x: Array, gamma) -> Array:
    return jnp.sign(x) * jnp.maximum(jnp.abs(x) - gamma, 0.0)


def ista_update(x_prev: Array, grad_step: Array, gamma) -> Array:
    """eta_gamma(x_prev + grad_step) — CPISTA Alg. 8 fused tail."""
    return soft_threshold(x_prev + grad_step, gamma)


def admm_z_update(x: Array, nu: Array, gamma) -> Array:
    """z = eta_gamma(x + nu) — CPADMM Alg. 6 / dense ADMM Alg. 2 line 5."""
    return soft_threshold(x + nu, gamma)

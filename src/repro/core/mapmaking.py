"""Herschel-style multi-observation map-making on the compressed-deblur stack.

Space observatories (Herschel/PACS map-making is the canonical case) scan
the same sky patch repeatedly at small pointing offsets and fuse the
dithered exposures into one map.  Under the paper's compressed-sensing
telescope model each exposure ``f`` observes

    y_f = P (C B) S_{s_f} x           (A_f = A S_{s_f},  A = P (C B))

where ``x`` is the sky map, ``S_s`` is the pointing offset as a *shift
circulant* (first column ``e_s``, so ``S_s v = roll(v, s)`` on the raster),
``B`` the telescope PSF (gaussian/airy circulants from
:mod:`repro.core.circulant`), ``C`` the sensing circulant and ``P`` the row
selector.  Because every factor is circulant, each frame's operator is the
*same* joint operator ``A`` applied to a shifted sky — so the whole stack
recovers through ONE planned operator with frames on the batch (data) axis:
recover ``z_f = S_{s_f} x`` jointly, then co-add by unshifting,

    x_hat = mean_f  roll(z_f_hat, -s_f).

The shifted-sky frames are *not* sparse point fields once blurred; the TV
prior (:class:`repro.ops.prox.TVProx`) is the right regularizer and is the
:func:`build_mapmaking_plan` default — this is the prox layer's flagship
non-l1 scenario (tests/test_mapmaking.py pins the recovered map's PSNR).

    python -m examples.mapmaking_herschel        # quickstart with PSNR table
"""

from __future__ import annotations

import math
from typing import NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp

from .circulant import PartialCirculant, shift_circulant
from .deblur import DeblurProblem, build_deblur_plan, build_multiframe_deblur_problem

Array = jax.Array


class MapMakingProblem(NamedTuple):
    """A dithered-exposure stack through one shared compressed optic.

    ``deblur`` carries the joint operator ``A = P (C B)`` and the *shifted*
    frame stack as its image (``deblur.image[f] = roll(sky, shifts[f])`` on
    the raster) — so every deblur helper (metrics, rendering, plan lowering)
    applies to the per-frame recovery unchanged.
    """

    deblur: DeblurProblem  # shared optic; image = (F, H, W) shifted skies
    sky: Array  # (H, W) ground-truth map
    shifts: Tuple[int, ...]  # per-frame raster offset s_f


def build_mapmaking_problem(
    key: Array,
    sky: Array,
    shifts: Sequence[int],
    blur_order: float = 3.0,
    subsample: float = 0.5,
    sensing: str = "romberg",
    blur_kind: str = "gaussian",
) -> MapMakingProblem:
    """Observe ``sky`` at each raster offset through one shared optic.

    ``shifts`` are flat-raster offsets (a multiple of the row width W is a
    pure vertical dither; small values are horizontal ones — raster wrap at
    row edges is part of the circulant model, exactly as for the paper's
    raster blur).  Defaults pick the astronomy-realistic gaussian PSF; the
    sensing/subsample knobs mirror :func:`build_deblur_problem`.
    """
    if sky.ndim != 2:
        raise ValueError(
            f"build_mapmaking_problem takes a single (H, W) sky map; got "
            f"shape {tuple(sky.shape)}"
        )
    if len(shifts) == 0:
        raise ValueError("need at least one pointing offset in shifts")
    h, w = sky.shape
    flat = sky.reshape(h * w)
    shifts = tuple(int(s) for s in shifts)
    frames = jnp.stack(
        [jnp.roll(flat, s).reshape(h, w) for s in shifts]
    )
    dp = build_multiframe_deblur_problem(
        key, frames, blur_order=blur_order, subsample=subsample,
        sensing=sensing, blur_kind=blur_kind,
    )
    return MapMakingProblem(deblur=dp, sky=sky, shifts=shifts)


def frame_operator(problem: MapMakingProblem, f: int) -> PartialCirculant:
    """The factored per-frame view ``A_f = P (C B S_{s_f})``, sky -> y_f.

    Composes the shared joint circulant with the frame's shift circulant —
    spectra multiply, no dense matrix.  ``frame_operator(p, f).matvec(sky)``
    equals ``p.deblur.op.matvec(roll(sky, s_f))`` (tests pin this), which is
    why the batched solve can share one planned operator.
    """
    joint = problem.deblur.op.circ
    shifted = joint.compose(
        shift_circulant(joint.n, problem.shifts[f], dtype=joint.col.dtype)
    )
    return PartialCirculant(shifted, problem.deblur.op.omega)


def build_mapmaking_plan(problem: MapMakingProblem, mesh=None, *, prox="tv",
                         **kw):
    """Lower the shared map-making operator; TV prior by default.

    Rides :func:`build_deblur_plan` (same knobs: config/tune or individual
    kwargs; frames land on a 'data' mesh axis when one exists).  ``prox``
    accepts any :mod:`repro.ops.prox` instance; the ``"tv"`` default builds
    :class:`~repro.ops.prox.TVProx` on the sky's own grid; pass ``None`` for
    the paper's l1 soft threshold (fused kernels stay on).
    """
    if prox == "tv":
        from repro.ops.prox import TVProx

        prox = TVProx(shape=tuple(problem.sky.shape))
    return build_deblur_plan(problem.deblur, mesh, prox=prox, **kw)


def coadd(problem: MapMakingProblem, z: Array) -> Array:
    """Fuse recovered shifted skies (..., F, n) into one (..., H, W) map:
    unshift each frame and average."""
    h, w = problem.sky.shape
    frames = [
        jnp.roll(z[..., f, :], -s, axis=-1)
        for f, s in enumerate(problem.shifts)
    ]
    return (sum(frames) / len(frames)).reshape(z.shape[:-2] + (h, w))


def mapmaking_metrics(problem: MapMakingProblem, z: Array) -> dict:
    """Map-level metrics of the co-added estimate vs the true sky.

    ``z`` is the batched solver output (..., F, n).  PSNR references the
    true map's peak intensity, matching :func:`deblur_metrics`.
    """
    x_hat = coadd(problem, z)
    err = problem.sky - x_hat
    mse = jnp.mean(err * err, axis=(-2, -1))
    peak = jnp.max(jnp.abs(problem.sky))
    safe_peak = jnp.where(peak > 0, peak, 1.0)
    psnr = jnp.where(
        peak > 0,
        10.0 * jnp.log10(safe_peak * safe_peak / (mse + 1e-20)),
        -jnp.inf,
    )
    rms = jnp.sqrt(mse)
    return {"map": x_hat, "mse": mse, "rms": rms, "psnr_db": psnr}


def solve_mapmaking(
    problem: MapMakingProblem,
    plan=None,
    method: str = "cpadmm",
    iters: int = 400,
    alpha: float = 1e-4,
    rho: float = 0.01,
    sigma: float = 0.01,
) -> Tuple[Array, dict]:
    """End-to-end recovery: batched solve of the shifted stack, then co-add.

    Returns ``(z_hat, metrics)`` where ``z_hat`` is the (F, n) recovered
    shifted-sky stack and ``metrics`` is :func:`mapmaking_metrics` (with the
    co-added map under ``"map"``).  Builds the default TV plan when none is
    given.
    """
    from .solvers import RecoveryProblem, solve

    if plan is None:
        plan = build_mapmaking_plan(problem)
    n = math.prod(problem.sky.shape)
    x_true = problem.deblur.image.reshape(len(problem.shifts), n)
    prob = RecoveryProblem(op=problem.deblur.op, y=problem.deblur.y,
                           x_true=x_true)
    z_hat, _ = solve(prob, method, iters=iters, alpha=alpha, rho=rho,
                     sigma=sigma, plan=plan)
    return z_hat, mapmaking_metrics(problem, z_hat)

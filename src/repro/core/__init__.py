"""Core paper contribution: circulant operators + LASSO solver family."""

from .circulant import (  # noqa: F401
    Circulant,
    DenseOperator,
    PartialCirculant,
    airy_blur,
    compose_sensing_blur,
    densify,
    gaussian_blur,
    gaussian_circulant,
    moving_average_blur,
    partial_gaussian_circulant,
    partial_romberg_circulant,
    random_omega,
    romberg_circulant,
    shift_circulant,
)
from .soft_threshold import soft_threshold  # noqa: F401
from .solvers import (  # noqa: F401
    PAPER_TARGET_MSE,
    RecoveryProblem,
    Trace,
    make_stepper,
    solve,
    solve_checkpointed,
    solve_until,
)

"""Unified recovery driver for the paper's solver family.

Methods
-------
    'ista'    Alg. 1 on any operator (dense op => the paper's PISTA baseline,
              circulant op => CPISTA: same algorithm, structured matvecs)
    'fista'   beyond-paper accelerated variant (same cost/iteration)
    'admm'    Alg. 2 on a dense operator (PADMM baseline; O(n^3) setup)
    'cpadmm'  Alg. 3 on a PartialCirculant (FFT setup + structured iterations)

Drivers
-------
    solve()              fixed iteration count, jit-scanned, metric traces
    solve_until()        while-loop with relative-change tolerance
    solve_checkpointed() host-chunked loop with checkpoint/restart callbacks —
                         the fault-tolerance path for very long recoveries
                         (paper Sec. 7 runs 3 h on a desktop GPU; at that
                         horizon restartability is a production requirement)

Every driver accepts a leading batch axis on ``y`` / ``x_true`` (B signals
sensed through one shared operator — the paper's off-line many-recoveries
workload): states, traces, and MSEs broadcast per signal, and
``solve_until`` tracks convergence per signal, freezing early finishers
instead of stalling the batch.  Batch-of-1 equals the unbatched run
(tests/test_batched_recovery.py).

Backends: every driver takes ``plan=`` (repro.ops.plan).  With no plan (or
a local ``plan(op)``) the steppers run the operator's own matvecs on one
device; with a distributed plan the same methods lower to the sharded
four-step transforms of repro.dist — these drivers are the only drivers,
so tolerance stopping, per-signal freezing, metric traces, and
checkpoint/restart work identically on a mesh (tests/test_plan.py,
tests/dist_progs/ista_prog.py).  A local plan's ``tail='pallas'`` swaps the
CPADMM step onto the fused kernel substrate (core.kernel_backend).

Recovery success follows the paper: MSE = ||x* - x||^2 / n <= 1e-4 (Sec. 6).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.ops import prox as prox_mod

from . import admm as admm_mod
from . import ista as ista_mod
from .circulant import DenseOperator, PartialCirculant

Array = jax.Array

PAPER_TARGET_MSE = 1e-4  # paper Sec. 6 recovery threshold


class RecoveryProblem(NamedTuple):
    op: Any  # matvec/rmatvec-capable operator
    y: Array  # (..., m) measurements
    x_true: Optional[Array] = None  # (..., n) ground truth (metrics only)


class Trace(NamedTuple):
    objective: Array  # (T, ...) LASSO objective per recorded step
    mse: Array  # (T, ...) MSE vs x_true (nan if no truth)
    nnz: Array  # (T, ...) support size of the iterate


def _metrics(problem: RecoveryProblem, x: Array, alpha) -> Tuple[Array, Array, Array]:
    obj = ista_mod.lasso_objective(problem.op, problem.y, x, alpha)
    if problem.x_true is not None:
        d = problem.x_true - x
        mse = jnp.mean(d * d, axis=-1)
    else:
        mse = jnp.full(obj.shape, jnp.nan, x.dtype)
    nnz = jnp.sum((jnp.abs(x) > 0).astype(jnp.int32), axis=-1)
    return obj, mse, nnz


def _metric_view(problem: RecoveryProblem, plan) -> RecoveryProblem:
    """The problem the metric traces are computed against.

    On a distributed plan the objective runs through the plan's mask-form
    operator (``||P^T y - diag(mask) C x||^2`` equals the m-subset
    objective, since the off-omega rows of both terms are zero) so metric
    matvecs stay sharded instead of replicating a full-size local FFT per
    recorded step.
    """
    if plan is None or not getattr(plan, "is_distributed", False):
        return problem
    return RecoveryProblem(
        op=plan.operator,
        y=plan._scattered_measurements(problem),
        x_true=problem.x_true,
    )


@dataclasses.dataclass(frozen=True)
class Stepper:
    """A (init, step, extract) triple hiding per-method state shapes."""

    init: Callable[[], Any]
    step: Callable[[Any], Any]
    extract: Callable[[Any], Array]  # state -> current x


VALID_METHODS = ("ista", "fista", "cpista", "admm", "padmm", "cpadmm")


def make_stepper(
    problem: RecoveryProblem,
    method: str,
    alpha: float = 1e-4,
    rho: float = 0.1,
    sigma: float = 0.1,
    tau: Optional[float] = None,
    plan=None,
    prox=None,
) -> Stepper:
    """Lower (problem, method) to a Stepper on the plan's backend.

    ``plan=None`` (or a local plan) runs the operator's own matvecs; a
    distributed plan (repro.ops.plan with a mesh) lowers the same method to
    the sharded four-step transforms — the stepper contract (init / step /
    extract-flat-x) is identical, which is what lets every driver below run
    unchanged on both backends.

    ``prox=`` swaps the prior (repro.ops.prox); None defaults to the plan's
    ``prox`` and then to the paper's identity-basis soft threshold, which
    keeps the fused Pallas tails eligible.  A non-l1 prox composes the
    z-update outside the fused kernels instead.
    """
    if prox is None and plan is not None:
        prox = getattr(plan, "prox", None)
    if plan is not None and getattr(plan, "is_distributed", False):
        return plan.build_stepper(
            problem, method, alpha=alpha, rho=rho, sigma=sigma, tau=tau, prox=prox
        )
    tail = getattr(plan, "tail", "jnp") if plan is not None else "jnp"
    op, y = problem.op, problem.y
    if method in ("ista", "fista", "cpista"):
        tau_v = (
            jnp.asarray(tau, y.dtype) if tau is not None else ista_mod.default_tau(op)
        )
        p = ista_mod.IstaParams(alpha=jnp.asarray(alpha, y.dtype), tau=tau_v)
        step_fn = ista_mod.fista_step if method == "fista" else ista_mod.ista_step
        return Stepper(
            init=lambda: ista_mod.ista_init(op, y),
            step=lambda s: step_fn(op, y, s, p, prox=prox),
            extract=lambda s: s.x,
        )
    if method in ("admm", "padmm"):
        if not isinstance(op, DenseOperator):
            raise TypeError("dense ADMM needs a DenseOperator; use 'cpadmm'")
        const = admm_mod.dense_admm_setup(op, y, rho)
        return Stepper(
            init=lambda: admm_mod.dense_admm_init(op, y),
            step=lambda s: admm_mod.dense_admm_step(const, s, alpha, rho, prox=prox),
            extract=lambda s: s.z,  # z is the sparse iterate
        )
    if method == "cpadmm":
        if not isinstance(op, PartialCirculant):
            raise TypeError("cpadmm needs a PartialCirculant operator")
        p = admm_mod.CpadmmParams(
            alpha=jnp.asarray(alpha, y.dtype),
            rho=jnp.asarray(rho, y.dtype),
            sigma=jnp.asarray(sigma, y.dtype),
            tau1=jnp.asarray(1.0 if tau is None else tau, y.dtype),
            tau2=jnp.asarray(1.0 if tau is None else tau, y.dtype),
        )
        const = admm_mod.cpadmm_setup(op, y, p)
        if tail == "pallas" and prox_mod.is_l1(prox):
            # plan attribute tail='pallas' on the local backend: the fused
            # kernels/cpadmm_tail substrate (core.kernel_backend).  The fused
            # kernel bakes in the soft threshold, so it's only eligible for
            # the l1 prior; other proxes take the composable jnp tail below.
            from repro.kernels.cpadmm_tail.ops import interpret_default

            from .kernel_backend import cpadmm_step_pallas

            interpret = interpret_default()
            step = lambda s: cpadmm_step_pallas(op, const, s, p, interpret=interpret)
        else:
            step = lambda s: admm_mod.cpadmm_step(op, const, s, p, prox=prox)
        return Stepper(
            init=lambda: admm_mod.cpadmm_init(op, y),
            step=step,
            extract=lambda s: s.z,
        )
    raise ValueError(
        f"unknown method {method!r}; valid methods: {', '.join(VALID_METHODS)}"
    )


def solve(
    problem: RecoveryProblem,
    method: str = "cpadmm",
    iters: int = 200,
    alpha: float = 1e-4,
    record_every: Optional[int] = None,
    plan=None,
    **kw,
) -> Tuple[Array, Trace]:
    """Run a fixed number of iterations under jit; record metric traces.

    ``plan=`` selects the execution backend (repro.ops.plan).  Each metric
    record costs one operator application, so ``record_every`` defaults to
    1 locally but to ``iters`` (a single trace point) on a distributed
    plan — a per-iteration trace there would add two transpose-collectives
    per iteration on top of the fused step's two; pass ``record_every``
    explicitly to trace a distributed run more often.
    """
    if record_every is None:
        distributed = plan is not None and getattr(plan, "is_distributed", False)
        record_every = iters if distributed else 1
    stepper = make_stepper(problem, method, alpha=alpha, plan=plan, **kw)
    metric_problem = _metric_view(problem, plan)
    inner = max(1, record_every)
    outer = max(1, iters // inner)

    def scan_body(state, _):
        state, _ = jax.lax.scan(
            lambda s, _: (stepper.step(s), None), state, None, length=inner
        )
        x = stepper.extract(state)
        return state, _metrics(metric_problem, x, alpha)

    state, (obj, mse, nnz) = jax.lax.scan(
        scan_body, stepper.init(), None, length=outer
    )
    return stepper.extract(state), Trace(objective=obj, mse=mse, nnz=nnz)


def _freeze_converged(new_state, old_state, active: Array, batch: Tuple[int, ...]):
    """Keep stepping active signals, freeze converged ones.

    ``active`` has the batch shape; every state leaf carrying the batch as
    leading dims is masked per signal (including the per-signal FISTA
    momentum, which is batched so a frozen — or later recycled — slot's
    momentum schedule matches a solo run).  Leaves without the batch prefix
    advance globally — harmless, since frozen signals' arrays no longer
    consume them.
    """

    def sel(new_leaf, old_leaf):
        if batch and new_leaf.shape[: len(batch)] == batch:
            m = active.reshape(batch + (1,) * (new_leaf.ndim - len(batch)))
            return jnp.where(m, new_leaf, old_leaf)
        return new_leaf

    return jax.tree.map(sel, new_state, old_state)


class UntilState(NamedTuple):
    """The tolerance-driven loop's carry, per slot.

    ``age`` counts iterations *since admission* (== iterations used once a
    slot converges) and ``delta`` is the last relative iterate change.  Both
    have the batch shape, which is what makes a slot re-armable mid-run:
    admitting a new signal into a converged slot resets that slot's state
    leaves, age, and delta (:func:`rearm_slots`) without disturbing its
    neighbours — the continuous-batching mechanism ``repro.serve`` builds
    on.  Keeping only a global iteration counter (the pre-serve design)
    would make a recycled slot inherit its predecessor's sub-``tol`` delta
    and iteration count, freezing it instantly before ``min_iters`` could
    apply.
    """

    state: Any  # solver state (leaves carry the batch prefix)
    age: Array  # (batch,) int32 — iterations since (re-)admission
    delta: Array  # (batch,) last relative iterate change (inf before a step)


def until_init(stepper: Stepper) -> Tuple[UntilState, Tuple[int, ...]]:
    """Fresh loop carry for a stepper; returns (carry, batch_shape)."""
    s0 = stepper.init()
    x0 = stepper.extract(s0)
    batch = x0.shape[:-1]
    return (
        UntilState(
            state=s0,
            age=jnp.zeros(batch, jnp.int32),
            delta=jnp.full(batch, jnp.inf, x0.dtype),
        ),
        batch,
    )


def until_active(u: UntilState, tol, min_iters, max_iters) -> Array:
    """Per-slot liveness: still inside the budget AND (young OR moving).

    ``tol`` / ``min_iters`` / ``max_iters`` may be scalars or per-slot
    arrays broadcastable to the batch shape — per-slot budgets are what let
    a serving batch mix requests with heterogeneous tolerances (and park
    empty slots with ``max_iters = 0``).

    ``min_iters`` guards against the thresholded iterate being frozen at 0
    during the first iterations (the relative change would be spuriously 0).
    """
    return jnp.logical_and(
        u.age < max_iters,
        jnp.logical_or(u.age < min_iters, u.delta > tol),
    )


def until_step(
    stepper: Stepper,
    u: UntilState,
    tol,
    min_iters,
    max_iters,
    batch: Tuple[int, ...],
) -> UntilState:
    """One masked iteration: step active slots, freeze the rest, update each
    active slot's age and relative change.  Frozen slots keep their last
    delta (the reporting value; a recycled slot gets a fresh inf via
    :func:`rearm_slots`, never this stale one)."""
    active = until_active(u, tol, min_iters, max_iters)
    new = _freeze_converged(stepper.step(u.state), u.state, active, batch)
    x_old = stepper.extract(u.state)
    x_new = stepper.extract(new)
    num = jnp.linalg.norm(x_new - x_old, axis=-1)
    den = jnp.linalg.norm(x_old, axis=-1) + 1e-12
    return UntilState(
        state=new,
        age=jnp.where(active, u.age + 1, u.age),
        delta=jnp.where(active, num / den, u.delta),
    )


def rearm_slots(
    u: UntilState, init: UntilState, admit: Array, batch: Tuple[int, ...]
) -> UntilState:
    """Admit new work into slots: where ``admit`` (batch-shaped bool), take
    the *init* carry — state leaves re-zeroed, age 0, delta inf — so the
    admitted signal runs exactly as it would alone; everywhere else the
    carry is untouched.  jit-friendly (pure where-select)."""
    return UntilState(
        state=_freeze_converged(init.state, u.state, admit, batch),
        age=jnp.where(admit, init.age, u.age),
        delta=jnp.where(admit, init.delta, u.delta),
    )


def solve_until(
    problem: RecoveryProblem,
    method: str = "cpadmm",
    tol=1e-7,
    max_iters=5000,
    min_iters=50,
    alpha: float = 1e-4,
    plan=None,
    **kw,
) -> Tuple[Array, Array]:
    """Iterate until relative iterate change < tol (or max_iters); returns
    (x, iterations_used).  Pure lax.while_loop — jit/pjit friendly.

    Batched: with measurements ``y`` of shape (..., m) the convergence test
    is per signal.  Signals whose relative change drops below ``tol``
    *freeze* (their state stops updating) while the rest keep iterating, so
    one early-converging signal neither stalls the batch nor keeps burning
    flops; the loop exits when every signal has converged.
    ``iterations_used`` then has the batch shape (scalar when unbatched) and
    matches what each signal would have used in a solo run.

    ``tol`` / ``min_iters`` / ``max_iters`` may each be per-signal arrays
    (broadcastable to the batch shape) — heterogeneous convergence budgets
    in one batch, the contract the serving dispatcher (``repro.serve``)
    leans on.  The loop body itself is exposed as
    :func:`until_init` / :func:`until_step` / :func:`rearm_slots` so a host
    scheduler can run it round-by-round and admit new signals into
    converged slots mid-run (continuous batching).

    ``plan=`` selects the execution backend: a distributed plan gives
    tolerance-stopped *distributed* recovery (the convergence test runs on
    the flat extract, so the per-signal freeze semantics are identical).
    """
    stepper = make_stepper(problem, method, alpha=alpha, plan=plan, **kw)
    u0, batch = until_init(stepper)

    def cond(u):
        return jnp.any(until_active(u, tol, min_iters, max_iters))

    def body(u):
        return until_step(stepper, u, tol, min_iters, max_iters, batch)

    u = jax.lax.while_loop(cond, body, u0)
    return stepper.extract(u.state), u.age


def solve_checkpointed(
    problem: RecoveryProblem,
    method: str = "cpadmm",
    iters: int = 1000,
    chunk: int = 100,
    alpha: float = 1e-4,
    save_cb: Optional[Callable[[int, Any], None]] = None,
    restore: Optional[Tuple[int, Any]] = None,
    plan=None,
    **kw,
) -> Tuple[Array, Array]:
    """Host-chunked driver: jit-run ``chunk`` iterations at a time, invoking
    ``save_cb(step, state)`` between chunks.  ``restore=(step, state)``
    resumes an interrupted recovery — see repro.ckpt.solver_checkpoint.

    With a distributed ``plan=`` the saved state leaves are the sharded
    (n1, n2)-layout iterates — the fault-tolerance path for very long
    *distributed* recoveries (paper Sec. 7's three-hour horizon)."""
    stepper = make_stepper(problem, method, alpha=alpha, plan=plan, **kw)

    @jax.jit
    def run_chunk(state):
        def body(s, _):
            return stepper.step(s), None

        state, _ = jax.lax.scan(body, state, None, length=chunk)
        return state

    start, state = (0, stepper.init()) if restore is None else restore
    step = start
    while step < iters:
        state = run_chunk(state)
        step += chunk
        if save_cb is not None:
            save_cb(step, state)
    x = stepper.extract(state)
    _, mse, _ = _metrics(_metric_view(problem, plan), x, alpha)
    return x, mse

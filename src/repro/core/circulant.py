"""Circulant / partial-circulant sensing operators (paper Secs. 4.2-4.3).

Conventions
-----------
The paper describes a circulant matrix by its *first row* ``v``:
``A[i, j] = v[(j - i) mod n]``.  Internally we store the *first column*
``col`` (``col[i] = v[(-i) mod n]``) because the eigenvalues of a circulant
are exactly ``fft(first column)``::

    C = F^H diag(fft(col)) F          (F = unitary DFT)

so every product / transpose / inverse / composition becomes a pointwise
operation on the length-``n//2+1`` real-FFT spectrum.  This is the O(n)
representation the paper exploits (Fig. 3), and the FFT path is the TPU-native
analogue of the paper's cache-friendly GPU kernels (DESIGN.md Sec. 2).

All operators act on the trailing axis and broadcast over leading batch axes.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

# one shared home for the rfft pair and half-spectrum bookkeeping, used by
# this module and by repro.dist.fft (see repro/ops/spectral.py)
from repro.ops.spectral import gram_inverse_spectrum as _gram_inverse_spectrum
from repro.ops.spectral import irfft as _irfft
from repro.ops.spectral import rfft as _rfft

Array = jax.Array


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Circulant:
    """Square circulant operator, stored as first column + cached spectrum."""

    col: Array  # (n,) real, first column
    spec: Array  # (n//2 + 1,) complex, rfft(col) == eigenvalues (half-plane)

    # -- pytree plumbing -------------------------------------------------
    def tree_flatten(self):
        return (self.col, self.spec), None

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)

    # -- constructors ----------------------------------------------------
    @classmethod
    def from_first_col(cls, col: Array) -> "Circulant":
        col = jnp.asarray(col)
        return cls(col=col, spec=_rfft(col, col.shape[-1]))

    @classmethod
    def from_first_row(cls, row: Array) -> "Circulant":
        """Paper convention: ``A[i, j] = row[(j - i) mod n]``."""
        row = jnp.asarray(row)
        col = jnp.roll(row[..., ::-1], 1, axis=-1)  # col[i] = row[(-i) mod n]
        return cls.from_first_col(col)

    @classmethod
    def from_spectrum(cls, spec: Array, n: int) -> "Circulant":
        col = _irfft(spec, n)
        return cls(col=col, spec=_rfft(col, n))  # re-fft keeps exact pairing

    # -- basic facts -------------------------------------------------------
    @property
    def n(self) -> int:
        return self.col.shape[-1]

    @property
    def first_row(self) -> Array:
        return jnp.roll(self.col[..., ::-1], 1, axis=-1)

    def operator_norm(self) -> Array:
        """Exact spectral norm: max |eigenvalue| = max |fft(col)|.

        rfft covers the full spectrum for real ``col`` (conjugate symmetry).
        """
        return jnp.max(jnp.abs(self.spec))

    def operator_norm_bound(self) -> Array:
        """The RecoveryOperator-protocol bound — exact for circulants."""
        return self.operator_norm()

    # -- algebra (all O(n) / O(n log n)) ----------------------------------
    def matvec(self, x: Array) -> Array:
        """C @ x via the convolution theorem."""
        return _irfft(self.spec * _rfft(x, self.n), self.n)

    def rmatvec(self, x: Array) -> Array:
        """C.T @ x.  For real circulants, spec(C.T) = conj(spec(C))."""
        return _irfft(jnp.conj(self.spec) * _rfft(x, self.n), self.n)

    def gram(self) -> "Circulant":
        """C.T @ C — circulant with spectrum |spec|^2 (real, >= 0)."""
        return Circulant.from_spectrum(
            (jnp.abs(self.spec) ** 2).astype(self.spec.dtype), self.n
        )

    def compose(self, other: "Circulant") -> "Circulant":
        """self @ other — circulants commute and multiply spectra.

        The composed operator stores the *exact* pointwise product spectrum
        (what every matvec / gram-inverse consumes) with its first column
        derived from it once — no irfft→rfft round trip, so composition is
        sheer bookkeeping and ``plan()`` can shard the product directly.
        """
        if self.n != other.n:
            raise ValueError(
                f"cannot compose circulants of different sizes: "
                f"n={self.n} vs n={other.n}"
            )
        spec = self.spec * other.spec
        return Circulant(col=_irfft(spec, self.n), spec=spec)

    def add_scaled_identity(self, rho: float, sigma: float) -> "Circulant":
        """rho * C + sigma * I."""
        return Circulant.from_spectrum(rho * self.spec + sigma, self.n)

    def inverse(self) -> "Circulant":
        """C^{-1} via reciprocal spectrum (paper Alg. 3 line 2: the O(n log n)
        inversion that replaces the O(n^3) dense inverse)."""
        return Circulant.from_spectrum(1.0 / self.spec, self.n)

    def gram_inverse_spectrum(self, rho, sigma) -> Array:
        """Half spectrum of (rho C^T C + sigma I)^{-1} — the CPADMM inner
        inverse (Alg. 3 line 2), pointwise in the spectrum.  This is the
        gram-inverse capability of repro.ops.operator.GramInvertibleOperator.
        """
        return _gram_inverse_spectrum(self.spec, rho, sigma)

    def transpose(self) -> "Circulant":
        return Circulant.from_spectrum(jnp.conj(self.spec), self.n)

    # -- oracles (O(n^2); tests / small-n baselines only) -----------------
    def to_dense(self) -> Array:
        n = self.n
        i = jnp.arange(n)[:, None]
        j = jnp.arange(n)[None, :]
        return self.col[(i - j) % n]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PartialCirculant:
    """A = P @ C: random row subsampling of a square circulant (Sec. 4.3).

    ``P`` is an m-by-n binary row selector for the index set ``omega``.
    This is the paper's sensing operator for CPADMM, and the deblurring
    operator when ``C = C_sense @ B_blur`` (Sec. 7).
    """

    circ: Circulant
    omega: Array  # (m,) int32 sorted row indices

    def tree_flatten(self):
        return (self.circ, self.omega), None

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)

    @property
    def n(self) -> int:
        return self.circ.n

    @property
    def m(self) -> int:
        return self.omega.shape[-1]

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.m, self.n)

    def matvec(self, x: Array) -> Array:
        """A @ x = (C @ x)[omega]."""
        return jnp.take(self.circ.matvec(x), self.omega, axis=-1)

    def rmatvec(self, y: Array) -> Array:
        """A.T @ y = C.T @ (P.T @ y) — scatter then circulant transpose."""
        return self.circ.rmatvec(self.project_back(y))

    def project_back(self, y: Array) -> Array:
        """P.T @ y: scatter m measurements into an n-vector."""
        shape = y.shape[:-1] + (self.n,)
        out = jnp.zeros(shape, y.dtype)
        return out.at[..., self.omega].set(y)

    def operator_norm_bound(self) -> Array:
        """||P C||_2 <= ||C||_2 (P is a selector with norm 1).

        Used for the safe ISTA step size tau < 1/||A||^2 (paper Alg. 1).
        """
        return self.circ.operator_norm()

    def gram_inverse_spectrum(self, rho, sigma) -> Array:
        """Spectrum of (rho C^T C + sigma I)^{-1} for the circulant part —
        what CPADMM's Alg. 3 line 2 inverts (the P part is handled by the
        diagonal D inverse; see repro.core.admm.cpadmm_setup)."""
        return self.circ.gram_inverse_spectrum(rho, sigma)

    def to_dense(self) -> Array:
        return self.circ.to_dense()[self.omega, :]


# ---------------------------------------------------------------------------
# Sensing-operator factories (paper Sec. 6 experimental setup)
# ---------------------------------------------------------------------------


def gaussian_circulant(
    key: Array, n: int, dtype=jnp.float32, normalize: bool = False
) -> Circulant:
    """Paper-faithful: first row drawn i.i.d. standard Gaussian (Sec. 6).

    ``normalize=True`` rescales to unit spectral norm (an O(n) operation,
    exact for circulants).  This leaves the recovery problem equivalent but
    conditions ISTA's step size to tau ~= 1 — the baseline experiments use
    the raw paper scaling, the optimized path normalizes (EXPERIMENTS.md
    §Perf records both).
    """
    row = jax.random.normal(key, (n,), dtype=dtype)
    c = Circulant.from_first_row(row)
    if normalize:
        c = Circulant.from_first_col(c.col / c.operator_norm())
    return c


def romberg_circulant(key: Array, n: int, dtype=jnp.float32) -> Circulant:
    """Beyond-paper: random-convolution sensing (Romberg, SIAM J. Imaging 2009
    — the paper's ref [22]).  Unit-magnitude spectrum with random phase makes
    C orthogonal (C^T C = I), which (a) conditions ISTA perfectly — the safe
    step tau is 1 instead of 1/max|spec|^2, and (b) makes the CPADMM inner
    inverse trivially well-conditioned.  Measurably fewer iterations for the
    same recovery MSE (see benchmarks/bench_ista_recovery.py).
    """
    nfreq = n // 2 + 1
    phase = jax.random.uniform(key, (nfreq,), dtype=dtype) * (2 * jnp.pi)
    spec = jnp.exp(1j * phase.astype(jnp.complex64 if dtype == jnp.float32 else jnp.complex128))
    # DC and (for even n) Nyquist bins must be real for a real time-domain row.
    spec = spec.at[0].set(1.0)
    if n % 2 == 0:
        spec = spec.at[-1].set(1.0)
    col = _irfft(spec, n)  # |spec| == 1 => C^T C = I, ||C||_2 = 1
    return Circulant.from_first_col(col.astype(dtype))


def random_omega(key: Array, n: int, m: int) -> Array:
    """Random m-subset of {0..n-1} (the P matrix diagonal support)."""
    return jnp.sort(jax.random.permutation(key, n)[:m]).astype(jnp.int32)


def partial_gaussian_circulant(
    key: Array, n: int, m: int, dtype=jnp.float32, normalize: bool = False
) -> PartialCirculant:
    kc, ko = jax.random.split(key)
    return PartialCirculant(
        gaussian_circulant(kc, n, dtype, normalize=normalize), random_omega(ko, n, m)
    )


def partial_romberg_circulant(
    key: Array, n: int, m: int, dtype=jnp.float32
) -> PartialCirculant:
    kc, ko = jax.random.split(key)
    return PartialCirculant(romberg_circulant(kc, n, dtype), random_omega(ko, n, m))


# ---------------------------------------------------------------------------
# Blur composition (paper Sec. 7)
# ---------------------------------------------------------------------------


def moving_average_blur(n: int, order: int, dtype=jnp.float32) -> Circulant:
    """Order-L blur: first row = [1/L]*L then zeros, right-circulated (Sec. 7).

    ``order`` must lie in (0, n]: a longer filter would silently truncate
    (``.at[:order].set`` clips out-of-range indices) and the kernel would no
    longer sum to 1.
    """
    if not 0 < order <= n:
        raise ValueError(
            f"blur order must satisfy 0 < order <= n; got order={order}, n={n} "
            f"(an order > n filter would wrap past the signal and truncate)"
        )
    row = jnp.zeros((n,), dtype).at[:order].set(1.0 / order)
    return Circulant.from_first_row(row)


def gaussian_blur(n: int, sigma: float, dtype=jnp.float32) -> Circulant:
    """Gaussian PSF, periodized on the circle: row[j] ~ exp(-d(j)^2 / 2 sigma^2)
    with d(j) = min(j, n - j) the circular distance, normalized to sum 1.

    ``sigma`` must lie in (0, n]: non-positive widths are degenerate and a
    width beyond the signal wraps into a nearly flat (information-destroying)
    kernel — same loudness contract as :func:`moving_average_blur`.
    """
    if not 0 < sigma <= n:
        raise ValueError(
            f"gaussian blur width must satisfy 0 < sigma <= n; got sigma={sigma}, "
            f"n={n} (sigma > n wraps the kernel into a flat average)"
        )
    j = jnp.arange(n, dtype=dtype)
    d = jnp.minimum(j, n - j)
    row = jnp.exp(-0.5 * (d / sigma) ** 2)
    return Circulant.from_first_row(row / jnp.sum(row))


def _bessel_j1(x: Array, nodes: int = 128) -> Array:
    """J1 by fixed midpoint quadrature of (1/pi) \\int_0^pi cos(t - x sin t) dt.

    jax 0.4.x ships no Bessel J; the integral form converges fast for the
    moderate arguments an Airy PSF needs (the far tail is masked off below).
    """
    t = (jnp.arange(nodes, dtype=x.dtype) + 0.5) * (jnp.pi / nodes)
    return jnp.mean(jnp.cos(t - x[..., None] * jnp.sin(t)), axis=-1)


def airy_blur(n: int, radius: float, dtype=jnp.float32) -> Circulant:
    """Airy-disk PSF — the diffraction pattern of a circular telescope
    aperture: intensity (2 J1(u)/u)^2 with ``radius`` the first dark ring
    (u = 3.8317 d / radius), periodized over circular distance, truncated
    past four rings (the tail carries ~0 flux), normalized to sum 1.

    ``radius`` must lie in (0, n]: same validation contract as
    :func:`moving_average_blur`.
    """
    if not 0 < radius <= n:
        raise ValueError(
            f"airy blur radius must satisfy 0 < radius <= n; got radius={radius}, "
            f"n={n} (the first dark ring cannot sit outside the signal)"
        )
    first_zero = 3.8317  # first root of J1
    j = jnp.arange(n, dtype=dtype)
    d = jnp.minimum(j, n - j)
    u = first_zero * d / radius
    safe_u = jnp.where(u > 0, u, 1.0)
    intensity = jnp.where(u > 0, (2.0 * _bessel_j1(safe_u) / safe_u) ** 2, 1.0)
    intensity = jnp.where(d <= 4.0 * radius, intensity, 0.0)
    return Circulant.from_first_row(intensity / jnp.sum(intensity))


def shift_circulant(n: int, shift: int, dtype=jnp.float32) -> Circulant:
    """The raster-offset operator S_s with ``S_s x = roll(x, s)`` — first
    column e_{s mod n}, unit-modulus spectrum.  Composing ``blur @ S_s``
    expresses one offset observation frame of a map-making scan
    (repro.core.mapmaking) as a single circulant."""
    if n <= 0:
        raise ValueError(f"shift circulant needs n > 0; got n={n}")
    col = jnp.zeros((n,), dtype).at[int(shift) % n].set(1.0)
    return Circulant.from_first_col(col)


def compose_sensing_blur(sense: Circulant, blur: Circulant) -> Circulant:
    """A = C @ B — still circulant (the key Sec. 7 observation)."""
    if sense.n != blur.n:
        raise ValueError(
            f"sensing and blur operators act on different signal lengths: "
            f"sense.n={sense.n} vs blur.n={blur.n}; build both for the same "
            f"flattened image size"
        )
    return sense.compose(blur)


# ---------------------------------------------------------------------------
# Dense reference operator (the PISTA / PADMM baseline of Secs. 5.3, 6)
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class DenseOperator:
    """Explicitly materialized m-by-n sensing matrix: the circulant-agnostic
    baseline (PISTA / PADMM).  Memory O(mn); matvec O(mn)."""

    mat: Array  # (m, n)

    def tree_flatten(self):
        return (self.mat,), None

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves)

    @property
    def m(self) -> int:
        return self.mat.shape[-2]

    @property
    def n(self) -> int:
        return self.mat.shape[-1]

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.m, self.n)

    def matvec(self, x: Array) -> Array:
        return jnp.einsum("mn,...n->...m", self.mat, x)

    def rmatvec(self, y: Array) -> Array:
        return jnp.einsum("mn,...m->...n", self.mat, y)

    def operator_norm_bound(self) -> Array:
        """A *guaranteed upper* bound on ||A||_2 (power iteration only gives a
        lower bound, which would make tau unsafe): min of the Holder bound
        sqrt(||A||_1 ||A||_inf) and the Frobenius norm."""
        holder = jnp.sqrt(
            jnp.max(jnp.sum(jnp.abs(self.mat), axis=0))
            * jnp.max(jnp.sum(jnp.abs(self.mat), axis=1))
        )
        frob = jnp.linalg.norm(self.mat)
        return jnp.minimum(holder, frob)

    def to_dense(self) -> Array:
        return self.mat


def densify(op) -> DenseOperator:
    """Materialize any structured operator (for baselines / oracles)."""
    return DenseOperator(op.to_dense())

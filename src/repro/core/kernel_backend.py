"""Pallas-kernel-backed solver steps (TPU execution path).

The solvers in ista.py/admm.py are written against pure-jnp circulant ops
(XLA fuses them well, and on CPU interpret-mode Pallas would be pure
overhead).  On TPU the hot loops swap in the kernels from repro.kernels via
this module; `tests/test_kernel_backend.py` pins exact agreement between the
two backends so the swap is always safe.

Routing: a ``repro.ops.plan`` with ``tail='pallas'`` selects
``cpadmm_step_pallas`` on the local backend (core.solvers.make_stepper) and
the same fused cpadmm_tail kernel inside the distributed step
(dist.recovery._tail) — one registry, both backends.

Step math is identical to ista.ista_step / admm.cpadmm_step — only the
execution substrate changes:
  * direct circulant matvec      -> kernels.circulant_matvec (time domain)
  * threshold + dual update      -> kernels.soft_threshold   (fused VPU)
  * frequency-domain x-update    -> kernels.spectral_pointwise between rffts
  * whole elementwise iter tail  -> kernels.cpadmm_tail (v-update + threshold
                                    + both dual updates, one VMEM pass)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.circulant_matvec.ops import circulant_matvec
from repro.kernels.cpadmm_tail.ops import fused_cpadmm_tail
from repro.kernels.soft_threshold.ops import fused_ista_update
from repro.kernels.spectral_pointwise.ops import spectral_update

# wire-compressed collectives (plan knob wire_dtype=): the demote-pack /
# promote-unpack pair the distributed transforms fuse around every transpose
# all-to-all — registered here like every kernel substrate so both backends
# share one routing point (dist.fft calls these; re-exported for callers
# that follow the registry rather than the kernel package).
from repro.kernels.wire_pack.ops import (  # noqa: F401  (registry re-export)
    WIRE_DTYPES,
    pack_wire,
    unpack_wire,
)

from .admm import CpadmmConst, CpadmmParams, CpadmmState
from .circulant import PartialCirculant
from .ista import IstaParams, IstaState

Array = jax.Array


def ista_step_pallas(
    op: PartialCirculant, y: Array, state: IstaState, p: IstaParams, *,
    interpret: bool = True,
) -> IstaState:
    """CPISTA iteration on the kernel substrate (Algs. 7-8)."""
    col = op.circ.col
    cx = circulant_matvec(col, state.x, interpret=interpret)
    r = y - jnp.take(cx, op.omega, axis=-1)
    rt = jnp.zeros_like(state.x).at[..., op.omega].set(r)
    grad = circulant_matvec(col, rt, transpose=True, interpret=interpret)
    x_new = fused_ista_update(state.x, p.tau * grad, p.alpha * p.tau, interpret=interpret)
    return IstaState(x=x_new, x_prev=state.x, t_mom=state.t_mom)


def cpadmm_step_pallas(
    op: PartialCirculant,
    const: CpadmmConst,
    state: CpadmmState,
    p: CpadmmParams,
    *,
    interpret: bool = True,
) -> CpadmmState:
    """CPADMM iteration: spectral_pointwise x-update + one fused tail pass."""
    n = op.n
    vm = jnp.fft.rfft(state.v + state.mu, axis=-1)
    zn = jnp.fft.rfft(state.z - state.nu, axis=-1)
    x_spec = spectral_update(
        op.circ.spec, const.b_spec.astype(op.circ.spec.dtype), vm, zn,
        p.rho, p.sigma, interpret=interpret,
    )
    x = jnp.fft.irfft(x_spec, n=n, axis=-1)

    cx = circulant_matvec(op.circ.col, x, interpret=interpret)
    # the entire elementwise tail (v-update, threshold, both duals) is one
    # VMEM-resident kernel pass — kernels/cpadmm_tail
    v, z, mu, nu = fused_cpadmm_tail(
        x, cx, const.d_diag, const.Pty, state.mu, state.nu,
        p.rho, p.alpha / p.sigma, p.tau1, p.tau2, interpret=interpret,
    )
    return CpadmmState(x=x, v=v, z=z, mu=mu, nu=nu)

"""Compressed image deblurring (paper Sec. 7).

Blur is modeled as a circulant convolution ``B`` (order-L moving average along
the raster scan, exactly the paper's filter).  Sensing uses a circulant ``C``;
the combined operator ``A = P C B`` is still (partial) circulant, so a single
CPADMM/CPISTA solve *jointly* undoes sub-sampling and blur — "compressed
deblurring".

The paper uses the 1024x1024 Abell-2744 Hubble frame; offline we synthesize a
statistically matched starfield (sparse point sources + a few extended blobs,
~10% nonzero pixels) in ``repro.data.synthetic``.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .circulant import (
    Circulant,
    PartialCirculant,
    compose_sensing_blur,
    gaussian_circulant,
    moving_average_blur,
    random_omega,
    romberg_circulant,
)

Array = jax.Array


class DeblurProblem(NamedTuple):
    op: PartialCirculant  # A = P (C B): the joint sensing+blur operator
    blur: Circulant  # B alone (for rendering the blurred observation)
    y: Array  # compressed measurements of the *blurred* image
    image: Array  # (H, W) ground truth (metrics/rendering only)


def build_deblur_problem(
    key: Array,
    image: Array,
    blur_order: int = 5,
    subsample: float = 0.5,
    sensing: str = "gaussian",
) -> DeblurProblem:
    """Paper Sec. 7 setup: L=5 raster blur, m = n/2 measurements.

    ``sensing='gaussian'`` is paper-faithful; ``'romberg'`` is the
    beyond-paper well-conditioned variant (see circulant.py).
    """
    h, w = image.shape
    n = h * w
    m = int(round(n * subsample))
    x = image.reshape(n)

    kc, ko = jax.random.split(key)
    make = gaussian_circulant if sensing == "gaussian" else romberg_circulant
    sense = make(kc, n, dtype=x.dtype)
    blur = moving_average_blur(n, blur_order, dtype=x.dtype)
    joint = compose_sensing_blur(sense, blur)  # C B, circulant
    omega = random_omega(ko, n, m)
    op = PartialCirculant(joint, omega)

    y = op.matvec(x)  # y = P C (B x): sense the blurred image
    return DeblurProblem(op=op, blur=blur, y=y, image=image)


def blurred_observation(problem: DeblurProblem) -> Array:
    """The Fig. 9(b) rendering: B x reshaped to the image grid."""
    h, w = problem.image.shape
    return problem.blur.matvec(problem.image.reshape(-1)).reshape(h, w)


def recovered_image(problem: DeblurProblem, x: Array) -> Array:
    h, w = problem.image.shape
    return x.reshape(h, w)


def deblur_metrics(problem: DeblurProblem, x: Array) -> dict:
    """Paper Sec. 7 metrics: MSE, normalized MSE, normalized abs error map."""
    truth = problem.image.reshape(-1)
    err = truth - x
    mse = jnp.mean(err * err)
    scale = jnp.mean(truth * truth) + 1e-12
    mean_int = jnp.mean(truth) + 1e-12
    return {
        "mse": mse,
        "normalized_mse": mse / scale,
        "mean_abs_err_over_mean_intensity": jnp.mean(jnp.abs(err)) / mean_int,
    }

"""Compressed image deblurring (paper Sec. 7).

Blur is modeled as a circulant convolution ``B`` (order-L moving average along
the raster scan, exactly the paper's filter).  Sensing uses a circulant ``C``;
the combined operator ``A = P C B`` is still (partial) circulant, so a single
CPADMM/CPISTA solve *jointly* undoes sub-sampling and blur — "compressed
deblurring".

The paper uses the 1024x1024 Abell-2744 Hubble frame; offline we synthesize a
statistically matched starfield (sparse point sources + a few extended blobs,
~10% nonzero pixels) in ``repro.data.synthetic``.

Multi-frame: real astronomical pipelines hand over *stacks* of frames
observed through the same optics (Herschel/PACS-style map-making), so
``build_multiframe_deblur_problem`` senses a (F, H, W) stack through one
shared operator and every helper here broadcasts over leading frame axes —
one batched CPADMM solve deblurs the whole stack.

Backends: ``build_deblur_plan`` lowers the joint operator through the
execution-plan layer (``repro.ops.plan``) — the same deblur solve runs
single-device or sharded over a mesh (frames over the data axis, each
frame's transforms over the model axis), with the composed spectrum
``spec(C)·spec(B)`` built and sharded exactly once.  The distributed solve
is pinned to the single-device one at 1e-5 rel (tests/test_deblur.py,
tests/dist_progs/deblur_prog.py).
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .circulant import (
    Circulant,
    PartialCirculant,
    airy_blur,
    compose_sensing_blur,
    gaussian_blur,
    gaussian_circulant,
    moving_average_blur,
    random_omega,
    romberg_circulant,
)

Array = jax.Array

BLUR_KINDS = ("moving-average", "gaussian", "airy")


def _make_blur(n: int, kind: str, order: float, dtype) -> Circulant:
    """Dispatch a PSF family by name; ``order`` is the family's width knob.

    moving-average takes the paper's integer raster length L; gaussian reads
    it as the std-dev sigma (pixels); airy as the first-null radius (pixels).
    Each builder does its own loud 0 < order <= n validation.
    """
    if kind == "moving-average":
        return moving_average_blur(n, int(order), dtype=dtype)
    if kind == "gaussian":
        return gaussian_blur(n, float(order), dtype=dtype)
    if kind == "airy":
        return airy_blur(n, float(order), dtype=dtype)
    raise ValueError(f"blur_kind must be one of {BLUR_KINDS}, got {kind!r}")


class DeblurProblem(NamedTuple):
    op: PartialCirculant  # A = P (C B): the joint sensing+blur operator
    blur: Circulant  # B alone (for rendering the blurred observation)
    y: Array  # (..., m) compressed measurements of the *blurred* image(s)
    image: Array  # (..., H, W) ground truth (metrics/rendering only)


def build_deblur_problem(
    key: Array,
    image: Array,
    blur_order: float = 5,
    subsample: float = 0.5,
    sensing: str = "gaussian",
    blur_kind: str = "moving-average",
) -> DeblurProblem:
    """Paper Sec. 7 setup: L=5 raster blur, m = n/2 measurements.

    ``sensing='gaussian'`` is paper-faithful; ``'romberg'`` is the
    beyond-paper well-conditioned variant (see circulant.py).
    ``blur_kind`` picks the PSF family (``moving-average`` is the paper's
    raster filter; ``gaussian``/``airy`` are the astronomy-realistic
    circulant PSFs) with ``blur_order`` as its width knob — see
    :func:`_make_blur`.
    """
    if image.ndim != 2:
        raise ValueError(
            f"build_deblur_problem takes a single (H, W) image; got shape "
            f"{tuple(image.shape)} — for a frame stack use "
            f"build_multiframe_deblur_problem"
        )
    h, w = image.shape
    n = h * w
    m = int(round(n * subsample))
    x = image.reshape(n)

    kc, ko = jax.random.split(key)
    make = gaussian_circulant if sensing == "gaussian" else romberg_circulant
    sense = make(kc, n, dtype=x.dtype)
    blur = _make_blur(n, blur_kind, blur_order, x.dtype)
    joint = compose_sensing_blur(sense, blur)  # C B, circulant
    omega = random_omega(ko, n, m)
    op = PartialCirculant(joint, omega)

    y = op.matvec(x)  # y = P C (B x): sense the blurred image
    return DeblurProblem(op=op, blur=blur, y=y, image=image)


def build_multiframe_deblur_problem(
    key: Array,
    images: Array,
    blur_order: float = 5,
    subsample: float = 0.5,
    sensing: str = "gaussian",
    blur_kind: str = "moving-average",
) -> DeblurProblem:
    """Sec. 7 setup for a (F, H, W) frame stack through ONE shared optic.

    All frames see the same blur + sensing operator (the telescope does not
    change between exposures), so ``y`` is (F, m) and one batched solve
    recovers the whole stack: build a ``RecoveryProblem`` with the returned
    op and the batched ``y`` and call ``core.solvers.solve`` as usual.
    """
    if images.ndim < 3:
        raise ValueError(
            f"build_multiframe_deblur_problem takes a (..., F, H, W)-like "
            f"frame stack (ndim >= 3); got shape {tuple(images.shape)} — for "
            f"a single image use build_deblur_problem"
        )
    single = build_deblur_problem(
        key, images.reshape(-1, *images.shape[-2:])[0],
        blur_order=blur_order, subsample=subsample, sensing=sensing,
        blur_kind=blur_kind,
    )
    n = images.shape[-2] * images.shape[-1]
    x = images.reshape(images.shape[:-2] + (n,))
    return DeblurProblem(
        op=single.op, blur=single.blur, y=single.op.matvec(x), image=images
    )


def build_deblur_plan(
    problem: DeblurProblem,
    mesh=None,
    *,
    config=None,
    tune=False,
    batch: int | None = None,
    n1: int | None = None,
    n2: int | None = None,
    rfft: bool | None = None,
    overlap: int | None = None,
    tail: str | None = None,
    fused: bool | None = None,
    batch_axis: str | None = None,
    axis_name: str | None = None,
    wire_dtype: str | None = None,
    prox=None,
):
    """Lower the joint sensing+blur operator ``A = P (C B)`` to a backend.

    The paper's flagship scenario on any backend: with ``mesh=None`` the
    identity lowering (the single-device solve); with a mesh, the composed
    spectrum ``spec(C)·spec(B)`` — already stored on the operator — is laid
    out and column-sharded once (no dense/time-domain round trip; see
    ``repro.ops.spectral.spectrum_layout_2d``) and every solver method runs
    through the sharded four-step transforms.

    Knobs arrive as ``config=repro.ops.PlanConfig(...)`` or as the
    individual keyword arguments (the compat path; mixing the two is an
    error, validated by ``repro.ops.resolve_plan_config`` like every other
    plan entry point).  Compat-path defaults are deblur-aware: the
    four-step factorization ``n1 x n2`` is the image's own (H, W) grid
    whenever it shards over the mesh axis (so the layout matches the raster
    the blur acts along), and a multi-frame stack is sharded over the
    mesh's ``data`` axis when one exists — one batched distributed solve
    deblurs the whole stack, every frame sharing each transform's single
    all-to-all.  A full ``config`` is taken verbatim (no deblur defaults —
    it is already explicit about every knob).

    ``tune=True`` / ``tune="measure"`` delegates the choice to the plan
    autotuner (:mod:`repro.ops.tune`): explicitly-passed knobs become pins,
    the frame stack sizes the tuning batch, and the image's own (H, W) grid
    is offered as an extra candidate factorization.
    """
    from repro.ops import plan as _plan

    frames = problem.image.ndim > 2
    if batch is None and frames:
        batch = math.prod(problem.image.shape[:-2])
    if mesh is None and not tune:
        # the single validation site rejects distributed-only knobs
        # (rfft/overlap/batch_axis) passed without a mesh
        return _plan(problem.op, config=config, rfft=rfft, overlap=overlap,
                     tail=tail, fused=fused, batch_axis=batch_axis,
                     wire_dtype=wire_dtype, prox=prox)
    h, w = problem.image.shape[-2:]
    if tune:
        pins = {
            k: v
            for k, v in dict(
                n1=n1, n2=n2, rfft=rfft, overlap=overlap, tail=tail,
                fused=fused, batch_axis=batch_axis, axis_name=axis_name,
                wire_dtype=wire_dtype, prox=prox,
            ).items()
            if v is not None
        }
        return _plan(
            problem.op, mesh, config=config, tune=tune, batch=batch,
            tune_opts={"extra_factorizations": [(h, w)]}, **pins,
        )
    if config is None:
        axis = axis_name if axis_name is not None else "model"
        if n1 is None and n2 is None:
            p = mesh.shape[axis]
            if h % p == 0 and (rfft or w % p == 0):
                n1, n2 = h, w
        if (
            batch_axis is None
            and frames
            and "data" in mesh.axis_names
            and axis != "data"
        ):
            batch_axis = "data"
    return _plan(
        problem.op, mesh, config=config, n1=n1, n2=n2, rfft=rfft,
        overlap=overlap, tail=tail, fused=fused, batch_axis=batch_axis,
        axis_name=axis_name, wire_dtype=wire_dtype, prox=prox,
    )


def blurred_observation(problem: DeblurProblem) -> Array:
    """The Fig. 9(b) rendering: B x reshaped to the image grid(s)."""
    shape = problem.image.shape
    flat = problem.image.reshape(shape[:-2] + (-1,))
    return problem.blur.matvec(flat).reshape(shape)


def recovered_image(problem: DeblurProblem, x: Array) -> Array:
    return x.reshape(x.shape[:-1] + problem.image.shape[-2:])


def deblur_metrics(problem: DeblurProblem, x: Array) -> dict:
    """Paper Sec. 7 metrics + PSNR, per frame over leading batch axes.

    ``x`` is (..., n); each metric comes back with the batch shape (scalars
    when unbatched).  PSNR uses the ground-truth peak intensity per frame;
    an all-zero frame has no peak to reference, so its PSNR is the ``-inf``
    sentinel rather than the misleading finite number an epsilon'd peak
    would produce.
    """
    shape = problem.image.shape
    truth = problem.image.reshape(shape[:-2] + (-1,))
    err = truth - x
    mse = jnp.mean(err * err, axis=-1)
    scale = jnp.mean(truth * truth, axis=-1) + 1e-12
    mean_int = jnp.mean(truth, axis=-1) + 1e-12
    peak = jnp.max(jnp.abs(truth), axis=-1)
    safe_peak = jnp.where(peak > 0, peak, 1.0)  # keep the log10 NaN-free
    psnr = jnp.where(
        peak > 0,
        10.0 * jnp.log10(safe_peak * safe_peak / (mse + 1e-20)),
        -jnp.inf,
    )
    return {
        "mse": mse,
        "normalized_mse": mse / scale,
        "mean_abs_err_over_mean_intensity": jnp.mean(jnp.abs(err), axis=-1) / mean_int,
        "psnr_db": psnr,
    }

"""ADMM for LASSO: dense baseline (paper Alg. 2) and circulant CPADMM (Alg. 3).

Dense ADMM (PADMM baseline)
    Pays the O(n^3) inverse of (A^T A + rho I) up front and stores the n x n
    inverse — the exact cost profile the paper measures in Figs. 3-4.

Circulant ADMM (CPADMM)
    For A = P C (partial circulant) the splitting of Yin et al. [25] makes
    both inner inverses structured:
        B = (rho C^T C + sigma I)^{-1}   — circulant: reciprocal spectrum,
                                           O(n log n) instead of O(n^3)
        D = (P^T P + rho I)^{-1}         — diagonal: 1/(1+rho) on Omega,
                                           1/rho elsewhere
    Each iteration is then 3 circulant matvecs (C^T v, C x twice — we reuse
    one) + elementwise work: exactly the paper's three GPU kernels
    (Algs. 4, 5, 6), here expressed in the FFT domain.

We implement the *scaled-dual* form of Alg. 3, which is algebraically the
paper's update with its trailing ``v <- v + mu`` folding (see the derivation
note in DESIGN.md Sec. 1 / tests/test_solvers.py::test_cpadmm_matches_paper_form).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.ops.spectral import apply_spectrum

from .circulant import DenseOperator, PartialCirculant
from .soft_threshold import soft_threshold

Array = jax.Array


# ---------------------------------------------------------------------------
# Dense ADMM — paper Alg. 2 (the PADMM baseline)
# ---------------------------------------------------------------------------


class DenseAdmmState(NamedTuple):
    x: Array
    z: Array
    u: Array


class DenseAdmmConst(NamedTuple):
    """Per-problem constants: the O(n^2)-memory inverse the paper measures."""

    B: Array  # (n, n) = (A^T A + rho I)^{-1}
    Aty: Array  # (n,) = A^T y


def dense_admm_setup(op: DenseOperator, y: Array, rho: float) -> DenseAdmmConst:
    """Alg. 2 line 2: the O(n^3) inversion (timed separately as PADMM-I)."""
    A = op.to_dense()
    n = A.shape[1]
    gram = A.T @ A + rho * jnp.eye(n, dtype=A.dtype)
    B = jnp.linalg.inv(gram)
    return DenseAdmmConst(B=B, Aty=op.rmatvec(y))


def dense_admm_init(op, y: Array) -> DenseAdmmState:
    batch = y.shape[:-1]
    z = jnp.zeros(batch + (op.n,), y.dtype)
    return DenseAdmmState(x=z, z=z, u=z)


def dense_admm_step(
    const: DenseAdmmConst, state: DenseAdmmState, alpha: float, rho: float, prox=None
) -> DenseAdmmState:
    """Alg. 2 lines 4-6 (``prox=None`` = the paper's soft threshold)."""
    x = jnp.einsum(
        "nk,...k->...n", const.B, const.Aty + rho * (state.z - state.u)
    )
    if prox is None:
        z = soft_threshold(x + state.u, alpha / rho)
    else:
        z = prox.apply(x + state.u, alpha / rho)
    u = state.u + x - z
    return DenseAdmmState(x=x, z=z, u=u)


# ---------------------------------------------------------------------------
# Circulant ADMM — paper Alg. 3 (CPADMM)
# ---------------------------------------------------------------------------


class CpadmmState(NamedTuple):
    x: Array  # primal estimate (the recovered signal)
    v: Array  # primal splitting variable, v ~= C x
    z: Array  # l1 auxiliary
    mu: Array  # scaled dual for v = C x
    nu: Array  # scaled dual for z = x


class CpadmmConst(NamedTuple):
    b_spec: Array  # rfft spectrum of B = (rho C^T C + sigma I)^{-1}
    d_diag: Array  # (n,) diagonal of D = (P^T P + rho I)^{-1}
    Pty: Array  # (..., n) = P^T y scattered measurements


class CpadmmParams(NamedTuple):
    alpha: Array
    rho: Array
    sigma: Array
    tau1: Array  # dual step, in (0, (sqrt(5)+1)/2) per paper Sec. 4.3
    tau2: Array


def cpadmm_setup(op: PartialCirculant, y: Array, p: CpadmmParams) -> CpadmmConst:
    """Alg. 3 line 2 — the FFT-based O(n log n) inversion.

    spec(rho C^T C + sigma I) = rho |spec(C)|^2 + sigma  (real, positive), so
    B's spectrum is its pointwise reciprocal (the operator's gram-inverse
    capability; one definition in repro.ops.spectral shared with the
    distributed plan layer).  D is diagonal by inspection.
    """
    b_spec = op.gram_inverse_spectrum(p.rho, p.sigma)
    d_diag = jnp.full((op.n,), 1.0 / p.rho, dtype=y.dtype)
    d_diag = d_diag.at[op.omega].set(1.0 / (1.0 + p.rho))
    return CpadmmConst(b_spec=b_spec, d_diag=d_diag, Pty=op.project_back(y))


def cpadmm_init(op: PartialCirculant, y: Array) -> CpadmmState:
    batch = y.shape[:-1]
    zeros = jnp.zeros(batch + (op.n,), y.dtype)
    return CpadmmState(x=zeros, v=zeros, z=zeros, mu=zeros, nu=zeros)


def _apply_spec(spec: Array, x: Array, n: int) -> Array:
    return apply_spectrum(spec, x, n)


def cpadmm_tail(
    x: Array, cx: Array, d_diag: Array, pty: Array, mu: Array, nu: Array, p, prox=None
) -> tuple:
    """The iteration tail shared by every CPADMM variant.

    Everything in Alg. 3 after the two circulant applies (x and Cx) is
    the v-update, the z-update, and both dual updates.  Single- and
    multi-device steps call this one definition so the jnp path and the
    fused Pallas kernel (kernels/cpadmm_tail) are pinned against the same
    math.  ``p`` is any params tuple exposing alpha/rho/sigma/tau1/tau2
    (CpadmmParams or DistCpadmmParams).  ``prox=None`` is the paper's
    soft-threshold z-update, under which the whole tail is elementwise
    (the fused-kernel contract); a ``Prox`` swaps the prior.
    Returns (v, z, mu', nu').
    """
    v = d_diag * (pty + p.rho * (cx - mu))
    if prox is None:
        z = soft_threshold(x + nu, p.alpha / p.sigma)
    else:
        z = prox.apply(x + nu, p.alpha / p.sigma)
    mu_new = mu + p.tau1 * (v - cx)
    nu_new = nu + p.tau2 * (x - z)
    return v, z, mu_new, nu_new


def cpadmm_step(
    op: PartialCirculant, const: CpadmmConst, state: CpadmmState, p: CpadmmParams, prox=None
) -> CpadmmState:
    """One Alg. 3 iteration (scaled-dual form).

    x-update:  (rho C^T C + sigma I) x = rho C^T (v + mu) + sigma (z - nu)
               -> two spectra fused: B and C^T (kernel: spectral_pointwise)
    v-update:  (P^T P + rho I) v = P^T y + rho (C x - mu)
    z-update:  soft threshold (Alg. 3 line 5)
    duals:     mu += tau1 (v - Cx);  nu += tau2 (x - z)
    (the last three are :func:`cpadmm_tail` — one fused Pallas pass on the
    kernel backend, kernels/cpadmm_tail)
    """
    C = op.circ
    n = op.n
    rhs = p.rho * C.rmatvec(state.v + state.mu) + p.sigma * (state.z - state.nu)
    x = _apply_spec(const.b_spec, rhs, n)

    cx = C.matvec(x)
    v, z, mu, nu = cpadmm_tail(
        x, cx, const.d_diag, const.Pty, state.mu, state.nu, p, prox=prox
    )
    return CpadmmState(x=x, v=v, z=z, mu=mu, nu=nu)


def default_cpadmm_params(
    alpha: float = 1e-4, rho: float = 0.1, sigma: float = 0.1, tau: float = 1.0
) -> CpadmmParams:
    """Paper Sec. 6 defaults: alpha = 1e-4, sigma = tau = 1e-1."""
    f32 = jnp.float32
    return CpadmmParams(
        alpha=jnp.asarray(alpha, f32),
        rho=jnp.asarray(rho, f32),
        sigma=jnp.asarray(sigma, f32),
        tau1=jnp.asarray(tau, f32),
        tau2=jnp.asarray(tau, f32),
    )

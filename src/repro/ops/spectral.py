"""Shared spectral helpers: the one home for rfft bookkeeping.

Every layer of the stack manipulates spectra of *real* signals, so the
half-spectrum (rfft) representation and its Hermitian bookkeeping show up
everywhere: the single-device circulant algebra (``repro.core.circulant``
stores eigenvalues as ``rfft(first column)``), the CPADMM inner inverse
(``repro.core.admm``), and the distributed four-step transforms
(``repro.dist.fft`` keeps ``n2//2 + 1`` columns on the wire).  These
helpers used to be copied privately between ``core/circulant.py`` and
``dist/fft.py``; they live here once, dependency-free (jax only), so both
import the same definitions.

Conventions: 1-D transforms act on the trailing axis and broadcast over
leading batch axes; ``n2``/``p`` in the half-spectrum helpers refer to the
four-step layout's column count and mesh size (see ``repro.dist.fft``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


# --------------------------------------------------------------------------
# trailing-axis real FFT pair (the core circulant algebra's workhorses)
# --------------------------------------------------------------------------


def rfft(x: Array, n: int) -> Array:
    """Length-``n`` real FFT along the trailing axis."""
    return jnp.fft.rfft(x, n=n, axis=-1)


def irfft(x: Array, n: int) -> Array:
    """Length-``n`` inverse real FFT along the trailing axis."""
    return jnp.fft.irfft(x, n=n, axis=-1)


def apply_spectrum(spec: Array, x: Array, n: int) -> Array:
    """``irfft(spec * rfft(x))`` — one circulant application by the
    convolution theorem (paper Sec. 4's C = F^H diag(spec) F identity)."""
    return irfft(spec * rfft(x, n), n)


def gram_inverse_spectrum(spec: Array, rho, sigma) -> Array:
    """Spectrum of ``(rho C^T C + sigma I)^{-1}`` from the spectrum of C.

    Paper Alg. 3 line 2: ``spec(rho C^T C + sigma I) = rho |spec|^2 + sigma``
    (real, positive), so the inverse is the pointwise reciprocal — the
    O(n log n) inversion that replaces the dense O(n^3) one.  Works on any
    spectrum layout (full, half, or the distributed column-sharded block):
    the identity is pointwise.
    """
    return (1.0 / (rho * jnp.abs(spec) ** 2 + sigma)).astype(spec.dtype)


# --------------------------------------------------------------------------
# half-spectrum (rfft) bookkeeping for the four-step (n1, n2) layout
# --------------------------------------------------------------------------


def full_from_half(spec_h: Array, n: int) -> Array:
    """Flat half spectrum (..., n//2 + 1) -> full flat DFT (..., n).

    Hermitian symmetry of a real signal's DFT, ``X[n - k] = conj(X[k])``,
    reconstructs the discarded bins.  This is pure bookkeeping (a conjugate
    flip + concatenate) — no transform runs, which is what lets a circulant's
    stored half spectrum be re-laid-out for any backend without a time-domain
    round trip (see :func:`spectrum_layout_2d`).  The flat case is
    :func:`half_to_full` on a single-row (n1 = 1) layout — one home for the
    symmetry math.
    """
    return half_to_full(spec_h[..., None, :], n)[..., 0, :]


def spectrum_layout_2d(
    spec_h: Array, n1: int, n2: int, *, rfft: bool = False, p: int = 1
) -> Array:
    """Flat half spectrum -> the four-step ``(n1, n2)`` spectrum layout.

    The four-step transform of :mod:`repro.dist.fft` produces
    ``F[k1, k2] = X[n2*k1 + k2]``, so the layout is a plain row-major reshape
    of the full flat DFT — meaning a circulant whose spectrum is already
    known (e.g. the composed sensing+blur operator ``spec(C)·spec(B)`` of
    paper Sec. 7) lowers onto the mesh with *zero* transforms: no irfft back
    to the first column, no distributed FFT of it.  ``rfft=True`` returns
    the half layout the rfft solver path consumes — the kept columns
    ``k2 in [0, n2//2]`` zero-padded to a multiple of the mesh size ``p``
    (matching ``rfft2_local``'s output exactly).
    """
    n = n1 * n2
    F = full_from_half(spec_h, n).reshape(spec_h.shape[:-1] + (n1, n2))
    if not rfft:
        return F
    nf, nf_pad = rfft_len(n2), padded_rfft_len(n2, p)
    pads = [(0, 0)] * F.ndim
    pads[-1] = (0, nf_pad - nf)
    return jnp.pad(F[..., :nf], pads)


def rfft_len(n2: int) -> int:
    """Kept columns of the half spectrum: k2 in [0, n2//2]."""
    return n2 // 2 + 1


def padded_rfft_len(n2: int, p: int) -> int:
    """Kept columns zero-padded up to a multiple of the mesh size ``p`` so
    the transpose-collective can split them evenly on any device count."""
    nf = rfft_len(n2)
    return -(-nf // p) * p


def half_to_full(Fh: Array, n2: int) -> Array:
    """Half-spectrum layout (..., n1, >=nf) -> full spectrum (..., n1, n2).

    The discarded columns follow from Hermitian symmetry of the flat DFT,
    ``X[n - k] = conj(X[k])``: with ``k = n2*k1 + k2`` that reads

        F[k1, k2] = conj(F[n1 - 1 - k1, n2 - k2])    for k2 in [nf, n2).

    Verification/bridging helper — solvers never materialize the full half.
    """
    nf = rfft_len(n2)
    Fh = Fh[..., :nf]
    tail = jnp.flip(jnp.conj(Fh[..., 1 : n2 - nf + 1]), axis=(-2, -1))
    return jnp.concatenate([Fh, tail], axis=-1)

"""The operator contract the solver drivers are generic over.

Everything in the paper's solver family touches a sensing operator through
four capabilities, and nothing else:

    matvec(x)                A @ x        (Alg. 1 line 3, Alg. 3 line 4)
    rmatvec(y)               A^T @ y      (Alg. 1 line 4, Alg. 3 line 3)
    operator_norm_bound()    an upper bound on ||A||_2, for the safe ISTA
                             step size tau < 1/||A||^2 (Alg. 1 init)
    n                        signal length

All of them are batch-aware: they act on the trailing axis and broadcast
over leading batch axes (the drivers' B-signals-one-operator workload).
``repro.core.circulant`` provides the three concrete families —
``DenseOperator`` (the PISTA/PADMM baseline), ``Circulant``, and
``PartialCirculant`` — and :func:`repro.ops.plan` lowers any conforming
operator to an execution backend (local matvecs, or the sharded four-step
transforms of ``repro.dist``).

The gram-inverse capability (``gram_inverse_spectrum``) is the extra
structure CPADMM needs (Alg. 3 line 2): operators built on a circulant can
invert ``rho A^T A + sigma I`` as a pointwise spectral reciprocal.  It is a
separate protocol because dense operators pay O(n^3) for the same inverse
(``repro.core.admm.dense_admm_setup``).
"""

from __future__ import annotations

from typing import Protocol, runtime_checkable

import jax

Array = jax.Array


@runtime_checkable
class RecoveryOperator(Protocol):
    """Minimal operator surface consumed by every solver driver."""

    @property
    def n(self) -> int:  # signal length (trailing-axis extent of x)
        ...

    def matvec(self, x: Array) -> Array:
        """A @ x, broadcasting over leading batch axes."""
        ...

    def rmatvec(self, y: Array) -> Array:
        """A^T @ y, broadcasting over leading batch axes."""
        ...

    def operator_norm_bound(self) -> Array:
        """A guaranteed *upper* bound on ||A||_2 (safe ISTA step sizes)."""
        ...


@runtime_checkable
class GramInvertibleOperator(RecoveryOperator, Protocol):
    """Operators whose regularized gram matrix inverts in the spectrum.

    ``gram_inverse_spectrum(rho, sigma)`` returns the (half) spectrum of
    ``(rho C^T C + sigma I)^{-1}`` where C is the operator's circulant part
    — the O(n log n) Alg. 3 line 2 inversion CPADMM is built on.
    """

    def gram_inverse_spectrum(self, rho, sigma) -> Array:
        ...

"""Execution plans: lower any RecoveryOperator to a solver backend.

``plan(op)`` is the identity lowering — the operator's own matvecs run on
one device, bit-exactly (tests/test_plan.py pins this).  ``plan(op, mesh)``
lowers the same operator to the sharded four-step transforms of
:mod:`repro.dist.fft`: matvecs become shard_mapped FFT applications (two
transpose-collectives each), and the CPADMM inner inverse stays a pointwise
spectral reciprocal on the column-sharded spectrum block.  Either way the
result is consumed by the *same* drivers — ``repro.core.solvers``'s
``solve`` / ``solve_until`` / ``solve_checkpointed`` take ``plan=`` and run
every method (ista / fista / cpadmm) on every backend, so tolerance
stopping, metric traces, per-signal convergence freezing, and
checkpoint/restart come for free on a mesh.

Distributed measurement convention
----------------------------------
On a mesh the m-subset gather/scatter of ``P`` would be a cross-shard
permutation, so the plan works in the *mask form* of the partial circulant:
``M = diag(mask) C`` with measurements scattered full-length
(``y_full = P^T y``).  The two forms produce identical solver iterates —
``M^T M = A^T A`` and ``M^T y_full = A^T y`` — and the drivers accept either
``problem.y`` of length m (scattered here via ``op.project_back``) or an
already-scattered length-n vector.

Plan attributes = backend knobs
-------------------------------
    rfft        half-spectrum transforms (half the FFT flops / wire bytes)
    overlap=K   chunked transpose-collectives overlapped with the local FFT
    tail        'jnp' or 'pallas' — the CPADMM elementwise-tail substrate
                (the fused kernels/cpadmm_tail VMEM pass); honored by the
                local backend too via core.kernel_backend
    fused       frequency-domain CPADMM x-update (2 all-to-alls/iter vs 6)
    batch_axis  mesh axis a leading batch of signals is sharded over
    wire_dtype  'fp32' (default) / 'bf16' / 'fp16' — the transpose
                all-to-all payload precision (repro.dist.fft wire packing);
                ``plan`` guards demoted wires with a one-matvec precision
                probe and falls back to fp32 past :data:`WIRE_ERROR_BOUND`
    hier_axes   (H, D) — run every transpose as the two-stage hierarchical
                exchange over the mesh's (host, device) axis pair
                (repro.dist.fft module docstring): intra-host all-to-all,
                local reshuffle, then inter-host hops carrying only the
                (H-1)/H cross-boundary payload.  None (default) keeps the
                flat exchange; a tuple ``axis_name=(host, device)`` with
                ``hier_axes=None`` is the flat layout *on* a hierarchical
                mesh (one monolithic all-to-all over both tiers)
    inter_wire_dtype  wire precision of only the inter-host (DCN) hops of
                the hierarchical exchange; guarded together with wire_dtype

All knobs live in one frozen, hashable :class:`PlanConfig` (also carrying
the four-step ``n1 x n2`` factorization and the mesh ``axis_name``): every
plan entry point — ``plan``, ``plan_from_parts``,
``launch.recover.build_plan``, ``core.deblur.build_deblur_plan`` — accepts
``config=PlanConfig(...)``, with the individual keyword arguments kept as a
thin compat path that constructs the same ``PlanConfig``
(:func:`resolve_plan_config` is the single validation site).  The config is
also the tuner's unit of currency: ``plan(op, mesh, tune=True)`` asks
:mod:`repro.ops.tune` to pick the config by cost model (see that module),
and the JSON tune cache stores winning configs verbatim.

All knobs are numerically pinned to their defaults
(tests/test_dist_equiv.py, tests/test_plan.py).
"""

from __future__ import annotations

import dataclasses
import functools
import math
import os
import warnings
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.dist.compat import shard_map
from repro.dist.fft import (
    DEVICE_AXIS,
    HOST_AXIS,
    MODEL_AXIS,
    WIRE_DTYPES,
    col_spec,
    layout_2d,
    matvec_local,
    rmatvec_local,
    row_spec,
    unlayout_2d,
)
from repro.dist.recovery import (
    DistCpadmmParams,
    DistCpadmmState,
    dist_cpadmm_core,
    dist_cpadmm_step,
    dist_cpadmm_step_fused,
)

from . import prox as prox_mod
from . import spectral

Array = jax.Array

_ISTA_METHODS = ("ista", "fista", "cpista")

# wire-precision guard: plan(..) with wire_dtype != 'fp32' probes one matvec
# against the fp32-wire plan and falls back (RuntimeWarning) when the
# relative error exceeds this bound.  Overridable for experiments via the
# REPRO_WIRE_ERROR_BOUND env var; the documented default tolerates bf16's
# ~3 decimal digits across the two transposes of a matvec with margin.
WIRE_ERROR_BOUND = float(os.environ.get("REPRO_WIRE_ERROR_BOUND", "1e-2"))


def _factorize(n: int, n1: Optional[int], n2: Optional[int], p: int, rfft: bool):
    """Pick/validate the four-step n = n1 x n2 split for a p-device axis.

    Constraints come from the transpose-collectives: rows (n1) must split
    evenly over the axis, and so must the spectrum columns unless the rfft
    path pads them (``spectral.padded_rfft_len``).
    """
    if n1 is not None and n2 is None:
        n2 = n // n1
    if n1 is None and n2 is not None:
        n1 = n // n2
    if n1 is None:
        for cand in range(math.isqrt(n), 0, -1):
            if n % cand:
                continue
            a, b = cand, n // cand
            if a % p == 0 and (rfft or b % p == 0):
                n1, n2 = a, b
                break
        else:
            raise ValueError(
                f"no n1 x n2 = {n} factorization shards over {p} devices; "
                f"pass n1/n2 explicitly"
            )
    if n1 * n2 != n:
        raise ValueError(f"n1 * n2 = {n1}*{n2} != n = {n}")
    if n1 % p:
        raise ValueError(f"n1 = {n1} must be divisible by the mesh axis size {p}")
    if not rfft and n2 % p:
        raise ValueError(
            f"n2 = {n2} must be divisible by the mesh axis size {p} "
            f"(or use rfft=True, which pads the kept columns)"
        )
    return n1, n2


@dataclasses.dataclass(frozen=True)
class PlanConfig:
    """Every backend knob of an execution plan, in one frozen hashable value.

    The fields are exactly the plan attributes documented in the module
    docstring plus the four-step factorization (``n1 x n2``) and the mesh
    axis the within-signal transforms shard over.  ``n1``/``n2`` left as
    ``None`` means "auto-factorize near sqrt(n)" (``plan``) — they must be
    concrete for ``plan_from_parts``, which has no operator to read ``n``
    from.

    A ``PlanConfig`` is hashable and JSON round-trippable (``to_dict`` /
    ``from_dict``), which is what lets the autotuner (:mod:`repro.ops.tune`)
    use it both as the candidate-space element and as the cached winner.
    """

    rfft: bool = False
    overlap: int = 1
    tail: str = "jnp"
    fused: bool = True
    batch_axis: Any = None
    n1: Optional[int] = None
    n2: Optional[int] = None
    axis_name: Any = MODEL_AXIS
    wire_dtype: str = "fp32"
    hier_axes: Any = None  # (H, D): two-stage transpose over (host, device)
    inter_wire_dtype: str = "fp32"  # DCN-hop payload of the two-stage path
    prox: Any = None  # the prior (repro.ops.prox.Prox); None = l1 threshold

    def validate(self, distributed: bool) -> "PlanConfig":
        """THE validation site for plan knobs (every entry point funnels
        here via :func:`resolve_plan_config`); returns self for chaining."""
        if self.tail not in ("jnp", "pallas"):
            raise ValueError(f"tail must be 'jnp' or 'pallas', got {self.tail!r}")
        if self.prox is not None and not (
            hasattr(self.prox, "apply") and hasattr(self.prox, "tag")
        ):
            raise ValueError(
                f"prox must be None (the l1 soft threshold) or a "
                f"repro.ops.prox.Prox (apply(x, gamma) + tag); got "
                f"{self.prox!r}"
            )
        if not isinstance(self.overlap, int) or self.overlap < 1:
            raise ValueError(f"overlap must be a positive int, got {self.overlap!r}")
        if self.wire_dtype not in WIRE_DTYPES:
            raise ValueError(
                f"wire_dtype must be one of {sorted(WIRE_DTYPES)}, got "
                f"{self.wire_dtype!r}"
            )
        if self.inter_wire_dtype not in WIRE_DTYPES:
            raise ValueError(
                f"inter_wire_dtype must be one of {sorted(WIRE_DTYPES)}, got "
                f"{self.inter_wire_dtype!r}"
            )
        if not (isinstance(self.axis_name, str) or (
            isinstance(self.axis_name, tuple) and len(self.axis_name) == 2
            and all(isinstance(a, str) for a in self.axis_name)
        )):
            raise ValueError(
                f"axis_name must be one mesh-axis name or a (host, device) "
                f"pair of names, got {self.axis_name!r}"
            )
        if self.hier_axes is not None:
            ok = (
                isinstance(self.hier_axes, tuple) and len(self.hier_axes) == 2
                and all(isinstance(x, int) and x >= 1 for x in self.hier_axes)
            )
            if not ok:
                raise ValueError(
                    f"hier_axes must be a (H, D) tuple of positive ints — "
                    f"the (host, device) factorization of the transform "
                    f"axis — or None for the flat exchange; got "
                    f"{self.hier_axes!r}"
                )
        if not distributed and self.wire_dtype != "fp32":
            raise ValueError(
                f"wire_dtype={self.wire_dtype!r} compresses the transpose "
                f"all-to-all payload of the *distributed* four-step "
                f"transforms — a local (mesh=None) plan has no wire to "
                f"compress and would silently ignore it; pass a mesh or "
                f"leave wire_dtype='fp32' (valid values: "
                f"{sorted(WIRE_DTYPES)})"
            )
        if not distributed and self.hier_axes is not None:
            raise ValueError(
                f"hier_axes={self.hier_axes!r} factors the transform axis "
                f"of a *distributed* (host, device) mesh for the two-stage "
                f"hierarchical transpose — a local (mesh=None) plan has no "
                f"mesh axes to factor; pass a hierarchical mesh "
                f"(repro.dist.compat.make_hier_mesh) or leave "
                f"hier_axes=None (valid values: None or a (H, D) tuple)"
            )
        if self.hier_axes is None and self.inter_wire_dtype != "fp32":
            raise ValueError(
                f"inter_wire_dtype={self.inter_wire_dtype!r} compresses the "
                f"inter-host hops of the *hierarchical* two-stage transpose "
                f"— without hier_axes there is no inter-host tier and it "
                f"would be silently ignored; set hier_axes=(H, D) or leave "
                f"inter_wire_dtype='fp32' (valid values: "
                f"{sorted(WIRE_DTYPES)})"
            )
        if not distributed and (
            self.rfft or self.overlap != 1 or self.batch_axis is not None
        ):
            raise ValueError(
                "rfft/overlap are distributed-backend knobs (the sharded "
                "four-step transforms), and batch_axis names a mesh axis; "
                "pass a mesh to use them — a local plan would silently "
                "ignore them"
            )
        if (self.n1 is not None and self.n1 < 1) or (
            self.n2 is not None and self.n2 < 1
        ):
            raise ValueError(f"n1/n2 must be positive, got {self.n1}/{self.n2}")
        return self

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        for key in ("batch_axis", "axis_name", "hier_axes"):
            if isinstance(d[key], tuple):
                d[key] = list(d[key])
        d["prox"] = prox_mod.prox_to_dict(self.prox)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "PlanConfig":
        d = dict(d)
        for key in ("batch_axis", "axis_name", "hier_axes"):
            if isinstance(d.get(key), list):
                d[key] = tuple(d[key])
        if d.get("prox") is not None:
            d["prox"] = prox_mod.prox_from_dict(d["prox"])
        return cls(**d)

    def describe(self) -> str:
        """Compact human-readable tag (bench rows, tuner logs, serve bucket
        keys — every knob that changes the compiled program must show)."""
        parts = [
            f"n1xn2={self.n1}x{self.n2}" if self.n1 else "n1xn2=auto",
            f"rfft={'on' if self.rfft else 'off'}",
            f"overlap={self.overlap}",
            f"tail={self.tail}",
        ]
        if not self.fused:
            parts.append("unfused")
        if self.batch_axis is not None:
            parts.append(f"batch_axis={self.batch_axis}")
        if self.wire_dtype != "fp32":
            parts.append(f"wire={self.wire_dtype}")
        if self.hier_axes is not None:
            parts.append(f"hier={self.hier_axes[0]}x{self.hier_axes[1]}")
        elif isinstance(self.axis_name, tuple):
            parts.append("hier=flat")  # factored axis, flat exchange
        if self.inter_wire_dtype != "fp32":
            parts.append(f"inter_wire={self.inter_wire_dtype}")
        if self.prox is not None:
            # the prior changes the compiled z-update (and serve engines must
            # never share across priors) — every non-default prox shows
            parts.append(f"prox={self.prox.tag}")
        return " ".join(parts)


def resolve_plan_config(config: Optional[PlanConfig], *, distributed: bool,
                        **knobs) -> PlanConfig:
    """``config=`` / legacy-kwargs reconciliation + the single validation.

    ``knobs`` are the legacy keyword arguments with ``None`` meaning "not
    given": either a full ``config`` is passed (and every legacy knob must
    stay unset — mixing the two would silently shadow fields), or a
    ``PlanConfig`` is constructed from whichever knobs were given, defaults
    filling the rest.
    """
    set_knobs = {k: v for k, v in knobs.items() if v is not None}
    if config is not None:
        if set_knobs:
            raise ValueError(
                f"pass config=PlanConfig(...) or individual plan knobs, not "
                f"both (got config= plus {sorted(set_knobs)})"
            )
        cfg = config
    else:
        cfg = PlanConfig(**set_knobs)
    return cfg.validate(distributed)


def _resolve_axes(cfg: PlanConfig, mesh):
    """Mesh-dependent half of the hier validation (the shape-only half lives
    in :meth:`PlanConfig.validate`): resolve the transform axis — one mesh
    axis name, or the (host, device) pair when the plan is hierarchical or
    the config names a factored axis — and check ``hier_axes`` against the
    mesh's actual extents.  Returns ``(axis_name, hier_axes)``.
    """
    if cfg.hier_axes is None and not isinstance(cfg.axis_name, tuple):
        return cfg.axis_name, None
    axes = (
        cfg.axis_name if isinstance(cfg.axis_name, tuple)
        else (HOST_AXIS, DEVICE_AXIS)
    )
    missing = [a for a in axes if a not in mesh.axis_names]
    if missing:
        raise ValueError(
            f"hierarchical plans shard the transform over the mesh-axis "
            f"pair {axes}, but this mesh has axes "
            f"{tuple(mesh.axis_names)} (missing {missing}); build the mesh "
            f"with repro.dist.compat.make_hier_mesh(data, host, device) or "
            f"pass axis_name=(host_axis, device_axis) naming existing axes"
        )
    extents = (mesh.shape[axes[0]], mesh.shape[axes[1]])
    if cfg.hier_axes is not None and tuple(cfg.hier_axes) != extents:
        raise ValueError(
            f"hier_axes={cfg.hier_axes} does not factor this mesh's "
            f"transform extent: axes {axes} have extents {extents} "
            f"(H x D = {extents[0] * extents[1]}); valid value: "
            f"hier_axes={extents}"
        )
    return axes, cfg.hier_axes


def _transform_extent(mesh, axis_name) -> int:
    """Total shard count p of the (possibly factored) transform axis."""
    if isinstance(axis_name, str):
        return mesh.shape[axis_name]
    return mesh.shape[axis_name[0]] * mesh.shape[axis_name[1]]


class PlannedOperator:
    """Mask-form ``diag(mask) C`` on the plan's mesh, acting on flat arrays.

    This is the distributed RecoveryOperator view: ``matvec``/``rmatvec``
    take flat (..., n) signals, run the sharded four-step transforms, and
    return flat results — so the core drivers' metric/objective code and
    ``RecoveryProblem`` construction work unchanged.  Measurements are in
    the scattered full-length convention (``project_back`` is the identity).
    """

    def __init__(self, plan: "ExecutionPlan"):
        self._plan = plan

    @property
    def n(self) -> int:
        return self._plan.n1 * self._plan.n2

    @property
    def m(self) -> int:
        return self.n  # mask form: measurements live scattered, length n

    def matvec(self, x: Array) -> Array:
        pl = self._plan
        x2d = layout_2d(x, pl.n1, pl.n2)
        return unlayout_2d(pl.mask2d * pl._apply(x2d, transpose=False))

    def rmatvec(self, r: Array) -> Array:
        # true adjoint of diag(mask) C: C^T diag(mask).  Solver residuals are
        # already masked (mask * r == r), but the protocol promises A^T r for
        # arbitrary full-length r.
        pl = self._plan
        r2d = pl.mask2d * layout_2d(r, pl.n1, pl.n2)
        return unlayout_2d(pl._apply(r2d, transpose=True))

    def operator_norm_bound(self) -> Array:
        if self._plan.norm_bound is None:
            raise ValueError("this plan carries no spectrum norm bound")
        return self._plan.norm_bound

    def project_back(self, y: Array) -> Array:
        return y  # already scattered full-length


@dataclasses.dataclass(frozen=True, eq=False)
class ExecutionPlan:
    """An operator lowered to an execution backend (see module docstring).

    Local plans (``mesh is None``) carry only the operator and knobs;
    distributed plans additionally hold the column-sharded spectrum block
    ``spec2d``, the row-sharded measurement mask ``mask2d``, and the
    four-step factorization ``n1 x n2``.
    """

    op: Any = None
    mesh: Any = None
    n1: Optional[int] = None
    n2: Optional[int] = None
    rfft: bool = False
    overlap: int = 1
    tail: str = "jnp"
    fused: bool = True
    batch_axis: Any = None
    axis_name: Any = MODEL_AXIS
    wire_dtype: str = "fp32"
    hier_axes: Any = None
    inter_wire_dtype: str = "fp32"
    prox: Any = None
    spec2d: Any = None
    mask2d: Any = None
    norm_bound: Any = None

    # -- basic facts -------------------------------------------------------
    @property
    def is_distributed(self) -> bool:
        return self.mesh is not None

    @property
    def hier(self) -> bool:
        """Whether transposes run as the two-stage hierarchical exchange."""
        return self.hier_axes is not None

    @property
    def config(self) -> PlanConfig:
        """The knobs of this plan as one :class:`PlanConfig` — the value the
        tuner caches and the parity tests compare across entry points."""
        return PlanConfig(
            rfft=self.rfft,
            overlap=self.overlap,
            tail=self.tail,
            fused=self.fused,
            batch_axis=self.batch_axis,
            n1=self.n1,
            n2=self.n2,
            axis_name=self.axis_name,
            wire_dtype=self.wire_dtype,
            hier_axes=self.hier_axes,
            inter_wire_dtype=self.inter_wire_dtype,
            prox=self.prox,
        )

    @property
    def operator(self):
        """The RecoveryOperator view of this plan: the original operator on
        one device, or the mask-form planned operator on the mesh."""
        if not self.is_distributed:
            return self.op
        return PlannedOperator(self)

    def matvec(self, x: Array) -> Array:
        return self.operator.matvec(x)

    def rmatvec(self, y: Array) -> Array:
        return self.operator.rmatvec(y)

    # -- sharding specs ----------------------------------------------------
    # delegated to repro.dist.fft's spec builders, which own the device-major
    # sharding convention for factored (host, device) transform axes
    # (batched arrays keep their leading batch entry even when batch_axis is
    # None — "batched but replicated" must not collapse to the 2-dim spec)
    def _row(self, batched: bool) -> P:
        if batched:
            return P(self.batch_axis, *row_spec(self.axis_name))
        return row_spec(self.axis_name)

    def _col(self, batched: bool) -> P:
        if batched:
            return P(self.batch_axis, *col_spec(self.axis_name))
        return col_spec(self.axis_name)

    # -- planned applications ---------------------------------------------
    def _apply(self, x2d: Array, transpose: bool) -> Array:
        """One sharded circulant application on layout-2d arrays (two
        transpose-collectives; half-spectrum when ``rfft``)."""
        local = rmatvec_local if self.rfft else matvec_local
        batched = x2d.ndim > 2
        fn = shard_map(
            functools.partial(
                local,
                axis_name=self.axis_name,
                transpose=transpose,
                overlap=self.overlap,
                wire_dtype=self.wire_dtype,
                hier=self.hier,
                inter_wire_dtype=self.inter_wire_dtype,
            ),
            mesh=self.mesh,
            in_specs=(self._col(False), self._row(batched)),
            out_specs=self._row(batched),
            check_vma=False,
        )
        return fn(self.spec2d, x2d)

    def _scattered_measurements(self, problem) -> Array:
        """problem.y -> the full-length scattered P^T y the mesh works in."""
        y = problem.y
        n = self.n1 * self.n2
        if y.shape[-1] == n:
            return y
        if hasattr(problem.op, "project_back"):
            return problem.op.project_back(y)
        raise ValueError(
            f"distributed plans need measurements of length n={n} (scattered "
            f"P^T y) or an operator with project_back; got length {y.shape[-1]}"
        )

    # -- steppers (consumed by repro.core.solvers drivers) -----------------
    def build_stepper(self, problem, method: str, alpha=1e-4, rho=0.1,
                      sigma=0.1, tau=None, prox=None):
        """Lower (problem, method) to a core ``Stepper`` on this backend.

        ``prox=None`` defaults to the plan's own ``prox`` knob."""
        prox = prox if prox is not None else self.prox
        if not self.is_distributed:
            from repro.core.solvers import make_stepper

            return make_stepper(
                problem, method, alpha=alpha, rho=rho, sigma=sigma, tau=tau,
                plan=self, prox=prox,
            )
        if method in _ISTA_METHODS:
            return self._ista_stepper(problem, method, alpha, tau, prox)
        if method == "cpadmm":
            return self._cpadmm_stepper(problem, alpha, rho, sigma, tau, prox)
        raise ValueError(
            f"method {method!r} has no distributed lowering; valid "
            f"distributed methods: ista, fista, cpista, cpadmm"
        )

    def _ista_stepper(self, problem, method: str, alpha, tau, prox=None):
        """Distributed CPISTA/FISTA: the core step math verbatim, with the
        matvecs lowered to planned four-step transforms.  State lives in
        the sharded (n1, n2) layout; ``extract`` flattens locally."""
        from repro.core import ista as ista_mod
        from repro.core.solvers import Stepper

        y_full = self._scattered_measurements(problem)
        if y_full.ndim > 2:
            raise ValueError("distributed plans support one leading batch axis")
        y2d = layout_2d(y_full, self.n1, self.n2)
        dt = y_full.dtype
        op2d = _Layout2DOperator(self)
        tau_v = (
            jnp.asarray(tau, dt) if tau is not None else ista_mod.default_tau(op2d)
        )
        p = ista_mod.IstaParams(alpha=jnp.asarray(alpha, dt), tau=tau_v)
        step_fn = ista_mod.fista_step if method == "fista" else ista_mod.ista_step
        # the dist ISTA step applies its prox at the global jit level (only
        # the matvecs are shard_mapped), so any prior threads straight in —
        # non-elementwise priors just need the flat-signal view of the
        # (n1, n2)-layout iterate (NOT a plain reshape: the four-step layout
        # is strided, see dist.fft.layout_2d)
        step_prox = prox if prox_mod.is_elementwise(prox) else _LayoutProx(
            prox, self.n1, self.n2
        )
        zeros = jnp.zeros_like(y2d)
        # per-signal momentum (batch-shaped) — matches ista_init, so frozen /
        # recycled slots keep a solo run's schedule (core.solvers.rearm_slots)
        return Stepper(
            init=lambda: ista_mod.IstaState(
                x=zeros, x_prev=zeros, t_mom=jnp.ones(y_full.shape[:-1], dt)
            ),
            step=lambda s: step_fn(op2d, y2d, s, p, prox=step_prox),
            extract=lambda s: unlayout_2d(s.x),
        )

    def _cpadmm_stepper(self, problem, alpha, rho, sigma, tau, prox=None):
        """Distributed CPADMM: the planned step functions of
        :mod:`repro.dist.recovery` under a per-iteration shard_map.

        Elementwise priors (l1, nonneg-l1) run inside the shard_map step —
        the tail stays local to each shard, and the fused Pallas tail stays
        eligible for l1.  Non-elementwise priors (TV, wavelet) need the whole
        signal: the step splits into the shard_mapped transform core
        (:func:`repro.dist.recovery.dist_cpadmm_core`) plus a global-level
        tail where GSPMD partitions the prox's rolls/reshapes."""
        from repro.core.solvers import Stepper

        y_full = self._scattered_measurements(problem)
        if y_full.ndim > 2:
            raise ValueError("distributed plans support one leading batch axis")
        batched = y_full.ndim > 1
        pty2d = layout_2d(y_full, self.n1, self.n2)
        dt = y_full.dtype
        t = 1.0 if tau is None else tau
        p = DistCpadmmParams(
            alpha=jnp.asarray(alpha, dt),
            rho=jnp.asarray(rho, dt),
            sigma=jnp.asarray(sigma, dt),
            tau1=jnp.asarray(t, dt),
            tau2=jnp.asarray(t, dt),
        )
        # Alg. 3 line 2, sharded: both inner inverses are local pointwise ops
        b_spec = spectral.gram_inverse_spectrum(self.spec2d, p.rho, p.sigma)
        d_diag = jnp.where(
            self.mask2d > 0, 1.0 / (1.0 + p.rho), 1.0 / p.rho
        ).astype(dt)
        rowS, rowB = self._row(False), self._row(batched)
        state_spec = DistCpadmmState(*(rowB,) * 5)
        zeros = jnp.zeros_like(pty2d)
        init = lambda: DistCpadmmState(zeros, zeros, zeros, zeros, zeros)

        if prox_mod.is_elementwise(prox):
            step_fn = dist_cpadmm_step_fused if self.fused else dist_cpadmm_step

            def local_step(spec, bs, dd, pty, state, pp):
                return step_fn(
                    spec, bs, dd, pty, state, pp,
                    self.axis_name, self.rfft, self.overlap, self.tail,
                    self.wire_dtype, self.hier, self.inter_wire_dtype,
                    prox=prox,
                )

            step_sm = shard_map(
                local_step,
                mesh=self.mesh,
                in_specs=(
                    self._col(False), self._col(False), rowS, rowB, state_spec,
                    DistCpadmmParams(*(P(),) * 5),
                ),
                out_specs=state_spec,
                check_vma=False,
            )
            return Stepper(
                init=init,
                step=lambda s: step_sm(self.spec2d, b_spec, d_diag, pty2d, s, p),
                extract=lambda s: unlayout_2d(s.z),
            )

        core_sm = self._cpadmm_core_sm(rowB)
        lprox = _LayoutProx(prox, self.n1, self.n2)

        def hybrid_step(s):
            x, cx = core_sm(self.spec2d, b_spec, s.v + s.mu, s.z - s.nu, p)
            v = d_diag * (pty2d + p.rho * (cx - s.mu))
            z = lprox.apply(x + s.nu, p.alpha / p.sigma)
            mu = s.mu + p.tau1 * (v - cx)
            nu = s.nu + p.tau2 * (x - z)
            return DistCpadmmState(x=x, v=v, z=z, mu=mu, nu=nu)

        return Stepper(
            init=init,
            step=hybrid_step,
            extract=lambda s: unlayout_2d(s.z),
        )

    def _cpadmm_core_sm(self, rowB: P):
        """shard_map of the CPADMM transform core (x-update + C x) — the
        non-elementwise-prior step runs this inside an otherwise global-level
        iteration so the prior sees whole signals."""
        col = self._col(False)

        def local_core(spec, bs, vmu, znu, pp):
            return dist_cpadmm_core(
                spec, bs, vmu, znu, pp,
                self.axis_name, self.rfft, self.overlap,
                self.wire_dtype, self.hier, self.inter_wire_dtype,
            )

        return shard_map(
            local_core,
            mesh=self.mesh,
            in_specs=(col, col, rowB, rowB, DistCpadmmParams(*(P(),) * 5)),
            out_specs=(rowB, rowB),
            check_vma=False,
        )

    # -- abstract iteration block (dry-run / HLO-analysis entry point) -----
    def cpadmm_block(self, iters: int, alpha=1e-4, rho=0.01, sigma=0.01,
                     tau=1.0):
        """Jitted ``block(spec, b_spec, d_diag, pty, state) -> state`` running
        ``iters`` scanned iterations inside one shard_map — a pure function
        of its operands, so ``.lower()`` with ShapeDtypeStructs exposes the
        compiled HLO (launch/cs_dryrun.py's roofline walks it).  The state
        (and pty) carry a leading batch dim sharded over ``batch_axis``.

        With a non-elementwise plan ``prox`` (TV/wavelet) the block is the
        hybrid split instead — shard_mapped transform core, global prox tail
        — jitted with explicit in_shardings so ``.lower()`` still exposes the
        partitioned HLO the tuner's cost model walks."""
        p = DistCpadmmParams(
            *(jnp.float32(v) for v in (alpha, rho, sigma, tau, tau))
        )
        rowS, rowB, col = self._row(False), self._row(True), self._col(False)
        state_spec = DistCpadmmState(*(rowB,) * 5)

        if prox_mod.is_elementwise(self.prox):
            prox = self.prox
            step_fn = dist_cpadmm_step_fused if self.fused else dist_cpadmm_step

            def block(spec, b_spec, d_diag, pty, state):
                def body(s, _):
                    return step_fn(
                        spec, b_spec, d_diag, pty, s, p,
                        self.axis_name, self.rfft, self.overlap, self.tail,
                        self.wire_dtype, self.hier, self.inter_wire_dtype,
                        prox=prox,
                    ), None

                state, _ = lax.scan(body, state, None, length=iters)
                return state

            return jax.jit(
                shard_map(
                    block,
                    mesh=self.mesh,
                    in_specs=(col, col, rowS, rowB, state_spec),
                    out_specs=state_spec,
                    check_vma=False,
                )
            )

        core_sm = self._cpadmm_core_sm(rowB)
        lprox = _LayoutProx(self.prox, self.n1, self.n2)

        def hybrid_block(spec, b_spec, d_diag, pty, state):
            def body(s, _):
                x, cx = core_sm(spec, b_spec, s.v + s.mu, s.z - s.nu, p)
                v = d_diag * (pty + p.rho * (cx - s.mu))
                z = lprox.apply(x + s.nu, p.alpha / p.sigma)
                mu = s.mu + p.tau1 * (v - cx)
                nu = s.nu + p.tau2 * (x - z)
                return DistCpadmmState(x=x, v=v, z=z, mu=mu, nu=nu), None

            state, _ = lax.scan(body, state, None, length=iters)
            return state

        sh = lambda spec: jax.sharding.NamedSharding(self.mesh, spec)
        return jax.jit(
            hybrid_block,
            in_shardings=(
                sh(col), sh(col), sh(rowS), sh(rowB),
                DistCpadmmState(*(sh(rowB),) * 5),
            ),
        )


class _LayoutProx:
    """A Prox adapted to the four-step (n1, n2) iterate layout.

    ``layout_2d`` is *strided* (``A[j1, j2] = x[j1 + n1*j2]``), not a
    row-major reshape, so a flat-signal prox applied to a distributed
    iterate must round-trip through ``unlayout_2d``/``layout_2d`` — a plain
    reshape would scramble the signal and be silently wrong.  Under the
    global jit both are data movements GSPMD partitions."""

    def __init__(self, prox, n1: int, n2: int):
        self._prox = prox
        self._n1 = n1
        self._n2 = n2

    def apply(self, a2d: Array, gamma) -> Array:
        flat = self._prox.apply(unlayout_2d(a2d), gamma)
        return layout_2d(flat, self._n1, self._n2)


class _Layout2DOperator:
    """The plan's operator view in the native (n1, n2) sharded layout —
    what the ISTA/FISTA step math consumes so iterates never leave the
    sharded layout between iterations."""

    def __init__(self, plan: ExecutionPlan):
        self._plan = plan

    def matvec(self, x2d: Array) -> Array:
        pl = self._plan
        return pl.mask2d * pl._apply(x2d, transpose=False)

    def rmatvec(self, r2d: Array) -> Array:
        # adjoint of diag(mask) C (the mask multiply is a bitwise no-op on
        # the already-masked residuals the ISTA step feeds in)
        pl = self._plan
        return pl._apply(pl.mask2d * r2d, transpose=True)

    def operator_norm_bound(self) -> Array:
        if self._plan.norm_bound is None:
            raise ValueError(
                "plan has no operator norm bound; pass tau explicitly"
            )
        return self._plan.norm_bound


def _wire_guard(wire_plan: ExecutionPlan) -> ExecutionPlan:
    """Error-controlled wire precision: probe one matvec of the demoted-wire
    plan against the fp32-wire twin and fall back when the relative error
    exceeds :data:`WIRE_ERROR_BOUND` (``REPRO_WIRE_ERROR_BOUND`` env).

    The probe is cheap (one planned matvec each way on a unit-norm random
    signal) and catches both gradual quantization loss and hard fp16
    overflow (payload magnitudes past float16's 65504 max turn the probe
    error non-finite, which fails the ``err <= bound`` check).  Both tiers
    are guarded at once: a demoted ``inter_wire_dtype`` (hierarchical DCN
    hops) trips the probe exactly like a demoted ``wire_dtype``, and the
    fallback restores fp32 on both.
    """
    if wire_plan.wire_dtype == "fp32" and wire_plan.inter_wire_dtype == "fp32":
        return wire_plan
    ref_plan = dataclasses.replace(
        wire_plan, wire_dtype="fp32", inter_wire_dtype="fp32"
    )
    n = wire_plan.n1 * wire_plan.n2
    x = jax.random.normal(jax.random.PRNGKey(0), (n,), jnp.float32)
    x = x / jnp.linalg.norm(x)
    got = wire_plan.matvec(x)
    ref = ref_plan.matvec(x)
    denom = jnp.linalg.norm(ref)
    err = float(jnp.linalg.norm(got - ref) / jnp.where(denom > 0, denom, 1.0))
    bound = WIRE_ERROR_BOUND
    if not err <= bound:  # noqa: SIM300  (NaN/inf must fail the guard too)
        warnings.warn(
            f"wire_dtype={wire_plan.wire_dtype!r} / inter_wire_dtype="
            f"{wire_plan.inter_wire_dtype!r} failed the precision "
            f"guard: relative matvec error {err:.3e} exceeds the bound "
            f"{bound:.1e} (REPRO_WIRE_ERROR_BOUND) — falling back to "
            f"fp32 wires on both tiers",
            RuntimeWarning,
            stacklevel=3,
        )
        return ref_plan
    return wire_plan


def _plan_with_config(op, mesh, cfg: PlanConfig) -> ExecutionPlan:
    """Lower ``op`` under an already-validated ``PlanConfig``."""
    if mesh is None:
        return ExecutionPlan(op=op, tail=cfg.tail, fused=cfg.fused, prox=cfg.prox)
    if hasattr(op, "circ"):  # PartialCirculant: mask = indicator of omega
        circ, omega = op.circ, op.omega
    elif hasattr(op, "spec") and hasattr(op, "col"):  # full Circulant
        circ, omega = op, None
    else:
        raise TypeError(
            f"distributed plans need a (partial) circulant operator, got "
            f"{type(op).__name__}"
        )
    n = circ.n
    axes, hier_axes = _resolve_axes(cfg, mesh)
    p = _transform_extent(mesh, axes)
    n1, n2 = _factorize(n, cfg.n1, cfg.n2, p, cfg.rfft)
    if omega is None:
        mask = jnp.ones((n,), circ.col.dtype)
    else:
        mask = jnp.zeros((n,), circ.col.dtype).at[omega].set(1.0)
    # the spectrum is already stored on the operator (half layout): re-lay it
    # out for the four-step transforms and shard the columns — no transform
    # runs here, so composed spectra (deblur's spec(C)·spec(B)) never round-
    # trip through the time domain
    spec2d = jax.device_put(
        spectral.spectrum_layout_2d(circ.spec, n1, n2, rfft=cfg.rfft, p=p),
        jax.sharding.NamedSharding(mesh, col_spec(axes)),
    )
    built = ExecutionPlan(
        op=op,
        mesh=mesh,
        n1=n1,
        n2=n2,
        rfft=cfg.rfft,
        overlap=cfg.overlap,
        tail=cfg.tail,
        fused=cfg.fused,
        batch_axis=cfg.batch_axis,
        axis_name=axes,
        wire_dtype=cfg.wire_dtype,
        hier_axes=hier_axes,
        inter_wire_dtype=cfg.inter_wire_dtype,
        prox=cfg.prox,
        spec2d=spec2d,
        mask2d=layout_2d(mask, n1, n2),
        norm_bound=op.operator_norm_bound(),
    )
    return _wire_guard(built)


def plan(
    op,
    mesh=None,
    *,
    config: Optional[PlanConfig] = None,
    tune=False,
    batch: Optional[int] = None,
    tune_opts: Optional[dict] = None,
    n1: Optional[int] = None,
    n2: Optional[int] = None,
    rfft: Optional[bool] = None,
    overlap: Optional[int] = None,
    tail: Optional[str] = None,
    fused: Optional[bool] = None,
    batch_axis: Any = None,
    axis_name: Any = None,
    wire_dtype: Optional[str] = None,
    hier_axes: Any = None,
    inter_wire_dtype: Optional[str] = None,
    prox: Any = None,
) -> ExecutionPlan:
    """Lower ``op`` to an execution plan (see module docstring).

    With ``mesh=None`` this is the identity lowering: ``plan(op).operator``
    *is* ``op``, so every matvec is bit-exact with the core path.  With a
    mesh, ``op`` must be a (partial) circulant: the plan lays the operator's
    *stored half spectrum* out into the column-sharded four-step layout
    (``spectral.spectrum_layout_2d`` — pure bookkeeping, no irfft back to
    the first column and no distributed FFT of it, so a composed operator
    like the Sec. 7 deblur spectrum ``spec(C)·spec(B)`` is built and sharded
    exactly once) plus the row-sharded measurement mask, and lowers matvecs
    / solver steps to the four-step transforms.

    Knobs come either as ``config=PlanConfig(...)`` or as the individual
    keyword arguments (a thin compat path producing the same config; mixing
    the two is an error).  ``n1``/``n2`` pick the layout factorization
    (auto-chosen near sqrt(n) when omitted).

    ``tune=True`` (cost model) or ``tune="measure"`` (cost model + wall-clock
    of the top candidates) asks :mod:`repro.ops.tune` to pick the config
    instead; any individual knob that *is* passed becomes a pin restricting
    the candidate space (``config=`` cannot be combined with ``tune`` —
    a full config leaves nothing to tune).  ``batch`` sizes the tuning
    workload (leading batch of signals); ``tune_opts`` forwards extras to
    :func:`repro.ops.tune.tuned_config` (e.g. ``cache=``, ``top_k=``).
    """
    if tune:
        if config is not None:
            raise ValueError(
                "tune= and config= are mutually exclusive: a full PlanConfig "
                "leaves nothing to tune (pass individual knobs to pin them)"
            )
        from . import tune as tune_mod

        pins = {
            k: v
            for k, v in dict(
                n1=n1, n2=n2, rfft=rfft, overlap=overlap, tail=tail,
                fused=fused, batch_axis=batch_axis, axis_name=axis_name,
                wire_dtype=wire_dtype, hier_axes=hier_axes,
                inter_wire_dtype=inter_wire_dtype, prox=prox,
            ).items()
            if v is not None
        }
        mode = tune if isinstance(tune, str) else "model"
        cfg = tune_mod.tuned_config(
            op, mesh, mode=mode, batch=batch, pins=pins, **(tune_opts or {})
        )
        cfg = cfg.validate(distributed=mesh is not None)
    else:
        cfg = resolve_plan_config(
            config,
            distributed=mesh is not None,
            n1=n1, n2=n2, rfft=rfft, overlap=overlap, tail=tail,
            fused=fused, batch_axis=batch_axis, axis_name=axis_name,
            wire_dtype=wire_dtype, hier_axes=hier_axes,
            inter_wire_dtype=inter_wire_dtype, prox=prox,
        )
    return _plan_with_config(op, mesh, cfg)


def plan_from_parts(
    mesh,
    spec2d=None,
    mask2d=None,
    *,
    config: Optional[PlanConfig] = None,
    n1: Optional[int] = None,
    n2: Optional[int] = None,
    rfft: Optional[bool] = None,
    overlap: Optional[int] = None,
    tail: Optional[str] = None,
    fused: Optional[bool] = None,
    batch_axis: Any = None,
    axis_name: Any = None,
    wire_dtype: Optional[str] = None,
    hier_axes: Any = None,
    inter_wire_dtype: Optional[str] = None,
    prox: Any = None,
) -> ExecutionPlan:
    """Distributed plan from pre-sharded parts instead of an operator.

    For callers that already live in the sharded representation: the
    deprecation shim ``repro.dist.recovery.make_dist_cpadmm`` (spectrum and
    mask arrive as arrays) and the abstract lowerings in
    ``launch/cs_dryrun.py`` and ``ops/tune.py`` (no concrete arrays at all —
    only :meth:`ExecutionPlan.cpadmm_block` is used).  ``spec2d`` is the
    column-sharded spectrum of C with the matching ``rfft`` layout;
    ``mask2d`` the row-sharded 0/1 measurement indicator.  Accepts
    ``config=PlanConfig(...)`` like :func:`plan`; with no operator to read
    ``n`` from, the factorization ``n1 x n2`` must be concrete either way.
    """
    cfg = resolve_plan_config(
        config,
        distributed=True,
        n1=n1, n2=n2, rfft=rfft, overlap=overlap, tail=tail,
        fused=fused, batch_axis=batch_axis, axis_name=axis_name,
        wire_dtype=wire_dtype, hier_axes=hier_axes,
        inter_wire_dtype=inter_wire_dtype, prox=prox,
    )
    if cfg.n1 is None or cfg.n2 is None:
        raise ValueError(
            "plan_from_parts has no operator to infer n from: the config "
            "must carry a concrete n1 x n2 factorization"
        )
    axes, hier = _resolve_axes(cfg, mesh)
    norm = jnp.max(jnp.abs(spec2d)) if spec2d is not None else None
    # no precision guard here: this entry point also serves the abstract
    # lowerings (no concrete spec2d at all) — plan() is the guarded route
    return ExecutionPlan(
        mesh=mesh,
        n1=cfg.n1,
        n2=cfg.n2,
        rfft=cfg.rfft,
        overlap=cfg.overlap,
        tail=cfg.tail,
        fused=cfg.fused,
        batch_axis=cfg.batch_axis,
        axis_name=axes,
        wire_dtype=cfg.wire_dtype,
        hier_axes=hier,
        inter_wire_dtype=cfg.inter_wire_dtype,
        prox=cfg.prox,
        spec2d=spec2d,
        mask2d=mask2d,
        norm_bound=norm,
    )

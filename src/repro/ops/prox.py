"""Pluggable proximal operators — the prior as a first-class plan knob.

The paper's solvers (CPISTA Alg. 1, CPADMM Alg. 3) hardwire the
identity-basis l1 prior: every z-update is ``eta_gamma(x + u)`` with
``eta_gamma`` the soft threshold of Eq. 4.  The astronomy workloads the
paper targets want more — *Compressed Sensing in Astronomy* (Bobin/Starck)
reconstructs under TV and wavelet analysis priors, and astronomical images
are nonnegative.  This module turns the prior into a value: a ``Prox``
object with ``apply(x, gamma)`` computing

    prox_{gamma * R}(x) = argmin_z  0.5 * ||z - x||^2 + gamma * R(z)

that threads through ``PlanConfig(prox=)``, the solver steppers, the tuner
and the serve bucket keys.  Contract:

* ``apply(x, gamma)`` acts on the trailing axis (flat signal of length n)
  and broadcasts over any leading batch axes — batched recovery applies the
  prior per-signal with one call.
* ``tag`` is a stable human-readable id; it parameterizes
  ``PlanConfig.describe()`` so serve buckets with different priors never
  share an engine, and distinct hyper-parameters yield distinct tags.
* ``elementwise`` marks proxes that act coordinate-wise.  Elementwise
  proxes can run *inside* a shard_map on sharded iterate blocks;
  non-elementwise proxes (TV, wavelet) need the whole signal and run at
  the global jit level where GSPMD partitions them.
* ``L1Prox`` is the bit-exact compatibility default: its ``apply`` is the
  same jnp expression as ``core.soft_threshold.soft_threshold``, so the
  refactor changes no numbers, and the fused Pallas tails
  (``kernels/soft_threshold``, ``kernels/cpadmm_tail``) stay reachable
  exactly when ``is_l1(prox)``.
* TV and wavelet additionally expose an ``analysis_op`` /
  ``analysis_rmatvec`` pair (the D and D^T of the analysis form
  ``R(z) = ||D z||_1``) for analysis-form ADMM splittings and diagnostics.

Everything here is plain jax — no imports from repro.core / repro.dist —
so any layer can depend on it without cycles.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, ClassVar, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

Array = jax.Array


def _soft(x: Array, gamma) -> Array:
    # Same expression as core.soft_threshold.soft_threshold — kept inline so
    # this module stays dependency-free while L1Prox remains bitwise equal.
    return jnp.sign(x) * jnp.maximum(jnp.abs(x) - gamma, 0.0)


class Prox:
    """Protocol/base for proximal operators (see module docstring).

    Subclasses are frozen dataclasses with only hashable fields so a Prox
    can sit inside the frozen ``PlanConfig`` and the tuner's group keys.
    """

    kind: ClassVar[str]
    elementwise: ClassVar[bool]

    @property
    def tag(self) -> str:
        raise NotImplementedError

    def apply(self, x: Array, gamma) -> Array:
        raise NotImplementedError

    def to_dict(self) -> Dict[str, Any]:
        d = {"kind": self.kind}
        d.update(
            {
                f.name: (list(v) if isinstance(v := getattr(self, f.name), tuple) else v)
                for f in dataclasses.fields(self)  # type: ignore[arg-type]
            }
        )
        return d


@dataclasses.dataclass(frozen=True)
class L1Prox(Prox):
    """Identity-basis l1 soft threshold (paper Eq. 4) — the compat default."""

    kind: ClassVar[str] = "l1"
    elementwise: ClassVar[bool] = True

    @property
    def tag(self) -> str:
        return "l1"

    def apply(self, x: Array, gamma) -> Array:
        return _soft(x, gamma)


@dataclasses.dataclass(frozen=True)
class NonNegL1Prox(Prox):
    """l1 + nonnegativity: prox is a one-sided shrink, max(x - gamma, 0).

    Astronomy images are photon counts — the positivity constraint is free
    regularization (Bobin/Starck Sec. 5).
    """

    kind: ClassVar[str] = "nonneg-l1"
    elementwise: ClassVar[bool] = True

    @property
    def tag(self) -> str:
        return "nonneg-l1"

    def apply(self, x: Array, gamma) -> Array:
        return jnp.maximum(x - gamma, 0.0)


@dataclasses.dataclass(frozen=True)
class TVProx(Prox):
    """Anisotropic 2-D total variation via Chambolle's dual projection.

    ``R(z) = ||Dv z||_1 + ||Dh z||_1`` with periodic (circulant) forward
    differences — the same wrap-around convention as the repo's circulant
    operators, so the analysis pair stays mesh-shardable (rolls lower to
    collective-permutes under GSPMD).  The prox solves the dual

        min_{||p||_inf <= gamma}  0.5 * ||x - D^T p||^2

    by ``iters`` projected-gradient steps with the safe step 1/8
    (||D||^2 <= 8 for the periodic 2-D difference operator); the primal is
    recovered as ``z = x - D^T p``.  A handful of inner iterations is the
    standard inexact-prox regime (Chambolle 2004; Beck/Teboulle FISTA-TV).
    """

    shape: Tuple[int, int]
    iters: int = 10
    kind: ClassVar[str] = "tv"
    elementwise: ClassVar[bool] = False

    def __post_init__(self):
        h, w = self.shape
        if not (h > 0 and w > 0):
            raise ValueError(f"TVProx shape must be positive; got {self.shape}")
        if self.iters <= 0:
            raise ValueError(f"TVProx iters must be positive; got {self.iters}")
        object.__setattr__(self, "shape", (int(h), int(w)))

    @property
    def tag(self) -> str:
        h, w = self.shape
        return f"tv[{h}x{w},it{self.iters}]"

    def _check(self, x: Array) -> None:
        h, w = self.shape
        if x.shape[-1] != h * w:
            raise ValueError(
                f"TVProx expects trailing axis of length h*w = {h * w} "
                f"(shape={self.shape}); got {x.shape[-1]}"
            )

    def apply(self, x: Array, gamma) -> Array:
        self._check(x)
        h, w = self.shape
        img = x.reshape(x.shape[:-1] + (h, w))

        def dv(z):
            return jnp.roll(z, -1, axis=-2) - z

        def dh(z):
            return jnp.roll(z, -1, axis=-1) - z

        def dvt(p):
            return jnp.roll(p, 1, axis=-2) - p

        def dht(p):
            return jnp.roll(p, 1, axis=-1) - p

        def body(_, carry):
            p1, p2 = carry
            z = img - (dvt(p1) + dht(p2))
            p1 = jnp.clip(p1 + 0.125 * dv(z), -gamma, gamma)
            p2 = jnp.clip(p2 + 0.125 * dh(z), -gamma, gamma)
            return p1, p2

        zero = jnp.zeros_like(img)
        p1, p2 = lax.fori_loop(0, self.iters, body, (zero, zero))
        out = img - (dvt(p1) + dht(p2))
        return out.reshape(x.shape)

    def analysis_op(self, x: Array) -> Array:
        """D x: stacked periodic differences, (..., n) -> (..., 2n)."""
        self._check(x)
        h, w = self.shape
        img = x.reshape(x.shape[:-1] + (h, w))
        dv = jnp.roll(img, -1, axis=-2) - img
        dh = jnp.roll(img, -1, axis=-1) - img
        flat = x.shape[:-1] + (h * w,)
        return jnp.concatenate([dv.reshape(flat), dh.reshape(flat)], axis=-1)

    def analysis_rmatvec(self, c: Array) -> Array:
        """D^T c: adjoint of ``analysis_op``, (..., 2n) -> (..., n)."""
        h, w = self.shape
        n = h * w
        if c.shape[-1] != 2 * n:
            raise ValueError(f"TVProx analysis_rmatvec expects trailing axis 2n = {2 * n}; got {c.shape[-1]}")
        grid = c.shape[:-1] + (h, w)
        p1 = c[..., :n].reshape(grid)
        p2 = c[..., n:].reshape(grid)
        out = (jnp.roll(p1, 1, axis=-2) - p1) + (jnp.roll(p2, 1, axis=-1) - p2)
        return out.reshape(c.shape[:-1] + (n,))


_SQRT2 = math.sqrt(2.0)
_SQRT3 = math.sqrt(3.0)
_WAVELET_FILTERS: Dict[str, Tuple[float, ...]] = {
    "haar": (1.0 / _SQRT2, 1.0 / _SQRT2),
    "db4": (
        (1.0 + _SQRT3) / (4.0 * _SQRT2),
        (3.0 + _SQRT3) / (4.0 * _SQRT2),
        (3.0 - _SQRT3) / (4.0 * _SQRT2),
        (1.0 - _SQRT3) / (4.0 * _SQRT2),
    ),
}


@dataclasses.dataclass(frozen=True)
class WaveletProx(Prox):
    """Soft threshold in an orthogonal periodized wavelet basis.

    ``prox_{gamma * ||W.||_1}(x) = W^T eta_gamma(W x)`` — exact for
    orthonormal W.  W is a ``levels``-deep periodized DWT with Haar or
    Daubechies-4 filters; only detail bands are thresholded (the coarsest
    approximation carries the image's DC/large-scale flux and is kept).
    """

    levels: int = 2
    wavelet: str = "haar"
    kind: ClassVar[str] = "wavelet"
    elementwise: ClassVar[bool] = False

    def __post_init__(self):
        if self.levels <= 0:
            raise ValueError(f"WaveletProx levels must be positive; got {self.levels}")
        if self.wavelet not in _WAVELET_FILTERS:
            raise ValueError(
                f"unknown wavelet {self.wavelet!r}; available: {sorted(_WAVELET_FILTERS)}"
            )

    @property
    def tag(self) -> str:
        return f"wavelet[{self.wavelet},L{self.levels}]"

    def _filters(self, dtype) -> Tuple[Array, Array]:
        h = jnp.asarray(_WAVELET_FILTERS[self.wavelet], dtype=dtype)
        length = h.shape[0]
        # QMF pair: g[k] = (-1)^k h[L-1-k]
        signs = jnp.asarray([(-1.0) ** k for k in range(length)], dtype=dtype)
        g = signs * h[::-1]
        return h, g

    def _check(self, n: int) -> None:
        step = 2**self.levels
        flen = len(_WAVELET_FILTERS[self.wavelet])
        if n % step != 0 or n // step < flen:
            raise ValueError(
                f"WaveletProx(levels={self.levels}, wavelet={self.wavelet!r}) needs the "
                f"signal length divisible by 2^levels = {step} with at least {flen} "
                f"coefficients at the coarsest level; got n={n}"
            )

    @staticmethod
    def _down(a: Array, f: Array) -> Array:
        # a'[i] = sum_m f[m] a[(2i+m) mod N]
        acc = f[0] * a
        for m in range(1, f.shape[0]):
            acc = acc + f[m] * jnp.roll(a, -m, axis=-1)
        return acc[..., ::2]

    @staticmethod
    def _up(c: Array, f: Array, n: int) -> Array:
        # adjoint of _down: scatter to even slots then correlate with +m rolls
        up = jnp.zeros(c.shape[:-1] + (n,), dtype=c.dtype)
        up = up.at[..., ::2].set(c)
        acc = f[0] * up
        for m in range(1, f.shape[0]):
            acc = acc + f[m] * jnp.roll(up, m, axis=-1)
        return acc

    def _decompose(self, x: Array):
        h, g = self._filters(x.dtype)
        a = x
        details = []
        for _ in range(self.levels):
            details.append(self._down(a, g))
            a = self._down(a, h)
        return a, details, (h, g)

    def _reconstruct(self, a: Array, details, filters) -> Array:
        h, g = filters
        for d in reversed(details):
            a = self._up(a, h, 2 * a.shape[-1]) + self._up(d, g, 2 * a.shape[-1])
        return a

    def apply(self, x: Array, gamma) -> Array:
        self._check(x.shape[-1])
        a, details, filters = self._decompose(x)
        details = [_soft(d, gamma) for d in details]
        return self._reconstruct(a, details, filters)

    def analysis_op(self, x: Array) -> Array:
        """W x: concatenated [d_1 | d_2 | ... | d_L | a_L], same length as x."""
        self._check(x.shape[-1])
        a, details, _ = self._decompose(x)
        return jnp.concatenate(details + [a], axis=-1)

    def analysis_rmatvec(self, c: Array) -> Array:
        """W^T c — for orthonormal W also the inverse transform."""
        n = c.shape[-1]
        self._check(n)
        lengths = [n // 2 ** (lvl + 1) for lvl in range(self.levels)]
        details, off = [], 0
        for ln in lengths:
            details.append(c[..., off : off + ln])
            off += ln
        a = c[..., off:]
        h, g = self._filters(c.dtype)
        return self._reconstruct(a, details, (h, g))


PROX_KINDS: Dict[str, type] = {
    L1Prox.kind: L1Prox,
    NonNegL1Prox.kind: NonNegL1Prox,
    TVProx.kind: TVProx,
    WaveletProx.kind: WaveletProx,
}


def is_l1(prox) -> bool:
    """True when the prior is the identity-basis soft threshold — i.e. the
    fused Pallas tails (`kernels/soft_threshold`, `kernels/cpadmm_tail`)
    compute exactly this prox and stay eligible."""
    return prox is None or type(prox) is L1Prox


def is_elementwise(prox) -> bool:
    """True when the prox acts coordinate-wise (safe inside a shard_map)."""
    return prox is None or bool(getattr(prox, "elementwise", False))


def prox_to_dict(prox) -> Dict[str, Any]:
    if prox is None:
        return None  # type: ignore[return-value]
    return prox.to_dict()


def prox_from_dict(d) -> Prox:
    """Rebuild a Prox from its ``to_dict`` form (PlanConfig JSON round-trip)."""
    if d is None:
        return None  # type: ignore[return-value]
    if isinstance(d, Prox):
        return d
    spec = dict(d)
    kind = spec.pop("kind", None)
    cls = PROX_KINDS.get(kind)
    if cls is None:
        raise ValueError(f"unknown prox kind {kind!r}; available: {sorted(PROX_KINDS)}")
    if "shape" in spec and isinstance(spec["shape"], list):
        spec["shape"] = tuple(spec["shape"])
    return cls(**spec)

"""repro.ops — the execution-plan operator layer.

This package is the seam between *what* the paper's solvers compute and
*where* it runs.  The mapping back to the paper (arXiv:1707.02244):

    operator.RecoveryOperator   the four capabilities Algs. 1-3 touch an
                                operator through: matvec / rmatvec (Alg. 1
                                lines 3-4, Alg. 3 lines 3-4), an operator
                                norm bound (Alg. 1's safe step size
                                tau < 1/||A||^2), and — for CPADMM — the
                                gram-inverse spectrum of Alg. 3 line 2
                                (GramInvertibleOperator).
    spectral                    the shared rfft / half-spectrum bookkeeping
                                behind the C = F^H diag(spec) F identity of
                                Sec. 4 (imported by core.circulant AND
                                dist.fft — one definition, both backends).
    plan.plan(op, mesh=None)    lowers an operator to an execution plan:
                                with no mesh, the identity lowering (the
                                operator's own O(n log n) matvecs — CPISTA
                                Alg. 1 / CPADMM Alg. 3 exactly as the paper
                                runs them on one GPU); with a mesh, the
                                sharded four-step transforms of repro.dist
                                (Sec. 4 made multi-device), with rfft /
                                overlap / tail / batch_axis as plan
                                attributes.

The core drivers (``repro.core.solvers.solve`` / ``solve_until`` /
``solve_checkpointed``) accept ``plan=`` and are the *only* drivers: every
method (ista / fista / cpadmm) runs on every backend, which is how the
distributed solvers inherit tolerance stopping, per-signal convergence
freezing, metric traces, and checkpoint/restart (the paper's Sec. 7
three-hour-recovery scenario) without a second driver stack.

Imports are lazy (PEP 562) so ``repro.core`` can import
``repro.ops.spectral`` without pulling the plan machinery (which itself
builds on ``repro.core`` and ``repro.dist``) into the import cycle.
"""

from . import spectral  # noqa: F401  (dependency-free; safe to load eagerly)

_LAZY = {
    "ExecutionPlan": "plan",
    "PlanConfig": "plan",
    "PlannedOperator": "plan",
    "plan": "plan",
    "plan_from_parts": "plan",
    "resolve_plan_config": "plan",
    "GramInvertibleOperator": "operator",
    "RecoveryOperator": "operator",
    "PlanCache": "tune",
    "tuned_config": "tune",
    "Prox": "prox",
    "L1Prox": "prox",
    "NonNegL1Prox": "prox",
    "TVProx": "prox",
    "WaveletProx": "prox",
    "prox_from_dict": "prox",
    "prox_to_dict": "prox",
}

__all__ = sorted(_LAZY) + ["spectral"]


def __getattr__(name: str):
    if name in _LAZY:
        import importlib

        mod = importlib.import_module(f".{_LAZY[name]}", __name__)
        # Bind every lazy name this module provides, not just the one asked
        # for: importing the `plan` submodule sets the package attribute
        # `repro.ops.plan` to the *module*, which would otherwise shadow the
        # function of the same name on the next lookup.
        for other, modname in _LAZY.items():
            if modname == _LAZY[name]:
                globals()[other] = getattr(mod, other)
        return globals()[name]
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

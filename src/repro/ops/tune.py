"""Cost-model-driven plan autotuning: pick a :class:`PlanConfig` instead of
hand-picking one.

The paper's tenfold speedup came from hand-matching the algorithm layout to
the GPU's constraints; the same matching problem reappears here as plan
knobs — rfft, overlap K, tail substrate, batch sharding, the four-step
``n1 x n2`` factorization — all hand-picked per workload even though the
dry-run stack already *models* their cost.  This module closes the loop:

    ``plan(op, mesh, tune=True)``            cost-model pick ("model" mode)
    ``plan(op, mesh, tune="measure")``       + wall-clock the top candidates

Pipeline
--------
1.  **Enumerate** (:func:`candidate_configs`): feasible ``n1 x n2``
    factorizations (the ``_factorize`` default plus caller extras, filtered
    by the transpose-collective divisibility rules), rfft on/off, overlap
    K in {1, 2, 4, 8}, tail substrates available on this backend,
    batch-axis splits the workload's batch actually divides over, and — on
    a factored ``(host, device)`` mesh — flat vs hierarchical exchange
    (``hier_axes``) with per-tier wire dtypes (``inter_wire_dtype``),
    scored by the two-tier ICI/DCN collective model.
2.  **Score** (:func:`score_candidates`): lower each candidate's abstract
    CPADMM iteration block (:meth:`ExecutionPlan.cpadmm_block` from
    ShapeDtypeStructs only — no concrete arrays), walk the compiled HLO with
    :func:`repro.launch.hlo_analysis.analyze_compiled`, and rank by the
    shared roofline + hidden-collective model
    (:func:`repro.launch.roofline.model_block_times` — the same math the
    ``cs_dryrun`` tables print).  Candidates differing only in overlap K
    share one compile: K changes how the transpose's wire time *schedules*
    (chunked collectives), not the payload, so the K sweep is evaluated
    analytically on the K=1 compile — one compile (~seconds) per
    (factorization, rfft, tail, batch split) group instead of per candidate.
3.  **Measure** (``mode="measure"``): wall-clock the top-k model picks as
    concrete blocks (real spectrum, zero state) and let measured time
    override the model's ranking.
4.  **Cache**: the winning config lands in a JSON store
    (:class:`PlanCache`, default ``artifacts/plan_cache.json``, override via
    ``REPRO_PLAN_CACHE``) keyed by (op signature, mesh shape, batch, dtype,
    jax version, backend, pins) — production runs never re-tune.  A
    "measure"-mode entry satisfies both request modes; a "model" entry is
    re-tuned when measurement is asked for.

``COUNTERS`` tracks scored / measured / cache-hit / cache-miss events so
tests (and doubters) can assert a warm cache skips all scoring.

    python -m repro.ops.tune --show     # inspect the cache
    python -m repro.ops.tune --clear    # drop it (e.g. after a jax upgrade)
"""

from __future__ import annotations

import dataclasses
import json
import math
import os
import tempfile
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.dist.fft import DEVICE_AXIS, HOST_AXIS, MODEL_AXIS, padded_rfft_len
from repro.dist.recovery import DistCpadmmState

from . import spectral
from .plan import (
    PlanConfig,
    _factorize,
    _plan_with_config,
    _transform_extent,
    plan_from_parts,
)

SDS = jax.ShapeDtypeStruct

DEFAULT_CACHE_PATH = os.path.join("artifacts", "plan_cache.json")
OVERLAPS = (1, 2, 4, 8)
SCORE_ITERS = 8  # iterations in the scored block: enough for the while-loop
#                  trip count to dominate one-off setup, small enough to keep
#                  measure-mode wall-clocks quick
MEASURE_REPEATS = 3

# scored: candidate groups compiled + cost-walked; measured: candidates
# wall-clocked; cache_hits/misses: PlanCache lookups.  Tests assert a warm
# cache leaves scored == measured == 0.
COUNTERS: Dict[str, int] = {
    "scored": 0, "measured": 0, "cache_hits": 0, "cache_misses": 0,
}


def reset_counters() -> None:
    for k in COUNTERS:
        COUNTERS[k] = 0


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------


# paths already warned about this process — a corrupt store quarantines and
# warns once, not on every subsequent lookup
_WARNED_CORRUPT: set = set()


class PlanCache:
    """JSON store of winning configs: ``key -> {config, mode, score, ...}``.

    Writes are atomic (tmp + rename), and :meth:`put` *re-reads the store
    just before the rename* and folds any concurrently-written entries into
    the payload — two tuners racing on different keys both land (the loser
    of a same-key race is overwritten, which is fine: both wrote a winner
    for the same workload).  An unparseable store is never silently treated
    as empty: it is quarantined to ``<path>.corrupt`` with a one-time
    warning, so a corrupted file can't force silent re-tuning forever while
    looking like a working cache.  The default path is overridable with the
    ``REPRO_PLAN_CACHE`` environment variable (tests point it at a tmpdir;
    ops can point it at a shared volume).
    """

    # test seam: called between the tmp write and the pre-replace re-read,
    # where a concurrent tuner's os.replace can land (tests/test_tune.py
    # simulates the race deterministically through it)
    _race_hook = None

    def __init__(self, path: Optional[str] = None):
        self.path = path or os.environ.get("REPRO_PLAN_CACHE", DEFAULT_CACHE_PATH)

    def _quarantine(self, reason: str) -> None:
        import warnings

        corrupt = f"{self.path}.corrupt"
        try:
            os.replace(self.path, corrupt)
        except OSError:
            corrupt = "<unmovable>"
        if self.path not in _WARNED_CORRUPT:
            _WARNED_CORRUPT.add(self.path)
            warnings.warn(
                f"plan cache {self.path} is unreadable ({reason}); "
                f"quarantined to {corrupt} and starting a fresh store — "
                f"delete the .corrupt file once inspected",
                RuntimeWarning,
                stacklevel=3,
            )

    def _load(self) -> Dict[str, dict]:
        try:
            with open(self.path) as f:
                raw = f.read()
        except OSError:  # missing store: legitimately empty
            return {}
        if not raw.strip():
            return {}
        try:
            data = json.loads(raw)
        except ValueError as e:
            self._quarantine(f"invalid JSON: {e}")
            return {}
        if not isinstance(data, dict):
            self._quarantine(f"top-level JSON is {type(data).__name__}, not dict")
            return {}
        return data

    def get(self, key: str) -> Optional[dict]:
        return self._load().get(key)

    def put(self, key: str, entry: dict) -> None:
        data = self._load()
        data[key] = entry
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        # unique per *call*, not just per process: two racing puts in one
        # process (threads, or the reentrant test seam) must not share a tmp
        fd, tmp = tempfile.mkstemp(
            prefix=os.path.basename(self.path) + ".tmp.", dir=d or "."
        )
        with os.fdopen(fd, "w") as f:
            json.dump(data, f, indent=1, sort_keys=True)
        if self._race_hook is not None:
            self._race_hook()
        # close the read-modify-write window: another tuner may have replaced
        # the store since our load above — re-read and merge (our key wins
        # its own slot) so concurrent winners are never silently dropped
        latest = self._load()
        if any(k not in data for k in latest):
            latest.update(data)
            with open(tmp, "w") as f:
                json.dump(latest, f, indent=1, sort_keys=True)
        os.replace(tmp, self.path)

    def clear(self) -> None:
        try:
            os.remove(self.path)
        except FileNotFoundError:
            pass

    def entries(self) -> Dict[str, dict]:
        return self._load()


def cache_key(op, mesh, batch: Optional[int], pins: Optional[dict]) -> str:
    """Everything the winning config is conditional on, flattened to a str.

    Op signature (type, n, m) rather than op identity: two partial
    circulants of the same size tune identically — the knobs depend on
    shapes, not spectrum values.  jax version + backend are in the key
    because the cost of a lowering is a property of the compiler.
    """
    sig = (type(op).__name__, getattr(op, "n", None), getattr(op, "m", None))
    axes = tuple(zip(mesh.axis_names, (mesh.shape[a] for a in mesh.axis_names)))
    dtype = str(getattr(getattr(op, "circ", op), "col", jnp.zeros(0)).dtype)

    def _jsonable(v):
        if hasattr(v, "to_dict") and hasattr(v, "tag"):  # a Prox pin
            return v.to_dict()
        return list(v) if isinstance(v, tuple) else v

    pin_s = json.dumps(
        {k: _jsonable(v) for k, v in sorted((pins or {}).items())}
    )
    return "|".join([
        f"op={sig}", f"mesh={axes}", f"batch={batch}", f"dtype={dtype}",
        f"jax={jax.__version__}", f"backend={jax.default_backend()}",
        f"pins={pin_s}",
    ])


# ---------------------------------------------------------------------------
# candidate enumeration
# ---------------------------------------------------------------------------


def _feasible_factorizations(
    n: int, p: int, rfft: bool, extra: Sequence[Tuple[int, int]]
) -> List[Tuple[int, int]]:
    """The ``_factorize`` near-sqrt default plus caller extras, deduped and
    filtered by the transpose-collective divisibility rules."""
    out: List[Tuple[int, int]] = []
    try:
        out.append(_factorize(n, None, None, p, rfft))
    except ValueError:
        pass
    for n1, n2 in extra:
        if n1 * n2 != n or n1 % p:
            continue
        if not rfft and n2 % p:
            continue
        if (n1, n2) not in out:
            out.append((n1, n2))
    return out


def candidate_configs(
    op,
    mesh,
    pins: Optional[dict] = None,
    batch: Optional[int] = None,
    extra_factorizations: Sequence[Tuple[int, int]] = (),
) -> List[PlanConfig]:
    """Enumerate the feasible candidate space, honoring ``pins``.

    A pin (any individual plan knob passed alongside ``tune=``) collapses
    that knob's axis of the space to the pinned value; ``n1``/``n2`` pins
    replace the factorization sweep.
    """
    pins = dict(pins or {})
    axis_name = pins.get("axis_name")
    if axis_name is None:
        # a hierarchical mesh (compat.make_hier_mesh) implies the factored
        # transform axis; the tuner then races flat-layout vs two-stage
        # hierarchical exchanges over it (hier_axes sweep below)
        if HOST_AXIS in mesh.axis_names and DEVICE_AXIS in mesh.axis_names:
            axis_name = (HOST_AXIS, DEVICE_AXIS)
        else:
            axis_name = MODEL_AXIS
    if isinstance(axis_name, (list, tuple)):
        axis_name = tuple(axis_name)
    t_axes = axis_name if isinstance(axis_name, tuple) else (axis_name,)
    missing = [a for a in t_axes if a not in mesh.axis_names]
    if missing:
        raise ValueError(
            f"axis_name {axis_name!r} not in mesh axes {mesh.axis_names}"
        )
    p = math.prod(mesh.shape[a] for a in t_axes)
    circ = getattr(op, "circ", op)
    n = circ.n

    rffts = (pins["rfft"],) if "rfft" in pins else (False, True)
    overlaps = (pins["overlap"],) if "overlap" in pins else OVERLAPS
    if "tail" in pins:
        tails: Tuple[str, ...] = (pins["tail"],)
    elif jax.default_backend() == "tpu":
        tails = ("jnp", "pallas")
    else:
        tails = ("jnp",)  # the pallas tail interprets (slowly) off-TPU
    fuseds = (pins["fused"],) if "fused" in pins else (True,)
    # default wire sweep stops at bf16: same exponent range as fp32, so the
    # plan()-side precision guard essentially always accepts it; fp16 (more
    # mantissa, tiny range) is opt-in via a pin — overflow on large-magnitude
    # spectra would make the guard demote it back to fp32 anyway
    wires = (pins["wire_dtype"],) if "wire_dtype" in pins else ("fp32", "bf16")

    # hier_axes sweep: on a factored transform axis, race the flat layout
    # (one monolithic all-to-all over both tiers) against the two-stage
    # hierarchical exchange — the two-tier cost model splits them apart
    if isinstance(axis_name, tuple):
        extents = tuple(mesh.shape[a] for a in axis_name)
        if "hier_axes" in pins:
            ha = pins["hier_axes"]
            hier_opts: Tuple[Any, ...] = (
                tuple(ha) if ha is not None else None,
            )
        else:
            hier_opts = (None, extents)
    else:
        ha = pins.get("hier_axes")
        hier_opts = (tuple(ha) if ha is not None else None,)
    # a non-fp32 inter wire only exists on hierarchical candidates (the flat
    # exchange has no separate inter-host hop) — pinning it drops flat
    if pins.get("inter_wire_dtype", "fp32") != "fp32":
        hier_opts = tuple(h for h in hier_opts if h is not None)
        if not hier_opts:
            raise ValueError(
                "inter_wire_dtype pin needs a hierarchical candidate space "
                "(a (host, device) mesh, or hier_axes pinned non-None)"
            )

    def _inter_wires(hier) -> Tuple[str, ...]:
        if hier is None:
            return ("fp32",)
        if "inter_wire_dtype" in pins:
            return (pins["inter_wire_dtype"],)
        return ("fp32", "bf16")  # same bf16-not-fp16 default as `wires`

    if "batch_axis" in pins:
        batch_axes: List[Any] = [pins["batch_axis"]]
    else:
        batch_axes = [None]
        other = tuple(a for a in mesh.axis_names if a not in t_axes)
        if other and batch:
            sizes = math.prod(mesh.shape[a] for a in other)
            if sizes > 1 and batch % sizes == 0:
                batch_axes.append(other if len(other) > 1 else other[0])

    out: List[PlanConfig] = []
    for rfft in rffts:
        if "n1" in pins or "n2" in pins:
            try:
                facs = [_factorize(n, pins.get("n1"), pins.get("n2"), p, rfft)]
            except ValueError:
                continue
        else:
            facs = _feasible_factorizations(n, p, rfft, extra_factorizations)
        for n1, n2 in facs:
            for tail in tails:
                for fused in fuseds:
                    for ba in batch_axes:
                        for wire in wires:
                            for hier in hier_opts:
                                for iw in _inter_wires(hier):
                                    for K in overlaps:
                                        out.append(PlanConfig(
                                            rfft=rfft, overlap=K, tail=tail,
                                            fused=fused, batch_axis=ba,
                                            n1=n1, n2=n2,
                                            axis_name=axis_name,
                                            wire_dtype=wire,
                                            hier_axes=hier,
                                            inter_wire_dtype=iw,
                                            prox=pins.get("prox"),
                                        ))
    if not out:
        raise ValueError(
            f"no feasible plan candidates for n={n} over a {p}-device "
            f"{axis_name!r} axis with pins {pins}"
        )
    return out


# ---------------------------------------------------------------------------
# scoring (abstract lowering + shared cost model)
# ---------------------------------------------------------------------------


def _group_key(cfg: PlanConfig) -> tuple:
    """Candidates equal up to overlap share one compile (see module header).

    ``wire_dtype`` is part of the key: demoting the wire changes the
    compiled collective's payload bytes (the HLO the cost walk reads), not
    just its schedule — so fp32 and bf16 wires never share a compile.  So
    are ``hier_axes`` and ``inter_wire_dtype``: the hierarchical exchange
    compiles to different collectives entirely (intra-tier all-to-all +
    inter-tier collective-permutes vs one monolithic all-to-all).  ``prox``
    too: a non-elementwise prior swaps the fused one-shard_map block for the
    hybrid core+global-tail lowering, and even an elementwise swap changes
    the tail math the walk prices."""
    return (cfg.rfft, cfg.n1, cfg.n2, cfg.tail, cfg.fused, cfg.batch_axis,
            cfg.axis_name, cfg.wire_dtype, cfg.hier_axes, cfg.inter_wire_dtype,
            cfg.prox)


def _compile_group(mesh, cfg: PlanConfig, batch: int, iters: int):
    """Lower + compile one candidate group's abstract CPADMM block at K=1."""
    pl = plan_from_parts(
        mesh, config=dataclasses.replace(cfg, overlap=1)
    )
    block = pl.cpadmm_block(iters)
    p = _transform_extent(mesh, cfg.axis_name)
    ncols = padded_rfft_len(cfg.n2, p) if cfg.rfft else cfg.n2
    spec_s = SDS((cfg.n1, ncols), jnp.complex64)
    diag_s = SDS((cfg.n1, cfg.n2), jnp.float32)
    real_b = SDS((batch, cfg.n1, cfg.n2), jnp.float32)
    state_s = DistCpadmmState(*(real_b,) * 5)
    return block.lower(spec_s, spec_s, diag_s, real_b, state_s).compile()


def _dcn_bytes(cost, cfg: PlanConfig, mesh) -> float:
    """Cross-host wire bytes of one compiled block, for the two-tier model.

    Hierarchical plans put exactly the inter-host hop into
    ``collective-permute`` ops (repro.dist.fft two-stage exchange), so their
    DCN bytes read straight off the HLO walk.  A *flat* exchange over a
    factored ``(host, device)`` axis spanning more than one host is a single
    monolithic all-to-all whose every byte crosses the boundary — its whole
    all-to-all payload is charged to DCN.  Single-axis plans have no host
    tier and ride ICI only (0.0 — the bit-for-bit fallback).
    """
    if cfg.hier_axes is not None:
        return float(cost.collective_bytes.get("collective-permute", 0.0))
    if isinstance(cfg.axis_name, tuple) and mesh.shape[cfg.axis_name[0]] > 1:
        return float(cost.collective_bytes.get("all-to-all", 0.0))
    return 0.0


def score_candidates(
    mesh, candidates: Sequence[PlanConfig], batch: int, iters: int = SCORE_ITERS
) -> List[Tuple[float, PlanConfig, dict]]:
    """Rank candidates by modeled block time, ascending.

    One compile + HLO walk per overlap-group; the overlap sweep is analytic
    (:func:`model_block_times` on the shared K=1 cost).  Cross-host bytes
    (:func:`_dcn_bytes`) are charged at ``DCN_BW`` — this is what splits
    flat from hierarchical candidates on a multi-host mesh.  Ties break
    toward the *simpler* config — lower overlap, then rfft off — so a mesh
    where a knob is cost-neutral (e.g. a 1-device axis, where collectives
    vanish) keeps the defaults rather than picking complexity for nothing.
    """
    from repro.launch.hlo_analysis import analyze_compiled
    from repro.launch.roofline import model_block_times

    costs: Dict[tuple, Any] = {}
    scored: List[Tuple[float, PlanConfig, dict]] = []
    for cfg in candidates:
        gk = _group_key(cfg)
        if gk not in costs:
            compiled = _compile_group(mesh, cfg, batch, iters)
            costs[gk] = analyze_compiled(compiled)
            COUNTERS["scored"] += 1
        times = model_block_times(
            costs[gk], cfg.overlap,
            dcn_bytes=_dcn_bytes(costs[gk], cfg, mesh),
        )
        scored.append((times["modeled_total_s"], cfg, times))
    scored.sort(key=lambda t: (t[0], t[1].overlap, t[1].rfft, t[1].describe()))
    return scored


# ---------------------------------------------------------------------------
# measurement (concrete top-k wall-clock)
# ---------------------------------------------------------------------------


def measure_config(
    op, mesh, cfg: PlanConfig, batch: int, iters: int = SCORE_ITERS,
    repeats: int = MEASURE_REPEATS,
) -> float:
    """Wall-clock one candidate's concrete CPADMM block: real spectrum and
    mask via the plan lowering, zero measurements/state (the *cost* of an
    iteration does not depend on the data values), min of ``repeats`` runs
    after a warmup."""
    pl = _plan_with_config(op, mesh, cfg)
    block = pl.cpadmm_block(iters)
    rho = sigma = jnp.float32(0.01)  # cpadmm_block's scoring defaults
    b_spec = spectral.gram_inverse_spectrum(pl.spec2d, rho, sigma)
    d_diag = jnp.where(pl.mask2d > 0, 1.0 / (1.0 + rho), 1.0 / rho).astype(
        jnp.float32
    )
    zeros = jnp.zeros((batch, pl.n1, pl.n2), jnp.float32)
    state = DistCpadmmState(*(zeros,) * 5)
    block(pl.spec2d, b_spec, d_diag, zeros, state).z.block_until_ready()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        block(pl.spec2d, b_spec, d_diag, zeros, state).z.block_until_ready()
        best = min(best, time.perf_counter() - t0)
    COUNTERS["measured"] += 1
    return best


# ---------------------------------------------------------------------------
# the tuner entry point
# ---------------------------------------------------------------------------


def tuned_config(
    op,
    mesh,
    mode: str = "model",
    batch: Optional[int] = None,
    pins: Optional[dict] = None,
    cache: Optional[PlanCache] = None,
    top_k: int = 2,
    score_iters: int = SCORE_ITERS,
    extra_factorizations: Sequence[Tuple[int, int]] = (),
) -> PlanConfig:
    """Pick the :class:`PlanConfig` for (op, mesh, batch) — cached.

    ``mode="model"`` ranks by the HLO cost model alone; ``mode="measure"``
    additionally wall-clocks the top ``top_k`` model picks and lets measured
    time decide.  ``pins`` (individual plan knobs) restrict the candidate
    space; they are part of the cache key, so pinned and unpinned tunes
    never collide.  With ``mesh=None`` there is nothing distributed to tune:
    the pins (validated) are the answer.
    """
    if mode not in ("model", "measure"):
        raise ValueError(f"tune mode must be 'model' or 'measure', got {mode!r}")
    pins = dict(pins or {})
    if mesh is None:
        return PlanConfig(**pins).validate(distributed=False)

    cache = cache if cache is not None else PlanCache()
    key = cache_key(op, mesh, batch, pins)
    hit = cache.get(key)
    if hit is not None and (mode != "measure" or hit.get("mode") == "measure"):
        COUNTERS["cache_hits"] += 1
        return PlanConfig.from_dict(hit["config"])
    COUNTERS["cache_misses"] += 1

    cands = candidate_configs(
        op, mesh, pins=pins, batch=batch,
        extra_factorizations=extra_factorizations,
    )
    bench_batch = batch or 1
    scored = score_candidates(mesh, cands, batch=bench_batch, iters=score_iters)
    best_score, best_cfg, best_detail = scored[0]
    entry: dict = {
        "config": best_cfg.to_dict(),
        "mode": "model",
        "modeled_total_s": best_score,
        "candidates": len(cands),
        "detail": {k: v for k, v in best_detail.items()},
    }
    if mode == "measure":
        # wall-clock the best candidate of the top_k best *distinct compile
        # groups* (not the raw top_k, which can be K-sweep variants of one
        # group): the model's close calls between groups are exactly what
        # measurement is for
        picks: List[PlanConfig] = []
        seen_groups: set = set()
        for _, cfg, _ in scored:
            gk = _group_key(cfg)
            if gk in seen_groups:
                continue
            seen_groups.add(gk)
            picks.append(cfg)
            if len(picks) >= top_k:
                break
        measured = []
        for cfg in picks:
            measured.append(
                (measure_config(op, mesh, cfg, bench_batch, score_iters), cfg)
            )
        measured.sort(key=lambda t: t[0])
        best_wall, best_cfg = measured[0]
        entry.update(
            config=best_cfg.to_dict(), mode="measure", measured_s=best_wall,
            measured_top_k=[
                {"config": c.to_dict(), "s": s} for s, c in measured
            ],
        )
    cache.put(key, entry)
    return best_cfg


# ---------------------------------------------------------------------------
# cache CLI
# ---------------------------------------------------------------------------


def main(argv: Optional[List[str]] = None) -> None:
    import argparse

    ap = argparse.ArgumentParser(
        description="Inspect or clear the plan-autotune cache."
    )
    ap.add_argument("--cache", default=None, help="cache path override")
    ap.add_argument("--show", action="store_true", help="print entries")
    ap.add_argument("--clear", action="store_true", help="delete the store")
    args = ap.parse_args(argv)
    cache = PlanCache(args.cache)
    if args.clear:
        cache.clear()
        print(f"cleared {cache.path}")
        return
    entries = cache.entries()
    print(f"{cache.path}: {len(entries)} cached plan(s)")
    for key, entry in sorted(entries.items()):
        cfg = PlanConfig.from_dict(entry["config"])
        score = entry.get("measured_s", entry.get("modeled_total_s"))
        print(f"  [{entry['mode']}] {cfg.describe()}  score={score:.3e}")
        print(f"    key: {key}")


if __name__ == "__main__":
    main()

"""Mamba-2 (SSD) block: chunked training form + recurrent decode.

Training uses the chunked SSD algorithm (Dao & Gu, arXiv:2405.21060): the
sequence is split into chunks of length Q; within a chunk the state-space
kernel is evaluated as a masked (semiseparable) attention-like product, and
chunk boundary states are propagated by a lax.scan — O(T Q) work and O(T)
memory instead of the O(T^2) naive form, and only the tiny inter-chunk scan
is sequential.  This is also what makes the 500k-token hybrid cells viable
(DESIGN.md §Arch-applicability).

Decode carries the (H, N, P) state exactly: h_t = a_t h_{t-1} + dt B_t x_t,
y_t = C_t h_t + D x_t — O(1) per token, no KV cache.

Note (DESIGN.md Sec. 5): the SSD recurrence is input-gated (time-varying),
so the paper's circulant structure does NOT apply inside this block.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain

from .config import ModelConfig
from .layers import dense_init, init_norm, rmsnorm

Array = jax.Array

CHUNK = 128


def init_mamba2(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    din, ns, g = cfg.d_ssm_inner, cfg.ssm_state, cfg.ssm_groups
    nh = cfg.n_ssm_heads
    conv_dim = din + 2 * g * ns
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], d, 2 * din + 2 * g * ns + nh, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "norm": init_norm(din, dtype),
        "out_proj": dense_init(ks[2], din, d, dtype),
    }


class Mamba2Cache(NamedTuple):
    conv: Array  # (B, conv_width-1, conv_dim) — rolling conv window
    state: Array  # (B, H, N, P) — SSM state
    length: Array  # (B,)


def init_mamba2_cache(cfg: ModelConfig, batch: int, dtype) -> Mamba2Cache:
    din, ns, g = cfg.d_ssm_inner, cfg.ssm_state, cfg.ssm_groups
    nh, p = cfg.n_ssm_heads, cfg.ssm_head_dim
    conv_dim = din + 2 * g * ns
    return Mamba2Cache(
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
        state=jnp.zeros((batch, nh, ns, p), jnp.float32),
        length=jnp.zeros((batch,), jnp.int32),
    )


def _split_proj(cfg: ModelConfig, zxbcdt: Array):
    din, ns, g = cfg.d_ssm_inner, cfg.ssm_state, cfg.ssm_groups
    z, xbc, dt = jnp.split(zxbcdt, [din, 2 * din + 2 * g * ns], axis=-1)
    return z, xbc, dt  # gate, conv-input, dt-logits


def _causal_conv(cfg, xbc: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv along seq: xbc (B,S,C), w (K,C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc)
    for i in range(k):  # k is 4: static unroll
        out = out + pad[:, i : i + xbc.shape[1], :] * w[i]
    return jax.nn.silu(out + b)


def _ssd_chunked(x, dt, a_log, B, C, d_skip, chunk=CHUNK):
    """Chunked SSD scan.

    x:  (Bt, T, H, P)   dt: (Bt, T, H)   B, C: (Bt, T, G, N)
    returns y: (Bt, T, H, P), final_state: (Bt, H, N, P)
    """
    bt, t, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    nc = t // chunk
    A = -jnp.exp(a_log)  # (H,) negative

    xc = x.reshape(bt, nc, chunk, h, p)
    dtc = dt.reshape(bt, nc, chunk, h)
    Bc = jnp.repeat(B.reshape(bt, nc, chunk, g, n), rep, axis=3)  # (bt,nc,Q,H,N)
    Cc = jnp.repeat(C.reshape(bt, nc, chunk, g, n), rep, axis=3)

    da = dtc * A  # (bt,nc,Q,H) log-decay per step
    cum = jnp.cumsum(da, axis=2)  # S_i (inclusive)
    seg_total = cum[:, :, -1, :]  # (bt,nc,H)

    # ---- intra-chunk: masked semiseparable "attention"
    # G[i, j] = C_i . B_j * exp(S_i - S_j) * dt_j   for j <= i
    li = cum[:, :, :, None, :]  # (bt,nc,Q,1,H)
    lj = cum[:, :, None, :, :]  # (bt,nc,1,Q,H)
    decay = jnp.exp(jnp.clip(li - lj, -60.0, 0.0))  # (bt,nc,Q,Q,H)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    scores = jnp.einsum("bcqhn,bckhn->bcqkh", Cc, Bc)  # (bt,nc,Q,Q,H)
    scores = scores * decay * dtc[:, :, None, :, :]
    scores = jnp.where(mask[None, None, :, :, None], scores, 0.0)
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", scores, xc)

    # ---- chunk summary states: sum_j exp(S_Q - S_j) dt_j B_j x_j^T
    w = jnp.exp(jnp.clip(seg_total[:, :, None, :] - cum, -60.0, 0.0)) * dtc
    chunk_state = jnp.einsum("bcqh,bcqhn,bcqhp->bchnp", w, Bc, xc)

    # ---- inter-chunk recurrence over nc chunks
    def scan_body(h_prev, inp):
        cs, tot = inp  # (bt,H,N,P), (bt,H)
        h_new = h_prev * jnp.exp(jnp.clip(tot, -60.0, 0.0))[:, :, None, None] + cs
        return h_new, h_prev  # emit the state *entering* the chunk

    h0 = jnp.zeros((bt, h, n, p), jnp.float32)
    h_final, h_in = jax.lax.scan(
        scan_body,
        h0,
        (chunk_state.swapaxes(0, 1).astype(jnp.float32), seg_total.swapaxes(0, 1)),
    )
    h_in = h_in.swapaxes(0, 1)  # (bt,nc,H,N,P) state entering each chunk

    # ---- inter-chunk contribution: C_i . h_in * exp(S_i)
    y_inter = jnp.einsum(
        "bcqhn,bchnp,bcqh->bcqhp",
        Cc,
        h_in.astype(Cc.dtype),
        jnp.exp(jnp.clip(cum, -60.0, 0.0)).astype(Cc.dtype),
    )

    y = (y_intra + y_inter).reshape(bt, t, h, p) + x * d_skip[None, None, :, None]
    return y, h_final


def mamba2_forward(params: dict, cfg: ModelConfig, x: Array) -> Array:
    """x: (B, S, D) -> (B, S, D).  S must be a multiple of CHUNK (pad upstream)."""
    b, s, d = x.shape
    din, ns, g = cfg.d_ssm_inner, cfg.ssm_state, cfg.ssm_groups
    nh, p = cfg.n_ssm_heads, cfg.ssm_head_dim

    zxbcdt = jnp.einsum("bsd,dk->bsk", x, params["in_proj"])
    z, xbc, dt_logit = _split_proj(cfg, zxbcdt)
    xbc = _causal_conv(cfg, xbc, params["conv_w"], params["conv_b"])
    xs, B, C = jnp.split(xbc, [din, din + g * ns], axis=-1)
    xs = constrain(xs, "batch", None, "ssm_inner")

    dt = jax.nn.softplus(dt_logit.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))  # (B,S,H)
    xh = xs.reshape(b, s, nh, p)
    Bh = B.reshape(b, s, g, ns)
    Ch = C.reshape(b, s, g, ns)

    pad = (-s) % CHUNK
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bh = jnp.pad(Bh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Ch = jnp.pad(Ch, ((0, 0), (0, pad), (0, 0), (0, 0)))

    y, _ = _ssd_chunked(
        xh.astype(jnp.float32), dt, params["a_log"].astype(jnp.float32),
        Bh.astype(jnp.float32), Ch.astype(jnp.float32),
        params["d_skip"].astype(jnp.float32),
    )
    y = y[:, :s].reshape(b, s, din).astype(x.dtype)
    y = y * jax.nn.silu(z)  # gated
    y = rmsnorm(params["norm"], y, cfg.norm_eps)
    return jnp.einsum("bsk,kd->bsd", y, params["out_proj"])


def mamba2_decode(
    params: dict, cfg: ModelConfig, x: Array, cache: Mamba2Cache
) -> Tuple[Array, Mamba2Cache]:
    """Single-token recurrent step.  x: (B, 1, D)."""
    b = x.shape[0]
    din, ns, g = cfg.d_ssm_inner, cfg.ssm_state, cfg.ssm_groups
    nh, p = cfg.n_ssm_heads, cfg.ssm_head_dim

    zxbcdt = jnp.einsum("bsd,dk->bsk", x, params["in_proj"])[:, 0]
    z, xbc, dt_logit = _split_proj(cfg, zxbcdt[:, None, :])
    xbc = xbc[:, 0]

    # rolling causal conv
    window = jnp.concatenate([cache.conv, xbc[:, None, :]], axis=1)  # (B,K,C)
    w = params["conv_w"]
    conv_out = jnp.einsum("bkc,kc->bc", window, w) + params["conv_b"]
    conv_out = jax.nn.silu(conv_out)
    new_conv = window[:, 1:]

    xs, B, C = jnp.split(conv_out, [din, din + g * ns], axis=-1)
    dt = jax.nn.softplus(dt_logit[:, 0].astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))  # (B,H)
    A = -jnp.exp(params["a_log"].astype(jnp.float32))
    a = jnp.exp(dt * A)  # (B,H)

    xh = xs.reshape(b, nh, p).astype(jnp.float32)
    rep = nh // g
    Bh = jnp.repeat(B.reshape(b, g, ns), rep, axis=1).astype(jnp.float32)  # (B,H,N)
    Ch = jnp.repeat(C.reshape(b, g, ns), rep, axis=1).astype(jnp.float32)

    state = cache.state * a[:, :, None, None] + jnp.einsum(
        "bh,bhn,bhp->bhnp", dt, Bh, xh
    )
    y = jnp.einsum("bhn,bhnp->bhp", Ch, state) + xh * params["d_skip"][None, :, None]
    y = y.reshape(b, 1, din).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(params["norm"], y, cfg.norm_eps)
    out = jnp.einsum("bsk,kd->bsd", y, params["out_proj"])
    return out, Mamba2Cache(conv=new_conv, state=state, length=cache.length + 1)

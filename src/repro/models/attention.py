"""Attention: GQA/MQA and MLA, with chunked online-softmax and KV-cache decode.

Training/prefill attention streams KV in chunks with a running (max, sum)
online softmax — flash-attention's algorithm expressed in pure JAX (lax.scan
over KV chunks).  Peak memory is O(S * chunk) instead of O(S^2), which is
what lets the 32k-prefill cells fit a 16 GiB chip (see EXPERIMENTS.md).

Decode attends one query position against a fixed-size cache with a length
mask — O(S) work per emitted token.

MLA (DeepSeek-V3) keeps the paper-faithful formulation: latent c_kv (rank
512) + shared RoPE key; the decode cache stores only (c_kv, k_rope) — the
8x KV-cache shrink that makes the 32k-decode cell cheap.  The "absorbed"
matmul variant is a §Perf hillclimb (see EXPERIMENTS.md).
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain

from .config import ModelConfig
from .layers import apply_rope, dense_init, init_norm, rmsnorm

Array = jax.Array

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# core chunked attention
# ---------------------------------------------------------------------------


def _attend_qtile(
    qf: Array,  # (B, Tq, K, G, Dh) pre-scaled fp32
    kc: Array,  # (B, nkv, Ck, K, Dh)
    vc: Array,  # (B, nkv, Ck, K, Dv)
    q_pos: Array,  # (Tq,) absolute positions of this q tile
    *,
    causal: bool,
    sk: int,
    chunk: int,
    kv_valid_len: Optional[Array],
    sliding_window: int,
) -> Array:
    """Online-softmax over KV chunks for one query tile (flash inner loop)."""
    b, tq, kh, g, dh = qf.shape
    dv = vc.shape[-1]

    def body(carry, inputs):
        m, l, acc, idx = carry
        kb, vb = inputs  # (B, Ck, K, Dh/Dv)
        kv_pos = idx * chunk + jnp.arange(chunk)
        s = jnp.einsum("bqkgd,bckd->bkgqc", qf, kb.astype(jnp.float32))
        mask = kv_pos[None, :] < sk  # padding mask, (Tq?, Ck) broadcast
        mask = jnp.broadcast_to(mask, (tq, chunk))
        if causal:
            mask = mask & (q_pos[:, None] >= kv_pos[None, :])
        if sliding_window:
            mask = mask & (kv_pos[None, :] > q_pos[:, None] - sliding_window)
        if kv_valid_len is not None:
            vmask = kv_pos[None, :] < kv_valid_len[:, None]  # (B, Ck)
            s = jnp.where(vmask[:, None, None, None, :], s, NEG_INF)
        s = jnp.where(mask[None, None, None, :, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqc,bckd->bkgqd", p, vb.astype(jnp.float32))
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new, idx + 1), None

    m0 = jnp.full((b, kh, g, tq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kh, g, tq), jnp.float32)
    a0 = jnp.zeros((b, kh, g, tq, dv), jnp.float32)
    (m, l, acc, _), _ = jax.lax.scan(
        body,
        (m0, l0, a0, jnp.zeros((), jnp.int32)),
        (kc.swapaxes(0, 1), vc.swapaxes(0, 1)),
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 3, 1, 2, 4)  # (B, Tq, K, G, Dv)


def _attend_chunked(
    q: Array,  # (B, Sq, H, Dh)
    k: Array,  # (B, Sk, K, Dh)
    v: Array,  # (B, Sk, K, Dv)
    *,
    causal: bool,
    q_offset: int | Array = 0,
    chunk: int = 1024,
    scale: Optional[float] = None,
    kv_valid_len: Optional[Array] = None,  # (B,) valid cache length (decode)
    sliding_window: int = 0,
) -> Array:
    """Flash-style attention: scan over query tiles x KV chunks.

    Peak live score tile is (B, K, G, q_chunk, chunk) fp32 — independent of
    Sq and Sk, which is what lets the 32k cells fit (EXPERIMENTS.md §Dry-run).
    GQA: H = G * K.
    """
    b, sq, h, dh = q.shape
    sk, kh = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = h // kh
    scale = scale if scale is not None else dh**-0.5
    qf = (q * scale).astype(jnp.float32).reshape(b, sq, kh, g, dh)

    n_kv = max(1, (sk + chunk - 1) // chunk)
    pad_kv = n_kv * chunk - sk
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    kc = k.reshape(b, n_kv, chunk, kh, dh)
    vc = v.reshape(b, n_kv, chunk, kh, dv)

    q_chunk = min(chunk, sq) if sq >= chunk else sq
    n_q = max(1, (sq + q_chunk - 1) // q_chunk)
    pad_q = n_q * q_chunk - sq
    if pad_q:
        qf = jnp.pad(qf, ((0, 0), (0, pad_q), (0, 0), (0, 0), (0, 0)))
    qt = qf.reshape(b, n_q, q_chunk, kh, g, dh)
    q_pos0 = jnp.asarray(q_offset)

    def q_body(_, inp):
        q_tile, qi = inp
        q_pos = q_pos0 + qi * q_chunk + jnp.arange(q_chunk)
        out = _attend_qtile(
            q_tile, kc, vc, q_pos,
            causal=causal, sk=sk, chunk=chunk,
            kv_valid_len=kv_valid_len, sliding_window=sliding_window,
        )
        return None, out

    q_body_fn = jax.checkpoint(q_body) if n_q > 1 else q_body
    _, outs = jax.lax.scan(
        q_body_fn, None, (qt.swapaxes(0, 1), jnp.arange(n_q))
    )  # (n_q, B, q_chunk, K, G, Dv)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, n_q * q_chunk, h, dv)
    return out[:, :sq].astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA / MQA
# ---------------------------------------------------------------------------


def init_gqa(key, cfg: ModelConfig, dtype) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, cfg.n_heads * hd, dtype),
        "wk": dense_init(ks[1], d, cfg.n_kv_heads * hd, dtype),
        "wv": dense_init(ks[2], d, cfg.n_kv_heads * hd, dtype),
        "wo": dense_init(ks[3], cfg.n_heads * hd, d, dtype),
    }


class KVCache(NamedTuple):
    k: Array  # (B, S_max, K, Dh)
    v: Array  # (B, S_max, K, Dv)
    length: Array  # (B,) int32 — filled positions


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> KVCache:
    hd = cfg.resolved_head_dim
    shape = (batch, max_len, cfg.n_kv_heads, hd)
    return KVCache(
        k=jnp.zeros(shape, dtype),
        v=jnp.zeros(shape, dtype),
        length=jnp.zeros((batch,), jnp.int32),
    )


def gqa_forward(
    params: dict,
    cfg: ModelConfig,
    x: Array,  # (B, S, D)
    positions: Array,  # (B, S)
    *,
    causal: bool = True,
    rope: bool = True,
    kv: Optional[Tuple[Array, Array]] = None,  # cross-attention K/V source
) -> Array:
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dh->bsh", x, params["wq"]).reshape(b, s, cfg.n_heads, hd)
    if kv is None:
        k = jnp.einsum("bsd,dh->bsh", x, params["wk"]).reshape(b, s, cfg.n_kv_heads, hd)
        v = jnp.einsum("bsd,dh->bsh", x, params["wv"]).reshape(b, s, cfg.n_kv_heads, hd)
        if rope:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    else:
        src = kv[0]
        sk = src.shape[1]
        k = jnp.einsum("bsd,dh->bsh", src, params["wk"]).reshape(b, sk, cfg.n_kv_heads, hd)
        v = jnp.einsum("bsd,dh->bsh", src, params["wv"]).reshape(b, sk, cfg.n_kv_heads, hd)
    q = constrain(q, "batch", None, "heads", None)
    k = constrain(k, "batch", None, "kv_heads", None)
    out = _attend_chunked(
        q, k, v, causal=causal, chunk=cfg.attn_chunk, sliding_window=cfg.sliding_window
    )
    out = out.reshape(b, s, cfg.n_heads * hd)
    y = jnp.einsum("bsh,hd->bsd", out, params["wo"])
    return constrain(y, "batch", None, "embed")  # bf16 TP reduce (see layers.mlp)


def gqa_decode(
    params: dict,
    cfg: ModelConfig,
    x: Array,  # (B, 1, D)
    cache: KVCache,
    *,
    rope: bool = True,
) -> Tuple[Array, KVCache]:
    b, s, d = x.shape
    assert s == 1
    hd = cfg.resolved_head_dim
    pos = cache.length[:, None]  # (B, 1)
    q = jnp.einsum("bsd,dh->bsh", x, params["wq"]).reshape(b, 1, cfg.n_heads, hd)
    k = jnp.einsum("bsd,dh->bsh", x, params["wk"]).reshape(b, 1, cfg.n_kv_heads, hd)
    v = jnp.einsum("bsd,dh->bsh", x, params["wv"]).reshape(b, 1, cfg.n_kv_heads, hd)
    if rope:
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    k_new = jax.vmap(lambda ck, kn, i: jax.lax.dynamic_update_slice(ck, kn, (i, 0, 0)))(
        cache.k, k.astype(cache.k.dtype), cache.length
    )
    v_new = jax.vmap(lambda cv, vn, i: jax.lax.dynamic_update_slice(cv, vn, (i, 0, 0)))(
        cache.v, v.astype(cache.v.dtype), cache.length
    )
    out = _attend_chunked(
        q,
        k_new,
        v_new,
        causal=False,  # masking via kv_valid_len
        chunk=cfg.attn_chunk,
        kv_valid_len=cache.length + 1,
        sliding_window=cfg.sliding_window,
    )
    out = out.reshape(b, 1, cfg.n_heads * hd)
    y = jnp.einsum("bsh,hd->bsd", out, params["wo"])
    return y, KVCache(k=k_new, v=v_new, length=cache.length + 1)


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (DeepSeek-V3)
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    h = cfg.n_heads
    ks = jax.random.split(key, 8)
    p = {
        "w_dkv": dense_init(ks[0], d, cfg.kv_lora_rank, dtype),  # latent down
        "w_krope": dense_init(ks[1], d, dr, dtype),  # shared rope key
        "kv_norm": init_norm(cfg.kv_lora_rank, dtype),
        "w_uk": dense_init(ks[2], cfg.kv_lora_rank, h * dn, dtype),
        "w_uv": dense_init(ks[3], cfg.kv_lora_rank, h * dv, dtype),
        "wo": dense_init(ks[4], h * dv, d, dtype),
    }
    if cfg.q_lora_rank:
        p["w_dq"] = dense_init(ks[5], d, cfg.q_lora_rank, dtype)
        p["q_norm"] = init_norm(cfg.q_lora_rank, dtype)
        p["w_uq"] = dense_init(ks[6], cfg.q_lora_rank, h * (dn + dr), dtype)
    else:
        p["w_q"] = dense_init(ks[7], d, h * (dn + dr), dtype)
    return p


class MLACache(NamedTuple):
    c_kv: Array  # (B, S_max, kv_lora_rank) — the compressed latent
    k_rope: Array  # (B, S_max, rope_head_dim)
    length: Array  # (B,)


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> MLACache:
    return MLACache(
        c_kv=jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        k_rope=jnp.zeros((batch, max_len, cfg.rope_head_dim), dtype),
        length=jnp.zeros((batch,), jnp.int32),
    )


def _mla_q(params, cfg, x, positions):
    b, s, _ = x.shape
    h, dn, dr = cfg.n_heads, cfg.nope_head_dim, cfg.rope_head_dim
    if cfg.q_lora_rank:
        cq = jnp.einsum("bsd,dr->bsr", x, params["w_dq"])
        cq = rmsnorm(params["q_norm"], cq, cfg.norm_eps)
        q = jnp.einsum("bsr,rh->bsh", cq, params["w_uq"])
    else:
        q = jnp.einsum("bsd,dh->bsh", x, params["w_q"])
    q = q.reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return jnp.concatenate([q_nope, q_rope], axis=-1)  # (B,S,H,dn+dr)


def _mla_kv_from_latent(params, cfg, c_kv, k_rope):
    """Expand latent to per-head K (nope||rope) and V."""
    b, sk, _ = c_kv.shape
    h, dn, dv = cfg.n_heads, cfg.nope_head_dim, cfg.v_head_dim
    k_nope = jnp.einsum("bsr,rh->bsh", c_kv, params["w_uk"]).reshape(b, sk, h, dn)
    v = jnp.einsum("bsr,rh->bsh", c_kv, params["w_uv"]).reshape(b, sk, h, dv)
    k_rope_b = jnp.broadcast_to(k_rope[:, :, None, :], (b, sk, h, cfg.rope_head_dim))
    k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    return k, v


def mla_forward(params: dict, cfg: ModelConfig, x: Array, positions: Array) -> Array:
    b, s, d = x.shape
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    q = _mla_q(params, cfg, x, positions)
    c_kv = jnp.einsum("bsd,dr->bsr", x, params["w_dkv"])
    c_kv = rmsnorm(params["kv_norm"], c_kv, cfg.norm_eps)
    k_rope = apply_rope(
        jnp.einsum("bsd,dr->bsr", x, params["w_krope"])[:, :, None, :], positions,
        cfg.rope_theta,
    )[:, :, 0, :]
    k, v = _mla_kv_from_latent(params, cfg, c_kv, k_rope)
    q = constrain(q, "batch", None, "heads", None)
    k = constrain(k, "batch", None, "heads", None)
    out = _attend_chunked(
        q, k, v, causal=True, chunk=cfg.attn_chunk, scale=(dn + dr) ** -0.5
    )
    out = out.reshape(b, s, cfg.n_heads * dv)
    y = jnp.einsum("bsh,hd->bsd", out, params["wo"])
    return constrain(y, "batch", None, "embed")  # bf16 TP reduce (see layers.mlp)


def mla_decode_absorbed(
    params: dict, cfg: ModelConfig, x: Array, cache: MLACache
) -> Tuple[Array, MLACache]:
    """Beyond-paper(arch) decode: DeepSeek's weight-absorption trick.

    The naive decode expands the latent cache to per-head K/V of shape
    (B, S, H, dn + dv) every step — at 32k cache that is a ~200 GB
    materialization *per token* (EXPERIMENTS.md §Perf).  Absorption folds
    W_uk into the query and W_uv into the output projection so attention
    runs directly in the rank-512 latent space:

        scores = (q_nope W_uk) . c_kv + q_rope . k_rope      (B,H,S)
        out    = softmax(scores) . c_kv                      (B,H,R)
        y      = out W_uv W_o   (materialized per head)

    No S x H tensor is ever built; per-step traffic ~ the latent cache
    itself.  Exact same math (tested vs mla_decode to fp tolerance).
    """
    b, s, d = x.shape
    assert s == 1
    h = cfg.n_heads
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    pos = cache.length[:, None]
    q = _mla_q(params, cfg, x, pos)  # (B,1,H,dn+dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]

    # append this step's latent to the cache (identical to naive path)
    c_new = jnp.einsum("bsd,dr->bsr", x, params["w_dkv"])
    c_new = rmsnorm(params["kv_norm"], c_new, cfg.norm_eps)
    kr_new = apply_rope(
        jnp.einsum("bsd,dr->bsr", x, params["w_krope"])[:, :, None, :], pos,
        cfg.rope_theta,
    )[:, :, 0, :]
    c_kv = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice(c, n, (i, 0)))(
        cache.c_kv, c_new.astype(cache.c_kv.dtype), cache.length
    )
    k_rope = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice(c, n, (i, 0)))(
        cache.k_rope, kr_new.astype(cache.k_rope.dtype), cache.length
    )

    # absorb W_uk into q: q_lat[b,h,r] = sum_dn q_nope[b,h,dn] W_uk[r, h*dn]
    w_uk = params["w_uk"].reshape(r, h, dn)
    q_lat = jnp.einsum("bhn,rhn->bhr", q_nope[:, 0].astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    scale = (dn + dr) ** -0.5
    scores = (
        jnp.einsum("bhr,bsr->bhs", q_lat, c_kv.astype(jnp.float32))
        + jnp.einsum("bhr,bsr->bhs", q_rope[:, 0].astype(jnp.float32),
                     k_rope.astype(jnp.float32))
    ) * scale
    valid = jnp.arange(c_kv.shape[1])[None, None, :] < (cache.length + 1)[:, None, None]
    scores = jnp.where(valid, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out_lat = jnp.einsum("bhs,bsr->bhr", w, c_kv.astype(jnp.float32))  # (B,H,R)

    # absorb W_uv then the output projection
    w_uv = params["w_uv"].reshape(r, h, dv)
    out_v = jnp.einsum("bhr,rhv->bhv", out_lat, w_uv.astype(jnp.float32))
    out_v = out_v.reshape(b, 1, h * dv).astype(x.dtype)
    y = jnp.einsum("bsh,hd->bsd", out_v, params["wo"])
    return y, MLACache(c_kv=c_kv, k_rope=k_rope, length=cache.length + 1)


def mla_decode(
    params: dict, cfg: ModelConfig, x: Array, cache: MLACache
) -> Tuple[Array, MLACache]:
    b, s, d = x.shape
    assert s == 1
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    pos = cache.length[:, None]
    q = _mla_q(params, cfg, x, pos)  # (B,1,H,dn+dr)
    c_new = jnp.einsum("bsd,dr->bsr", x, params["w_dkv"])
    c_new = rmsnorm(params["kv_norm"], c_new, cfg.norm_eps)
    kr_new = apply_rope(
        jnp.einsum("bsd,dr->bsr", x, params["w_krope"])[:, :, None, :], pos,
        cfg.rope_theta,
    )[:, :, 0, :]
    c_kv = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice(c, n, (i, 0)))(
        cache.c_kv, c_new.astype(cache.c_kv.dtype), cache.length
    )
    k_rope = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice(c, n, (i, 0)))(
        cache.k_rope, kr_new.astype(cache.k_rope.dtype), cache.length
    )
    k, v = _mla_kv_from_latent(params, cfg, c_kv, k_rope)
    out = _attend_chunked(
        q,
        k,
        v,
        causal=False,
        chunk=cfg.attn_chunk,
        scale=(dn + dr) ** -0.5,
        kv_valid_len=cache.length + 1,
    )
    out = out.reshape(b, 1, cfg.n_heads * dv)
    y = jnp.einsum("bsh,hd->bsd", out, params["wo"])
    return y, MLACache(c_kv=c_kv, k_rope=k_rope, length=cache.length + 1)

"""Foundational layers: norms, RoPE, embeddings, GLU MLPs, initializers.

Functional style throughout: ``init_*`` builds a param dict, ``apply``-style
functions are pure.  Sharding is expressed with logical-axis constraints via
``repro.dist.sharding.constrain`` (identity when no mesh is active, so smoke
tests on one CPU device are unaffected).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain

Array = jax.Array


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype) -> Array:
    """Truncated-normal fan-in init (LLaMA-style 1/sqrt(d_in))."""
    std = d_in**-0.5
    return (jax.random.truncated_normal(key, -3, 3, (d_in, d_out)) * std).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> Array:
    return (jax.random.truncated_normal(key, -3, 3, (vocab, d)) * (d**-0.5)).astype(
        dtype
    )


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------


def init_norm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: dict, x: Array, eps: float = 1e-5) -> Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dtype)


def layernorm(params: dict, x: Array, eps: float = 1e-5) -> Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * params["scale"].astype(jnp.float32)).astype(dtype)


def apply_norm(params: dict, x: Array, kind: str = "rmsnorm", eps: float = 1e-5):
    return rmsnorm(params, x, eps) if kind == "rmsnorm" else layernorm(params, x, eps)


# --------------------------------------------------------------------------
# rotary position embeddings
# --------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., S, H, Dh); positions: (..., S) int32."""
    dh = x.shape[-1]
    freqs = rope_frequencies(dh, theta)  # (Dh/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos = jnp.cos(angles)[..., :, None, :]  # (..., S, 1, Dh/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n_pos: int, d: int) -> Array:
    """Whisper-style fixed sinusoidal table (n_pos, d)."""
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / (half - 1))
    args = jnp.arange(n_pos)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(args), jnp.cos(args)], axis=-1).astype(jnp.float32)


# --------------------------------------------------------------------------
# GLU MLP family (SwiGLU / GeGLU)
# --------------------------------------------------------------------------


def init_mlp(key, d: int, d_ff: int, dtype, variant: str = "glu") -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_up": dense_init(k2, d, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d, dtype),
    }
    if variant == "glu":
        p["w_gate"] = dense_init(k1, d, d_ff, dtype)
    return p


def mlp(params: dict, x: Array, act: str = "silu") -> Array:
    actfn = jax.nn.silu if act == "silu" else (lambda v: jax.nn.gelu(v, approximate=True))
    up = jnp.einsum("...d,df->...f", x, params["w_up"])
    if "w_gate" in params:  # GLU family
        gate = jnp.einsum("...d,df->...f", x, params["w_gate"])
        h = actfn(gate) * up
    else:  # plain 2-matrix MLP (granite / minitron / whisper)
        h = actfn(up)
    h = constrain(h, "batch", None, "mlp")
    out = jnp.einsum("...f,fd->...d", h, params["w_down"])
    # Force the TP partial-sum reduction HERE, in bf16: without this, XLA
    # defers the all-reduce past the residual into the next norm's fp32
    # region — 2x the wire bytes (EXPERIMENTS.md §Perf, codeqwen cell).
    return constrain(out, "batch", None, "embed")


# --------------------------------------------------------------------------
# embedding / unembedding
# --------------------------------------------------------------------------


def init_embedding(key, vocab_padded: int, d: int, dtype, tie: bool) -> dict:
    k1, k2 = jax.random.split(key)
    p = {"table": embed_init(k1, vocab_padded, d, dtype)}
    if not tie:
        p["unembed"] = dense_init(k2, d, vocab_padded, dtype)
    return p


def embed(params: dict, tokens: Array, dtype) -> Array:
    return jnp.take(params["table"], tokens, axis=0).astype(dtype)


def unembed(params: dict, x: Array, tie: bool) -> Array:
    if tie:
        return jnp.einsum("...d,vd->...v", x, params["table"])
    return jnp.einsum("...d,dv->...v", x, params["unembed"])

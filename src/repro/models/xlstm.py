"""xLSTM blocks: mLSTM (matrix memory, parallelizable) and sLSTM (scalar
memory, sequential) — Beck et al., arXiv:2405.04517.

mLSTM trains in its chunked-parallel form (quadratic within a chunk, gate-
decay recurrence across chunks — same schedule shape as SSD in ssm.py) with
log-space gate stabilization.  sLSTM has a genuine hidden-to-gate recurrence
(not associative), so training runs a lax.scan over time; xlstm-350m places
it on every ``slstm_every``-th block only.

Decode: mLSTM carries (C: dk x dv matrix cell, n: dk normalizer, m: log gate
max) per head; sLSTM carries (c, n, h, m) scalar vectors.  Both are O(1) per
token — this is why the xlstm arch runs the 500k long-context cell.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain

from .config import ModelConfig
from .layers import dense_init, init_norm, rmsnorm

Array = jax.Array

CHUNK = 256


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    din = 2 * d  # xLSTM pf=2 up-projection
    h = cfg.n_heads
    ks = jax.random.split(key, 8)
    return {
        "w_up": dense_init(ks[0], d, 2 * din, dtype),  # x-branch + gate-branch
        "w_q": dense_init(ks[1], din, din, dtype),
        "w_k": dense_init(ks[2], din, din, dtype),
        "w_v": dense_init(ks[3], din, din, dtype),
        "w_i": dense_init(ks[4], din, h, dtype),  # input gate (per head)
        "w_f": dense_init(ks[5], din, h, dtype),  # forget gate
        "w_o": dense_init(ks[6], din, din, dtype),  # output gate proj
        "norm": init_norm(din, dtype),
        "w_down": dense_init(ks[7], din, d, dtype),
    }


class MlstmCache(NamedTuple):
    C: Array  # (B, H, Dk, Dv)
    n: Array  # (B, H, Dk)
    m: Array  # (B, H) log-space gate max
    length: Array


def init_mlstm_cache(cfg: ModelConfig, batch: int) -> MlstmCache:
    h = cfg.n_heads
    dk = 2 * cfg.d_model // h
    return MlstmCache(
        C=jnp.zeros((batch, h, dk, dk), jnp.float32),
        n=jnp.zeros((batch, h, dk), jnp.float32),
        m=jnp.full((batch, h), -1e30, jnp.float32),
        length=jnp.zeros((batch,), jnp.int32),
    )


def _mlstm_parallel(q, k, v, i_gate, f_gate):
    """Stabilized chunkwise-quadratic mLSTM.

    q,k,v: (B, T, H, Dk); i_gate,f_gate: (B, T, H) raw logits.
    Chunked exactly like SSD: intra-chunk quadratic + inter-chunk recurrence.
    """
    b, t, h, dk = q.shape
    logf = jax.nn.log_sigmoid(f_gate.astype(jnp.float32))  # (B,T,H)
    logi = i_gate.astype(jnp.float32)
    nc = t // CHUNK

    qc = q.reshape(b, nc, CHUNK, h, dk).astype(jnp.float32) * dk**-0.5
    kc = k.reshape(b, nc, CHUNK, h, dk).astype(jnp.float32)
    vc = v.reshape(b, nc, CHUNK, h, dk).astype(jnp.float32)
    lf = logf.reshape(b, nc, CHUNK, h)
    li = logi.reshape(b, nc, CHUNK, h)

    F = jnp.cumsum(lf, axis=2)  # (b,nc,Q,h) inclusive log-forget prefix
    Ftot = F[:, :, -1, :]

    # log weight of source j for target i (within chunk): F_i - F_j + logi_j
    lw = F[:, :, :, None, :] - F[:, :, None, :, :] + li[:, :, None, :, :]
    mask = jnp.tril(jnp.ones((CHUNK, CHUNK), bool))
    lw = jnp.where(mask[None, None, :, :, None], lw, -1e30)  # finite: -inf NaNs the backward

    # log weight of the incoming inter-chunk state for target i: F_i (+ m_prev)
    # combined stabilizer per (i): max(max_j lw, F_i + m_prev)
    def scan_chunks(carry, inp):
        C_prev, n_prev, m_prev = carry  # (b,h,dk,dk),(b,h,dk),(b,h)
        qb, kb, vb, lwb, Fb, lib, Ftotb = inp
        # lwb: (b,Q,Q,h); Fb: (b,Q,h)
        state_lw = Fb + m_prev[:, None, :]  # (b,Q,h)
        m_intra = jnp.max(lwb, axis=2)  # (b,Q,h); masked entries are -1e30
        m_i = jnp.maximum(m_intra, state_lw)  # (b,Q,h)

        w_intra = jnp.exp(jnp.clip(lwb - m_i[:, :, None, :], -60.0, 0.0))
        w_intra = jnp.where(mask[None, :, :, None], w_intra, 0.0)
        scores = jnp.einsum("bqhd,bkhd->bqkh", qb, kb) * w_intra
        num_intra = jnp.einsum("bqkh,bkhd->bqhd", scores, vb)
        den_intra = jnp.sum(scores, axis=2)  # (b,q,h): q . (weighted k sum)

        w_state = jnp.exp(jnp.clip(state_lw - m_i, -60.0, 0.0))  # (b,Q,h)
        num_state = jnp.einsum("bqhd,bhde->bqhe", qb, C_prev) * w_state[..., None]
        den_state = jnp.einsum("bqhd,bhd->bqh", qb, n_prev) * w_state

        num = num_intra + num_state
        den = jnp.abs(den_intra + den_state)
        # clamp: exp(-m) overflows to inf for fully-masked (padded) rows,
        # and inf in a differentiable path NaNs the VJP (0 * inf)
        yb = num / jnp.maximum(den, jnp.exp(jnp.clip(-m_i, -60.0, 60.0)))[..., None]

        # ---- update inter-chunk state to end of this chunk
        m_new = jnp.maximum(
            Ftotb + m_prev,
            jnp.max(jnp.maximum(Ftotb[:, None, :] - Fb + lib, -1e30), axis=1),
        )
        w_carry = jnp.exp(jnp.clip(Ftotb + m_prev - m_new, -60.0, 0.0))
        w_inj = jnp.exp(jnp.clip(Ftotb[:, None, :] - Fb + lib - m_new[:, None, :], -60.0, 0.0))
        C_new = C_prev * w_carry[..., None, None] + jnp.einsum(
            "bqh,bqhd,bqhe->bhde", w_inj, kb, vb
        )
        n_new = n_prev * w_carry[..., None] + jnp.einsum("bqh,bqhd->bhd", w_inj, kb)
        return (C_new, n_new, m_new), yb

    C0 = jnp.zeros((b, h, dk, dk), jnp.float32)
    n0 = jnp.zeros((b, h, dk), jnp.float32)
    m0 = jnp.full((b, h), -1e30, jnp.float32)
    inputs = (
        qc.swapaxes(0, 1), kc.swapaxes(0, 1), vc.swapaxes(0, 1),
        lw.swapaxes(0, 1), F.swapaxes(0, 1), li.swapaxes(0, 1), Ftot.swapaxes(0, 1),
    )
    (_, _, _), ys = jax.lax.scan(scan_chunks, (C0, n0, m0), inputs)
    y = ys.swapaxes(0, 1).reshape(b, t, h, dk)
    return y


def mlstm_forward(params: dict, cfg: ModelConfig, x: Array) -> Array:
    b, s, d = x.shape
    din = 2 * d
    h = cfg.n_heads
    dk = din // h
    up = jnp.einsum("bsd,dk->bsk", x, params["w_up"])
    xb, gb = jnp.split(up, 2, axis=-1)  # main branch / output-gate branch
    xb = constrain(xb, "batch", None, "ssm_inner")

    q = jnp.einsum("bsk,kj->bsj", xb, params["w_q"]).reshape(b, s, h, dk)
    k = jnp.einsum("bsk,kj->bsj", xb, params["w_k"]).reshape(b, s, h, dk)
    v = jnp.einsum("bsk,kj->bsj", xb, params["w_v"]).reshape(b, s, h, dk)
    ig = jnp.einsum("bsk,kh->bsh", xb, params["w_i"])
    fg = jnp.einsum("bsk,kh->bsh", xb, params["w_f"]) + 3.0  # forget-bias init

    pad = (-s) % CHUNK
    if pad:
        q, k, v = (jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0))) for a in (q, k, v))
        ig = jnp.pad(ig, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
        fg = jnp.pad(fg, ((0, 0), (0, pad), (0, 0)))

    y = _mlstm_parallel(q, k, v, ig, fg)[:, :s]
    y = y.reshape(b, s, din).astype(x.dtype)
    y = rmsnorm(params["norm"], y, cfg.norm_eps)
    y = y * jax.nn.silu(jnp.einsum("bsk,kj->bsj", gb, params["w_o"]))
    return jnp.einsum("bsk,kd->bsd", y, params["w_down"])


def mlstm_decode(
    params: dict, cfg: ModelConfig, x: Array, cache: MlstmCache
) -> Tuple[Array, MlstmCache]:
    b = x.shape[0]
    d = cfg.d_model
    din, h = 2 * d, cfg.n_heads
    dk = din // h
    up = jnp.einsum("bsd,dk->bsk", x, params["w_up"])[:, 0]
    xb, gb = jnp.split(up, 2, axis=-1)
    q = jnp.einsum("bk,kj->bj", xb, params["w_q"]).reshape(b, h, dk).astype(jnp.float32) * dk**-0.5
    k = jnp.einsum("bk,kj->bj", xb, params["w_k"]).reshape(b, h, dk).astype(jnp.float32)
    v = jnp.einsum("bk,kj->bj", xb, params["w_v"]).reshape(b, h, dk).astype(jnp.float32)
    logi = jnp.einsum("bk,kh->bh", xb, params["w_i"]).astype(jnp.float32)
    logf = jax.nn.log_sigmoid(
        jnp.einsum("bk,kh->bh", xb, params["w_f"]).astype(jnp.float32) + 3.0
    )

    m_new = jnp.maximum(logf + cache.m, logi)
    wc = jnp.exp(jnp.clip(logf + cache.m - m_new, -60.0, 0.0))
    wi = jnp.exp(jnp.clip(logi - m_new, -60.0, 0.0))
    C = cache.C * wc[..., None, None] + wi[..., None, None] * jnp.einsum(
        "bhd,bhe->bhde", k, v
    )
    n = cache.n * wc[..., None] + wi[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, C)
    den = jnp.maximum(
        jnp.abs(jnp.einsum("bhd,bhd->bh", q, n)),
        jnp.exp(jnp.clip(-m_new, -60.0, 60.0)),
    )
    y = (num / den[..., None]).reshape(b, 1, din).astype(x.dtype)
    y = rmsnorm(params["norm"], y, cfg.norm_eps)
    y = y * jax.nn.silu(jnp.einsum("bsk,kj->bsj", gb[:, None, :], params["w_o"]))
    out = jnp.einsum("bsk,kd->bsd", y, params["w_down"])
    return out, MlstmCache(C=C, n=n, m=m_new, length=cache.length + 1)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    return {
        "w_x": dense_init(ks[0], d, 4 * d, dtype),  # i,f,z,o from input
        "w_h": dense_init(ks[1], d, 4 * d, dtype),  # recurrent
        "b": jnp.zeros((4 * d,), jnp.float32),
        "norm": init_norm(d, dtype),
        "w_up": dense_init(ks[2], d, 2 * d, dtype),  # post-FFN (pf 4/3 approx 2x gated)
        "w_down": dense_init(ks[3], d, d, dtype),
    }


class SlstmCache(NamedTuple):
    c: Array  # (B, D)
    n: Array  # (B, D)
    h: Array  # (B, D)
    m: Array  # (B, D)
    length: Array


def init_slstm_cache(cfg: ModelConfig, batch: int) -> SlstmCache:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return SlstmCache(c=z, n=z, h=z, m=jnp.full((batch, d), -1e30), length=jnp.zeros((batch,), jnp.int32))


def _slstm_cell(params, x_t, state):
    """One exponential-gated sLSTM step (stabilized)."""
    c, n, h, m = state
    gates = (
        jnp.einsum("bd,dk->bk", x_t, params["w_x"]).astype(jnp.float32)
        + jnp.einsum("bd,dk->bk", h.astype(x_t.dtype), params["w_h"]).astype(jnp.float32)
        + params["b"]
    )
    i_l, f_l, z_l, o_l = jnp.split(gates, 4, axis=-1)
    logf = jax.nn.log_sigmoid(f_l)
    m_new = jnp.maximum(logf + m, i_l)
    i_s = jnp.exp(jnp.clip(i_l - m_new, -60.0, 0.0))
    f_s = jnp.exp(jnp.clip(logf + m - m_new, -60.0, 0.0))
    c_new = f_s * c + i_s * jnp.tanh(z_l)
    n_new = f_s * n + i_s
    h_new = jax.nn.sigmoid(o_l) * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new)


def slstm_forward(params: dict, cfg: ModelConfig, x: Array) -> Array:
    b, s, d = x.shape

    def body(state, x_t):
        state = _slstm_cell(params, x_t, state)
        return state, state[2]  # emit h

    z = jnp.zeros((b, d), jnp.float32)
    init = (z, z, z, jnp.full((b, d), -1e30))
    _, hs = jax.lax.scan(body, init, x.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).astype(x.dtype)
    y = rmsnorm(params["norm"], y, cfg.norm_eps)
    up = jnp.einsum("bsd,dk->bsk", y, params["w_up"])
    g, u = jnp.split(up, 2, axis=-1)
    return jnp.einsum("bsd,dk->bsk", jax.nn.gelu(g, approximate=True) * u, params["w_down"])


def slstm_decode(
    params: dict, cfg: ModelConfig, x: Array, cache: SlstmCache
) -> Tuple[Array, SlstmCache]:
    state = (cache.c, cache.n, cache.h, cache.m)
    c, n, h, m = _slstm_cell(params, x[:, 0], state)
    y = h[:, None, :].astype(x.dtype)
    y = rmsnorm(params["norm"], y, cfg.norm_eps)
    up = jnp.einsum("bsd,dk->bsk", y, params["w_up"])
    g, u = jnp.split(up, 2, axis=-1)
    out = jnp.einsum("bsd,dk->bsk", jax.nn.gelu(g, approximate=True) * u, params["w_down"])
    return out, SlstmCache(c=c, n=n, h=h, m=m, length=cache.length + 1)

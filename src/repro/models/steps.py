"""Train / prefill / decode step builders — the functions the launcher jits.

``make_train_step`` closes over the model config and optimizer config and
returns a pure ``(state, batch) -> (state, metrics)`` suitable for pjit with
donated state.  Optional CS gradient compression (the paper's technique as a
distributed-optimization feature, DESIGN.md Sec. 5) is applied to the
cross-replica gradient mean when ``compress_axis`` names a mesh axis.
"""

from __future__ import annotations

from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim import adamw as opt_mod

from . import lm
from .config import ModelConfig
from .losses import chunked_cross_entropy

Array = jax.Array


class TrainState(NamedTuple):
    params: dict
    opt: opt_mod.AdamWState
    step: Array


def init_train_state(key, cfg: ModelConfig, opt_cfg: opt_mod.AdamWConfig) -> TrainState:
    params = lm.init_params(key, cfg)
    return TrainState(
        params=params, opt=opt_mod.init(params, opt_cfg), step=jnp.zeros((), jnp.int32)
    )


def loss_fn(params, cfg: ModelConfig, batch: Dict[str, Array]):
    tokens = batch["tokens"]  # (B, S+1)
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    hidden, aux = lm.forward(
        params,
        cfg,
        inputs,
        img_embeds=batch.get("img_embeds"),
        frames=batch.get("frames"),
    )
    if cfg.n_img_tokens:
        hidden = hidden[:, cfg.n_img_tokens :]  # loss only on the text stream
    nll, acc = chunked_cross_entropy(params, cfg, hidden, targets)
    total = nll + 1e-2 * aux
    return total, {"loss": nll, "acc": acc, "aux": aux}


def make_train_step(
    cfg: ModelConfig, opt_cfg: opt_mod.AdamWConfig, microbatches: int = 1
):
    """``microbatches > 1`` runs gradient accumulation: the global batch is
    split along dim 0 and scanned, dividing peak activation memory by the
    microbatch count at unchanged math (fp32 grad accumulators).  This is
    the memory lever that fits the 4k-train cells on 16 GiB chips
    (EXPERIMENTS.md §Perf)."""

    def grads_of(params, batch):
        return jax.value_and_grad(lambda p: loss_fn(p, cfg, batch), has_aux=True)(
            params
        )

    def train_step(state: TrainState, batch: Dict[str, Array]):
        if microbatches == 1:
            (_, metrics), grads = grads_of(state.params, batch)
        else:
            mb = jax.tree.map(
                lambda a: a.reshape((microbatches, a.shape[0] // microbatches) + a.shape[1:]),
                batch,
            )

            def body(acc, micro):
                (_, metrics), grads = grads_of(state.params, micro)
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / microbatches, acc, grads
                )
                return acc, metrics

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )
            grads, metrics_all = jax.lax.scan(body, zeros, mb)
            metrics = jax.tree.map(lambda m: jnp.mean(m), metrics_all)
        params, opt, opt_metrics = opt_mod.update(state.params, grads, state.opt, opt_cfg)
        metrics = dict(metrics, **opt_metrics, step=state.step + 1)
        return TrainState(params=params, opt=opt, step=state.step + 1), metrics

    return train_step


def make_eval_step(cfg: ModelConfig):
    def eval_step(params, batch):
        _, metrics = loss_fn(params, cfg, batch)
        return metrics

    return eval_step


def make_prefill_step(cfg: ModelConfig):
    """Forward over the full prompt; returns last-position logits (B, V)."""

    def prefill_step(params, batch):
        hidden, _ = lm.forward(
            params,
            cfg,
            batch["tokens"],
            img_embeds=batch.get("img_embeds"),
            frames=batch.get("frames"),
        )
        return lm.logits_for(params, cfg, hidden[:, -1:])[:, 0]

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    """(params, tokens (B,1), DecodeState) -> (logits (B,V), DecodeState)."""

    def decode_step(params, tokens, state: lm.DecodeState):
        return lm.decode_step(params, cfg, tokens, state)

    return decode_step


def greedy_generate(
    params, cfg: ModelConfig, prompt: Array, steps: int, max_len: int
) -> Array:
    """Host-driven greedy decoding used by examples and smoke tests."""
    b = prompt.shape[0]
    state = lm.init_decode_state(cfg, b, max_len)
    decode = jax.jit(make_decode_step(cfg))
    # feed the prompt token by token (tiny prompts in tests)
    for i in range(prompt.shape[1]):
        logits, state = decode(params, prompt[:, i : i + 1], state)
    out = [jnp.argmax(logits[:, : cfg.vocab], axis=-1)]
    for _ in range(steps - 1):
        logits, state = decode(params, out[-1][:, None], state)
        out.append(jnp.argmax(logits[:, : cfg.vocab], axis=-1))
    return jnp.stack(out, axis=1)

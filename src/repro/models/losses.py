"""Losses: sequence-chunked cross-entropy over a padded vocab.

The LM head is the memory cliff for the big-vocab archs (gemma/minitron:
256k vocab -> a materialized (B, S, V) bf16 logit tensor at train_4k would
be ~34 GiB per device).  We never materialize it: the head runs under a
lax.scan over sequence chunks, each chunk computing logits -> log-softmax ->
NLL and reducing to scalars, with jax.checkpoint so the backward pass
recomputes chunk logits instead of storing them.  Peak head memory drops to
(B, loss_chunk, V) — the single biggest memory lever in the §Perf log.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import unembed

Array = jax.Array


def _chunk_nll(params, cfg: ModelConfig, h_chunk: Array, t_chunk: Array) -> Tuple[Array, Array]:
    """-> (sum NLL over chunk, sum correct-token count). fp32 accumulation."""
    table = params["embed"]
    table = {k: v.astype(h_chunk.dtype) if v.dtype == jnp.float32 else v for k, v in table.items()}
    logits = unembed(table, h_chunk, cfg.tie_embeddings).astype(jnp.float32)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = jnp.tanh(logits / c) * c
    if cfg.vocab_padded != cfg.vocab:
        # padded vocab rows exist only for sharding; mask them out of softmax
        pad_mask = jnp.arange(cfg.vocab_padded) >= cfg.vocab
        logits = jnp.where(pad_mask, -1e30, logits)
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, t_chunk[..., None], axis=-1)[..., 0]
    nll = lse - tgt
    acc = (jnp.argmax(logits, axis=-1) == t_chunk).astype(jnp.float32)
    return jnp.sum(nll), jnp.sum(acc)


def chunked_cross_entropy(
    params: dict, cfg: ModelConfig, hidden: Array, targets: Array
) -> Tuple[Array, Array]:
    """hidden: (B, S, D), targets: (B, S) -> (mean NLL, mean accuracy)."""
    b, s, d = hidden.shape
    chunk = min(cfg.loss_chunk, s)
    n = s // chunk
    rem = s - n * chunk

    def body(carry, inp):
        nll_sum, acc_sum = carry
        h_c, t_c = inp
        nll, acc = _chunk_nll(params, cfg, h_c, t_c)
        return (nll_sum + nll, acc_sum + acc), None

    body = jax.checkpoint(body)
    hs = hidden[:, : n * chunk].reshape(b, n, chunk, d).swapaxes(0, 1)
    ts = targets[:, : n * chunk].reshape(b, n, chunk).swapaxes(0, 1)
    (nll_sum, acc_sum), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), (hs, ts)
    )
    if rem:
        nll, acc = _chunk_nll(params, cfg, hidden[:, n * chunk :], targets[:, n * chunk :])
        nll_sum, acc_sum = nll_sum + nll, acc_sum + acc
    count = b * s
    return nll_sum / count, acc_sum / count

"""Model assembly: block composition, segment-scanned stacks, caches,
decoder-only / encoder-decoder / VLM variants, and the train/prefill/decode
entry points that the launcher lowers.

Layer stacking
--------------
Consecutive layers of the same kind form a *segment* whose params are
stacked along a leading axis and executed with ``lax.scan`` (+ optional
``jax.checkpoint`` per layer).  One compiled block body per segment keeps
the HLO small enough to compile 61-layer/88-layer models with a 512-device
GSPMD partition in reasonable time — this is the difference between a
minutes-long and an hours-long dry-run.

Heterogeneous patterns map to segments naturally:
    deepseek-v3   [dense x3][moe x58]            -> 2 segments
    xlstm-350m    ([mlstm x7][slstm x1]) x3      -> 6 segments
    zamba2        [mamba2 x38] + shared attention block applied every k-th
                  layer inside the scan (lax.cond on the layer index)
"""

from __future__ import annotations

from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain, grad_reduce_boundary

from . import attention as attn_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from . import xlstm as xlstm_mod
from .config import ModelConfig
from .layers import (
    apply_norm,
    embed,
    init_embedding,
    init_mlp,
    init_norm,
    mlp,
    sinusoidal_positions,
    unembed,
)

Array = jax.Array


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# per-layer init / forward / decode
# ---------------------------------------------------------------------------


def _init_layer(key, kind: str, cfg: ModelConfig) -> dict:
    dt = _pdtype(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    if kind in ("dense", "moe"):
        p = {"ln1": init_norm(d, dt), "ln2": init_norm(d, dt)}
        if cfg.attn_type == "mla":
            p["attn"] = attn_mod.init_mla(ks[0], cfg, dt)
        else:
            p["attn"] = attn_mod.init_gqa(ks[0], cfg, dt)
        if kind == "dense":
            p["mlp"] = init_mlp(ks[1], d, cfg.d_ff, dt, cfg.mlp_variant)
        else:
            p["moe"] = moe_mod.init_moe(ks[1], cfg, dt)
        return p
    if kind == "mamba2":
        return {"ln": init_norm(d, dt), "mamba": ssm_mod.init_mamba2(ks[0], cfg, dt)}
    if kind == "mlstm":
        return {"ln": init_norm(d, dt), "mlstm": xlstm_mod.init_mlstm(ks[0], cfg, dt)}
    if kind == "slstm":
        return {"ln": init_norm(d, dt), "slstm": xlstm_mod.init_slstm(ks[0], cfg, dt)}
    raise ValueError(kind)


def _layer_forward(
    params: dict,
    kind: str,
    cfg: ModelConfig,
    x: Array,
    positions: Array,
    shared: Optional[dict] = None,
    layer_idx: Optional[Array] = None,
) -> Tuple[Array, Array]:
    """-> (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("dense", "moe"):
        x = grad_reduce_boundary(x)
        h = apply_norm(params["ln1"], x, cfg.norm_type, cfg.norm_eps)
        if cfg.attn_type == "mla":
            a = attn_mod.mla_forward(params["attn"], cfg, h, positions)
        else:
            a = attn_mod.gqa_forward(
                params["attn"], cfg, h, positions, rope=cfg.use_rope
            )
        x = x + a
        h = apply_norm(params["ln2"], x, cfg.norm_type, cfg.norm_eps)
        if kind == "dense":
            x = x + mlp(params["mlp"], h, cfg.act)
        else:
            y, aux = moe_mod.moe_ffn(params["moe"], cfg, h, cfg.act)
            x = x + y
        # sequence-parallel boundary: no-op unless rules map "seq" (SP mode)
        x = constrain(x, "batch", "seq", "embed")
        return x, aux
    if kind == "mamba2":
        h = apply_norm(params["ln"], x, cfg.norm_type, cfg.norm_eps)
        x = x + ssm_mod.mamba2_forward(params["mamba"], cfg, h)
        if shared is not None and cfg.attn_every and layer_idx is not None:
            def with_attn(x):
                h = apply_norm(shared["ln1"], x, cfg.norm_type, cfg.norm_eps)
                x = x + attn_mod.gqa_forward(shared["attn"], cfg, h, positions)
                h = apply_norm(shared["ln2"], x, cfg.norm_type, cfg.norm_eps)
                return x + mlp(shared["mlp"], h, cfg.act)

            x = jax.lax.cond(
                layer_idx % cfg.attn_every == 0, with_attn, lambda x: x, x
            )
        return x, aux
    if kind == "mlstm":
        h = apply_norm(params["ln"], x, cfg.norm_type, cfg.norm_eps)
        return x + xlstm_mod.mlstm_forward(params["mlstm"], cfg, h), aux
    if kind == "slstm":
        h = apply_norm(params["ln"], x, cfg.norm_type, cfg.norm_eps)
        return x + xlstm_mod.slstm_forward(params["slstm"], cfg, h), aux
    raise ValueError(kind)


def _init_layer_cache(kind: str, cfg: ModelConfig, batch: int, max_len: int, dtype):
    if kind in ("dense", "moe"):
        if cfg.attn_type == "mla":
            return attn_mod.init_mla_cache(cfg, batch, max_len, dtype)
        return attn_mod.init_kv_cache(cfg, batch, max_len, dtype)
    if kind == "mamba2":
        return ssm_mod.init_mamba2_cache(cfg, batch, dtype)
    if kind == "mlstm":
        return xlstm_mod.init_mlstm_cache(cfg, batch)
    if kind == "slstm":
        return xlstm_mod.init_slstm_cache(cfg, batch)
    raise ValueError(kind)


def _layer_decode(
    params: dict,
    kind: str,
    cfg: ModelConfig,
    x: Array,
    cache,
    shared: Optional[dict] = None,
    shared_cache=None,
    layer_idx: Optional[Array] = None,
):
    """-> (x, new_cache, new_shared_cache)."""
    if kind in ("dense", "moe"):
        h = apply_norm(params["ln1"], x, cfg.norm_type, cfg.norm_eps)
        if cfg.attn_type == "mla":
            decode_fn = (
                attn_mod.mla_decode_absorbed if cfg.mla_absorbed else attn_mod.mla_decode
            )
            a, cache = decode_fn(params["attn"], cfg, h, cache)
        else:
            a, cache = attn_mod.gqa_decode(
                params["attn"], cfg, h, cache, rope=cfg.use_rope
            )
        x = x + a
        h = apply_norm(params["ln2"], x, cfg.norm_type, cfg.norm_eps)
        if kind == "dense":
            x = x + mlp(params["mlp"], h, cfg.act)
        else:
            y, _ = moe_mod.moe_ffn(params["moe"], cfg, h, cfg.act)
            x = x + y
        return x, cache, shared_cache
    if kind == "mamba2":
        h = apply_norm(params["ln"], x, cfg.norm_type, cfg.norm_eps)
        y, cache = ssm_mod.mamba2_decode(params["mamba"], cfg, h, cache)
        x = x + y
        if shared is not None and cfg.attn_every and layer_idx is not None:
            def with_attn(arg):
                x, sc = arg
                h = apply_norm(shared["ln1"], x, cfg.norm_type, cfg.norm_eps)
                a, sc = attn_mod.gqa_decode(shared["attn"], cfg, h, sc)
                x = x + a
                h = apply_norm(shared["ln2"], x, cfg.norm_type, cfg.norm_eps)
                return x + mlp(shared["mlp"], h, cfg.act), sc

            def skip(arg):
                x, sc = arg
                # keep cache shape: append a masked (zero-weight) entry is
                # wrong; instead leave cache untouched
                return x, sc

            x, shared_cache = jax.lax.cond(
                layer_idx % cfg.attn_every == 0, with_attn, skip, (x, shared_cache)
            )
        return x, cache, shared_cache
    if kind == "mlstm":
        h = apply_norm(params["ln"], x, cfg.norm_type, cfg.norm_eps)
        y, cache = xlstm_mod.mlstm_decode(params["mlstm"], cfg, h, cache)
        return x + y, cache, shared_cache
    if kind == "slstm":
        h = apply_norm(params["ln"], x, cfg.norm_type, cfg.norm_eps)
        y, cache = xlstm_mod.slstm_decode(params["slstm"], cfg, h, cache)
        return x + y, cache, shared_cache
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# segments
# ---------------------------------------------------------------------------


class Segment(NamedTuple):
    kind: str
    n: int
    start: int  # absolute index of first layer


def segments_of(cfg: ModelConfig) -> List[Segment]:
    kinds = cfg.layer_kinds()
    segs: List[Segment] = []
    i = 0
    while i < len(kinds):
        j = i
        while j < len(kinds) and kinds[j] == kinds[i]:
            j += 1
        segs.append(Segment(kind=kinds[i], n=j - i, start=i))
        i = j
    return segs


def _stack_layers(key, kind: str, n: int, cfg: ModelConfig):
    keys = jax.random.split(key, n)
    layers = [_init_layer(k, kind, cfg) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)


# ---------------------------------------------------------------------------
# full decoder stack
# ---------------------------------------------------------------------------


def init_params(key, cfg: ModelConfig) -> dict:
    dt = _pdtype(cfg)
    keys = jax.random.split(key, 8 + len(segments_of(cfg)))
    p: Dict[str, Any] = {
        "embed": init_embedding(keys[0], cfg.vocab_padded, cfg.d_model, dt, cfg.tie_embeddings),
        "final_norm": init_norm(cfg.d_model, dt),
        "segments": [
            _stack_layers(keys[2 + i], seg.kind, seg.n, cfg)
            for i, seg in enumerate(segments_of(cfg))
        ],
    }
    nseg = len(segments_of(cfg))
    if cfg.block_type == "mamba2" and cfg.attn_every:
        shared = {
            "ln1": init_norm(cfg.d_model, dt),
            "ln2": init_norm(cfg.d_model, dt),
            "attn": attn_mod.init_gqa(keys[2 + nseg], cfg, dt),
            "mlp": init_mlp(keys[3 + nseg], cfg.d_model, cfg.d_ff, dt, cfg.mlp_variant),
        }
        p["shared_attn"] = shared
    if cfg.is_encdec:
        p["encoder"] = _init_encoder(keys[4 + nseg], cfg)
        p["cross"] = _stack_cross_layers(keys[5 + nseg], cfg)
    return p


def backbone_forward(
    params: dict, cfg: ModelConfig, x: Array, positions: Array,
    cross_kv: Optional[Array] = None,
) -> Tuple[Array, Array]:
    """Run all segments.  x: (B, S, D) embedded input.  -> (hidden, aux)."""
    aux_total = jnp.zeros((), jnp.float32)
    shared = params.get("shared_attn")
    cross_params = params.get("cross")

    for si, seg in enumerate(segments_of(cfg)):
        seg_params = params["segments"][si]
        idxs = jnp.arange(seg.start, seg.start + seg.n)

        def body(carry, inp):
            x = carry
            layer_params, layer_idx = inp
            x, aux = _layer_forward(
                layer_params, seg.kind, cfg, x, positions, shared, layer_idx
            )
            if cross_params is not None and seg.kind in ("dense", "moe"):
                # encoder-decoder: interleave cross-attention after self-attn
                x = _cross_forward_one(cross_params, cfg, x, layer_idx, cross_kv)
            return x, aux

        if cfg.remat:
            body = jax.checkpoint(body)
        x, auxs = jax.lax.scan(body, x, (seg_params, idxs))
        aux_total = aux_total + jnp.sum(auxs)
    x = apply_norm(params["final_norm"], x, cfg.norm_type, cfg.norm_eps)
    return x, aux_total


# ---------------------------------------------------------------------------
# encoder-decoder (whisper)
# ---------------------------------------------------------------------------


def _init_encoder(key, cfg: ModelConfig) -> dict:
    dt = _pdtype(cfg)
    keys = jax.random.split(key, 2)
    return {
        "layers": _stack_layers(keys[0], "dense", cfg.n_enc_layers, cfg),
        "final_norm": init_norm(cfg.d_model, dt),
    }


def _stack_cross_layers(key, cfg: ModelConfig):
    """One cross-attention (+norm) per decoder layer, stacked."""
    dt = _pdtype(cfg)
    keys = jax.random.split(key, cfg.n_layers)

    def one(k):
        return {
            "ln": init_norm(cfg.d_model, dt),
            "attn": attn_mod.init_gqa(k, cfg, dt),
        }

    layers = [one(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)


def _cross_forward_one(cross_params, cfg, x, layer_idx, cross_kv):
    layer = jax.tree.map(lambda a: a[layer_idx], cross_params)
    h = apply_norm(layer["ln"], x, cfg.norm_type, cfg.norm_eps)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    a = attn_mod.gqa_forward(
        layer["attn"], cfg, h, positions, causal=False, rope=False, kv=(cross_kv, None)
    )
    return x + a


def encoder_forward(params: dict, cfg: ModelConfig, frames: Array) -> Array:
    """frames: (B, S_enc, D) stubbed post-conv embeddings -> encoder memory."""
    params = cast_params(params, cfg)
    frames = frames.astype(_dtype(cfg))
    b, s, d = frames.shape
    pos_table = sinusoidal_positions(s, d).astype(frames.dtype)
    x = frames + pos_table[None]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    enc = params["encoder"]

    def body(x, layer_params):
        h = apply_norm(layer_params["ln1"], x, cfg.norm_type, cfg.norm_eps)
        a = attn_mod.gqa_forward(
            layer_params["attn"], cfg, h, positions, causal=False, rope=False
        )
        x = x + a
        h = apply_norm(layer_params["ln2"], x, cfg.norm_type, cfg.norm_eps)
        return x + mlp(layer_params["mlp"], h, cfg.act), None

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, enc["layers"])
    return apply_norm(enc["final_norm"], x, cfg.norm_type, cfg.norm_eps)


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------


def cast_params(params: dict, cfg: ModelConfig) -> dict:
    """Cast weights to the compute dtype once per step.  Precision-critical
    paths (norms, router logits, SSM gates, losses) re-promote to fp32
    internally, so this is safe; it is what makes every matmul bf16 on TPU."""
    dt = _dtype(cfg)
    return jax.tree.map(lambda a: a.astype(dt) if a.dtype == jnp.float32 else a, params)


def forward(
    params: dict,
    cfg: ModelConfig,
    tokens: Array,  # (B, S) int32
    img_embeds: Optional[Array] = None,  # (B, N_img, D) VLM stub
    frames: Optional[Array] = None,  # (B, S_enc, D) enc-dec stub
) -> Tuple[Array, Array]:
    """Token stream -> final hidden states (B, S_total, D), aux loss."""
    dt = _dtype(cfg)
    params = cast_params(params, cfg)
    x = embed(params["embed"], tokens, dt)
    x = x * jnp.asarray(cfg.d_model**0.5, dt)  # gemma/whisper-style scale
    if img_embeds is not None:
        x = jnp.concatenate([img_embeds.astype(dt), x], axis=1)
    b, s, _ = x.shape
    if not cfg.use_rope:  # absolute sinusoidal positions (whisper decoder)
        x = x + sinusoidal_positions(s, cfg.d_model).astype(dt)[None]
    x = constrain(x, "batch", "seq", "embed")
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    cross_kv = None
    if cfg.is_encdec:
        assert frames is not None
        cross_kv = encoder_forward(params, cfg, frames.astype(dt))
    h, aux = backbone_forward(params, cfg, x, positions, cross_kv)
    return h, aux


def logits_for(params: dict, cfg: ModelConfig, hidden: Array) -> Array:
    logits = unembed(params["embed"], hidden, cfg.tie_embeddings)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = jnp.tanh(logits / c) * c
    return logits


# ----- caches ---------------------------------------------------------------


class DecodeState(NamedTuple):
    """Per-layer caches grouped by segment (stacked along the layer axis)."""

    segments: Tuple[Any, ...]
    shared_attn: Any  # zamba shared-attn KV cache (or None)
    cross_kv: Any  # enc-dec encoder memory (or None)


def init_decode_state(
    cfg: ModelConfig, batch: int, max_len: int, cross_kv: Optional[Array] = None
) -> DecodeState:
    dt = _dtype(cfg)
    seg_caches = []
    for seg in segments_of(cfg):
        one = _init_layer_cache(seg.kind, cfg, batch, max_len, dt)
        seg_caches.append(jax.tree.map(lambda a: jnp.stack([a] * seg.n), one))
    shared = None
    if cfg.block_type == "mamba2" and cfg.attn_every:
        shared = attn_mod.init_kv_cache(cfg, batch, max_len, dt)
    return DecodeState(segments=tuple(seg_caches), shared_attn=shared, cross_kv=cross_kv)


def decode_step(
    params: dict, cfg: ModelConfig, tokens: Array, state: DecodeState
) -> Tuple[Array, DecodeState]:
    """One token in (B, 1) -> logits (B, vocab_padded), updated caches."""
    dt = _dtype(cfg)
    params = cast_params(params, cfg)
    x = embed(params["embed"], tokens, dt) * jnp.asarray(cfg.d_model**0.5, dt)
    x = constrain(x, "batch", None, "embed")
    shared = params.get("shared_attn")
    cross_params = params.get("cross")
    new_seg_caches = []
    shared_cache = state.shared_attn

    for si, seg in enumerate(segments_of(cfg)):
        seg_params = params["segments"][si]
        seg_cache = state.segments[si]
        idxs = jnp.arange(seg.start, seg.start + seg.n)

        if seg.kind == "mamba2" and shared is not None:
            # shared cache is carried across layers -> put it in the scan carry
            def body(carry, inp):
                x, sc = carry
                layer_params, layer_cache, layer_idx = inp
                x, new_cache, sc = _layer_decode(
                    layer_params, seg.kind, cfg, x, layer_cache, shared, sc, layer_idx
                )
                return (x, sc), new_cache

            (x, shared_cache), new_cache = jax.lax.scan(
                body, (x, shared_cache), (seg_params, seg_cache, idxs)
            )
        else:
            def body(x, inp):
                layer_params, layer_cache, layer_idx = inp
                x, new_cache, _ = _layer_decode(
                    layer_params, seg.kind, cfg, x, layer_cache, None, None, layer_idx
                )
                if cross_params is not None and seg.kind in ("dense", "moe"):
                    x = _cross_decode_one(cross_params, cfg, x, layer_idx, state.cross_kv)
                return x, new_cache

            x, new_cache = jax.lax.scan(body, x, (seg_params, seg_cache, idxs))
        new_seg_caches.append(new_cache)

    x = apply_norm(params["final_norm"], x, cfg.norm_type, cfg.norm_eps)
    logits = logits_for(params, cfg, x)[:, 0]
    return logits, DecodeState(
        segments=tuple(new_seg_caches), shared_attn=shared_cache, cross_kv=state.cross_kv
    )


def _cross_decode_one(cross_params, cfg, x, layer_idx, cross_kv):
    layer = jax.tree.map(lambda a: a[layer_idx], cross_params)
    h = apply_norm(layer["ln"], x, cfg.norm_type, cfg.norm_eps)
    b = x.shape[0]
    positions = jnp.zeros((b, 1), jnp.int32)
    a = attn_mod.gqa_forward(
        layer["attn"], cfg, h, positions, causal=False, rope=False, kv=(cross_kv, None)
    )
    return x + a

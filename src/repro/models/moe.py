"""Mixture-of-Experts FFN: top-k routing, shared experts, EP-shardable dispatch.

Dispatch is capacity-based (drop-on-overflow) via sort-free cumulative
positioning: tokens pick experts, each (token, choice) computes its slot in
the expert's buffer by a masked cumsum, and slots beyond capacity are
dropped (standard Switch/GShard semantics, capacity_factor configurable).
The (E, C, D) expert buffers carry an "experts" logical axis, so under the
production mesh GSPMD turns gather/scatter into the canonical EP
all-to-alls.

Router supports DeepSeek's aux-loss-free bias balancing (a slowly-updated
per-expert bias added to the routing logits *only for selection*, not for
the combine weights); the classic load-balancing auxiliary loss is also
computed and returned for monitoring.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import constrain

from .config import ModelConfig
from .layers import dense_init

Array = jax.Array


def init_moe(key, cfg: ModelConfig, dtype) -> dict:
    d, dff, e = cfg.d_model, cfg.d_ff_expert, cfg.n_experts
    ks = jax.random.split(key, 5)
    std = d**-0.5
    p = {
        "router": dense_init(ks[0], d, e, jnp.float32),  # router kept fp32
        "router_bias": jnp.zeros((e,), jnp.float32),
        "w_gate": (jax.random.truncated_normal(ks[1], -3, 3, (e, d, dff)) * std).astype(dtype),
        "w_up": (jax.random.truncated_normal(ks[2], -3, 3, (e, d, dff)) * std).astype(dtype),
        "w_down": (jax.random.truncated_normal(ks[3], -3, 3, (e, dff, d)) * (dff**-0.5)).astype(dtype),
    }
    if cfg.n_shared_experts:
        from .layers import init_mlp

        p["shared"] = init_mlp(ks[4], d, cfg.d_ff_expert * cfg.n_shared_experts, dtype)
    return p


def _routing(params, cfg: ModelConfig, x2d: Array) -> Tuple[Array, Array, Array]:
    """-> (top-k expert ids (T,k), combine weights (T,k), aux loss scalar)."""
    logits = jnp.einsum(
        "td,de->te", x2d.astype(jnp.float32), params["router"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)
    select = logits + params["router_bias"] if cfg.router_aux_free_bias else logits
    _, idx = jax.lax.top_k(select, cfg.top_k)  # (T, k)
    gates = jnp.take_along_axis(probs, idx, axis=-1)  # (T, k)
    gates = gates / (jnp.sum(gates, axis=-1, keepdims=True) + 1e-9)
    # Switch-style load-balance monitor: E * sum_e f_e * p_e
    e = cfg.n_experts
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx, e, dtype=jnp.float32), axis=1), axis=0
    ) / cfg.top_k
    aux = e * jnp.sum(me * ce)
    return idx, gates.astype(x2d.dtype), aux


def moe_ffn(params: dict, cfg: ModelConfig, x: Array, act: str = "silu") -> Tuple[Array, Array]:
    """x: (B, S, D) -> (same, aux_loss).  Capacity-dropped top-k dispatch."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    cap = max(1, int(cfg.capacity_factor * t * k / e))
    x2d = x.reshape(t, d)

    idx, gates, aux = _routing(params, cfg, x2d)  # (T,k)

    # --- slot assignment: position of each (token, choice) within its expert
    flat_e = idx.reshape(-1)  # (T*k,)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # (T*k, E)
    pos_in_expert = (jnp.cumsum(onehot, axis=0) - 1) * onehot  # (T*k, E)
    slot = jnp.sum(pos_in_expert, axis=-1)  # (T*k,)
    keep = slot < cap
    slot = jnp.where(keep, slot, cap)  # overflow -> scratch row

    # --- dispatch: (E, cap+1, D) buffers (+1 scratch row swallows drops)
    buf = jnp.zeros((e, cap + 1, d), x.dtype)
    tok_ids = jnp.repeat(jnp.arange(t), k)
    buf = buf.at[flat_e, slot].add(x2d[tok_ids])
    buf = constrain(buf, "experts", None, None)

    # --- expert FFN (batched einsum over the expert dim => EP-shardable)
    actfn = jax.nn.silu if act == "silu" else (lambda v: jax.nn.gelu(v, approximate=True))
    gate = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])
    up = jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    h = actfn(gate) * up
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    out_buf = constrain(out_buf, "experts", None, None)

    # --- combine: gather slots back and weight by gates
    gathered = out_buf[flat_e, slot]  # (T*k, D)
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    combined = jnp.zeros((t, d), x.dtype).at[tok_ids].add(
        gathered * gates.reshape(-1)[:, None]
    )

    out = combined.reshape(b, s, d)
    if cfg.n_shared_experts:
        from .layers import mlp

        out = out + mlp(params["shared"], x, act)  # (B,S,D): keeps constraints rank-3

    return out, aux


def update_router_bias(params: dict, cfg: ModelConfig, aux_counts: Array, lr: float = 1e-3) -> dict:
    """DeepSeek aux-free balancing: nudge biases toward uniform expert load.

    ``aux_counts``: (E,) fraction of tokens routed to each expert this step.
    Called from the train loop (outside grad) — the bias is a buffer, not a
    trained parameter.
    """
    target = 1.0 / cfg.n_experts
    new_bias = params["router_bias"] + lr * jnp.sign(target - aux_counts)
    return dict(params, router_bias=new_bias)

"""Unified model configuration covering all ten assigned architectures.

One superset dataclass: every assigned arch (dense / MoE+MLA / hybrid-SSM /
VLM / xLSTM / enc-dec audio) is a point in this space, selected via
``repro.configs.registry``.  Fields default to "off" so dense transformers
stay simple.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | vlm | ssm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # --- attention ---------------------------------------------------------
    attn_type: str = "gqa"  # gqa | mla
    rope_theta: float = 1e4
    use_rope: bool = True  # False => absolute sinusoidal positions (whisper)
    attn_chunk: int = 1024  # online-softmax KV chunk (flash-style)
    sliding_window: int = 0  # 0 = full attention

    # --- MLA (deepseek-v3) --------------------------------------------------
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    rope_head_dim: int = 0
    nope_head_dim: int = 0
    v_head_dim: int = 0
    mla_absorbed: bool = False  # decode via weight absorption (EXPERIMENTS §Perf)

    # --- MLP ----------------------------------------------------------------
    act: str = "silu"  # silu (swiglu) | gelu (geglu)
    mlp_variant: str = "glu"  # glu (3 mats) | plain (2 mats: granite/minitron/whisper)

    # --- MoE ----------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    first_k_dense: int = 0
    capacity_factor: float = 1.25
    router_aux_free_bias: bool = True  # deepseek aux-loss-free balancing

    # --- SSM (mamba2) / hybrid ----------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    ssm_groups: int = 8
    attn_every: int = 0  # hybrid: shared attention block every k-th layer

    # --- xLSTM ---------------------------------------------------------------
    slstm_every: int = 0  # every k-th block is sLSTM (rest mLSTM); 0 = none

    # --- encoder-decoder (whisper) -------------------------------------------
    is_encdec: bool = False
    n_enc_layers: int = 0
    enc_seq_len: int = 1500  # encoder positions (frames after conv stub)

    # --- VLM (pixtral) --------------------------------------------------------
    n_img_tokens: int = 0  # patch embeddings prepended to the text stream

    # --- block selection -------------------------------------------------------
    block_type: str = "transformer"  # transformer | mamba2 | xlstm

    # --- norms / embeddings ----------------------------------------------------
    norm_type: str = "rmsnorm"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    logit_softcap: float = 0.0  # gemma-style final softcap (0 = off)

    # --- numerics / compilation --------------------------------------------------
    dtype: str = "bfloat16"  # activation/compute dtype
    param_dtype: str = "float32"
    remat: bool = True
    scan_layers: bool = True
    loss_chunk: int = 512  # sequence chunking for the LM head (memory)

    # -------------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def vocab_padded(self) -> int:
        """Vocab padded so TP-16 sharding divides evenly (Megatron-style)."""
        return _round_up(self.vocab, 256)

    @property
    def d_ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_ssm_inner // self.ssm_head_dim

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kinds, resolving hybrid / first-k-dense patterns."""
        kinds = []
        for i in range(self.n_layers):
            if self.block_type == "mamba2":
                kinds.append("mamba2")
            elif self.block_type == "xlstm":
                if self.slstm_every and (i % self.slstm_every == self.slstm_every - 1):
                    kinds.append("slstm")
                else:
                    kinds.append("mlstm")
            elif self.is_moe and i >= self.first_k_dense:
                kinds.append("moe")
            else:
                kinds.append("dense")
        return tuple(kinds)

    def validate(self) -> "ModelConfig":
        assert self.n_heads % max(self.n_kv_heads, 1) == 0 or self.attn_type == "mla"
        if self.is_moe:
            assert self.top_k > 0 and self.d_ff_expert > 0
        if self.block_type == "mamba2":
            assert self.ssm_state > 0
            assert self.d_ssm_inner % self.ssm_head_dim == 0
        if self.attn_type == "mla":
            assert self.kv_lora_rank > 0 and self.nope_head_dim > 0
        return self


# Parameter counting (for roofline MODEL_FLOPS = 6 N D, DESIGN.md §Roofline) --


def count_params(cfg: ModelConfig) -> dict:
    """Analytical parameter counts: total and active-per-token (MoE)."""
    d, hd = cfg.d_model, cfg.resolved_head_dim
    v = cfg.vocab_padded
    embed = v * d * (1 if cfg.tie_embeddings else 2)

    def attn_params():
        if cfg.attn_type == "mla":
            q = (
                d * cfg.q_lora_rank
                + cfg.q_lora_rank * cfg.n_heads * (cfg.nope_head_dim + cfg.rope_head_dim)
                if cfg.q_lora_rank
                else d * cfg.n_heads * (cfg.nope_head_dim + cfg.rope_head_dim)
            )
            kv = d * (cfg.kv_lora_rank + cfg.rope_head_dim) + cfg.kv_lora_rank * cfg.n_heads * (
                cfg.nope_head_dim + cfg.v_head_dim
            )
            o = cfg.n_heads * cfg.v_head_dim * d
            return q + kv + o
        q = d * cfg.n_heads * hd
        kv = 2 * d * cfg.n_kv_heads * hd
        o = cfg.n_heads * hd * d
        return q + kv + o

    def dense_mlp():
        mats = 3 if cfg.mlp_variant == "glu" else 2
        return mats * d * cfg.d_ff

    def moe_mlp():
        per_expert = 3 * d * cfg.d_ff_expert
        shared = cfg.n_shared_experts * per_expert
        router = d * cfg.n_experts
        return cfg.n_experts * per_expert + shared + router

    def mamba2_block():
        din, ns, g = cfg.d_ssm_inner, cfg.ssm_state, cfg.ssm_groups
        nh = cfg.n_ssm_heads
        in_proj = d * (2 * din + 2 * g * ns + nh)
        conv = cfg.ssm_conv * (din + 2 * g * ns)
        out = din * d
        return in_proj + conv + out + 3 * nh  # + A, D, dt_bias

    def mlstm_block():
        din = 2 * d
        return d * (3 * din) + din * d + 3 * (d * din // 4)  # qkv-ish + gates + out

    def slstm_block():
        return 4 * d * d * 2 + int(2.7 * d * d)

    total = embed
    active = embed
    for kind in cfg.layer_kinds():
        if kind == "dense":
            p = attn_params() + dense_mlp()
            total += p
            active += p
        elif kind == "moe":
            pe = 3 * d * cfg.d_ff_expert
            shared = cfg.n_shared_experts * pe
            total += attn_params() + moe_mlp()
            active += attn_params() + shared + cfg.top_k * pe + d * cfg.n_experts
        elif kind == "mamba2":
            p = mamba2_block()
            if cfg.attn_every:
                pass  # shared attn counted once below
            total += p
            active += p
        elif kind == "mlstm":
            p = mlstm_block()
            total += p
            active += p
        elif kind == "slstm":
            p = slstm_block()
            total += p
            active += p
    if cfg.attn_every and cfg.block_type == "mamba2":
        p = attn_params() + dense_mlp()
        total += p  # one shared block
        active += p
    if cfg.is_encdec:
        enc = cfg.n_enc_layers * (attn_params() + dense_mlp())
        dec_cross = cfg.n_layers * attn_params()
        total += enc + dec_cross
        active += enc + dec_cross
    return {"total": int(total), "active": int(active)}

"""Plan autotuner (repro.ops.tune): ISSUE 6's tentpole contract.

  * Cache round-trip determinism — a warm cache hit returns the
    bit-identical config with *zero* scoring or measurement (counters).
  * Cost-model ranking sanity — rfft beats full-complex at n = 4096^2, the
    case PR 2 measured at 1.98x lower wire bytes.
  * Pins collapse the candidate space; the single validation site rejects
    bad inputs the same way at every entry point.

The 8-device tuned-vs-untuned solve equivalence lives in
tests/dist_progs/autotune_prog.py (slow lane).
"""

import dataclasses
import os

import jax
import jax.numpy as jnp
import pytest

from repro.core import RecoveryProblem, solve
from repro.core.circulant import PartialCirculant, gaussian_circulant
from repro.data.synthetic import paper_regime, sparse_signal
from repro.dist.compat import make_mesh
from repro.ops import PlanConfig, plan, tune

N1, N2 = 32, 16
N = N1 * N2


@pytest.fixture(autouse=True)
def _fresh_counters():
    tune.reset_counters()
    yield


@pytest.fixture
def cache(tmp_path):
    return tune.PlanCache(str(tmp_path / "plan_cache.json"))


def _problem(batch=()):
    x = sparse_signal(jax.random.PRNGKey(0), N, paper_regime(N)[1], batch=batch)
    C = gaussian_circulant(jax.random.PRNGKey(1), N, normalize=True)
    m = paper_regime(N)[0]
    omega = jnp.sort(jax.random.permutation(jax.random.PRNGKey(2), N)[:m])
    op = PartialCirculant(C, omega.astype(jnp.int32))
    return RecoveryProblem(op=op, y=op.matvec(x), x_true=x)


# ---------------------------------------------------------------------------
# cache: round-trip determinism, warm hits skip everything
# ---------------------------------------------------------------------------


def test_warm_cache_hit_skips_all_scoring_and_is_bit_identical(cache):
    op = _problem().op
    mesh = make_mesh((1,), ("model",))
    cfg1 = tune.tuned_config(op, mesh, batch=2, cache=cache)
    assert tune.COUNTERS["cache_misses"] == 1
    assert tune.COUNTERS["scored"] > 0
    tune.reset_counters()
    cfg2 = tune.tuned_config(op, mesh, batch=2, cache=cache)
    assert cfg2 == cfg1  # frozen dataclass equality = field-wise identity
    assert tune.COUNTERS == {
        "scored": 0, "measured": 0, "cache_hits": 1, "cache_misses": 0,
    }


def test_config_json_round_trip_is_lossless(cache):
    cfg = PlanConfig(rfft=True, overlap=4, tail="pallas", fused=False,
                     batch_axis=("pod", "data"), n1=64, n2=128)
    assert PlanConfig.from_dict(cfg.to_dict()) == cfg
    # and through the store itself
    cache.put("k", {"config": cfg.to_dict(), "mode": "model"})
    assert PlanConfig.from_dict(cache.get("k")["config"]) == cfg


def test_model_entry_does_not_satisfy_measure_request(cache):
    op = _problem().op
    mesh = make_mesh((1,), ("model",))
    tune.tuned_config(op, mesh, mode="model", batch=2, cache=cache)
    tune.reset_counters()
    tune.tuned_config(op, mesh, mode="measure", batch=2, cache=cache)
    assert tune.COUNTERS["cache_misses"] == 1
    assert tune.COUNTERS["measured"] > 0
    # ...but a measure entry satisfies both modes
    tune.reset_counters()
    tune.tuned_config(op, mesh, mode="model", batch=2, cache=cache)
    tune.tuned_config(op, mesh, mode="measure", batch=2, cache=cache)
    assert tune.COUNTERS["cache_hits"] == 2 and tune.COUNTERS["scored"] == 0


def test_pins_are_part_of_the_cache_key(cache):
    op = _problem().op
    mesh = make_mesh((1,), ("model",))
    k_free = tune.cache_key(op, mesh, 2, {})
    k_pin = tune.cache_key(op, mesh, 2, {"rfft": True})
    assert k_free != k_pin
    cfg = tune.tuned_config(op, mesh, batch=2, cache=cache,
                            pins={"rfft": False})
    assert cfg.rfft is False  # the pin survives into the winner


# ---------------------------------------------------------------------------
# cost-model ranking sanity
# ---------------------------------------------------------------------------


def test_rfft_beats_full_complex_at_4096_squared():
    """PR 2 measured the half-spectrum path at ~2x lower FFT flops and wire
    bytes per signal; the model must rank it first at the production size."""
    mesh = make_mesh((1,), ("model",))
    cands = [
        PlanConfig(rfft=False, n1=4096, n2=4096),
        PlanConfig(rfft=True, n1=4096, n2=4096),
    ]
    scored = tune.score_candidates(mesh, cands, batch=1, iters=2)
    assert scored[0][1].rfft is True
    assert scored[0][0] < scored[1][0]
    assert tune.COUNTERS["scored"] == 2


def test_overlap_sweep_shares_one_compile():
    mesh = make_mesh((1,), ("model",))
    cands = [
        PlanConfig(rfft=True, overlap=K, n1=N1, n2=N2) for K in (1, 2, 4, 8)
    ]
    scored = tune.score_candidates(mesh, cands, batch=1, iters=2)
    assert len(scored) == 4
    assert tune.COUNTERS["scored"] == 1  # one compile group, analytic K sweep
    # on a 1-device axis collectives vanish: ties break toward overlap=1
    assert scored[0][1].overlap == 1


# ---------------------------------------------------------------------------
# candidate space + pins
# ---------------------------------------------------------------------------


def test_candidate_configs_honor_pins():
    op = _problem().op
    mesh = make_mesh((1,), ("model",))
    free = tune.candidate_configs(op, mesh)
    assert {c.rfft for c in free} == {False, True}
    assert {c.overlap for c in free} == set(tune.OVERLAPS)
    pinned = tune.candidate_configs(op, mesh, pins={"rfft": True, "overlap": 2})
    assert all(c.rfft and c.overlap == 2 for c in pinned)
    n1_pinned = tune.candidate_configs(op, mesh, pins={"n1": 16})
    assert all(c.n1 == 16 and c.n2 == N // 16 for c in n1_pinned)


def test_candidate_configs_reject_unknown_axis():
    op = _problem().op
    mesh = make_mesh((1,), ("model",))
    with pytest.raises(ValueError, match="axis_name"):
        tune.candidate_configs(op, mesh, pins={"axis_name": "pod"})


def test_extra_factorizations_filtered_by_divisibility():
    op = _problem().op
    mesh = make_mesh((1,), ("model",))
    cands = tune.candidate_configs(
        op, mesh, pins={"rfft": True, "overlap": 1},
        extra_factorizations=[(N1, N2), (7, 11)],  # (7,11) != N: dropped
    )
    facs = {(c.n1, c.n2) for c in cands}
    assert (N1, N2) in facs and (7, 11) not in facs


# ---------------------------------------------------------------------------
# entry-point plumbing
# ---------------------------------------------------------------------------


def test_plan_tune_rejects_full_config():
    op = _problem().op
    mesh = make_mesh((1,), ("model",))
    with pytest.raises(ValueError, match="mutually exclusive"):
        plan(op, mesh, config=PlanConfig(), tune=True)


def test_tuned_config_rejects_unknown_mode():
    with pytest.raises(ValueError, match="model.*measure"):
        tune.tuned_config(None, None, mode="guess")


def test_local_tune_is_the_pins(cache):
    cfg = tune.tuned_config(_problem().op, None, pins={"tail": "pallas"})
    assert cfg == PlanConfig(tail="pallas")
    assert tune.COUNTERS["scored"] == 0  # nothing distributed to score


def test_measure_mode_plan_solves_correctly(cache):
    """End-to-end: a measure-tuned plan drives the same solve the default
    plan does (1-device fast-lane version of autotune_prog.py)."""
    prob = _problem(batch=(2,))
    mesh = make_mesh((1,), ("model",))
    pl = plan(prob.op, mesh, tune="measure", batch=2,
              tune_opts={"cache": cache})
    assert tune.COUNTERS["measured"] > 0
    x_ref, _ = solve(prob, "cpadmm", iters=150, record_every=150,
                     alpha=1e-4, rho=0.01, sigma=0.01)
    x_tuned, _ = solve(prob, "cpadmm", iters=150, record_every=150,
                       alpha=1e-4, rho=0.01, sigma=0.01, plan=pl)
    rel = float(jnp.linalg.norm(x_tuned - x_ref)
                / (jnp.linalg.norm(x_ref) + 1e-30))
    # re-knobbing is exact; a demoted wire (the timer may pick bf16) is
    # bounded by the plan layer's precision guard instead
    from repro.ops.plan import WIRE_ERROR_BOUND

    tol = 1e-5 if pl.wire_dtype == "fp32" else WIRE_ERROR_BOUND
    assert rel <= tol, (rel, pl.config.describe())
    # the cached winner rebuilds the identical plan config
    pl2 = plan(prob.op, mesh, tune="measure", batch=2,
               tune_opts={"cache": cache})
    assert pl2.config == pl.config


def test_cache_cli_show_and_clear(cache, capsys):
    op = _problem().op
    mesh = make_mesh((1,), ("model",))
    tune.tuned_config(op, mesh, batch=1, cache=cache)
    tune.main(["--cache", cache.path, "--show"])
    out = capsys.readouterr().out
    assert "1 cached plan" in out and "[model]" in out
    tune.main(["--cache", cache.path, "--clear"])
    assert cache.entries() == {}


def test_group_key_ignores_overlap_only():
    a = PlanConfig(rfft=True, overlap=1, n1=8, n2=8)
    b = dataclasses.replace(a, overlap=8)
    c = dataclasses.replace(a, rfft=False)
    assert tune._group_key(a) == tune._group_key(b)
    assert tune._group_key(a) != tune._group_key(c)


# ---------------------------------------------------------------------------
# cache durability: concurrent writers merge, corrupt stores quarantine
# ---------------------------------------------------------------------------


def _entry(tag):
    return {"config": PlanConfig(n1=8, n2=8).to_dict(), "mode": "model",
            "modeled_total_s": 1.0, "tag": tag}


def test_concurrent_puts_merge_instead_of_dropping(tmp_path):
    """Two tuners racing on different keys must both land: writer A's
    read-modify-write window is interleaved (via the _race_hook test seam)
    with writer B's complete put — the pre-replace re-read folds B's entry
    into A's payload instead of silently clobbering it."""
    path = str(tmp_path / "plan_cache.json")
    a, b = tune.PlanCache(path), tune.PlanCache(path)
    a._race_hook = lambda: tune.PlanCache.put(b, "key_b", _entry("b"))
    a.put("key_a", _entry("a"))
    entries = tune.PlanCache(path).entries()
    assert set(entries) == {"key_a", "key_b"}
    assert entries["key_a"]["tag"] == "a" and entries["key_b"]["tag"] == "b"


def test_concurrent_same_key_put_is_last_writer_wins(tmp_path):
    path = str(tmp_path / "plan_cache.json")
    a, b = tune.PlanCache(path), tune.PlanCache(path)
    a._race_hook = lambda: tune.PlanCache.put(b, "key", _entry("b"))
    a.put("key", _entry("a"))  # a's replace lands after b's
    assert tune.PlanCache(path).entries()["key"]["tag"] == "a"


def test_corrupt_cache_quarantined_with_one_time_warning(tmp_path):
    """An unparseable store must not be silently treated as empty (which
    re-tuned forever): it is moved aside to .corrupt with one warning, and
    the tuner proceeds on a fresh store."""
    path = str(tmp_path / "plan_cache.json")
    with open(path, "w") as f:
        f.write("{ not json !!")
    cache = tune.PlanCache(path)
    with pytest.warns(RuntimeWarning, match="quarantined"):
        assert cache.entries() == {}
    assert os.path.exists(path + ".corrupt")
    assert not os.path.exists(path)
    # warned once per path per process: a second unreadable store at the
    # same path quarantines again but stays quiet
    with open(path, "w") as f:
        f.write("[1, 2, 3]")  # parseable but not a dict: also corrupt
    import warnings as _w

    with _w.catch_warnings():
        _w.simplefilter("error")
        assert cache.get("anything") is None
    # the store works again after quarantine
    cache.put("k", _entry("fresh"))
    assert cache.get("k")["tag"] == "fresh"


def test_missing_cache_file_is_silently_empty(tmp_path):
    import warnings as _w

    cache = tune.PlanCache(str(tmp_path / "nope.json"))
    with _w.catch_warnings():
        _w.simplefilter("error")
        assert cache.entries() == {}


def test_candidate_configs_sweep_wire_dtypes():
    """The free candidate space sweeps fp32 + bf16 wires (fp16 is opt-in
    via a pin — range-fragile), and a wire_dtype pin collapses the sweep."""
    op = _problem().op
    mesh = make_mesh((1,), ("model",))
    free = tune.candidate_configs(op, mesh)
    assert {c.wire_dtype for c in free} == {"fp32", "bf16"}
    pinned = tune.candidate_configs(op, mesh, pins={"wire_dtype": "fp32"})
    assert {c.wire_dtype for c in pinned} == {"fp32"}
    fp16 = tune.candidate_configs(op, mesh, pins={"wire_dtype": "fp16"})
    assert {c.wire_dtype for c in fp16} == {"fp16"}


def test_group_key_splits_on_wire_dtype():
    """Wire dtype changes the collective payload program, so candidates
    with different wires must never share a lowering/compile group."""
    a = PlanConfig(rfft=True, overlap=1, n1=8, n2=8)
    w = dataclasses.replace(a, wire_dtype="bf16")
    assert tune._group_key(a) != tune._group_key(w)
    assert tune._group_key(w) == tune._group_key(
        dataclasses.replace(w, overlap=4))


def test_one_device_tie_breaks_to_fp32_wire():
    """On a 1-device axis collectives vanish, so every wire models the same
    cost — the tie must break toward the exact fp32 default rather than
    buying bf16 rounding for nothing.  (The real bf16-under-fp32 byte
    ranking needs a multi-device mesh: tests/dist_progs/autotune_prog.py
    and wire_prog.py assert it on compiled 8-device HLO.)"""
    mesh = make_mesh((1,), ("model",))
    cands = [
        PlanConfig(rfft=True, n1=N1, n2=N2, wire_dtype=w)
        for w in ("bf16", "fp32")
    ]
    scored = tune.score_candidates(mesh, cands, batch=1, iters=2)
    assert scored[0][1].wire_dtype == "fp32"
    assert tune.COUNTERS["scored"] == 2  # wire splits the compile group


# ---------------------------------------------------------------------------
# hierarchical candidates + the two-tier cost model
# ---------------------------------------------------------------------------


def test_factored_mesh_auto_enumerates_flat_and_hier():
    """A (host, device) mesh with no pins races the flat layout against the
    hierarchical exchange, with bf16 inter wires only on hier candidates
    (flat has no inter-host hop to demote)."""
    from repro.dist.compat import make_hier_mesh

    op = _problem().op
    mesh = make_hier_mesh(1, 1, 1)
    cands = tune.candidate_configs(op, mesh)
    assert {c.hier_axes for c in cands} == {None, (1, 1)}
    assert all(c.axis_name == ("host", "device") for c in cands)
    assert {c.inter_wire_dtype for c in cands if c.hier_axes is None} \
        == {"fp32"}
    assert {c.inter_wire_dtype for c in cands if c.hier_axes is not None} \
        == {"fp32", "bf16"}
    # a hier pin collapses the sweep; a flat mesh never grows hier candidates
    pinned = tune.candidate_configs(op, mesh, pins={"hier_axes": (1, 1)})
    assert {c.hier_axes for c in pinned} == {(1, 1)}
    flat = tune.candidate_configs(op, make_mesh((1,), ("model",)))
    assert {c.hier_axes for c in flat} == {None}


def test_inter_wire_pin_drops_flat_candidates():
    from repro.dist.compat import make_hier_mesh

    op = _problem().op
    cands = tune.candidate_configs(
        op, make_hier_mesh(1, 1, 1), pins={"inter_wire_dtype": "bf16"}
    )
    assert cands and all(c.hier_axes == (1, 1) for c in cands)
    with pytest.raises(ValueError, match="hierarchical candidate space"):
        tune.candidate_configs(
            op, make_mesh((1,), ("model",)), pins={"inter_wire_dtype": "bf16"}
        )


def test_group_key_splits_on_hier_and_inter_wire():
    """hier compiles different collectives entirely (a2a + permutes vs one
    monolithic a2a) and the inter wire changes the permute payload — neither
    may share a compile with its flat/fp32 twin."""
    a = PlanConfig(rfft=True, overlap=1, n1=8, n2=8,
                   axis_name=("host", "device"))
    h = dataclasses.replace(a, hier_axes=(2, 4))
    hw = dataclasses.replace(h, inter_wire_dtype="bf16")
    assert len({tune._group_key(c) for c in (a, h, hw)}) == 3
    assert tune._group_key(h) == tune._group_key(
        dataclasses.replace(h, overlap=4))


def test_dcn_bytes_policy():
    """Hier plans charge exactly their collective-permute bytes to DCN; a
    flat exchange spanning hosts charges all its all-to-all bytes; single-
    axis plans charge nothing (the bit-for-bit fallback)."""
    from repro.dist.compat import make_hier_mesh

    class _Cost:
        collective_bytes = {"all-to-all": 1000.0, "collective-permute": 250.0}

    mesh_h = make_hier_mesh(1, 1, 1)
    hier = PlanConfig(hier_axes=(1, 1), axis_name=("host", "device"))
    tflat = PlanConfig(axis_name=("host", "device"))
    single = PlanConfig()
    assert tune._dcn_bytes(_Cost(), hier, mesh_h) == 250.0
    # H=1: the "flat" exchange never leaves the host -> ICI only
    assert tune._dcn_bytes(_Cost(), tflat, mesh_h) == 0.0
    assert tune._dcn_bytes(_Cost(), single, make_mesh((1,), ("model",))) == 0.0


def test_two_tier_model_ranks_hier_above_flat():
    """Under the two-tier model a hier block (full payload on ICI + 1/H on
    DCN) must outscore the flat block (full payload on DCN) whenever
    DCN_BW < ICI_BW / H — asserted on synthetic costs through the real
    scoring math, pinning the win condition the dryrun table reports."""
    from repro.launch.roofline import DCN_BW, ICI_BW, model_block_times

    class _Cost:
        flops = 1e9
        bytes = 1e6
        collective_bytes: dict = {}

    B, H = 8e8, 2
    flat_cost, hier_cost = _Cost(), _Cost()
    flat_cost.collective_bytes = {"all-to-all": B}
    hier_cost.collective_bytes = {"all-to-all": B,
                                  "collective-permute": B / H}
    assert DCN_BW < ICI_BW / H  # the regime the constants encode
    t_flat = model_block_times(flat_cost, dcn_bytes=B)
    t_hier = model_block_times(hier_cost, dcn_bytes=B / H)
    assert t_hier["collective_s"] < t_flat["collective_s"]
    assert t_hier["dcn_collective_s"] == pytest.approx(
        t_flat["dcn_collective_s"] / H)
    # and with no DCN bytes the split reproduces the single-tier term
    t0 = model_block_times(flat_cost)
    assert t0["collective_s"] == B / ICI_BW == t0["ici_collective_s"]
    assert t0["dcn_collective_s"] == 0.0

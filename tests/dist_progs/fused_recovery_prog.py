import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
import numpy as np
from repro.core.circulant import gaussian_circulant
from repro.data.synthetic import paper_regime, sparse_signal
from repro.dist.compat import make_mesh
from repro.dist.fft import layout_2d, unlayout_2d
from repro.dist.recovery import make_dist_cpadmm, make_dist_spectrum

mesh = make_mesh((8,), ("model",))
n1, n2 = 32, 32
n = n1*n2
m, k = paper_regime(n)
x_true = sparse_signal(jax.random.PRNGKey(0), n, k)
C = gaussian_circulant(jax.random.PRNGKey(1), n, normalize=True)
omega = jnp.sort(jax.random.permutation(jax.random.PRNGKey(2), n)[:m])
mask = jnp.zeros((n,)).at[omega].set(1.0)
y_full = mask * C.matvec(x_true)
spec2d = make_dist_spectrum(mesh)(layout_2d(C.col, n1, n2))
a = (spec2d, layout_2d(mask, n1, n2), layout_2d(y_full, n1, n2),
     jnp.float32(1e-4), jnp.float32(0.01), jnp.float32(0.01))
zb = unlayout_2d(make_dist_cpadmm(mesh, n1, n2, 400)(*a))
zf = unlayout_2d(make_dist_cpadmm(mesh, n1, n2, 400, fused=True)(*a))
np.testing.assert_allclose(np.asarray(zf), np.asarray(zb), atol=3e-5)
print("fused == baseline, mse:", float(jnp.mean((zf-x_true)**2)))
print("ALL OK")

"""Subprocess prog: distributed four-step FFT correctness on 8 fake devices."""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.circulant import gaussian_circulant
from repro.dist.compat import make_mesh
from repro.dist.fft import (
    freq_flat,
    layout_2d,
    make_distributed_fft,
    make_distributed_matvec,
    unlayout_2d,
)

mesh = make_mesh((8,), ("model",))
n1, n2 = 64, 32
n = n1 * n2

key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (n,))
a2d = layout_2d(x, n1, n2)

fft2d, ifft2d = make_distributed_fft(mesh, n1, n2)
F = fft2d(a2d.astype(jnp.complex64))

# forward: F.reshape(-1) must equal fft(x)
want = jnp.fft.fft(x.astype(jnp.complex64))
np.testing.assert_allclose(np.asarray(freq_flat(F)), np.asarray(want), rtol=2e-3, atol=2e-2)
print("fft fwd OK")

# roundtrip
back = ifft2d(F)
np.testing.assert_allclose(np.asarray(jnp.real(back)), np.asarray(a2d), atol=1e-4)
print("fft roundtrip OK")

# distributed circulant matvec == single-device oracle
C = gaussian_circulant(jax.random.PRNGKey(1), n, normalize=True)
spec2d = fft2d(layout_2d(C.col, n1, n2).astype(jnp.complex64))
mv = make_distributed_matvec(mesh)
got = unlayout_2d(mv(spec2d, a2d))
want_mv = C.matvec(x)
np.testing.assert_allclose(np.asarray(got), np.asarray(want_mv), atol=5e-4)
print("matvec OK")

got_t = unlayout_2d(mv(spec2d, a2d, True))
want_t = C.rmatvec(x)
np.testing.assert_allclose(np.asarray(got_t), np.asarray(want_t), atol=5e-4)
print("matvec_T OK")

# communication structure: exactly 2 all-to-alls per distributed matvec
hlo = mv.lower(spec2d, a2d).compile().as_text()
n_a2a = hlo.count("all-to-all")
assert n_a2a >= 2, f"expected all-to-all collectives, found {n_a2a}"
print(f"collective structure OK ({n_a2a} all-to-all ops)")
print("ALL OK")

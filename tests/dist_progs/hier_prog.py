"""Subprocess prog: hierarchical two-stage transpose on a real 8-device mesh.

ISSUE 9 acceptance, measured on the compiled HLO rather than modeled, on a
``(data=2, host=2, device=2)`` mesh:

  * the hierarchical exchange is *bit-exact* with the flat all-to-all at
    fp32 wires — against both the flat layout on the same factored mesh and
    a plain single-axis mesh — for matvec, rmatvec, every overlap K, and an
    end-to-end CPADMM solve;
  * stage structure in the HLO: each transpose lowers to exactly one
    intra-host all-to-all plus one inter-host collective-permute pair
    (H=2 -> a single rotation hop), i.e. 2 all-to-alls and 2 permutes per
    matvec (fwd + inv transform);
  * the inter-host hop carries exactly ``1/H`` of the flat collective's
    bytes: the sub-block staying on the host is sliced out locally and
    never wired;
  * demoting only the inter-host hop (``inter_wire_dtype='bf16'``) keeps
    the solve within the plan layer's wire bound, and is no worse than
    demoting *both* tiers to bf16 — the intra-host all-to-all still runs
    fp32;
  * the autotuner, given the factored mesh and no hier pin, selects the
    hierarchical exchange on the strength of the two-tier cost model alone.

(The ISSUE's "1e-5 with demoted inter wire" is physically unattainable:
bf16 has 8 mantissa bits, ~2e-3 relative quantization per crossing.  The
pin here is the honest version: fp32 hier is *bit-exact*, and the bf16
inter wire stays within WIRE_ERROR_BOUND of the fp32-wire solve.)
"""

import os
import tempfile

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["REPRO_PLAN_CACHE"] = os.path.join(
    tempfile.mkdtemp(prefix="hier_prog_cache"), "plan_cache.json"
)

import re

import jax
import jax.numpy as jnp

from repro.core import RecoveryProblem, solve
from repro.core.circulant import PartialCirculant, gaussian_circulant
from repro.data.synthetic import paper_regime, sparse_signal
from repro.dist.compat import make_hier_mesh, make_mesh
from repro.ops import plan
from repro.ops.plan import WIRE_ERROR_BOUND
from repro.ops.tune import tuned_config

H, D = 2, 2
mesh = make_hier_mesh(2, H, D)  # data=2 x host=2 x device=2
flat_mesh = make_mesh((2, 4), ("data", "model"))
n1, n2 = 32, 32
n = n1 * n2
m, k = paper_regime(n)
ALPHA, RHO, SIGMA = 1e-4, 0.01, 0.01

x_true = sparse_signal(jax.random.PRNGKey(0), n, k)
C = gaussian_circulant(jax.random.PRNGKey(1), n, normalize=True)
omega = jnp.sort(jax.random.permutation(jax.random.PRNGKey(2), n)[:m]).astype(jnp.int32)
op = PartialCirculant(C, omega)
prob = RecoveryProblem(op=op, y=op.matvec(x_true), x_true=x_true)


def _collective_lines(p, kind):
    """One ``(dtypes, total result bytes)`` entry per ``kind`` collective op
    in the compiled matvec HLO — the wire_prog buffer walk, aggregated per
    op because XLA may emit the tuple form (one result shape per split) for
    multi-axis collectives."""
    hlo = (
        jax.jit(p.operator.matvec)
        .lower(jnp.zeros((n,), jnp.float32))
        .compile()
        .as_text()
    )
    out = []
    for line in hlo.splitlines():
        if re.search(rf"(?<!%)\b{kind}(?:-start)?\(", line):
            lhs = line.split(f" {kind}", 1)[0]
            bufs = []
            for dtype, bits, dims in re.findall(
                r"\b([a-z])(\d+)\[([\d,]*)\]", lhs
            ):
                elems = 1
                for d in dims.split(","):
                    elems *= int(d) if d else 1
                bufs.append((f"{dtype}{bits}", elems * int(bits) // 8))
            if bufs:
                out.append((frozenset(d for d, _ in bufs),
                            sum(b for _, b in bufs)))
    return out


x = jax.random.normal(jax.random.PRNGKey(3), (n,), jnp.float32)
yfull = jnp.zeros((n,)).at[omega].set(op.matvec(x_true))

pl_single = plan(op, flat_mesh, n1=n1, n2=n2, rfft=True)
pl_flat = plan(op, mesh, n1=n1, n2=n2, rfft=True, axis_name=("host", "device"))
pl_hier = plan(op, mesh, n1=n1, n2=n2, rfft=True, hier_axes=(H, D))

ref = pl_single.matvec(x)
assert jnp.array_equal(pl_flat.matvec(x), ref), "flat-on-factored-mesh drifted"
assert jnp.array_equal(pl_hier.matvec(x), ref), "hier matvec not bit-exact"
assert jnp.array_equal(pl_hier.rmatvec(yfull), pl_single.rmatvec(yfull))
for K in (2, 4):
    pK = plan(op, mesh, n1=n1, n2=n2, rfft=True, hier_axes=(H, D), overlap=K)
    assert jnp.array_equal(pK.matvec(x), ref), f"hier overlap={K} drifted"
print("fp32 hier: bit-exact vs flat (both meshes), all overlap K")

# -- HLO stage structure + the 1/H inter-host byte pin ----------------------
a2a_flat = _collective_lines(pl_flat, "all-to-all")
a2a_hier = _collective_lines(pl_hier, "all-to-all")
cp_hier = _collective_lines(pl_hier, "collective-permute")
assert not _collective_lines(pl_flat, "collective-permute")
# one matvec = fwd + inv transform: 2 intra-host all-to-alls and, at H=2,
# one rotation permute each -> 2 collective-permutes
assert len(a2a_flat) == 2, a2a_flat
assert len(a2a_hier) == 2, a2a_hier
assert len(cp_hier) == 2, cp_hier
flat_bytes = sum(b for _, b in a2a_flat)
intra_bytes = sum(b for _, b in a2a_hier)
inter_bytes = sum(b for _, b in cp_hier)
print(f"per-matvec wire bytes: flat a2a {flat_bytes}, hier intra {intra_bytes} "
      f"+ inter {inter_bytes}")
# the intra stage reshuffles the full payload on the fast tier...
assert intra_bytes == flat_bytes, (intra_bytes, flat_bytes)
# ...and the inter-host hop carries exactly 1/H of the flat bytes
assert inter_bytes * H == flat_bytes, (inter_bytes, H, flat_bytes)

# -- per-tier wire precision -------------------------------------------------
kw = dict(iters=300, record_every=300, alpha=ALPHA, rho=RHO, sigma=SIGMA)
x32, _ = solve(prob, "cpadmm", plan=pl_hier, **kw)
assert jnp.array_equal(
    x32, solve(prob, "cpadmm", plan=pl_flat, **kw)[0]
), "hier cpadmm not bit-exact"

pl_inter16 = plan(op, mesh, n1=n1, n2=n2, rfft=True, hier_axes=(H, D),
                  inter_wire_dtype="bf16")
assert pl_inter16.inter_wire_dtype == "bf16", "guard must accept bf16 inter"
# the demoted hop really is 16-bit on the wire; the intra tier stays f32
assert {d for ds, _ in _collective_lines(pl_inter16, "collective-permute")
        for d in ds} == {"u16"}
assert all(
    d in ("c64", "f32")
    for ds, _ in _collective_lines(pl_inter16, "all-to-all") for d in ds
)
x16, _ = solve(prob, "cpadmm", plan=pl_inter16, **kw)
rel16 = float(jnp.linalg.norm(x16 - x32) / (jnp.linalg.norm(x32) + 1e-30))
print(f"bf16-inter vs fp32 cpadmm: rel {rel16:.2e} (bound {WIRE_ERROR_BOUND:.1e})")
assert rel16 <= WIRE_ERROR_BOUND, rel16

# demoting only 1/H of the bytes must not be worse than demoting all of them
pl_both16 = plan(op, mesh, n1=n1, n2=n2, rfft=True, hier_axes=(H, D),
                 wire_dtype="bf16", inter_wire_dtype="bf16")
xb, _ = solve(prob, "cpadmm", plan=pl_both16, **kw)
relb = float(jnp.linalg.norm(xb - x32) / (jnp.linalg.norm(x32) + 1e-30))
print(f"bf16-both vs fp32 cpadmm: rel {relb:.2e}")
assert rel16 <= relb * 1.5 + 1e-12, (rel16, relb)

# -- the tuner picks hier unaided on the factored mesh -----------------------
cfg = tuned_config(op, mesh, batch=2, pins={"n1": n1, "n2": n2, "rfft": True,
                                            "fused": True})
print(f"tuned: {cfg.describe()}")
assert cfg.hier_axes == (H, D), cfg
print("ALL OK")

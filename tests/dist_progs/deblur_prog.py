"""Subprocess prog: compressed-domain deblurring through the plan on 8 devices.

ISSUE 5 acceptance: the paper's flagship Sec. 7 scenario — the joint
sensing+blur operator A = P (C B) — runs distributed on a real (2, 4)
data x model mesh via ``build_deblur_plan``: a 4-frame stack shards over
the data axis, each frame's four-step transforms over the model axis, and
the composed spectrum spec(C)·spec(B) is laid out and sharded once (no
time-domain round trip).  Pins: the planned solve matches the single-device
one at 1e-5 rel per frame, every frame clears the 45 dB multiframe golden
PSNR pin, a planned matvec is exactly 2 all-to-alls, and the direct
spectrum layout agrees with the four-step transform of the first column on
all 8 devices.
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import re

import jax
import jax.numpy as jnp

from repro.core import RecoveryProblem, solve
from repro.core.deblur import (
    build_deblur_plan,
    build_multiframe_deblur_problem,
    deblur_metrics,
)
from repro.data.synthetic import starfield
from repro.dist.compat import make_mesh
from repro.dist.fft import layout_2d
from repro.dist.recovery import make_dist_spectrum

F, H, W = 4, 32, 32
ITERS = 800
KW = dict(alpha=1e-3, rho=0.01, sigma=0.01)

imgs = jnp.stack(
    [starfield(jax.random.PRNGKey(i), h=H, w=W, density=0.05, n_blobs=2)
     for i in range(F)]
)
p = build_multiframe_deblur_problem(
    jax.random.PRNGKey(1), imgs, blur_order=5, subsample=0.5, sensing="romberg"
)
prob = RecoveryProblem(op=p.op, y=p.y, x_true=imgs.reshape(F, -1))

mesh = make_mesh((2, 4), ("data", "model"))
pl = build_deblur_plan(p, mesh, rfft=True)
assert (pl.n1, pl.n2) == (H, W), (pl.n1, pl.n2)
assert pl.batch_axis == "data", pl.batch_axis

# the direct spectrum re-layout must equal the four-step transform of the
# first column on the real 8-device mesh (half layout, padded columns)
spec_fft = make_dist_spectrum(mesh, axis_name="model", rfft=True)(
    layout_2d(p.op.circ.col, pl.n1, pl.n2)
)
scale = float(jnp.max(jnp.abs(spec_fft)))
err = float(jnp.max(jnp.abs(pl.spec2d - spec_fft))) / scale
print(f"composed-spectrum layout vs four-step FFT: max rel {err:.2e}")
assert err <= 1e-5, err

# collective structure: one planned joint matvec = fwd + inv transform =
# exactly 2 all-to-alls (op *definitions*; operand references are %-prefixed)
hlo = (
    jax.jit(pl.operator.matvec)
    .lower(jnp.zeros((H * W,), jnp.float32))
    .compile()
    .as_text()
)
n_a2a = len(re.findall(r"(?<!%)\ball-to-all(?:-start)?\(", hlo))
assert n_a2a == 2, f"expected 2 all-to-alls per planned deblur matvec, got {n_a2a}"
print(f"collective structure OK ({n_a2a} all-to-alls per matvec)")

# single-device reference vs the planned distributed solve, per frame
x_ref, _ = solve(prob, "cpadmm", iters=ITERS, record_every=ITERS, **KW)
x_dist, _ = solve(prob, "cpadmm", iters=ITERS, record_every=ITERS, plan=pl, **KW)
for f in range(F):
    rel = float(
        jnp.linalg.norm(x_dist[f] - x_ref[f])
        / (jnp.linalg.norm(x_ref[f]) + 1e-30)
    )
    print(f"frame {f}: planned vs single-device rel {rel:.2e}")
    assert rel <= 1e-5, (f, rel)

# the multiframe golden PSNR pin through the planned path
psnr = deblur_metrics(p, x_dist)["psnr_db"]
print("per-frame PSNR (dB):", [f"{float(v):.2f}" for v in psnr])
assert (psnr >= 45.0).all(), psnr

# full-complex path (rfft=False) stays pinned too, shorter budget
pl_full = build_deblur_plan(p, mesh, rfft=False)
x_ref300, _ = solve(prob, "cpadmm", iters=300, record_every=300, **KW)
x_full, _ = solve(prob, "cpadmm", iters=300, record_every=300, plan=pl_full, **KW)
rel = float(jnp.linalg.norm(x_full - x_ref300) / jnp.linalg.norm(x_ref300))
print(f"full-complex planned vs single-device rel {rel:.2e}")
assert rel <= 1e-5, rel
print("ALL OK")

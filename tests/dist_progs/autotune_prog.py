"""Subprocess prog: plan autotuner on a real 8-device mesh.

ISSUE 6 acceptance: ``plan(op, mesh, tune=True)`` on 8 fake CPU devices
produces a plan whose CPADMM solve matches the untuned default plan —
at 1e-5 relative error when the winner keeps the fp32 wire (re-knobbing
never changes what is computed), or within the plan layer's wire
precision bound when the tuner picks a demoted ``wire_dtype`` (the one
knob that *is* allowed to trade bounded error for wire bytes; a
wire_dtype='fp32' pin restores the exact-parity contract).  Also checks
the two properties that need a non-trivial mesh to mean anything:

  * the cost model's rfft preference corresponds to a real wire-byte win —
    the half-spectrum plan's matvec moves fewer all-to-all bytes than the
    full-complex one at the same n;
  * a warm cache hit skips all scoring/compilation (counter-asserted).
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import re
import tempfile

import jax
import jax.numpy as jnp

from repro.core import RecoveryProblem, solve
from repro.core.circulant import PartialCirculant, gaussian_circulant
from repro.data.synthetic import paper_regime, sparse_signal
from repro.dist.compat import make_mesh
from repro.ops import plan, tune

mesh = make_mesh((8,), ("model",))
n1, n2 = 32, 32
n = n1 * n2
m, k = paper_regime(n)
ALPHA, RHO, SIGMA = 1e-4, 0.01, 0.01

x_true = sparse_signal(jax.random.PRNGKey(0), n, k)
C = gaussian_circulant(jax.random.PRNGKey(1), n, normalize=True)
omega = jnp.sort(jax.random.permutation(jax.random.PRNGKey(2), n)[:m]).astype(jnp.int32)
op = PartialCirculant(C, omega)
prob = RecoveryProblem(op=op, y=op.matvec(x_true), x_true=x_true)

cache = tune.PlanCache(os.path.join(tempfile.mkdtemp(), "plan_cache.json"))
tune.reset_counters()

# tune=True (model mode): enumerate + score over the 8-way mesh
tuned_pl = plan(op, mesh, tune=True, tune_opts={"cache": cache})
print("tuned config:", tuned_pl.config.describe())
assert tune.COUNTERS["scored"] > 0 and tune.COUNTERS["cache_misses"] == 1

# tuned solve == untuned solve: exact-parity contract at fp32 wire, the
# documented precision bound when the tuner picked a demoted wire
from repro.ops.plan import WIRE_ERROR_BOUND

default_pl = plan(op, mesh, n1=n1, n2=n2)
kw = dict(iters=300, record_every=300, alpha=ALPHA, rho=RHO, sigma=SIGMA)
x_def, _ = solve(prob, "cpadmm", plan=default_pl, **kw)
x_tun, _ = solve(prob, "cpadmm", plan=tuned_pl, **kw)
rel = float(jnp.linalg.norm(x_tun - x_def) / (jnp.linalg.norm(x_def) + 1e-30))
tol = 1e-5 if tuned_pl.wire_dtype == "fp32" else WIRE_ERROR_BOUND
print(f"tuned vs untuned cpadmm: rel {rel:.2e} (wire={tuned_pl.wire_dtype})")
assert rel <= tol, (rel, tol)

# pinning wire_dtype='fp32' restores the strict re-knob-only contract
pinned_pl = plan(op, mesh, tune=True, wire_dtype="fp32",
                 tune_opts={"cache": cache})
assert pinned_pl.wire_dtype == "fp32"
x_pin, _ = solve(prob, "cpadmm", plan=pinned_pl, **kw)
rel_pin = float(
    jnp.linalg.norm(x_pin - x_def) / (jnp.linalg.norm(x_def) + 1e-30)
)
print(f"fp32-pinned tuned vs untuned cpadmm: rel {rel_pin:.2e}")
assert rel_pin <= 1e-5, rel_pin

# the model's rfft preference is physical: fewer all-to-all bytes on the wire
def _a2a_bytes(p):
    hlo = (
        jax.jit(p.operator.matvec)
        .lower(jnp.zeros((n,), jnp.float32))
        .compile()
        .as_text()
    )
    total = 0
    for line in hlo.splitlines():
        if re.search(r"(?<!%)\ball-to-all(?:-start)?\(", line):
            # LHS is a tuple of per-shard buffers: (c64[4,4]{1,0}, ...)
            lhs = line.split(" all-to-all", 1)[0]
            for dtype_bits, dims in re.findall(r"\b[a-z](\d+)\[([\d,]*)\]", lhs):
                elems = 1
                for d in dims.split(","):
                    elems *= int(d) if d else 1
                total += elems * int(dtype_bits) // 8
    return total


full_b = _a2a_bytes(plan(op, mesh, n1=n1, n2=n2, rfft=False))
half_b = _a2a_bytes(plan(op, mesh, n1=n1, n2=n2, rfft=True))
print(f"all-to-all bytes per matvec: full-complex {full_b}, rfft {half_b}")
assert half_b < full_b, (half_b, full_b)
assert tuned_pl.config.rfft, "model should pick the cheaper-wire rfft plan"

# warm cache: bit-identical config, zero scoring
tune.reset_counters()
warm_pl = plan(op, mesh, tune=True, tune_opts={"cache": cache})
assert warm_pl.config == tuned_pl.config
assert tune.COUNTERS == {
    "scored": 0, "measured": 0, "cache_hits": 1, "cache_misses": 0,
}, tune.COUNTERS
print("warm cache hit: no scoring, no compiles")
print("ALL OK")

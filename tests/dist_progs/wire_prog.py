"""Subprocess prog: wire-compressed collectives on a real 8-device mesh.

ISSUE 8 acceptance, measured on the compiled HLO rather than modeled:

  * the bf16 wire roughly halves the all-to-all payload bytes of one
    distributed rfft matvec vs the fp32 wire (the packed (re, im) planes
    cross the wire as 2-byte elements — asserted at >= 1.8x, < 2.2x);
  * the demoted payload really is 16-bit on the wire: the bf16 program's
    transpose collectives carry u16 buffers (the bitcast that defeats
    XLA:CPU's float-normalization re-promotion), and no f32 all-to-all
    survives;
  * the end-to-end CPADMM solve through the bf16 wire stays within the
    plan layer's documented precision bound of the fp32-wire solve.
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import re

import jax
import jax.numpy as jnp

from repro.core import RecoveryProblem, solve
from repro.core.circulant import PartialCirculant, gaussian_circulant
from repro.data.synthetic import paper_regime, sparse_signal
from repro.dist.compat import make_mesh
from repro.ops import plan
from repro.ops.plan import WIRE_ERROR_BOUND

mesh = make_mesh((8,), ("model",))
n1, n2 = 32, 32
n = n1 * n2
m, k = paper_regime(n)
ALPHA, RHO, SIGMA = 1e-4, 0.01, 0.01

x_true = sparse_signal(jax.random.PRNGKey(0), n, k)
C = gaussian_circulant(jax.random.PRNGKey(1), n, normalize=True)
omega = jnp.sort(jax.random.permutation(jax.random.PRNGKey(2), n)[:m]).astype(jnp.int32)
op = PartialCirculant(C, omega)
prob = RecoveryProblem(op=op, y=op.matvec(x_true), x_true=x_true)


def _a2a_buffers(p):
    """(dtype tag, bytes) per all-to-all operand buffer in the compiled
    matvec HLO — same walk as autotune_prog, keeping the dtype visible."""
    hlo = (
        jax.jit(p.operator.matvec)
        .lower(jnp.zeros((n,), jnp.float32))
        .compile()
        .as_text()
    )
    out = []
    for line in hlo.splitlines():
        if re.search(r"(?<!%)\ball-to-all(?:-start)?\(", line):
            lhs = line.split(" all-to-all", 1)[0]
            for dtype, bits, dims in re.findall(
                r"\b([a-z])(\d+)\[([\d,]*)\]", lhs
            ):
                elems = 1
                for d in dims.split(","):
                    elems *= int(d) if d else 1
                out.append((f"{dtype}{bits}", elems * int(bits) // 8))
    return out


pl32 = plan(op, mesh, n1=n1, n2=n2, rfft=True)
pl16 = plan(op, mesh, n1=n1, n2=n2, rfft=True, wire_dtype="bf16")
assert pl16.wire_dtype == "bf16", "guard must accept bf16 on this problem"

buf32 = _a2a_buffers(pl32)
buf16 = _a2a_buffers(pl16)
bytes32 = sum(b for _, b in buf32)
bytes16 = sum(b for _, b in buf16)
ratio = bytes32 / bytes16
print(f"a2a bytes per rfft matvec: fp32 wire {bytes32}, bf16 wire {bytes16} "
      f"({ratio:.2f}x down)")
assert 1.8 <= ratio < 2.2, ratio

# the payload is genuinely 16-bit on the wire — u16 after the bitcast that
# stops XLA:CPU's float-normalization pass from re-promoting the collective
dtypes16 = {d for d, _ in buf16}
assert dtypes16 == {"u16"}, dtypes16
assert all(d in ("c64", "f32") for d, _ in buf32), buf32

# end-to-end: the bf16-wire solve lands within the documented bound
kw = dict(iters=300, record_every=300, alpha=ALPHA, rho=RHO, sigma=SIGMA)
x32, _ = solve(prob, "cpadmm", plan=pl32, **kw)
x16, _ = solve(prob, "cpadmm", plan=pl16, **kw)
rel = float(jnp.linalg.norm(x16 - x32) / (jnp.linalg.norm(x32) + 1e-30))
print(f"bf16-wire vs fp32-wire cpadmm: rel {rel:.2e} "
      f"(bound {WIRE_ERROR_BOUND:.1e})")
assert rel <= WIRE_ERROR_BOUND, rel

# recovery quality is preserved, not just mutual closeness
q32 = float(jnp.linalg.norm(x32 - x_true) / jnp.linalg.norm(x_true))
q16 = float(jnp.linalg.norm(x16 - x_true) / jnp.linalg.norm(x_true))
print(f"recovery error vs truth: fp32 wire {q32:.2e}, bf16 wire {q16:.2e}")
assert q16 <= q32 + WIRE_ERROR_BOUND, (q16, q32)
print("ALL OK")

"""Subprocess prog: the recovery server on an 8-device mesh.

ISSUE 7 acceptance, distributed leg: the continuous-batching dispatcher
runs its bucket engines through ``repro.ops.plan`` on a real mesh — and
bucket isolation holds where it matters most: rfft and full-complex plan
configs lower to *different* collective programs, so requests pinning each
must never share a batch.  Every result (recycled slots included) must
match its solo tolerance-stopped solve to 1e-5 relative.
"""

import dataclasses
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp

from repro.core import RecoveryProblem, solve_until
from repro.core.circulant import PartialCirculant, gaussian_circulant
from repro.data.synthetic import paper_regime
from repro.dist.compat import make_mesh
from repro.ops import PlanConfig
from repro.serve import ManualClock, RecoveryServer, synthetic_workload

mesh = make_mesh((8,), ("model",))
n1, n2 = 32, 32
n = n1 * n2
m, k = paper_regime(n)
RHO = 0.01

C = gaussian_circulant(jax.random.PRNGKey(1), n, normalize=True)
omega = jnp.sort(jax.random.permutation(jax.random.PRNGKey(2), n)[:m]).astype(jnp.int32)
op = PartialCirculant(C, omega)

cfg_rfft = PlanConfig(rfft=True, n1=n1, n2=n2)
cfg_full = PlanConfig(rfft=False, n1=n1, n2=n2)

# 6 requests over 2 slots per bucket forces recycling; half pin the rfft
# plan, half the full-complex one — two buckets by construction
base = synthetic_workload(op, 6, rate=1000.0, seed=5, tols=(1e-3, 1e-5),
                          max_iters=400)
reqs = [
    dataclasses.replace(r, plan_config=cfg_rfft if i % 2 else cfg_full)
    for i, r in enumerate(base)
]

srv = RecoveryServer(mesh=mesh, slots=2, round_iters=32, rho=RHO, sigma=RHO,
                     clock=ManualClock())
results = srv.serve(reqs)
stats = srv.stats()
assert len(results) == 6, len(results)
assert stats["buckets"] == 2, stats  # rfft and full-complex never mix
recycled = stats["total"]["recycled"]
assert recycled >= 2, stats  # 6 requests - 2 buckets x 2 cold slots
print(f"2 isolated buckets (rfft / full-complex), {recycled} recycled slots")

by_id = {r.request_id: r for r in reqs}
for res in results:
    req = by_id[res.request_id]
    x_solo, used = solve_until(
        RecoveryProblem(op=op, y=req.y), "cpadmm", tol=req.tol,
        max_iters=req.max_iters, min_iters=req.min_iters, rho=RHO, sigma=RHO,
    )
    rel = float(jnp.linalg.norm(res.x - x_solo)
                / (jnp.linalg.norm(x_solo) + 1e-30))
    print(f"{res.request_id} [{res.bucket.split('|')[-1]}]: "
          f"iters {res.iterations} (solo {int(used)}), rel {rel:.2e}")
    assert rel <= 1e-5, (res.request_id, rel)
    # either converged inside the budget, or exhausted it exactly as the
    # solo run did — never silently stopped early
    assert res.converged or res.iterations == req.max_iters, res.request_id
print("ALL OK")

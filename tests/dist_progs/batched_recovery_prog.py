"""Subprocess prog: batched (data-axis) + rfft distributed CPADMM on 8 fake
devices == 8 sequential single-signal core solves (ISSUE 2 acceptance).

Mesh is (data=2, model=4): B=8 signals ride the data axis two-per-shard
while each signal's four-step rfft stays sharded over 4 model devices —
every transform is still exactly one all-to-all for the whole batch.
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import RecoveryProblem, solve
from repro.core.circulant import PartialCirculant, gaussian_circulant
from repro.data.synthetic import paper_regime, sparse_signal
from repro.dist.compat import make_mesh
from repro.dist.fft import layout_2d, unlayout_2d
from repro.dist.recovery import make_dist_cpadmm, make_dist_spectrum

mesh = make_mesh((2, 4), ("data", "model"))
n1, n2 = 32, 32
n = n1 * n2
B = 8
m, k = paper_regime(n)
ITERS = 400
ALPHA, RHO, SIGMA = 1e-4, 0.01, 0.01

x_true = sparse_signal(jax.random.PRNGKey(0), n, k, batch=(B,))
C = gaussian_circulant(jax.random.PRNGKey(1), n, normalize=True)
omega = jnp.sort(jax.random.permutation(jax.random.PRNGKey(2), n)[:m])
mask = jnp.zeros((n,)).at[omega].set(1.0)
y_full = mask * C.matvec(x_true)  # (B, n): P^T y per signal

spec_h = make_dist_spectrum(mesh, rfft=True)(layout_2d(C.col, n1, n2))
solver = make_dist_cpadmm(
    mesh, n1, n2, ITERS, fused=True, rfft=True, batch_axis="data"
)
z2d = solver(
    spec_h,
    layout_2d(mask, n1, n2),
    layout_2d(y_full, n1, n2),
    jnp.float32(ALPHA),
    jnp.float32(RHO),
    jnp.float32(SIGMA),
)
zb = unlayout_2d(z2d)
assert zb.shape == (B, n), zb.shape

# one all-to-all per transform for the WHOLE batch: 2 per fused iteration
hlo = solver.lower(
    spec_h, layout_2d(mask, n1, n2), layout_2d(y_full, n1, n2),
    jnp.float32(ALPHA), jnp.float32(RHO), jnp.float32(SIGMA),
).compile().as_text()
n_a2a = hlo.count("all-to-all")
assert n_a2a >= 2, f"expected all-to-all collectives in the solver, got {n_a2a}"
print(f"collective structure OK ({n_a2a} all-to-all ops for B={B})")

op = PartialCirculant(C, omega.astype(jnp.int32))
worst = 0.0
for b in range(B):
    prob = RecoveryProblem(op=op, y=jnp.take(C.matvec(x_true[b]), omega), x_true=x_true[b])
    x_ref, _ = solve(prob, "cpadmm", iters=ITERS, record_every=ITERS,
                     alpha=ALPHA, rho=RHO, sigma=SIGMA)
    rel = float(jnp.linalg.norm(zb[b] - x_ref) / (jnp.linalg.norm(x_ref) + 1e-30))
    worst = max(worst, rel)
    assert rel <= 1e-5, (b, rel)
print(f"batched B={B} on (2,4) mesh == sequential core solves; worst rel {worst:.2e}")

mse = float(jnp.mean((zb - x_true) ** 2))
assert mse < 1e-4, mse
np.testing.assert_allclose(np.asarray(zb).shape, (B, n))
print("batched final MSE:", mse)
print("ALL OK")

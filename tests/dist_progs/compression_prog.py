"""Subprocess prog: CS gradient compression as a cross-replica collective.

Checks (8 fake devices, 'data' axis):
  1. compressed_mean reduces a *sparse* per-replica gradient family with low
     error vs exact pmean,
  2. wire bytes are n/ratio of the dense all-reduce,
  3. error feedback drives the residual accumulation: over steps, the mean
     decoded gradient tracks the true mean (compression error does not
     accumulate as a bias).
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.compression import (
    compressed_mean,
    compression_wire_bytes,
    identity_wire_bytes,
    make_compressor,
)
from repro.dist.compat import make_mesh, shard_map

mesh = make_mesh((8,), ("data",))
DIM = 4096
RATIO = 8
spec, state0 = make_compressor(jax.random.PRNGKey(7), DIM, ratio=RATIO, decode_iters=50, alpha=3e-3)

print("wire bytes:", compression_wire_bytes(spec), "vs dense", identity_wire_bytes(DIM))
assert compression_wire_bytes(spec) * (RATIO - 1) < identity_wire_bytes(DIM)

# sparse per-replica gradients: shared support (top-k structure), distinct
# values.  k chosen within the CS budget: m = DIM/ratio = 512 measurements
# recover k=64 reliably (m ~ 8k > 2k log(n/k)); denser gradients rely on the
# error-feedback path (checked below).
k = DIM // 64
support = jax.random.permutation(jax.random.PRNGKey(0), DIM)[:k]
vals = jax.random.normal(jax.random.PRNGKey(1), (8, k))
g_all = jnp.zeros((8, DIM)).at[:, support].set(vals)
g_mean_true = jnp.mean(g_all, axis=0)


def worker(g, st):
    out, new_st = compressed_mean(spec, st, g, "data")
    return out, new_st


fn = shard_map(
    worker,
    mesh=mesh,
    in_specs=(P("data", None), P(None)),
    out_specs=(P("data", None), P(None)),
    check_vma=False,
)

state = state0
outs, state = jax.jit(fn)(g_all, state)
err = float(jnp.linalg.norm(outs[0] - g_mean_true) / jnp.linalg.norm(g_mean_true))
print("one-shot relative decode error:", err)
assert err < 0.35, err

# error feedback over repeated steps with the SAME gradient: time-averaged
# decoded gradient must converge to the truth (EF-SGD guarantee shape)
accum = jnp.zeros((DIM,))
state = state0
STEPS = 30
for _ in range(STEPS):
    outs, state = jax.jit(fn)(g_all, state)
    accum = accum + outs[0]
avg = accum / STEPS
err_avg = float(jnp.linalg.norm(avg - g_mean_true) / jnp.linalg.norm(g_mean_true))
print("time-averaged relative error with EF:", err_avg)
assert err_avg < err * 0.7, (err_avg, err)
print("ALL OK")

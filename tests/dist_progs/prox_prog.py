"""Subprocess prog: pluggable priors through the plan on 8 real fake devices.

ISSUE 10 acceptance, distributed leg: every prior recovers through the
planned path on an 8-device mesh and matches the single-device solve at
1e-5 rel.  The elementwise priors (l1 / nonneg-l1) ride the one-shard_map
fused CPADMM block (prox=None vs prox=L1Prox() is asserted *bitwise* there,
so the fused lowering demonstrably stayed on); the non-elementwise TV and
wavelet priors take the hybrid core + global-tail lowering, where GSPMD
partitions the prox's rolls over the same mesh.  The TV map-making stack
(shift circulants, (2, 4) data x model mesh) closes with its golden PSNR.
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp

from repro.core import RecoveryProblem, partial_gaussian_circulant, solve
from repro.core.mapmaking import (
    build_mapmaking_plan,
    build_mapmaking_problem,
    solve_mapmaking,
)
from repro.data.synthetic import extended_emission, paper_regime, sparse_signal
from repro.dist.compat import make_mesh
from repro.ops import plan
from repro.ops.prox import L1Prox, NonNegL1Prox, TVProx, WaveletProx

N, BATCH, ITERS = 256, 2, 60
KW = dict(alpha=1e-3, rho=0.01, sigma=0.01)

m, k = paper_regime(N)
x_true = sparse_signal(jax.random.PRNGKey(0), N, k, batch=(BATCH,))
op = partial_gaussian_circulant(jax.random.PRNGKey(1), N, m, normalize=True)
prob = RecoveryProblem(op=op, y=op.matvec(x_true), x_true=x_true)
mesh = make_mesh((8,), ("model",))

# every prior: planned 8-device solve == single-device at 1e-5 rel
priors = [
    ("none", None),
    ("l1", L1Prox()),
    ("nonneg-l1", NonNegL1Prox()),
    ("tv", TVProx(shape=(16, 16))),
    ("wavelet", WaveletProx()),
]
for name, prox in priors:
    for method in ("ista", "cpadmm"):
        x_l, _ = solve(prob, method, iters=ITERS, record_every=ITERS,
                       plan=plan(op, prox=prox), **KW)
        x_d, _ = solve(prob, method, iters=ITERS, record_every=ITERS,
                       plan=plan(op, mesh, prox=prox), **KW)
        rel = float(jnp.linalg.norm(x_d - x_l) / (jnp.linalg.norm(x_l) + 1e-30))
        print(f"{name:>9}/{method}: dist vs local rel {rel:.2e}")
        assert rel <= 1e-5, (name, method, rel)

# the fused elementwise block stayed on: None == L1Prox bitwise on the mesh
for method in ("ista", "cpadmm"):
    x0, _ = solve(prob, method, iters=ITERS, record_every=ITERS,
                  plan=plan(op, mesh), **KW)
    x1, _ = solve(prob, method, iters=ITERS, record_every=ITERS,
                  plan=plan(op, mesh, prox=L1Prox()), **KW)
    assert jnp.array_equal(x0, x1), method
print("mesh None == L1Prox bitwise OK")

# rfft layout through the hybrid (non-elementwise) path too
pl_tv_r = plan(op, mesh, prox=TVProx(shape=(16, 16)), rfft=True)
x_r, _ = solve(prob, "cpadmm", iters=ITERS, record_every=ITERS,
               plan=pl_tv_r, **KW)
x_lr, _ = solve(prob, "cpadmm", iters=ITERS, record_every=ITERS,
                plan=plan(op, prox=TVProx(shape=(16, 16))), **KW)
rel = float(jnp.linalg.norm(x_r - x_lr) / (jnp.linalg.norm(x_lr) + 1e-30))
print(f"tv/cpadmm rfft hybrid: dist vs local rel {rel:.2e}")
assert rel <= 1e-5, rel

# the TV map-making acceptance scenario on a (2, 4) data x model mesh
sky = extended_emission(jax.random.PRNGKey(7), 16, 16, n_sources=3)
mp = build_mapmaking_problem(jax.random.PRNGKey(11), sky, [0, 1, 16, 17],
                             blur_order=1.0, subsample=0.5)
mesh2 = make_mesh((2, 4), ("data", "model"))
pl_mm = build_mapmaking_plan(mp, mesh2)
assert "prox=tv" in pl_mm.config.describe()
assert pl_mm.batch_axis == "data"
z_l, m_l = solve_mapmaking(mp, method="cpadmm", iters=600, alpha=1e-4)
z_d, m_d = solve_mapmaking(mp, plan=pl_mm, method="cpadmm", iters=600,
                           alpha=1e-4)
rel = float(jnp.linalg.norm(z_d - z_l) / (jnp.linalg.norm(z_l) + 1e-30))
psnr = float(m_d["psnr_db"])
print(f"mapmaking (2,4) mesh: dist vs local rel {rel:.2e}, map PSNR {psnr:.1f} dB")
assert rel <= 1e-5, rel
assert 44.0 < psnr < 52.0, psnr
print("ALL OK")

"""Subprocess prog: sharded train step on a (2,4) mesh matches the math and
runs collectives; checkpoint save -> elastic restore onto a different mesh."""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import tempfile

import jax
import numpy as np

from repro.ckpt import checkpoint as ckpt
from repro.configs.registry import smoke_config
from repro.dist.compat import make_mesh
from repro.dist.sharding import activate_rules, rules_for_arch
from repro.launch.partition import batch_shardings, train_state_shardings
from repro.models import steps
from repro.optim.adamw import AdamWConfig

cfg = smoke_config("codeqwen15_7b")
opt_cfg = AdamWConfig(lr_peak=1e-3, warmup_steps=2, total_steps=10)

mesh = make_mesh((2, 4), ("data", "model"))
rules = rules_for_arch(cfg, mesh)

B, S = 8, 32
batch = {
    "tokens": jax.random.randint(jax.random.PRNGKey(0), (B, S + 1), 0, cfg.vocab)
}

# ---- single-device reference
state0 = steps.init_train_state(jax.random.PRNGKey(42), cfg, opt_cfg)
ref_step = jax.jit(steps.make_train_step(cfg, opt_cfg))
_, ref_metrics = ref_step(state0, batch)
ref_loss = float(ref_metrics["loss"])
print("single-device loss:", ref_loss)

# ---- sharded
state_shape = jax.eval_shape(
    lambda: steps.init_train_state(jax.random.PRNGKey(42), cfg, opt_cfg)
)
state_sh = train_state_shardings(mesh, state_shape, rules)
batch_sh = batch_shardings(mesh, jax.eval_shape(lambda: batch), rules)

state_dist = jax.tree.map(
    lambda a, s: jax.device_put(np.asarray(a), s), state0, state_sh
)
batch_dist = jax.tree.map(
    lambda a, s: jax.device_put(np.asarray(a), s), batch, batch_sh
)

with activate_rules(rules, mesh):
    train_step = jax.jit(
        steps.make_train_step(cfg, opt_cfg),
        in_shardings=(state_sh, batch_sh),
        out_shardings=None,
    )
    new_state, metrics = train_step(state_dist, batch_dist)
    dist_loss = float(metrics["loss"])
print("sharded loss:", dist_loss)
assert abs(dist_loss - ref_loss) / ref_loss < 2e-2, (dist_loss, ref_loss)

# params actually sharded?
wq = new_state.params["segments"][0]["attn"]["wq"]
n_shards = len({d for s in wq.addressable_shards for d in [s.device]})
assert n_shards == 8, n_shards
print("param sharding OK")

# ---- checkpoint on (2,4), elastic restore onto (4,2)
tmp = tempfile.mkdtemp()
ckpt.save(tmp, 1, jax.device_get(new_state))
mesh2 = make_mesh((4, 2), ("data", "model"))
rules2 = rules_for_arch(cfg, mesh2)
state_sh2 = train_state_shardings(mesh2, state_shape, rules2)
step_no, restored = ckpt.restore(tmp, None, state_shape, state_sh2)
assert step_no == 1
np.testing.assert_allclose(
    np.asarray(jax.device_get(restored.params["final_norm"]["scale"])),
    np.asarray(jax.device_get(new_state.params["final_norm"]["scale"])),
)
# one more step on the NEW mesh from the restored state
batch_sh2 = batch_shardings(mesh2, jax.eval_shape(lambda: batch), rules2)
batch2 = jax.tree.map(lambda a, s: jax.device_put(np.asarray(a), s), batch, batch_sh2)
with activate_rules(rules2, mesh2):
    train_step2 = jax.jit(
        steps.make_train_step(cfg, opt_cfg), in_shardings=(state_sh2, batch_sh2)
    )
    _, m2 = train_step2(restored, batch2)
print("post-restore loss:", float(m2["loss"]))
assert np.isfinite(float(m2["loss"]))
print("elastic restore OK")
print("ALL OK")

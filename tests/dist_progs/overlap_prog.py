"""Subprocess prog: overlapped chunked-transpose FFT pipeline on 8 fake
devices — overlap=K must match the monolithic overlap=1 path at 1e-5 rel
with real (non-trivial) all-to-alls, and the chunking must actually multiply
the collective count in the lowered HLO (K chunk-collectives in flight is
the latency-hiding structure XLA schedules around).
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.circulant import gaussian_circulant
from repro.dist.compat import make_mesh
from repro.dist.fft import (
    layout_2d,
    make_distributed_fft,
    make_distributed_matvec,
    make_distributed_rfft,
)
from repro.dist.recovery import make_dist_cpadmm, make_dist_spectrum

mesh = make_mesh((8,), ("model",))
n1, n2 = 64, 32
n = n1 * n2


def rel(got, want):
    return float(jnp.linalg.norm(got - want) / (jnp.linalg.norm(want) + 1e-30))


x2d = layout_2d(jax.random.normal(jax.random.PRNGKey(0), (n,)), n1, n2)

# fft / rfft: overlap=K == overlap=1, and roundtrips close
for K in (2, 4):
    f1, i1 = make_distributed_fft(mesh, n1, n2, overlap=1)
    fk, ik = make_distributed_fft(mesh, n1, n2, overlap=K)
    F1, Fk = f1(x2d.astype(jnp.complex64)), fk(x2d.astype(jnp.complex64))
    assert rel(Fk, F1) <= 1e-5, (K, rel(Fk, F1))
    assert rel(jnp.real(ik(Fk)), x2d) <= 1e-4

    r1, ir1 = make_distributed_rfft(mesh, n1, n2, overlap=1)
    rk, irk = make_distributed_rfft(mesh, n1, n2, overlap=K)
    H1, Hk = r1(x2d), rk(x2d)
    assert rel(Hk, H1) <= 1e-5, (K, rel(Hk, H1))
    assert rel(irk(Hk), x2d) <= 1e-5
    print(f"fft/rfft overlap={K} OK")

# chunked collective structure: the forward transform must lower to K
# all-to-alls (one per chunk) instead of 1 — independent ops XLA's async
# scheduler can put in flight while the next chunk's FFT runs
for K in (1, 4):
    fk, _ = make_distributed_fft(mesh, n1, n2, overlap=K)
    hlo = fk.lower(x2d.astype(jnp.complex64)).compile().as_text()
    count = hlo.count("all-to-all-start(") + hlo.count(" all-to-all(")
    assert count >= K, f"overlap={K}: expected >= {K} all-to-alls, got {count}"
    print(f"collective structure overlap={K} OK ({count} all-to-all ops)")

# distributed matvec with overlap == monolithic matvec, both layouts
C = gaussian_circulant(jax.random.PRNGKey(1), n, normalize=True)
spec_h = make_distributed_rfft(mesh, n1, n2)[0](layout_2d(C.col, n1, n2))
mv1 = make_distributed_matvec(mesh, rfft=True, overlap=1)
mv4 = make_distributed_matvec(mesh, rfft=True, overlap=4)
for transpose in (False, True):
    assert rel(mv4(spec_h, x2d, transpose), mv1(spec_h, x2d, transpose)) <= 1e-5
print("overlapped matvec OK")

# end-to-end: overlapped fused rfft solver == monolithic solver on 8 devices
mask = jnp.zeros((n,)).at[jnp.sort(jax.random.permutation(jax.random.PRNGKey(2), n)[: n // 2])].set(1.0)
y_full = mask * C.matvec(jax.random.normal(jax.random.PRNGKey(3), (n,)))
spec = make_dist_spectrum(mesh, rfft=True)(layout_2d(C.col, n1, n2))
args = (
    spec,
    layout_2d(mask, n1, n2),
    layout_2d(y_full, n1, n2),
    jnp.float32(1e-4),
    jnp.float32(0.01),
    jnp.float32(0.01),
)
z1 = make_dist_cpadmm(mesh, n1, n2, 100, fused=True, rfft=True, overlap=1)(*args)
z4 = make_dist_cpadmm(mesh, n1, n2, 100, fused=True, rfft=True, overlap=4)(*args)
r = rel(z4, z1)
assert r <= 1e-5, r
print(f"overlapped solver == monolithic solver on 8 devices (rel {r:.2e})")

np.testing.assert_allclose(np.asarray(z4).shape, np.asarray(z1).shape)
print("ALL OK")

"""Subprocess prog: distributed CPISTA/FISTA via the plan API on 8 devices.

ISSUE 4 acceptance: the *core* drivers run ista and fista on a real mesh
through ``repro.ops.plan`` — tolerance-stopped (solve_until) and
fixed-budget (solve) — matching the single-device solver to 1e-5 relative
error.  Also checks the collective structure: one planned matvec is exactly
two all-to-alls (forward + inverse four-step transform).
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import re

import jax
import jax.numpy as jnp

from repro.core import RecoveryProblem, solve, solve_until
from repro.core.circulant import PartialCirculant, gaussian_circulant
from repro.data.synthetic import paper_regime, sparse_signal
from repro.dist.compat import make_mesh
from repro.ops import plan

mesh = make_mesh((8,), ("model",))
n1, n2 = 32, 32
n = n1 * n2
m, k = paper_regime(n)
ALPHA = 1e-4

x_true = sparse_signal(jax.random.PRNGKey(0), n, k)
C = gaussian_circulant(jax.random.PRNGKey(1), n, normalize=True)
omega = jnp.sort(jax.random.permutation(jax.random.PRNGKey(2), n)[:m]).astype(jnp.int32)
op = PartialCirculant(C, omega)
prob = RecoveryProblem(op=op, y=op.matvec(x_true), x_true=x_true)

pl = plan(op, mesh, n1=n1, n2=n2, rfft=True)

# collective structure: one planned matvec = one forward + one inverse
# four-step transform = exactly 2 all-to-alls
hlo = (
    jax.jit(pl.operator.matvec)
    .lower(jnp.zeros((n,), jnp.float32))
    .compile()
    .as_text()
)
# count op *definitions* (operand references are %-prefixed)
n_a2a = len(re.findall(r"(?<!%)\ball-to-all(?:-start)?\(", hlo))
assert n_a2a == 2, f"expected 2 all-to-alls per planned matvec, got {n_a2a}"
print(f"collective structure OK ({n_a2a} all-to-alls per matvec)")

# fixed-budget: ista mid-trajectory, fista at convergence (momentum
# transiently amplifies FFT rounding noise; see tests/test_plan.py)
x_fista = None
for method, iters in (("ista", 300), ("fista", 800)):
    x_ref, _ = solve(prob, method, iters=iters, record_every=iters, alpha=ALPHA)
    x_dist, _ = solve(
        prob, method, iters=iters, record_every=iters, alpha=ALPHA, plan=pl
    )
    rel = float(jnp.linalg.norm(x_dist - x_ref) / (jnp.linalg.norm(x_ref) + 1e-30))
    print(f"{method} solve: rel {rel:.2e}")
    assert rel <= 1e-5, (method, rel)
    if method == "fista":
        x_fista = x_dist

# tolerance-stopped distributed ISTA — the new capability
x_ref, used_ref = solve_until(prob, "ista", tol=1e-7, max_iters=3000, alpha=ALPHA)
x_dist, used = solve_until(
    prob, "ista", tol=1e-7, max_iters=3000, alpha=ALPHA, plan=pl
)
rel = float(jnp.linalg.norm(x_dist - x_ref) / (jnp.linalg.norm(x_ref) + 1e-30))
print(f"ista solve_until: rel {rel:.2e}, iters {int(used)} (core {int(used_ref)})")
assert rel <= 1e-5, rel
assert int(used) > 0

# recovery quality (paper Sec. 6 threshold) on the converged FISTA run —
# plain ISTA's O(1/t) decay needs far more than this budget to get there
mse = float(jnp.mean((x_fista - x_true) ** 2))
print("distributed fista final MSE:", mse)
assert mse < 1e-4, mse
print("ALL OK")

"""Subprocess prog: distributed CPADMM == single-device CPADMM, on 8 devices."""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import RecoveryProblem, solve
from repro.core.circulant import PartialCirculant, gaussian_circulant
from repro.data.synthetic import paper_regime, sparse_signal
from repro.dist.compat import make_mesh
from repro.dist.fft import layout_2d, unlayout_2d
from repro.dist.recovery import make_dist_cpadmm, make_dist_spectrum

mesh = make_mesh((8,), ("model",))
n1, n2 = 32, 32
n = n1 * n2
m, k = paper_regime(n)

# Build the problem in the distributed layout's index space.
x_true = sparse_signal(jax.random.PRNGKey(0), n, k)
C = gaussian_circulant(jax.random.PRNGKey(1), n, normalize=True)
omega = jnp.sort(jax.random.permutation(jax.random.PRNGKey(2), n)[:m])
mask = jnp.zeros((n,)).at[omega].set(1.0)
y_full = mask * C.matvec(x_true)  # P^T y in full-length form

ITERS = 400
ALPHA, RHO, SIGMA = 1e-4, 0.01, 0.01

# ---- single-device reference (core solver)
op = PartialCirculant(C, omega.astype(jnp.int32))
prob = RecoveryProblem(op=op, y=jnp.take(C.matvec(x_true), omega), x_true=x_true)
x_ref, tr = solve(prob, "cpadmm", iters=ITERS, record_every=ITERS,
                  alpha=ALPHA, rho=RHO, sigma=SIGMA)
print("single-device final MSE:", float(tr.mse[-1]))

# ---- distributed solver
spec_fn = make_dist_spectrum(mesh)
spec2d = spec_fn(layout_2d(C.col, n1, n2))
solver = make_dist_cpadmm(mesh, n1, n2, ITERS)
z2d = solver(
    spec2d,
    layout_2d(mask, n1, n2),
    layout_2d(y_full, n1, n2),
    jnp.float32(ALPHA),
    jnp.float32(RHO),
    jnp.float32(SIGMA),
)
x_dist = unlayout_2d(z2d)

np.testing.assert_allclose(np.asarray(x_dist), np.asarray(x_ref), atol=2e-4)
mse_dist = float(jnp.mean((x_dist - x_true) ** 2))
print("distributed final MSE:", mse_dist)
assert mse_dist < 1e-4, mse_dist
print("ALL OK")

"""benchmarks/compare.py perf gate: loud failures, not KeyError tracebacks.

ISSUE 6 satellite: a baseline suite missing from the candidate run must
fail the gate with an explicit MISSING-suites message (the signature of a
suite dropped from benchmarks/run.py registration), and malformed
artifacts must die with a SystemExit diagnostic instead of a stack trace.

Runs under ``python -m pytest`` from the repo root (the cwd on sys.path is
what makes ``import benchmarks.compare`` resolve — benchmarks/ is a plain
directory, not an installed package).
"""

import json

import pytest

from benchmarks import compare


def _artifact(tmp_path, name, rows):
    p = tmp_path / name
    p.write_text(json.dumps({"smoke": True, "rows": rows}))
    return str(p)


def _row(suite, name, us):
    return {"suite": suite, "name": name, "us_per_call": us, "derived": ""}


BASE_ROWS = [
    _row("matvec", "matvec_fft", 1000.0),
    _row("matvec", "matvec_dense", 2000.0),
    _row("throughput", "throughput_batched", 3000.0),
    _row("deblur", "deblur_solve", 5000.0),
]


def test_missing_suite_fails_loudly(tmp_path, capsys):
    base = _artifact(tmp_path, "base.json", BASE_ROWS)
    # candidate run lost the whole deblur suite
    fresh = _artifact(tmp_path, "fresh.json", BASE_ROWS[:2])
    with pytest.raises(SystemExit) as ei:
        compare.main([fresh, "--baseline", base])
    assert ei.value.code == 1
    out = capsys.readouterr().out
    assert "MISSING suites" in out and "deblur" in out
    assert "dropped from the runner registration" in out


def test_missing_row_within_surviving_suite_fails(tmp_path, capsys):
    base = _artifact(tmp_path, "base.json", BASE_ROWS)
    fresh = _artifact(tmp_path, "fresh.json",
                      [BASE_ROWS[0], BASE_ROWS[2], BASE_ROWS[3]])
    with pytest.raises(SystemExit):
        compare.main([fresh, "--baseline", base])
    out = capsys.readouterr().out
    assert "MISSING rows" in out and "matvec_dense" in out
    assert "MISSING suites" not in out  # matvec suite itself survived


def test_identical_runs_pass(tmp_path, capsys):
    base = _artifact(tmp_path, "base.json", BASE_ROWS)
    fresh = _artifact(tmp_path, "fresh.json", BASE_ROWS)
    compare.main([fresh, "--baseline", base])
    assert "perf gate OK" in capsys.readouterr().out


def test_new_suite_in_fresh_run_passes(tmp_path, capsys):
    base = _artifact(tmp_path, "base.json", BASE_ROWS)
    fresh = _artifact(
        tmp_path, "fresh.json",
        BASE_ROWS + [_row("autotune", "autotune_cold_tune", 9000.0)],
    )
    compare.main([fresh, "--baseline", base])
    assert "perf gate OK" in capsys.readouterr().out


def test_invalid_json_is_a_diagnostic_not_a_traceback(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    with pytest.raises(SystemExit, match="not valid JSON"):
        compare.load_rows(str(bad))


def test_missing_rows_key_is_a_diagnostic(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"smoke": True}))
    with pytest.raises(SystemExit, match="no 'rows' list"):
        compare.load_rows(str(bad))


def test_unreadable_file_is_a_diagnostic(tmp_path):
    with pytest.raises(SystemExit, match="cannot read"):
        compare.load_rows(str(tmp_path / "nope.json"))


def test_malformed_row_is_a_diagnostic(tmp_path):
    bad = _artifact(tmp_path, "bad.json", [{"name": "x"}])  # no us_per_call
    with pytest.raises(SystemExit, match=r"rows\[0\] lacks"):
        compare.load_rows(bad)


def test_regression_beyond_threshold_fails(tmp_path, capsys):
    base = _artifact(tmp_path, "base.json", BASE_ROWS)
    slow = [dict(r) for r in BASE_ROWS]
    slow[3]["us_per_call"] *= 10  # deblur regresses, others hold the median
    fresh = _artifact(tmp_path, "fresh.json", slow)
    with pytest.raises(SystemExit):
        compare.main([fresh, "--baseline", base])
    assert "REGRESSED" in capsys.readouterr().out

"""CS gradient-compression unit tests (single device; collective path is
covered by tests/dist_progs/compression_prog.py)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compression import (
    compress,
    compression_wire_bytes,
    decode,
    identity_wire_bytes,
    make_compressor,
    update_residual,
)

DIM = 2048


def _sparse_grad(key, k=DIM // 64):
    sup = jax.random.permutation(key, DIM)[:k]
    vals = jax.random.normal(jax.random.fold_in(key, 1), (k,))
    return jnp.zeros((DIM,)).at[sup].set(vals)


def test_wire_reduction():
    spec, _ = make_compressor(jax.random.PRNGKey(0), DIM, ratio=8)
    assert compression_wire_bytes(spec) * 8 == identity_wire_bytes(spec.n)


def test_sparse_gradient_roundtrip():
    # m = 256 measurements for k = 32 nonzeros: needs ~80 FISTA decode steps
    # at this tighter m/k ratio (the receiver-side cost knob).
    spec, st = make_compressor(jax.random.PRNGKey(0), DIM, ratio=8, decode_iters=80)
    g = _sparse_grad(jax.random.PRNGKey(1))
    y, e = compress(spec, st, g)
    assert y.shape == (spec.m,)
    gh = decode(spec, st, y)[:DIM]
    err = float(jnp.linalg.norm(gh - g) / jnp.linalg.norm(g))
    assert err < 0.15, err


def test_error_feedback_accumulates_residual():
    """With a gradient too dense to recover one-shot, error feedback must
    carry the unrecovered part forward instead of dropping it."""
    spec, st = make_compressor(jax.random.PRNGKey(0), DIM, ratio=8)
    g = jax.random.normal(jax.random.PRNGKey(2), (DIM,)) * 0.1  # dense!
    y, e = compress(spec, st, g)
    gh = decode(spec, st, y)
    st2 = update_residual(st, e, gh)
    # residual norm > 0 (couldn't recover everything)...
    assert float(jnp.linalg.norm(st2.residual)) > 0
    # ...and the next compression input includes it
    y2, e2 = compress(spec, st2, g)
    np.testing.assert_allclose(
        np.asarray(e2), np.asarray(jnp.pad(g, (0, spec.n - DIM)) + st2.residual),
        atol=1e-6,
    )


def test_deterministic_operator_across_hosts():
    """Same key => identical sensing operator with zero coordination."""
    _, a = make_compressor(jax.random.PRNGKey(7), DIM)
    _, b = make_compressor(jax.random.PRNGKey(7), DIM)
    np.testing.assert_array_equal(np.asarray(a.col), np.asarray(b.col))
    np.testing.assert_array_equal(np.asarray(a.omega), np.asarray(b.omega))

"""Compressed deblurring application tests (paper Sec. 7)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import RecoveryProblem, solve
from repro.core.circulant import Circulant
from repro.core.deblur import (
    blurred_observation,
    build_deblur_problem,
    deblur_metrics,
    recovered_image,
)
from repro.data.synthetic import starfield


@pytest.fixture(scope="module")
def small_problem():
    img = starfield(jax.random.PRNGKey(0), h=32, w=32, density=0.08, n_blobs=3)
    return build_deblur_problem(
        jax.random.PRNGKey(1), img, blur_order=5, subsample=0.5, sensing="romberg"
    )


def test_operator_is_joint_sense_blur(small_problem):
    """A = P (C B) — verified against the dense product on a tiny image."""
    p = small_problem
    n = p.image.size
    # dense check on a random vector instead of full materialization (n=1024)
    x = jax.random.normal(jax.random.PRNGKey(2), (n,))
    via_parts = p.op.circ.matvec(x)
    # the joint circulant must equal sense-after-blur applied sequentially:
    # spec(joint) = spec(C) * spec(B); verify with an independent blur apply
    blurred = p.blur.matvec(x)
    sense_spec = p.op.circ.spec / jnp.where(p.blur.spec == 0, 1.0, p.blur.spec)
    sense = Circulant.from_spectrum(sense_spec, n)
    np.testing.assert_allclose(
        np.asarray(sense.matvec(blurred)), np.asarray(via_parts), atol=5e-3
    )


def test_measurements_are_of_blurred_image(small_problem):
    p = small_problem
    x = p.image.reshape(-1)
    direct = jnp.take(p.op.circ.matvec(x), p.op.omega, axis=-1)
    np.testing.assert_allclose(np.asarray(p.y), np.asarray(direct), atol=1e-5)


def test_blur_smears_forward():
    img = jnp.zeros((8, 8)).at[3, 3].set(1.0)
    prob = build_deblur_problem(jax.random.PRNGKey(0), img, blur_order=4)
    b = np.asarray(blurred_observation(prob)).reshape(-1)
    flat = np.zeros(64)
    flat[3 * 8 + 3] = 1.0
    # order-4 moving average along the raster, circular
    expect = np.zeros(64)
    for l in range(4):
        expect[(3 * 8 + 3 - l) % 64] += 0.25
    np.testing.assert_allclose(b, expect, atol=1e-6)


def test_compressed_deblurring_recovers(small_problem):
    """End-to-end Sec. 7: recover a sharp image from compressed blurred
    measurements; normalized MSE must land in the paper's 1e-4 order."""
    p = small_problem
    prob = RecoveryProblem(op=p.op, y=p.y, x_true=p.image.reshape(-1))
    x, tr = solve(prob, "cpadmm", iters=800, record_every=800, alpha=1e-3, rho=0.01, sigma=0.01)
    m = deblur_metrics(p, x)
    assert float(m["normalized_mse"]) < 5e-3
    img = recovered_image(p, x)
    assert img.shape == p.image.shape
    # the recovery must beat simply using the blurred observation
    blurred = blurred_observation(p)
    blurred_nmse = float(
        jnp.mean((blurred - p.image) ** 2) / jnp.mean(p.image**2)
    )
    assert float(m["normalized_mse"]) < blurred_nmse / 5


def test_starfield_statistics():
    img = starfield(jax.random.PRNGKey(3), h=64, w=64, density=0.1, n_blobs=4)
    frac_lit = float(jnp.mean(img > 0))
    assert 0.05 < frac_lit < 0.5  # sparse-ish, blobs add some support
    assert float(img.min()) >= 0.0 and float(img.max()) <= 1.0

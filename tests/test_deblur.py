"""Compressed deblurring application tests (paper Sec. 7)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import RecoveryProblem, solve
from repro.core.circulant import Circulant
from repro.core.deblur import (
    blurred_observation,
    build_deblur_plan,
    build_deblur_problem,
    build_multiframe_deblur_problem,
    deblur_metrics,
    recovered_image,
)
from repro.data.synthetic import starfield

SOLVE_KW = dict(alpha=1e-3, rho=0.01, sigma=0.01)


def _rel(got, want):
    got, want = jnp.asarray(got), jnp.asarray(want)
    return float(jnp.linalg.norm(got - want) / (jnp.linalg.norm(want) + 1e-30))


@pytest.fixture(scope="module")
def small_problem():
    img = starfield(jax.random.PRNGKey(0), h=32, w=32, density=0.08, n_blobs=3)
    return build_deblur_problem(
        jax.random.PRNGKey(1), img, blur_order=5, subsample=0.5, sensing="romberg"
    )


def test_operator_is_joint_sense_blur(small_problem):
    """A = P (C B) — verified against the dense product on a tiny image."""
    p = small_problem
    n = p.image.size
    # dense check on a random vector instead of full materialization (n=1024)
    x = jax.random.normal(jax.random.PRNGKey(2), (n,))
    via_parts = p.op.circ.matvec(x)
    # the joint circulant must equal sense-after-blur applied sequentially:
    # spec(joint) = spec(C) * spec(B); verify with an independent blur apply
    blurred = p.blur.matvec(x)
    sense_spec = p.op.circ.spec / jnp.where(p.blur.spec == 0, 1.0, p.blur.spec)
    sense = Circulant.from_spectrum(sense_spec, n)
    np.testing.assert_allclose(
        np.asarray(sense.matvec(blurred)), np.asarray(via_parts), atol=5e-3
    )


def test_measurements_are_of_blurred_image(small_problem):
    p = small_problem
    x = p.image.reshape(-1)
    direct = jnp.take(p.op.circ.matvec(x), p.op.omega, axis=-1)
    np.testing.assert_allclose(np.asarray(p.y), np.asarray(direct), atol=1e-5)


def test_blur_smears_forward():
    img = jnp.zeros((8, 8)).at[3, 3].set(1.0)
    prob = build_deblur_problem(jax.random.PRNGKey(0), img, blur_order=4)
    b = np.asarray(blurred_observation(prob)).reshape(-1)
    flat = np.zeros(64)
    flat[3 * 8 + 3] = 1.0
    # order-4 moving average along the raster, circular
    expect = np.zeros(64)
    for l in range(4):
        expect[(3 * 8 + 3 - l) % 64] += 0.25
    np.testing.assert_allclose(b, expect, atol=1e-6)


def test_compressed_deblurring_recovers(small_problem):
    """End-to-end Sec. 7: recover a sharp image from compressed blurred
    measurements; normalized MSE must land in the paper's 1e-4 order."""
    p = small_problem
    prob = RecoveryProblem(op=p.op, y=p.y, x_true=p.image.reshape(-1))
    x, tr = solve(prob, "cpadmm", iters=800, record_every=800, alpha=1e-3, rho=0.01, sigma=0.01)
    m = deblur_metrics(p, x)
    assert float(m["normalized_mse"]) < 5e-3
    img = recovered_image(p, x)
    assert img.shape == p.image.shape
    # the recovery must beat simply using the blurred observation
    blurred = blurred_observation(p)
    blurred_nmse = float(
        jnp.mean((blurred - p.image) ** 2) / jnp.mean(p.image**2)
    )
    assert float(m["normalized_mse"]) < blurred_nmse / 5


# Golden values recorded per case (starfield key 0, problem key 1, 800
# CPADMM iterations): (psnr_db, normalized_mse, rel_err).  A solver refactor
# that silently degrades recovery shows up here as a PSNR drop / error rise
# even while the looser end-to-end bound above still passes.  Bands are
# ~10-15% wide to absorb cross-platform float accumulation differences —
# not algorithmic drift, which moves these numbers by integer factors.
GOLDEN = {
    # the canonical paper-regime case (the original golden pin)
    ("romberg", 32, 32): (45.00, 6.67e-4, 2.58e-2),
    # odd, non-square extents: n = 31*33 exercises the odd-n rfft bookkeeping
    ("romberg", 31, 33): (43.19, 1.01e-3, 3.18e-2),
    # paper-faithful gaussian sensing (worse conditioning, lower quality —
    # pinned all the same so a conditioning regression is loud)
    ("gaussian", 32, 32): (33.94, 8.49e-3, 9.22e-2),
}


def _golden_problem(sensing, h, w):
    img = starfield(jax.random.PRNGKey(0), h=h, w=w, density=0.08, n_blobs=3)
    return build_deblur_problem(
        jax.random.PRNGKey(1), img, blur_order=5, subsample=0.5, sensing=sensing
    )


def _check_golden(p, x, case):
    golden_psnr, golden_nmse, golden_rel = GOLDEN[case]
    m = deblur_metrics(p, x)
    rel = _rel(x, p.image.reshape(p.image.shape[:-2] + (-1,)))
    assert float(m["psnr_db"]) > golden_psnr - 0.5, case
    assert float(m["normalized_mse"]) < golden_nmse * 1.15, case
    assert rel < golden_rel * 1.15, case
    # and the pin is two-sided: suspicious *improvements* need a human look
    assert float(m["psnr_db"]) < golden_psnr + 3.0, case


@pytest.mark.parametrize("sensing,h,w", sorted(GOLDEN))
def test_deblur_golden_regression(sensing, h, w):
    """Pin the recovery quality of the Sec. 7 pipeline on fixed seeds,
    across sensing families and odd non-square image extents."""
    p = _golden_problem(sensing, h, w)
    prob = RecoveryProblem(op=p.op, y=p.y, x_true=p.image.reshape(-1))
    x, _ = solve(prob, "cpadmm", iters=800, record_every=800, **SOLVE_KW)
    _check_golden(p, x, (sensing, h, w))


# Same harness, richer PSF families (repro.core.circulant gaussian/airy):
# (psnr_db, normalized_mse, rel_err) recorded at 800 CPADMM iterations.  The
# airy PSF concentrates energy in a tight core (easy deconvolution, high
# PSNR); the gaussian sigma=1 spreads it (harder, lower) — both pinned so a
# PSF-spectrum regression is loud in either direction.
GOLDEN_PSF = {
    ("gaussian", 1.0): (43.24, 1.00e-3, 3.16e-2),
    ("airy", 2.0): (53.19, 1.01e-4, 1.01e-2),
}


@pytest.mark.parametrize("blur_kind,order", sorted(GOLDEN_PSF))
def test_deblur_golden_psf_families(blur_kind, order):
    """The Sec. 7 pipeline accepts the astronomy-realistic PSF families end
    to end — composed through the same joint operator and golden-pinned
    like the moving-average cases, through the planned (rfft) path."""
    from repro.dist.compat import make_mesh

    img = starfield(jax.random.PRNGKey(0), h=32, w=32, density=0.08, n_blobs=3)
    p = build_deblur_problem(
        jax.random.PRNGKey(1), img, blur_order=order, subsample=0.5,
        sensing="romberg", blur_kind=blur_kind,
    )
    prob = RecoveryProblem(op=p.op, y=p.y, x_true=img.reshape(-1))
    x_ref, _ = solve(prob, "cpadmm", iters=800, record_every=800, **SOLVE_KW)
    golden_psnr, golden_nmse, golden_rel = GOLDEN_PSF[(blur_kind, order)]
    m = deblur_metrics(p, x_ref)
    rel = _rel(x_ref, img.reshape(-1))
    assert float(m["psnr_db"]) > golden_psnr - 0.5, (blur_kind, order)
    assert float(m["psnr_db"]) < golden_psnr + 3.0, (blur_kind, order)
    assert float(m["normalized_mse"]) < golden_nmse * 1.15
    assert rel < golden_rel * 1.15
    # the planned lowering composes the same PSF spectrum (1e-5 parity)
    pl = build_deblur_plan(p, make_mesh((1,), ("model",)), rfft=True)
    x_pl, _ = solve(prob, "cpadmm", iters=800, record_every=800, plan=pl,
                    **SOLVE_KW)
    assert _rel(x_pl, x_ref) <= 1e-5


def test_make_blur_dispatch_validates():
    from repro.core.deblur import _make_blur

    with pytest.raises(ValueError, match="blur_kind"):
        build_deblur_problem(jax.random.PRNGKey(0), jnp.zeros((8, 8)),
                             blur_kind="box")
    # each family's own loud width validation surfaces through the builder
    for kind in ("moving-average", "gaussian", "airy"):
        with pytest.raises(ValueError):
            _make_blur(64, kind, 0, jnp.float32)
        with pytest.raises(ValueError):
            _make_blur(64, kind, 65, jnp.float32)


# ---------------------------------------------------------------------------
# the PSF families themselves (repro.core.circulant builders)
# ---------------------------------------------------------------------------


def test_gaussian_blur_kernel():
    from repro.core.circulant import gaussian_blur

    B = gaussian_blur(32, 2.0)
    col = np.asarray(B.col)
    assert col.sum() == pytest.approx(1.0, abs=1e-6)  # flux-preserving
    assert col[0] == col.max()  # peak at zero lag
    np.testing.assert_allclose(col[1:], col[1:][::-1], atol=1e-7)  # symmetric
    # circular distance: col[j] depends on min(j, n-j) only
    assert col[1] == pytest.approx(col[31], abs=1e-7)
    # monotone decay over the first half
    assert (np.diff(col[:16]) <= 1e-9).all()


def test_airy_blur_kernel():
    from repro.core.circulant import airy_blur

    B = airy_blur(64, 4.0)
    col = np.asarray(B.col)
    assert col.sum() == pytest.approx(1.0, abs=1e-6)
    assert col[0] == col.max()
    np.testing.assert_allclose(col[1:], col[1:][::-1], atol=1e-7)
    # the first null lands at the radius: intensity there ~ 0
    assert col[4] < col[0] * 1e-4
    # truncated past 4 radii (finite support keeps the PSF compact)
    assert col[20] == 0.0
    # the sidelobe between the first and second null is nonzero (it is an
    # airy pattern, not a disk): ~1.75% of the peak at u ~ 5.14
    assert col[5] > 0.0


def test_bessel_j1_quadrature():
    """The fixed midpoint quadrature for J1 is accurate to float32 over the
    argument range the airy PSF evaluates (u in [0, ~15.3])."""
    from repro.core.circulant import _bessel_j1

    # reference values (Abramowitz & Stegun / scipy.special.j1)
    for x, want in ((0.5, 0.2422684577), (1.0, 0.4400505857),
                    (3.8317, 0.0000074570), (7.0155, -1.4375e-5),
                    (10.0, 0.0434727462)):
        got = float(_bessel_j1(jnp.asarray(x)))
        assert got == pytest.approx(want, abs=5e-5), x


def test_psf_builders_validate_width():
    """gaussian/airy port moving_average_blur's loud 0 < width <= n rule."""
    from repro.core.circulant import airy_blur, gaussian_blur

    for build, name in ((gaussian_blur, "sigma"), (airy_blur, "radius")):
        with pytest.raises(ValueError, match=name):
            build(8, 0)
        with pytest.raises(ValueError, match=name):
            build(8, -1.5)
        with pytest.raises(ValueError, match=name):
            build(8, 9.0)
        build(8, 8.0)  # width == n is the legal extreme


def test_shift_circulant_is_roll():
    from repro.core.circulant import shift_circulant

    x = jnp.arange(8.0)
    for s in (0, 1, 3, -2, 11):
        S = shift_circulant(8, s)
        np.testing.assert_allclose(
            np.asarray(S.matvec(x)), np.asarray(jnp.roll(x, s)), atol=1e-6
        )
        # adjoint is the inverse shift (S is a permutation)
        np.testing.assert_allclose(
            np.asarray(S.rmatvec(x)), np.asarray(jnp.roll(x, -s)), atol=1e-6
        )
    with pytest.raises(ValueError, match="n"):
        shift_circulant(0, 1)


def test_psf_families_compose_with_sensing():
    """Every PSF family rides compose_sensing_blur into the joint operator
    the deblur pipeline plans over."""
    from repro.core.circulant import (
        airy_blur,
        compose_sensing_blur,
        gaussian_blur,
        gaussian_circulant,
    )

    C = gaussian_circulant(jax.random.PRNGKey(2), 32)
    for B in (gaussian_blur(32, 1.5), airy_blur(32, 2.0)):
        A = compose_sensing_blur(C, B)
        np.testing.assert_allclose(
            np.asarray(A.to_dense()),
            np.asarray(C.to_dense()) @ np.asarray(B.to_dense()),
            atol=1e-3,
        )


# ---------------------------------------------------------------------------
# the planned (execution-plan) deblur path — ISSUE 5 tentpole
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rfft", [False, True])
def test_deblur_planned_matches_single_device(small_problem, rfft):
    """Distributed (planned) deblur == the single-device solve at 1e-5 rel:
    the composed operator lowered through ops.plan on a 1-device mesh (the
    8-device variant rides tests/dist_progs/deblur_prog.py)."""
    from repro.dist.compat import make_mesh

    p = small_problem
    prob = RecoveryProblem(op=p.op, y=p.y, x_true=p.image.reshape(-1))
    x_ref, _ = solve(prob, "cpadmm", iters=300, record_every=300, **SOLVE_KW)
    pl = build_deblur_plan(p, make_mesh((1,), ("model",)), rfft=rfft)
    # deblur-aware defaults: the four-step layout is the image's own grid
    assert (pl.n1, pl.n2) == p.image.shape
    x_dist, _ = solve(prob, "cpadmm", iters=300, record_every=300,
                      plan=pl, **SOLVE_KW)
    assert _rel(x_dist, x_ref) <= 1e-5


@pytest.mark.parametrize("sensing,h,w", [("romberg", 32, 32), ("romberg", 31, 33)])
def test_deblur_golden_regression_planned(sensing, h, w):
    """The golden pins hold through the planned path too (rfft layout), and
    the planned solve tracks the core one at 1e-5 — covering odd extents,
    where the half-spectrum padding logic is busiest."""
    from repro.dist.compat import make_mesh

    p = _golden_problem(sensing, h, w)
    prob = RecoveryProblem(op=p.op, y=p.y, x_true=p.image.reshape(-1))
    x_ref, _ = solve(prob, "cpadmm", iters=800, record_every=800, **SOLVE_KW)
    pl = build_deblur_plan(p, make_mesh((1,), ("model",)), rfft=True)
    x, _ = solve(prob, "cpadmm", iters=800, record_every=800, plan=pl, **SOLVE_KW)
    assert _rel(x, x_ref) <= 1e-5
    _check_golden(p, x, (sensing, h, w))


def test_multiframe_deblur_golden_planned():
    """The multiframe golden PSNR pin through the planned path: every frame
    of a 4-frame stack recovers at >= 45 dB from one batched distributed
    solve (values recorded: [46.02, 48.23, 45.31, 48.46] dB)."""
    from repro.dist.compat import make_mesh

    F = 4
    imgs = jnp.stack(
        [starfield(jax.random.PRNGKey(i), h=32, w=32, density=0.05, n_blobs=2)
         for i in range(F)]
    )
    p = build_multiframe_deblur_problem(
        jax.random.PRNGKey(1), imgs, blur_order=5, subsample=0.5,
        sensing="romberg",
    )
    prob = RecoveryProblem(op=p.op, y=p.y, x_true=imgs.reshape(F, -1))
    pl = build_deblur_plan(p, make_mesh((1,), ("model",)), rfft=True)
    x, _ = solve(prob, "cpadmm", iters=800, record_every=800, plan=pl, **SOLVE_KW)
    psnr = np.asarray(deblur_metrics(p, x)["psnr_db"])
    assert psnr.shape == (F,)
    assert (psnr >= 45.0).all(), psnr
    assert (psnr <= 52.0).all(), psnr  # two-sided: improvements need a look


def test_build_deblur_plan_local_and_batch_defaults():
    """mesh=None is the identity lowering; a (data, model) mesh auto-shards
    a frame stack over the data axis."""
    from repro.dist.compat import make_mesh

    imgs = jnp.stack(
        [starfield(jax.random.PRNGKey(i), h=16, w=16, density=0.08, n_blobs=2)
         for i in range(2)]
    )
    p = build_multiframe_deblur_problem(
        jax.random.PRNGKey(4), imgs, blur_order=3, subsample=0.6, sensing="romberg"
    )
    pl_local = build_deblur_plan(p)
    assert not pl_local.is_distributed and pl_local.operator is p.op
    pl = build_deblur_plan(p, make_mesh((1, 1), ("data", "model")), rfft=True)
    assert pl.is_distributed and pl.batch_axis == "data"
    assert (pl.n1, pl.n2) == (16, 16)


def test_multiframe_deblur_batched_recovery():
    """A (F, H, W) stack through one shared optic recovers per frame with a
    single batched solve; metrics come back with the frame axis."""
    F = 3
    imgs = jnp.stack(
        [starfield(jax.random.PRNGKey(10 + i), h=16, w=16, density=0.08, n_blobs=2)
         for i in range(F)]
    )
    p = build_multiframe_deblur_problem(
        jax.random.PRNGKey(4), imgs, blur_order=3, subsample=0.6, sensing="romberg"
    )
    assert p.y.shape == (F, p.op.m)
    prob = RecoveryProblem(op=p.op, y=p.y, x_true=imgs.reshape(F, -1))
    x, _ = solve(prob, "cpadmm", iters=500, record_every=500,
                 alpha=1e-3, rho=0.01, sigma=0.01)
    m = deblur_metrics(p, x)
    assert m["normalized_mse"].shape == (F,)
    assert (np.asarray(m["normalized_mse"]) < 5e-3).all()
    img = recovered_image(p, x)
    assert img.shape == imgs.shape
    assert blurred_observation(p).shape == imgs.shape
    # batched == per-frame sequential (same operator, independent frames)
    for f in range(F):
        single = RecoveryProblem(op=p.op, y=p.y[f], x_true=imgs[f].reshape(-1))
        xs, _ = solve(single, "cpadmm", iters=500, record_every=500,
                      alpha=1e-3, rho=0.01, sigma=0.01)
        rel = float(jnp.linalg.norm(x[f] - xs) / (jnp.linalg.norm(xs) + 1e-30))
        assert rel <= 1e-6, f


def test_build_deblur_problem_rejects_stacks():
    """Batched input used to die with a bare tuple-unpack error; now both
    builders point at each other with a clear message."""
    imgs = jnp.zeros((2, 8, 8))
    with pytest.raises(ValueError, match="build_multiframe_deblur_problem"):
        build_deblur_problem(jax.random.PRNGKey(0), imgs)
    with pytest.raises(ValueError, match="build_deblur_problem"):
        build_multiframe_deblur_problem(jax.random.PRNGKey(0), jnp.zeros((8, 8)))


def test_deblur_metrics_degenerate_frame_psnr():
    """An all-zero frame has no peak to reference: PSNR is the -inf sentinel
    (not the misleading finite number an epsilon'd peak produced), and the
    batch shape survives."""
    lit = starfield(jax.random.PRNGKey(0), h=8, w=8, density=0.3, n_blobs=2)
    imgs = jnp.stack([lit, jnp.zeros((8, 8))])
    p = build_multiframe_deblur_problem(
        jax.random.PRNGKey(1), imgs, blur_order=2, subsample=0.8, sensing="romberg"
    )
    m = deblur_metrics(p, jnp.zeros((2, 64)))
    assert m["psnr_db"].shape == (2,)
    assert np.isfinite(float(m["psnr_db"][0]))
    assert float(m["psnr_db"][1]) == -np.inf
    # a perfect reconstruction of a lit frame still reports a huge finite PSNR
    m2 = deblur_metrics(p, imgs.reshape(2, -1))
    assert np.isfinite(float(m2["psnr_db"][0])) and float(m2["psnr_db"][0]) > 100.0


def test_starfield_statistics():
    img = starfield(jax.random.PRNGKey(3), h=64, w=64, density=0.1, n_blobs=4)
    frac_lit = float(jnp.mean(img > 0))
    assert 0.05 < frac_lit < 0.5  # sparse-ish, blobs add some support
    assert float(img.min()) >= 0.0 and float(img.max()) <= 1.0


def test_multiframe_deblur_golden_bf16_wire():
    """ISSUE 8 acceptance: the 4-frame golden deblur stack recovers at
    >= 45 dB PSNR per frame with the bf16 wire — halving the transpose
    all-to-all bytes costs no visible reconstruction quality (values
    recorded: [45.91, 48.18, 45.32, 48.05] dB, within 0.4 dB of the
    fp32-wire pins)."""
    from repro.dist.compat import make_mesh

    F = 4
    imgs = jnp.stack(
        [starfield(jax.random.PRNGKey(i), h=32, w=32, density=0.05, n_blobs=2)
         for i in range(F)]
    )
    p = build_multiframe_deblur_problem(
        jax.random.PRNGKey(1), imgs, blur_order=5, subsample=0.5,
        sensing="romberg",
    )
    prob = RecoveryProblem(op=p.op, y=p.y, x_true=imgs.reshape(F, -1))
    pl = build_deblur_plan(p, make_mesh((1,), ("model",)), rfft=True,
                           wire_dtype="bf16")
    assert pl.wire_dtype == "bf16"  # the precision guard accepted the wire
    x, _ = solve(prob, "cpadmm", iters=800, record_every=800, plan=pl, **SOLVE_KW)
    psnr = np.asarray(deblur_metrics(p, x)["psnr_db"])
    assert psnr.shape == (F,)
    assert (psnr >= 45.0).all(), psnr
    assert (psnr <= 52.0).all(), psnr

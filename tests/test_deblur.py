"""Compressed deblurring application tests (paper Sec. 7)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import RecoveryProblem, solve
from repro.core.circulant import Circulant
from repro.core.deblur import (
    blurred_observation,
    build_deblur_problem,
    deblur_metrics,
    recovered_image,
)
from repro.data.synthetic import starfield


@pytest.fixture(scope="module")
def small_problem():
    img = starfield(jax.random.PRNGKey(0), h=32, w=32, density=0.08, n_blobs=3)
    return build_deblur_problem(
        jax.random.PRNGKey(1), img, blur_order=5, subsample=0.5, sensing="romberg"
    )


def test_operator_is_joint_sense_blur(small_problem):
    """A = P (C B) — verified against the dense product on a tiny image."""
    p = small_problem
    n = p.image.size
    # dense check on a random vector instead of full materialization (n=1024)
    x = jax.random.normal(jax.random.PRNGKey(2), (n,))
    via_parts = p.op.circ.matvec(x)
    # the joint circulant must equal sense-after-blur applied sequentially:
    # spec(joint) = spec(C) * spec(B); verify with an independent blur apply
    blurred = p.blur.matvec(x)
    sense_spec = p.op.circ.spec / jnp.where(p.blur.spec == 0, 1.0, p.blur.spec)
    sense = Circulant.from_spectrum(sense_spec, n)
    np.testing.assert_allclose(
        np.asarray(sense.matvec(blurred)), np.asarray(via_parts), atol=5e-3
    )


def test_measurements_are_of_blurred_image(small_problem):
    p = small_problem
    x = p.image.reshape(-1)
    direct = jnp.take(p.op.circ.matvec(x), p.op.omega, axis=-1)
    np.testing.assert_allclose(np.asarray(p.y), np.asarray(direct), atol=1e-5)


def test_blur_smears_forward():
    img = jnp.zeros((8, 8)).at[3, 3].set(1.0)
    prob = build_deblur_problem(jax.random.PRNGKey(0), img, blur_order=4)
    b = np.asarray(blurred_observation(prob)).reshape(-1)
    flat = np.zeros(64)
    flat[3 * 8 + 3] = 1.0
    # order-4 moving average along the raster, circular
    expect = np.zeros(64)
    for l in range(4):
        expect[(3 * 8 + 3 - l) % 64] += 0.25
    np.testing.assert_allclose(b, expect, atol=1e-6)


def test_compressed_deblurring_recovers(small_problem):
    """End-to-end Sec. 7: recover a sharp image from compressed blurred
    measurements; normalized MSE must land in the paper's 1e-4 order."""
    p = small_problem
    prob = RecoveryProblem(op=p.op, y=p.y, x_true=p.image.reshape(-1))
    x, tr = solve(prob, "cpadmm", iters=800, record_every=800, alpha=1e-3, rho=0.01, sigma=0.01)
    m = deblur_metrics(p, x)
    assert float(m["normalized_mse"]) < 5e-3
    img = recovered_image(p, x)
    assert img.shape == p.image.shape
    # the recovery must beat simply using the blurred observation
    blurred = blurred_observation(p)
    blurred_nmse = float(
        jnp.mean((blurred - p.image) ** 2) / jnp.mean(p.image**2)
    )
    assert float(m["normalized_mse"]) < blurred_nmse / 5


def test_deblur_golden_regression(small_problem):
    """Pin the recovery quality of the Sec. 7 pipeline on a fixed seed.

    Golden values recorded from the same fixture (starfield key 0, problem
    key 1, romberg sensing, 800 CPADMM iterations).  A solver refactor that
    silently degrades recovery shows up here as a PSNR drop / error rise
    even while the looser end-to-end bound above still passes.  Bands are
    ~10-15% wide to absorb cross-platform float accumulation differences —
    not algorithmic drift, which moves these numbers by integer factors.
    """
    GOLDEN_PSNR_DB = 45.00
    GOLDEN_NMSE = 6.67e-4
    GOLDEN_REL_ERR = 2.58e-2

    p = small_problem
    prob = RecoveryProblem(op=p.op, y=p.y, x_true=p.image.reshape(-1))
    x, _ = solve(prob, "cpadmm", iters=800, record_every=800,
                 alpha=1e-3, rho=0.01, sigma=0.01)
    m = deblur_metrics(p, x)
    rel = float(jnp.linalg.norm(x - p.image.reshape(-1)) / jnp.linalg.norm(p.image))

    assert float(m["psnr_db"]) > GOLDEN_PSNR_DB - 0.5
    assert float(m["normalized_mse"]) < GOLDEN_NMSE * 1.15
    assert rel < GOLDEN_REL_ERR * 1.15
    # and the pin is two-sided: suspicious *improvements* need a human look
    assert float(m["psnr_db"]) < GOLDEN_PSNR_DB + 3.0


def test_multiframe_deblur_batched_recovery():
    """A (F, H, W) stack through one shared optic recovers per frame with a
    single batched solve; metrics come back with the frame axis."""
    from repro.core.deblur import build_multiframe_deblur_problem

    F = 3
    imgs = jnp.stack(
        [starfield(jax.random.PRNGKey(10 + i), h=16, w=16, density=0.08, n_blobs=2)
         for i in range(F)]
    )
    p = build_multiframe_deblur_problem(
        jax.random.PRNGKey(4), imgs, blur_order=3, subsample=0.6, sensing="romberg"
    )
    assert p.y.shape == (F, p.op.m)
    prob = RecoveryProblem(op=p.op, y=p.y, x_true=imgs.reshape(F, -1))
    x, _ = solve(prob, "cpadmm", iters=500, record_every=500,
                 alpha=1e-3, rho=0.01, sigma=0.01)
    m = deblur_metrics(p, x)
    assert m["normalized_mse"].shape == (F,)
    assert (np.asarray(m["normalized_mse"]) < 5e-3).all()
    img = recovered_image(p, x)
    assert img.shape == imgs.shape
    assert blurred_observation(p).shape == imgs.shape
    # batched == per-frame sequential (same operator, independent frames)
    for f in range(F):
        single = RecoveryProblem(op=p.op, y=p.y[f], x_true=imgs[f].reshape(-1))
        xs, _ = solve(single, "cpadmm", iters=500, record_every=500,
                      alpha=1e-3, rho=0.01, sigma=0.01)
        rel = float(jnp.linalg.norm(x[f] - xs) / (jnp.linalg.norm(xs) + 1e-30))
        assert rel <= 1e-6, f


def test_starfield_statistics():
    img = starfield(jax.random.PRNGKey(3), h=64, w=64, density=0.1, n_blobs=4)
    frac_lit = float(jnp.mean(img > 0))
    assert 0.05 < frac_lit < 0.5  # sparse-ish, blobs add some support
    assert float(img.min()) >= 0.0 and float(img.max()) <= 1.0

"""Solver correctness: ISTA/FISTA/CPISTA, dense ADMM, CPADMM (paper Algs. 1-3)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    PAPER_TARGET_MSE,
    RecoveryProblem,
    densify,
    partial_gaussian_circulant,
    partial_romberg_circulant,
    solve,
    solve_checkpointed,
    solve_until,
)
from repro.core.ista import lasso_objective
from repro.data.synthetic import paper_regime, sparse_signal


def _normalized_problem(n=256, seed=0, sensing="gaussian"):
    m, k = paper_regime(n)
    x = sparse_signal(jax.random.PRNGKey(seed), n, k)
    if sensing == "gaussian":
        op = partial_gaussian_circulant(jax.random.PRNGKey(seed + 1), n, m, normalize=True)
    else:
        op = partial_romberg_circulant(jax.random.PRNGKey(seed + 1), n, m)
    y = op.matvec(x)
    return RecoveryProblem(op=op, y=y, x_true=x)


TUNED = dict(alpha=1e-4, rho=0.01, sigma=0.01)


# ---------------------------------------------------------------------------
# Paper Sec. 6 headline: recovery to MSE <= 1e-4 in the m=n/2, k~=n/10 regime
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method,iters", [("cpadmm", 400), ("fista", 600)])
def test_paper_regime_recovery(method, iters):
    prob = _normalized_problem()
    kw = TUNED if method == "cpadmm" else dict(alpha=1e-4)
    _, tr = solve(prob, method, iters=iters, record_every=iters, **kw)
    assert float(tr.mse[-1]) < PAPER_TARGET_MSE


def test_romberg_sensing_recovers_faster_than_gaussian():
    """Beyond-paper claim: orthogonal random-convolution sensing needs fewer
    ISTA iterations for the same MSE (better restricted conditioning)."""
    budget = 200
    pg = _normalized_problem(seed=3, sensing="gaussian")
    pr = _normalized_problem(seed=3, sensing="romberg")
    _, tg = solve(pg, "ista", iters=budget, record_every=budget, alpha=1e-4)
    _, trr = solve(pr, "ista", iters=budget, record_every=budget, alpha=1e-4)
    assert float(trr.mse[-1]) < float(tg.mse[-1])


# ---------------------------------------------------------------------------
# CPISTA == PISTA: identical algorithm, structured representation (Sec. 5.2)
# ---------------------------------------------------------------------------


def test_cpista_matches_dense_pista_trajectory():
    prob = _normalized_problem(n=128, seed=7)
    dense_prob = RecoveryProblem(
        op=densify(prob.op), y=prob.y, x_true=prob.x_true
    )
    tau = 0.5  # fixed so both paths use the exact same step size
    xc, trc = solve(prob, "ista", iters=50, alpha=1e-4, tau=tau, record_every=10)
    xd, trd = solve(dense_prob, "ista", iters=50, alpha=1e-4, tau=tau, record_every=10)
    np.testing.assert_allclose(np.asarray(xc), np.asarray(xd), atol=5e-5)
    np.testing.assert_allclose(
        np.asarray(trc.objective), np.asarray(trd.objective), rtol=1e-3, atol=1e-5
    )


# ---------------------------------------------------------------------------
# ISTA descent property (convergence guarantee of Sec. 2.2)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", ["ista", "cpadmm"])
def test_objective_decreases(method):
    prob = _normalized_problem(n=128, seed=1)
    kw = TUNED if method == "cpadmm" else dict(alpha=1e-4)
    _, tr = solve(prob, method, iters=120, record_every=10, **kw)
    obj = np.asarray(tr.objective)
    # ISTA is monotone; ADMM is not but must trend down decisively.
    if method == "ista":
        assert (np.diff(obj) <= 1e-5).all()
    assert obj[-1] < obj[0] * 0.5


def test_fista_beats_ista_at_fixed_budget():
    prob = _normalized_problem(n=256, seed=2)
    budget = 150
    _, ti = solve(prob, "ista", iters=budget, record_every=budget, alpha=1e-4)
    _, tf = solve(prob, "fista", iters=budget, record_every=budget, alpha=1e-4)
    assert float(tf.mse[-1]) < float(ti.mse[-1])


# ---------------------------------------------------------------------------
# CPADMM and dense ADMM reach the same LASSO minimizer (Algs. 2 vs 3)
# ---------------------------------------------------------------------------


def test_cpadmm_matches_dense_admm_fixed_point():
    prob = _normalized_problem(n=96, seed=4)
    dense_prob = RecoveryProblem(op=densify(prob.op), y=prob.y, x_true=prob.x_true)
    xc, _ = solve(prob, "cpadmm", iters=2500, record_every=2500, **TUNED)
    xd, _ = solve(dense_prob, "admm", iters=2500, record_every=2500, alpha=1e-4, rho=0.01)
    oc = float(lasso_objective(prob.op, prob.y, xc, 1e-4))
    od = float(lasso_objective(prob.op, prob.y, xd, 1e-4))
    # same minimizer up to solver tolerance
    np.testing.assert_allclose(np.asarray(xc), np.asarray(xd), atol=2e-3)
    assert oc == pytest.approx(od, rel=1e-2)


def test_cpadmm_state_satisfies_constraints_at_convergence():
    """At the fixed point the splitting constraints v = Cx and z = x hold."""
    prob = _normalized_problem(n=128, seed=5)
    from repro.core.solvers import make_stepper

    stepper = make_stepper(prob, "cpadmm", **TUNED)
    s = stepper.init()
    for _ in range(1500):
        s = stepper.step(s)
    cx = prob.op.circ.matvec(s.x)
    assert float(jnp.max(jnp.abs(s.v - cx))) < 5e-3
    assert float(jnp.max(jnp.abs(s.z - s.x))) < 5e-3


# ---------------------------------------------------------------------------
# Drivers
# ---------------------------------------------------------------------------


def test_solve_until_stops_early():
    prob = _normalized_problem(n=128, seed=6)
    x, iters = solve_until(prob, "cpadmm", tol=1e-6, max_iters=4000, **TUNED)
    assert int(iters) < 4000
    d = prob.x_true - x
    assert float(jnp.mean(d * d)) < 1e-3


def test_checkpointed_resume_is_exact():
    """Fault-tolerance invariant: kill-and-resume == uninterrupted run."""
    prob = _normalized_problem(n=128, seed=8)
    saved = {}

    def cb(step, state):
        saved[step] = state

    x_full, _ = solve_checkpointed(prob, "cpadmm", iters=200, chunk=50, save_cb=cb, **TUNED)
    # resume from the checkpoint taken at step 100
    x_res, _ = solve_checkpointed(
        prob, "cpadmm", iters=200, chunk=50, restore=(100, saved[100]), **TUNED
    )
    np.testing.assert_allclose(np.asarray(x_full), np.asarray(x_res), atol=1e-6)


def test_batched_recovery():
    """Solvers broadcast over leading batch axes (the data-parallel unit)."""
    n, batch = 128, 3
    m, k = paper_regime(n)
    x = sparse_signal(jax.random.PRNGKey(0), n, k, batch=(batch,))
    op = partial_gaussian_circulant(jax.random.PRNGKey(1), n, m, normalize=True)
    y = op.matvec(x)
    prob = RecoveryProblem(op=op, y=y, x_true=x)
    xh, tr = solve(prob, "cpadmm", iters=400, record_every=400, **TUNED)
    assert xh.shape == (batch, n)
    assert tr.mse.shape == (1, batch)
    assert (np.asarray(tr.mse[-1]) < 1e-3).all()

"""Block-level correctness: chunked/parallel training forms must agree with
the sequential decode recurrences, and attention must match a naive oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import smoke_config
from repro.models import attention as attn_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod


# ---------------------------------------------------------------------------
# attention: chunked online-softmax vs naive softmax oracle
# ---------------------------------------------------------------------------


def _naive_attention(q, k, v, causal, scale=None, window=0):
    b, sq, h, dh = q.shape
    kh = k.shape[2]
    g = h // kh
    scale = scale or dh**-0.5
    qf = (q * scale).astype(jnp.float32).reshape(b, sq, kh, g, dh)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qf, k.astype(jnp.float32))
    if causal:
        mask = jnp.tril(jnp.ones((sq, k.shape[1]), bool))
        if window:
            mask = mask & (
                jnp.arange(k.shape[1])[None, :] > jnp.arange(sq)[:, None] - window
            )
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bkgqd", p, v.astype(jnp.float32))
    return o.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, -1)


@pytest.mark.parametrize("sq,chunk", [(16, 8), (64, 16), (33, 16)])
@pytest.mark.parametrize("gqa", [(4, 4), (4, 2), (8, 1)])
def test_chunked_attention_matches_naive(sq, chunk, gqa):
    h, kh = gqa
    dh = 16
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(keys[0], (2, sq, h, dh))
    k = jax.random.normal(keys[1], (2, sq, kh, dh))
    v = jax.random.normal(keys[2], (2, sq, kh, dh))
    got = attn_mod._attend_chunked(q, k, v, causal=True, chunk=chunk)
    want = _naive_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4)


def test_sliding_window_attention():
    sq, h, dh, win = 32, 2, 8, 8
    keys = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(keys[0], (1, sq, h, dh))
    k = jax.random.normal(keys[1], (1, sq, h, dh))
    v = jax.random.normal(keys[2], (1, sq, h, dh))
    got = attn_mod._attend_chunked(q, k, v, causal=True, chunk=16, sliding_window=win)
    want = _naive_attention(q, k, v, causal=True, window=win)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4)


def test_gqa_decode_matches_forward():
    """Feeding tokens one-by-one through the KV cache must reproduce the
    parallel (training) attention outputs position-by-position."""
    cfg = smoke_config("codeqwen15_7b")
    cfg = cfg.__class__(**{**cfg.__dict__, "dtype": "float32"})
    params = attn_mod.init_gqa(jax.random.PRNGKey(0), cfg, jnp.float32)
    b, s = 2, 12
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model)) * 0.3
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    full = attn_mod.gqa_forward(params, cfg, x, positions)

    cache = attn_mod.init_kv_cache(cfg, b, max_len=16, dtype=jnp.float32)
    outs = []
    for t in range(s):
        y, cache = attn_mod.gqa_decode(params, cfg, x[:, t : t + 1], cache)
        outs.append(y)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=3e-4)


def test_mla_decode_matches_forward():
    cfg = smoke_config("deepseek_v3_671b")
    cfg = cfg.__class__(**{**cfg.__dict__, "dtype": "float32"})
    params = attn_mod.init_mla(jax.random.PRNGKey(0), cfg, jnp.float32)
    b, s = 2, 10
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model)) * 0.3
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    full = attn_mod.mla_forward(params, cfg, x, positions)

    cache = attn_mod.init_mla_cache(cfg, b, max_len=16, dtype=jnp.float32)
    outs = []
    for t in range(s):
        y, cache = attn_mod.mla_decode(params, cfg, x[:, t : t + 1], cache)
        outs.append(y)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=3e-4)


# ---------------------------------------------------------------------------
# mamba2: chunked SSD vs naive recurrence, and decode consistency
# ---------------------------------------------------------------------------


def _naive_ssd(x, dt, a_log, B, C, d_skip):
    """Direct per-step recurrence h_t = a_t h_{t-1} + dt_t B_t x_t^T."""
    bt, t, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    A = -jnp.exp(a_log)
    Bh = jnp.repeat(B, rep, axis=2)
    Ch = jnp.repeat(C, rep, axis=2)

    def step(hstate, inp):
        xt, dtt, Bt, Ct = inp
        a = jnp.exp(dtt * A)  # (bt,h)
        hstate = hstate * a[:, :, None, None] + jnp.einsum(
            "bh,bhn,bhp->bhnp", dtt, Bt, xt
        )
        y = jnp.einsum("bhn,bhnp->bhp", Ct, hstate)
        return hstate, y

    h0 = jnp.zeros((bt, h, n, p))
    _, ys = jax.lax.scan(
        step,
        h0,
        (x.swapaxes(0, 1), dt.swapaxes(0, 1), Bh.swapaxes(0, 1), Ch.swapaxes(0, 1)),
    )
    return ys.swapaxes(0, 1) + x * d_skip[None, None, :, None]


def test_ssd_chunked_matches_naive():
    bt, t, h, p, g, n = 2, 256, 4, 8, 2, 16
    keys = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(keys[0], (bt, t, h, p))
    dt = jax.nn.softplus(jax.random.normal(keys[1], (bt, t, h)) - 1.0)
    a_log = jnp.log(jnp.linspace(0.5, 2.0, h))
    B = jax.random.normal(keys[2], (bt, t, g, n)) * 0.3
    C = jax.random.normal(keys[3], (bt, t, g, n)) * 0.3
    d_skip = jnp.ones((h,))
    got, _ = ssm_mod._ssd_chunked(x, dt, a_log, B, C, d_skip, chunk=64)
    want = _naive_ssd(x, dt, a_log, B, C, d_skip)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)


def test_mamba2_decode_matches_forward():
    cfg = smoke_config("zamba2_1p2b")
    cfg = cfg.__class__(**{**cfg.__dict__, "dtype": "float32"})
    params = ssm_mod.init_mamba2(jax.random.PRNGKey(0), cfg, jnp.float32)
    b, s = 2, ssm_mod.CHUNK  # one full chunk
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model)) * 0.3
    full = ssm_mod.mamba2_forward(params, cfg, x)

    cache = ssm_mod.init_mamba2_cache(cfg, b, jnp.float32)
    outs = []
    for t in range(s):
        y, cache = ssm_mod.mamba2_decode(params, cfg, x[:, t : t + 1], cache)
        outs.append(y)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), rtol=3e-3, atol=3e-3)


# ---------------------------------------------------------------------------
# xLSTM: parallel mLSTM vs sequential decode; sLSTM scan vs cell
# ---------------------------------------------------------------------------


def test_mlstm_decode_matches_forward():
    cfg = smoke_config("xlstm_350m")
    cfg = cfg.__class__(**{**cfg.__dict__, "dtype": "float32"})
    params = xlstm_mod.init_mlstm(jax.random.PRNGKey(0), cfg, jnp.float32)
    b, s = 2, xlstm_mod.CHUNK
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model)) * 0.3
    full = xlstm_mod.mlstm_forward(params, cfg, x)

    cache = xlstm_mod.init_mlstm_cache(cfg, b)
    outs = []
    for t in range(s):
        y, cache = xlstm_mod.mlstm_decode(params, cfg, x[:, t : t + 1], cache)
        outs.append(y)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), rtol=4e-3, atol=4e-3)


def test_mlstm_multichunk_consistency():
    """2-chunk forward == two stitched 1-chunk computations via decode path."""
    cfg = smoke_config("xlstm_350m")
    cfg = cfg.__class__(**{**cfg.__dict__, "dtype": "float32"})
    params = xlstm_mod.init_mlstm(jax.random.PRNGKey(3), cfg, jnp.float32)
    b, s = 1, 2 * xlstm_mod.CHUNK
    x = jax.random.normal(jax.random.PRNGKey(4), (b, s, cfg.d_model)) * 0.3
    full = xlstm_mod.mlstm_forward(params, cfg, x)
    cache = xlstm_mod.init_mlstm_cache(cfg, b)
    outs = []
    for t in range(s):
        y, cache = xlstm_mod.mlstm_decode(params, cfg, x[:, t : t + 1], cache)
        outs.append(y)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), rtol=4e-3, atol=4e-3)


def test_slstm_decode_matches_forward():
    cfg = smoke_config("xlstm_350m")
    cfg = cfg.__class__(**{**cfg.__dict__, "dtype": "float32"})
    params = xlstm_mod.init_slstm(jax.random.PRNGKey(0), cfg, jnp.float32)
    b, s = 2, 16
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model)) * 0.3
    full = xlstm_mod.slstm_forward(params, cfg, x)
    cache = xlstm_mod.init_slstm_cache(cfg, b)
    outs = []
    for t in range(s):
        y, cache = xlstm_mod.slstm_decode(params, cfg, x[:, t : t + 1], cache)
        outs.append(y)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=1e-4)

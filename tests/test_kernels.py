"""Per-kernel allclose sweeps vs pure-jnp oracles (interpret=True on CPU)."""

import pytest

try:  # optional dev dep; CI installs it — only the property tests need it
    import hypothesis
    import hypothesis.strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False
import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.banded_conv.ops import blur_apply
from repro.kernels.banded_conv.ref import banded_circulant_matvec_ref
from repro.kernels.circulant_matvec.kernel import circulant_matvec_pallas
from repro.kernels.circulant_matvec.ops import circulant_matvec
from repro.kernels.circulant_matvec.ref import (
    circulant_matvec_fft_ref,
    circulant_matvec_ref,
)
from repro.kernels.cpadmm_tail.ops import fused_cpadmm_tail
from repro.kernels.cpadmm_tail.ref import cpadmm_tail_ref
from repro.kernels.soft_threshold.ops import fused_admm_update, fused_ista_update
from repro.kernels.soft_threshold.ref import (
    admm_threshold_dual_update_ref,
    ista_threshold_update_ref,
)
from repro.kernels.spectral_pointwise.ops import spectral_update
from repro.kernels.spectral_pointwise.ref import cpadmm_spectral_update_ref

SETTINGS = dict(max_examples=20, deadline=None)


def _tol(want, rel=2e-5):
    return rel * max(1.0, float(jnp.max(jnp.abs(want))))


# ---------------------------------------------------------------------------
# circulant_matvec: grid/block sweeps, both transposes, both dtypes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,block", [(128, 128), (256, 128), (512, 256), (640, 128)])
@pytest.mark.parametrize("transpose", [False, True])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_circulant_matvec_shapes(n, block, transpose, dtype):
    col = jax.random.normal(jax.random.PRNGKey(0), (n,), dtype)
    x = jax.random.normal(jax.random.PRNGKey(1), (n,), dtype)
    got = circulant_matvec_pallas(col, x, transpose=transpose, block=block)
    want = circulant_matvec_ref(col, x, transpose=transpose)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=_tol(want, 1e-4))


@pytest.mark.parametrize("use_gather", [True, False])
def test_circulant_matvec_gather_vs_slices(use_gather):
    """Both tile-materialization strategies must agree (toolchain fallback)."""
    n, block = 256, 128
    col = jax.random.normal(jax.random.PRNGKey(2), (n,))
    x = jax.random.normal(jax.random.PRNGKey(3), (n,))
    got = circulant_matvec_pallas(col, x, block=block, use_gather=use_gather)
    want = circulant_matvec_ref(col, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=_tol(want, 1e-4))


if HAVE_HYPOTHESIS:

    @hypothesis.given(
        nblocks=st.integers(1, 6), seed=st.integers(0, 2**16), transpose=st.booleans()
    )
    @hypothesis.settings(**SETTINGS)
    def test_circulant_matvec_property(nblocks, seed, transpose):
        n = nblocks * 128
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        col = jax.random.normal(k1, (n,))
        x = jax.random.normal(k2, (n,))
        got = circulant_matvec_pallas(col, x, transpose=transpose, block=128)
        want = circulant_matvec_ref(col, x, transpose=transpose)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=_tol(want, 1e-4))

else:  # keep the absence visible as a skip, not a silent non-collection

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_circulant_matvec_property():
        pass


def test_dispatcher_fft_path_matches_direct():
    n = 512
    col = jax.random.normal(jax.random.PRNGKey(4), (n,))
    x = jax.random.normal(jax.random.PRNGKey(5), (n,))
    d = circulant_matvec(col, x, force="direct")
    f = circulant_matvec(col, x, force="fft")
    np.testing.assert_allclose(np.asarray(d), np.asarray(f), atol=_tol(f, 1e-4))


def test_fft_ref_matches_dense_ref():
    n = 384
    col = jax.random.normal(jax.random.PRNGKey(6), (n,))
    x = jax.random.normal(jax.random.PRNGKey(7), (n,))
    np.testing.assert_allclose(
        np.asarray(circulant_matvec_fft_ref(col, x)),
        np.asarray(circulant_matvec_ref(col, x)),
        atol=_tol(circulant_matvec_ref(col, x), 1e-4),
    )


# ---------------------------------------------------------------------------
# soft_threshold fusions
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [1024, 4096, 1000, 7])  # includes pad paths
@pytest.mark.parametrize("gamma", [0.0, 1e-3, 0.5])
def test_fused_ista_update(n, gamma):
    x = jax.random.normal(jax.random.PRNGKey(0), (n,))
    d = jax.random.normal(jax.random.PRNGKey(1), (n,)) * 0.1
    got = fused_ista_update(x, d, gamma)
    want = ista_threshold_update_ref(x, d, gamma)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


if HAVE_HYPOTHESIS:

    @hypothesis.given(
        n=st.integers(1, 5000), gamma=st.floats(0, 2.0), tau=st.floats(0.1, 1.6),
        seed=st.integers(0, 2**16),
    )
    @hypothesis.settings(**SETTINGS)
    def test_fused_admm_update_property(n, gamma, tau, seed):
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        x = jax.random.normal(k1, (n,))
        nu = jax.random.normal(k2, (n,))
        z, nu2 = fused_admm_update(x, nu, gamma, tau)
        zr, nur = admm_threshold_dual_update_ref(x, nu, gamma, tau)
        np.testing.assert_allclose(np.asarray(z), np.asarray(zr), atol=1e-6)
        np.testing.assert_allclose(np.asarray(nu2), np.asarray(nur), atol=1e-6)

else:

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_fused_admm_update_property():
        pass


def test_threshold_kills_small_entries():
    x = jnp.asarray([0.4, -0.4, 2.0, -2.0])
    out = fused_ista_update(x, jnp.zeros(4), 0.5)
    np.testing.assert_allclose(np.asarray(out), [0.0, 0.0, 1.5, -1.5], atol=1e-7)


# ---------------------------------------------------------------------------
# spectral_pointwise (CPADMM x-update in the Fourier domain)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("nf", [129, 512, 1025, 3])
def test_spectral_update(nf):
    keys = jax.random.split(jax.random.PRNGKey(0), 6)
    mk = lambda k: jax.lax.complex(
        jax.random.normal(k, (nf,)), jax.random.normal(jax.random.fold_in(k, 1), (nf,))
    )
    c, vm, zn = mk(keys[0]), mk(keys[1]), mk(keys[2])
    b = jax.random.uniform(keys[3], (nf,)) + 0.1
    rho, sigma = 0.7, 0.05
    got = spectral_update(c, b.astype(jnp.complex64), vm, zn, rho, sigma)
    want = cpadmm_spectral_update_ref(c, b, vm, zn, rho, sigma)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize(
    "nf",
    [
        129,  # n//2+1 for n = 256 (even n: Nyquist bin present)
        128,  # n//2+1 for n = 254
        64,   # n//2+1 for odd n = 127
        1025, # n//2+1 for n = 2048
        33,   # n//2+1 for odd n = 65
    ],
)
def test_spectral_update_half_spectrum_lengths(nf):
    """The kernel must handle every half-spectrum length the rfft paths
    produce: nf = n//2+1 for even and odd n (pad path exercised when nf is
    not a multiple of the block)."""
    keys = jax.random.split(jax.random.PRNGKey(2), 6)
    mk = lambda k: jax.lax.complex(
        jax.random.normal(k, (nf,)), jax.random.normal(jax.random.fold_in(k, 1), (nf,))
    )
    c, vm, zn = mk(keys[0]), mk(keys[1]), mk(keys[2])
    b = jax.random.uniform(keys[3], (nf,)) + 0.1
    got = spectral_update(c, b.astype(jnp.complex64), vm, zn, 0.3, 0.07)
    want = cpadmm_spectral_update_ref(c, b, vm, zn, 0.3, 0.07)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize("batch,nf", [(1, 129), (4, 65), (3, 513)])
def test_spectral_update_batched(batch, nf):
    """Leading batch axes (B signals, one operator) map to the outer grid;
    batch-of-1 equals the unbatched kernel."""
    keys = jax.random.split(jax.random.PRNGKey(3), 6)
    mk = lambda k, s: jax.lax.complex(
        jax.random.normal(k, s), jax.random.normal(jax.random.fold_in(k, 1), s)
    )
    c = mk(keys[0], (nf,))
    b = jax.random.uniform(keys[3], (nf,)) + 0.1
    vm, zn = mk(keys[1], (batch, nf)), mk(keys[2], (batch, nf))
    got = spectral_update(c, b.astype(jnp.complex64), vm, zn, 0.7, 0.05)
    want = cpadmm_spectral_update_ref(c, b, vm, zn, 0.7, 0.05)
    assert got.shape == (batch, nf)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    if batch == 1:
        single = spectral_update(c, b.astype(jnp.complex64), vm[0], zn[0], 0.7, 0.05)
        np.testing.assert_allclose(np.asarray(got[0]), np.asarray(single), atol=0)


@pytest.mark.parametrize("n", [254, 127, 65])  # odd n and non-block-aligned
def test_circulant_matvec_half_spectrum_ns(n):
    """Dispatcher FFT path (rfft/irfft round trip, nf = n//2+1) vs the dense
    oracle at the odd / non-128-multiple sizes the batched pipeline hits."""
    col = jax.random.normal(jax.random.PRNGKey(8), (n,))
    x = jax.random.normal(jax.random.PRNGKey(9), (n,))
    for transpose in (False, True):
        got = circulant_matvec(col, x, transpose=transpose)  # falls to FFT path
        want = circulant_matvec_ref(col, x, transpose=transpose)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), atol=_tol(want, 1e-4)
        )


def test_spectral_update_is_cpadmm_x_update():
    """End-to-end: irfft(kernel(rfft(...))) == the solver's x-update math."""
    from repro.core.admm import CpadmmParams, cpadmm_init, cpadmm_setup, cpadmm_step
    from repro.core.circulant import partial_gaussian_circulant

    n = 256
    op = partial_gaussian_circulant(jax.random.PRNGKey(0), n, n // 2, normalize=True)
    y = jax.random.normal(jax.random.PRNGKey(1), (n // 2,))
    p = CpadmmParams(*(jnp.asarray(v, jnp.float32) for v in (1e-4, 0.1, 0.1, 1.0, 1.0)))
    const = cpadmm_setup(op, y, p)
    s = cpadmm_init(op, y)
    # a couple of reference steps to get a nontrivial state
    for _ in range(3):
        s = cpadmm_step(op, const, s, p)
    # kernel-evaluated x-update
    vm = jnp.fft.rfft(s.v + s.mu)
    zn = jnp.fft.rfft(s.z - s.nu)
    xs = spectral_update(op.circ.spec, const.b_spec.astype(jnp.complex64), vm, zn, p.rho, p.sigma)
    x_kernel = jnp.fft.irfft(xs, n=n)
    s_next = cpadmm_step(op, const, s, p)
    np.testing.assert_allclose(np.asarray(x_kernel), np.asarray(s_next.x), atol=2e-5)


# ---------------------------------------------------------------------------
# cpadmm_tail (fused elementwise iteration tail: v-update + threshold + duals)
# ---------------------------------------------------------------------------


def _tail_case(sig_shape, batch, pty_batched, seed=0):
    keys = jax.random.split(jax.random.PRNGKey(seed), 6)
    bs = batch + sig_shape
    x = jax.random.normal(keys[0], bs)
    cx = jax.random.normal(keys[1], bs)
    mu = jax.random.normal(keys[2], bs)
    nu = jax.random.normal(keys[3], bs)
    d_diag = jax.random.uniform(keys[4], sig_shape) + 0.1
    pty = jax.random.normal(keys[5], bs if pty_batched else sig_shape)
    return x, cx, d_diag, pty, mu, nu


@pytest.mark.parametrize(
    "sig_shape,batch,pty_batched",
    [
        ((1024,), (), False),  # flat, block-aligned (single-device layout)
        ((1000,), (), False),  # pad path
        ((7,), (), False),  # tiny (whole vector smaller than a block)
        ((32, 16), (), False),  # (n1/p, n2) four-step block
        ((32, 15), (3,), False),  # batched signals, shared P^T y, odd cols
        ((32, 15), (3,), True),  # batched signals, per-signal P^T y
        ((16, 16), (2, 2), True),  # multi-dim leading batch
    ],
)
def test_fused_cpadmm_tail(sig_shape, batch, pty_batched):
    x, cx, d_diag, pty, mu, nu = _tail_case(sig_shape, batch, pty_batched)
    rho, gamma, tau1, tau2 = 0.7, 0.3, 1.0, 0.9
    got = fused_cpadmm_tail(x, cx, d_diag, pty, mu, nu, rho, gamma, tau1, tau2)
    want = cpadmm_tail_ref(x, cx, d_diag, pty, mu, nu, rho, gamma, tau1, tau2)
    for g, w, name in zip(got, want, ("v", "z", "mu", "nu")):
        assert g.shape == w.shape, (name, g.shape, w.shape)
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(w), atol=1e-6, err_msg=name
        )


def test_fused_cpadmm_tail_matches_solver_tail():
    """The kernel, its oracle, and core.admm.cpadmm_tail are the same math."""
    from repro.core.admm import CpadmmParams, cpadmm_tail

    x, cx, d_diag, pty, mu, nu = _tail_case((512,), (), False, seed=5)
    p = CpadmmParams(*(jnp.asarray(v, jnp.float32) for v in (0.02, 0.5, 0.1, 1.0, 0.8)))
    want = cpadmm_tail(x, cx, d_diag, pty, mu, nu, p)
    got = fused_cpadmm_tail(
        x, cx, d_diag, pty, mu, nu, p.rho, p.alpha / p.sigma, p.tau1, p.tau2
    )
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), atol=1e-6)


def test_fused_cpadmm_tail_thresholds():
    """gamma large enough must zero z and leave nu' = nu + tau2 * x."""
    n = 8
    x = jnp.asarray([0.4, -0.4, 2.0, -2.0, 0.0, 1.0, -1.0, 0.1])
    zeros = jnp.zeros((n,))
    d = jnp.ones((n,))
    v, z, mu, nu = fused_cpadmm_tail(x, zeros, d, zeros, zeros, zeros, 0.5, 5.0, 1.0, 1.0)
    np.testing.assert_allclose(np.asarray(z), np.zeros(n), atol=1e-7)
    np.testing.assert_allclose(np.asarray(nu), np.asarray(x), atol=1e-7)


# ---------------------------------------------------------------------------
# banded_conv (Sec. 7 blur stencil)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,order", [(1024, 5), (2048, 3), (4096, 17), (1000, 5)])
def test_banded_conv(n, order):
    taps = jax.random.normal(jax.random.PRNGKey(0), (order,))
    x = jax.random.normal(jax.random.PRNGKey(1), (n,))
    got = blur_apply(taps, x, order=order)
    want = banded_circulant_matvec_ref(taps, x, order=order)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_banded_conv_matches_full_circulant():
    """Order-L taps == full circulant with zero-padded first row."""
    from repro.core.circulant import moving_average_blur

    n, order = 1024, 5
    B = moving_average_blur(n, order)
    x = jax.random.normal(jax.random.PRNGKey(2), (n,))
    got = blur_apply(jnp.full((order,), 1.0 / order), x, order=order)
    np.testing.assert_allclose(np.asarray(got), np.asarray(B.matvec(x)), atol=1e-5)


if HAVE_HYPOTHESIS:

    @hypothesis.given(
        nblk=st.integers(1, 4), order=st.integers(1, 32), seed=st.integers(0, 2**16)
    )
    @hypothesis.settings(**SETTINGS)
    def test_banded_conv_property(nblk, order, seed):
        n = nblk * 1024
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        taps = jax.random.normal(k1, (order,))
        x = jax.random.normal(k2, (n,))
        got = blur_apply(taps, x, order=order)
        want = banded_circulant_matvec_ref(taps, x, order=order)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4 * order)

else:

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_banded_conv_property():
        pass

"""The pluggable prox layer (repro.ops.prox): properties, pins, threading.

Three layers of contract, each pinned:

  * operator properties — every prox is (firmly) non-expansive, batched
    application equals the per-signal loop, TV/wavelet have the right fixed
    points and adjoints;
  * bit-exactness — ``L1Prox`` is the paper's soft threshold *bitwise*, and
    threading ``prox=None`` / ``prox=L1Prox()`` through every solver,
    compressor and plan entry point reproduces the pre-refactor iterates
    bit-for-bit (the fused Pallas tails stay eligible);
  * plan/serve integration — ``PlanConfig`` validates/serializes/describes
    the prox, planned solves match core ones per prior, and serve buckets
    keyed by distinct ``prox=`` tags never share an engine.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import RecoveryProblem, partial_gaussian_circulant, solve, soft_threshold
from repro.core.compression import decode, make_compressor
from repro.core.solvers import make_stepper
from repro.data.synthetic import paper_regime, sparse_signal
from repro.ops import PlanConfig, plan
from repro.ops.prox import (
    PROX_KINDS,
    L1Prox,
    NonNegL1Prox,
    TVProx,
    WaveletProx,
    is_elementwise,
    is_l1,
    prox_from_dict,
    prox_to_dict,
)

SOLVE_KW = dict(alpha=1e-3, rho=0.01, sigma=0.01)
METHODS = ("ista", "fista", "cpadmm")

ALL_PROXES = [
    L1Prox(),
    NonNegL1Prox(),
    TVProx(shape=(8, 8)),
    WaveletProx(levels=2, wavelet="haar"),
    WaveletProx(levels=1, wavelet="db4"),
]


def _ids(proxes):
    return [p.tag for p in proxes]


def _rel(got, want):
    got, want = jnp.asarray(got), jnp.asarray(want)
    return float(jnp.linalg.norm(got - want) / (jnp.linalg.norm(want) + 1e-30))


def _problem(n=256, batch=2, seed=0):
    m, k = paper_regime(n)
    x_true = sparse_signal(jax.random.PRNGKey(seed), n, k, batch=(batch,))
    op = partial_gaussian_circulant(jax.random.PRNGKey(seed + 1), n, m,
                                    normalize=True)
    return RecoveryProblem(op=op, y=op.matvec(x_true), x_true=x_true)


# -- operator properties ----------------------------------------------------


@pytest.mark.parametrize("prox", ALL_PROXES, ids=_ids(ALL_PROXES))
def test_prox_nonexpansive(prox):
    """||prox(x) - prox(y)|| <= ||x - y|| — definitional for a prox of a
    convex function; a broken inner loop (TV) or non-orthonormal filter bank
    (wavelet) violates it."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    for gamma in (0.01, 0.3):
        x = jax.random.normal(k1, (64,))
        y = jax.random.normal(k2, (64,))
        lhs = float(jnp.linalg.norm(prox.apply(x, gamma) - prox.apply(y, gamma)))
        rhs = float(jnp.linalg.norm(x - y))
        assert lhs <= rhs * (1 + 1e-5), (prox.tag, gamma)


@pytest.mark.parametrize("prox", ALL_PROXES, ids=_ids(ALL_PROXES))
def test_prox_batched_equals_loop(prox):
    """Batch axes broadcast: prox of a (B, n) stack == stacking per-signal
    applications (the solver batching contract)."""
    x = jax.random.normal(jax.random.PRNGKey(5), (3, 64))
    got = prox.apply(x, 0.1)
    want = jnp.stack([prox.apply(x[i], 0.1) for i in range(3)])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_l1_prox_is_soft_threshold_bitwise():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 128)) * 2.0
    for gamma in (0.0, 0.05, 1.5):
        got = L1Prox().apply(x, gamma)
        want = soft_threshold(x, gamma)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_nonneg_l1_prox():
    x = jnp.array([-1.0, -0.05, 0.05, 1.0])
    got = np.asarray(NonNegL1Prox().apply(x, 0.1))
    np.testing.assert_allclose(got, [0.0, 0.0, 0.0, 0.9], atol=1e-7)
    assert (got >= 0).all()


def test_tv_prox_constant_fixed_point():
    """A constant image has zero TV: the prox must return it unchanged."""
    x = jnp.full((64,), 0.7)
    got = TVProx(shape=(8, 8)).apply(x, 0.5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x), atol=1e-6)


def test_tv_prox_reduces_tv_norm():
    prox = TVProx(shape=(8, 8), iters=20)
    x = jax.random.normal(jax.random.PRNGKey(1), (64,))

    def tv(v):
        img = v.reshape(8, 8)
        return float(
            jnp.abs(jnp.roll(img, -1, 0) - img).sum()
            + jnp.abs(jnp.roll(img, -1, 1) - img).sum()
        )

    z = prox.apply(x, 0.2)
    assert tv(z) < tv(x)


def test_tv_analysis_adjoint():
    """<D x, p> == <x, D^T p> — the dual inner loop silently diverges if
    the roll-based adjoint pair drifts."""
    prox = TVProx(shape=(8, 8))
    kx, kp = jax.random.split(jax.random.PRNGKey(2))
    x = jax.random.normal(kx, (64,))
    p = jax.random.normal(kp, (128,))
    lhs = float(jnp.vdot(prox.analysis_op(x), p))
    rhs = float(jnp.vdot(x, prox.analysis_rmatvec(p)))
    assert lhs == pytest.approx(rhs, rel=1e-5)


@pytest.mark.parametrize("wavelet", ["haar", "db4"])
def test_wavelet_prox_perfect_reconstruction(wavelet):
    """gamma=0 thresholds nothing: W^T W x == x (orthonormal filter bank)."""
    prox = WaveletProx(levels=2, wavelet=wavelet)
    x = jax.random.normal(jax.random.PRNGKey(4), (64,))
    np.testing.assert_allclose(
        np.asarray(prox.apply(x, 0.0)), np.asarray(x), atol=2e-6
    )
    # analysis is orthonormal: energy preserved
    c = prox.analysis_op(x)
    assert float(jnp.vdot(c, c)) == pytest.approx(float(jnp.vdot(x, x)), rel=1e-5)
    np.testing.assert_allclose(
        np.asarray(prox.analysis_rmatvec(c)), np.asarray(x), atol=2e-6
    )


def test_prox_validation_errors():
    with pytest.raises(ValueError, match="shape"):
        TVProx(shape=(0, 8))
    with pytest.raises(ValueError, match="iters"):
        TVProx(shape=(8, 8), iters=0)
    with pytest.raises(ValueError, match="wavelet"):
        WaveletProx(wavelet="sym9")
    with pytest.raises(ValueError, match="levels"):
        WaveletProx(levels=0)
    # trailing-dim mismatch is loud, not a silent reshape
    with pytest.raises(ValueError):
        TVProx(shape=(8, 8)).apply(jnp.zeros(63), 0.1)
    with pytest.raises(ValueError):
        WaveletProx(levels=3).apply(jnp.zeros(12), 0.1)


# -- registry + serialization ----------------------------------------------


def test_prox_serialization_round_trip():
    for prox in ALL_PROXES:
        d = prox_to_dict(prox)
        json.dumps(d)  # JSON-safe (the tune cache stores pins this way)
        back = prox_from_dict(d)
        assert back == prox and type(back) is type(prox)
    assert prox_to_dict(None) is None and prox_from_dict(None) is None
    assert set(PROX_KINDS) == {"l1", "nonneg-l1", "tv", "wavelet"}
    with pytest.raises(ValueError, match="kind"):
        prox_from_dict({"kind": "nope"})


def test_prox_helpers_and_hashability():
    assert is_l1(None) and is_l1(L1Prox())
    assert not is_l1(TVProx(shape=(4, 4))) and not is_l1(NonNegL1Prox())
    assert is_elementwise(None) and is_elementwise(NonNegL1Prox())
    assert not is_elementwise(TVProx(shape=(4, 4)))
    assert not is_elementwise(WaveletProx())
    # frozen dataclasses: usable as jit static args / dict keys
    assert len({L1Prox(), L1Prox(), TVProx(shape=(4, 4))}) == 2


# -- solver threading: bit-exactness + composability ------------------------


@pytest.mark.parametrize("method", METHODS)
def test_solver_none_vs_l1prox_bitwise(method):
    """The refactor's central pin: prox=None (pre-refactor expressions,
    verbatim) and prox=L1Prox() produce bit-identical iterates."""
    prob = _problem()
    x0, _ = solve(prob, method, iters=40, record_every=40, plan=plan(prob.op),
                  **SOLVE_KW)
    x1, _ = solve(prob, method, iters=40, record_every=40,
                  plan=plan(prob.op, prox=L1Prox()), **SOLVE_KW)
    np.testing.assert_array_equal(np.asarray(x0), np.asarray(x1))


def test_cpadmm_pallas_tail_l1_only():
    """tail='pallas' stays on the fused kernel for the l1 prior (bit-exact
    vs the jnp tail in interpret mode) and silently composes the jnp tail
    for a non-l1 prox instead of crashing the fused kernel."""
    prob = _problem(batch=1)
    prob = RecoveryProblem(op=prob.op, y=prob.y[0], x_true=prob.x_true[0])
    pl_jnp = plan(prob.op, tail="jnp")
    pl_pal = plan(prob.op, tail="pallas")
    x_j, _ = solve(prob, "cpadmm", iters=20, record_every=20, plan=pl_jnp,
                   **SOLVE_KW)
    x_p, _ = solve(prob, "cpadmm", iters=20, record_every=20, plan=pl_pal,
                   **SOLVE_KW)
    assert _rel(x_p, x_j) < 1e-6
    # non-l1 prox through the pallas-tagged plan: composable fallback
    prox = NonNegL1Prox()
    x_f, _ = solve(prob, "cpadmm", iters=20, record_every=20, plan=pl_pal,
                   prox=prox, **SOLVE_KW)
    x_r, _ = solve(prob, "cpadmm", iters=20, record_every=20, plan=pl_jnp,
                   prox=prox, **SOLVE_KW)
    np.testing.assert_array_equal(np.asarray(x_f), np.asarray(x_r))
    assert float(x_f.min()) >= 0.0


@pytest.mark.parametrize(
    "prox",
    [NonNegL1Prox(), TVProx(shape=(16, 16)), WaveletProx()],
    ids=["nonneg-l1", "tv", "wavelet"],
)
@pytest.mark.parametrize("method", METHODS)
def test_solver_non_l1_proxes_run(method, prox):
    prob = _problem()
    x, _ = solve(prob, method, iters=40, record_every=40,
                 plan=plan(prob.op, prox=prox), **SOLVE_KW)
    assert x.shape == prob.x_true.shape
    assert bool(jnp.all(jnp.isfinite(x)))
    # the prior actually engaged: result differs from the l1 solve
    x_l1, _ = solve(prob, method, iters=40, record_every=40,
                    plan=plan(prob.op), **SOLVE_KW)
    assert not jnp.array_equal(x, x_l1)


def test_make_stepper_prox_defaults_to_plan():
    """make_stepper(prob, m, plan=pl) picks up pl.prox; an explicit prox=
    argument overrides it."""
    prob = _problem()
    pl = plan(prob.op, prox=NonNegL1Prox())
    st = make_stepper(prob, "cpadmm", plan=pl, **SOLVE_KW)
    s = st.init()
    for _ in range(10):
        s = st.step(s)
    assert float(st.extract(s).min()) >= 0.0  # nonneg prox engaged
    st2 = make_stepper(prob, "cpadmm", plan=pl, prox=L1Prox(), **SOLVE_KW)
    st3 = make_stepper(prob, "cpadmm", plan=plan(prob.op), **SOLVE_KW)
    s2, s3 = st2.init(), st3.init()
    for _ in range(10):
        s2, s3 = st2.step(s2), st3.step(s3)
    np.testing.assert_array_equal(
        np.asarray(st2.extract(s2)), np.asarray(st3.extract(s3))
    )


# -- compression satellite --------------------------------------------------


def test_compression_decode_l1_bitwise():
    """The compressor's decode routes through the prox layer; the default
    spec (prox=None) must be bit-identical to an explicit L1Prox spec."""
    spec0, state = make_compressor(jax.random.PRNGKey(0), 200, ratio=4)
    spec1, _ = make_compressor(jax.random.PRNGKey(0), 200, ratio=4,
                               prox=L1Prox())
    assert spec0.prox is None and isinstance(spec1.prox, L1Prox)
    g = sparse_signal(jax.random.PRNGKey(2), spec0.n, 12)
    y = jnp.take(
        jnp.fft.irfft(
            jnp.fft.rfft(state.col) * jnp.fft.rfft(g), n=spec0.n
        ).astype(jnp.float32),
        state.omega,
    )
    np.testing.assert_array_equal(
        np.asarray(decode(spec0, state, y)), np.asarray(decode(spec1, state, y))
    )


def test_compression_decode_nonneg_prox():
    spec, state = make_compressor(jax.random.PRNGKey(1), 200, ratio=4,
                                  prox=NonNegL1Prox())
    y = jax.random.normal(jax.random.PRNGKey(3), (spec.m,))
    x = decode(spec, state, y)
    assert float(x.min()) >= 0.0


# -- plan layer: config, parity, serve buckets ------------------------------


def test_plan_config_prox_validation_and_describe():
    cfg = PlanConfig(prox=TVProx(shape=(8, 8), iters=5))
    cfg.validate(distributed=False)
    assert "prox=tv[8x8,it5]" in cfg.describe()
    assert "prox=" not in PlanConfig().describe()  # default stays tagless
    with pytest.raises(ValueError, match="prox"):
        PlanConfig(prox="tv").validate(distributed=False)
    back = PlanConfig.from_dict(cfg.to_dict())
    assert back.prox == cfg.prox
    json.dumps(cfg.to_dict())


@pytest.mark.parametrize(
    "prox",
    [None, L1Prox(), NonNegL1Prox(), TVProx(shape=(16, 16)), WaveletProx()],
    ids=["none", "l1", "nonneg-l1", "tv", "wavelet"],
)
@pytest.mark.parametrize("method", ("ista", "cpadmm"))
def test_planned_mesh_matches_local_per_prior(method, prox):
    """Distributed (1-device mesh: same collectives code, cheap in CI) ==
    local at 1e-5 rel for every prior; the 8-device variant rides
    tests/dist_progs/prox_prog.py."""
    from repro.dist.compat import make_mesh

    prob = _problem()
    pl_l = plan(prob.op, prox=prox)
    pl_d = plan(prob.op, make_mesh((1,), ("model",)), prox=prox)
    x_l, _ = solve(prob, method, iters=30, record_every=30, plan=pl_l,
                   **SOLVE_KW)
    x_d, _ = solve(prob, method, iters=30, record_every=30, plan=pl_d,
                   **SOLVE_KW)
    assert _rel(x_d, x_l) <= 1e-5, (method, prox and prox.tag)


def test_planned_mesh_none_vs_l1_bitwise():
    """On the mesh path too, None and L1Prox() share the fused lowering."""
    from repro.dist.compat import make_mesh

    prob = _problem()
    mesh = make_mesh((1,), ("model",))
    for method in ("ista", "cpadmm"):
        x0, _ = solve(prob, method, iters=30, record_every=30,
                      plan=plan(prob.op, mesh), **SOLVE_KW)
        x1, _ = solve(prob, method, iters=30, record_every=30,
                      plan=plan(prob.op, mesh, prox=L1Prox()), **SOLVE_KW)
        np.testing.assert_array_equal(np.asarray(x0), np.asarray(x1))


def test_tuner_candidates_carry_prox_pin():
    from repro.dist.compat import make_mesh
    from repro.ops.tune import cache_key, candidate_configs

    mesh = make_mesh((1,), ("model",))
    op = _problem().op
    prox = TVProx(shape=(16, 16))
    cands = candidate_configs(op, mesh, pins={"prox": prox})
    assert cands and all(c.prox == prox for c in cands)
    # distinct prox pins key distinct cache entries
    k_tv = cache_key(op, mesh, 2, {"prox": prox})
    k_l1 = cache_key(op, mesh, 2, {"prox": L1Prox()})
    k_none = cache_key(op, mesh, 2, {})
    assert len({k_tv, k_l1, k_none}) == 3


def test_serve_buckets_split_on_prox():
    """Requests differing only in the plan config's prox never share an
    engine (ISSUE acceptance: distinct prox= tags, distinct buckets)."""
    from repro.serve import RecoveryRequest, RecoveryServer

    op = _problem().op
    y = jnp.zeros((op.m,), jnp.float32)
    server = RecoveryServer(slots=2)

    def req(rid, cfg):
        return RecoveryRequest(request_id=rid, op=op, y=y, plan_config=cfg)

    k_l1 = server.bucket_key(req("a", PlanConfig()))
    k_tv = server.bucket_key(req("b", PlanConfig(prox=TVProx(shape=(16, 16)))))
    k_wv = server.bucket_key(req("c", PlanConfig(prox=WaveletProx())))
    assert len({k_l1, k_tv, k_wv}) == 3

"""The roofline depends on the HLO walker being right — pin it to closed forms."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import analyze_hlo


def _hlo(fn, *specs):
    return jax.jit(fn).lower(*specs).compile().as_text()


S = jax.ShapeDtypeStruct


def test_single_matmul_flops_exact():
    hlo = _hlo(lambda a, b: a @ b, S((128, 64), jnp.float32), S((64, 32), jnp.float32))
    c = analyze_hlo(hlo)
    assert c.flops == pytest.approx(2 * 128 * 64 * 32, rel=0.05)


def test_matmul_bytes_reasonable():
    hlo = _hlo(lambda a, b: a @ b, S((128, 128), jnp.float32), S((128, 128), jnp.float32))
    c = analyze_hlo(hlo)
    ideal = 3 * 128 * 128 * 4
    assert ideal * 0.9 <= c.bytes <= ideal * 3


def test_scan_trip_count_applied():
    def scanned(x, ws):
        def body(c, w):
            return jnp.dot(c, w), None

        return jax.lax.scan(body, x, ws)[0]

    hlo = _hlo(scanned, S((64, 64), jnp.float32), S((12, 64, 64), jnp.float32))
    c = analyze_hlo(hlo)
    assert c.flops == pytest.approx(12 * 2 * 64**3, rel=0.1)


def test_nested_scan_trip_counts_multiply():
    def nested(x, ws):
        def outer(c, _):
            def inner(ci, w):
                return jnp.dot(ci, w), None

            return jax.lax.scan(inner, c, ws)[0], None

        return jax.lax.scan(outer, x, None, length=3)[0]

    hlo = _hlo(nested, S((64, 64), jnp.float32), S((5, 64, 64), jnp.float32))
    c = analyze_hlo(hlo)
    assert c.flops == pytest.approx(15 * 2 * 64**3, rel=0.1)


def test_fft_flops_5nlogn():
    import math

    hlo = _hlo(lambda v: jnp.fft.fft(v), S((8192,), jnp.complex64))
    c = analyze_hlo(hlo)
    assert c.flops == pytest.approx(5 * 8192 * math.log2(8192), rel=0.2)


def test_slice_does_not_charge_source():
    """Slicing 1 row from a big matrix must cost ~row bytes, not matrix bytes."""

    def f(a, i):
        return jax.lax.dynamic_slice_in_dim(a, i, 1, axis=0) * 2.0

    hlo = _hlo(f, S((4096, 4096), jnp.float32), S((), jnp.int32))
    c = analyze_hlo(hlo)
    assert c.bytes < 4096 * 4096 * 4 * 0.1  # far below the full matrix

"""Absorbed-MLA decode (§Perf hillclimb) must match the naive path exactly."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import smoke_config
from repro.models import attention as A
from repro.models import lm, steps


def test_absorbed_matches_naive_unit():
    cfg = smoke_config("deepseek_v3_671b")
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = A.init_mla(jax.random.PRNGKey(0), cfg, jnp.float32)
    b = 2
    x = jax.random.normal(jax.random.PRNGKey(1), (b, 10, cfg.d_model)) * 0.3
    c1 = A.init_mla_cache(cfg, b, 16, jnp.float32)
    c2 = A.init_mla_cache(cfg, b, 16, jnp.float32)
    for t in range(10):
        y1, c1 = A.mla_decode(params, cfg, x[:, t : t + 1], c1)
        y2, c2 = A.mla_decode_absorbed(params, cfg, x[:, t : t + 1], c2)
        np.testing.assert_allclose(
            np.asarray(y2), np.asarray(y1), atol=3e-4, err_msg=f"step {t}"
        )
    np.testing.assert_allclose(np.asarray(c2.c_kv), np.asarray(c1.c_kv), atol=1e-5)


def test_absorbed_full_model_decode():
    """End-to-end deepseek-smoke decode with cfg.mla_absorbed=True is finite
    and consistent with the naive configuration."""
    base = dataclasses.replace(smoke_config("deepseek_v3_671b"), dtype="float32")
    params = lm.init_params(jax.random.PRNGKey(0), base)
    tok = jnp.zeros((2, 1), jnp.int32)

    outs = {}
    for absorbed in (False, True):
        cfg = dataclasses.replace(base, mla_absorbed=absorbed)
        state = lm.init_decode_state(cfg, 2, max_len=8)
        decode = jax.jit(steps.make_decode_step(cfg))
        logits = None
        st = state
        for _ in range(3):
            logits, st = decode(params, tok, st)
        outs[absorbed] = np.asarray(logits)
        assert np.isfinite(outs[absorbed]).all()
    np.testing.assert_allclose(outs[True], outs[False], atol=5e-3)

"""Wire pack/unpack kernels: round-trip properties + substrate parity.

The wire_pack triple (repro.kernels.wire_pack) is the demote/promote pair
every wire-compressed transpose collective fuses around (dist/fft).  These
tests pin:

  * shape/layout contract: pack adds exactly one leading (re, im) plane
    axis, unpack removes it, for odd/even n1 x n2 blocks, batched and
    unbatched, and rfft half-spectrum column counts;
  * round-trip accuracy per wire dtype (bit-exact at fp32, bounded
    relative error at bf16/fp16);
  * jnp-vs-pallas(interpret) substrate parity — the Pallas kernels must be
    drop-in for the pure-jnp path XLA fuses on CPU.
"""

import pytest

try:  # optional dev dep; CI installs it — only the property tests need it
    import hypothesis
    import hypothesis.strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False
import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.wire_pack.kernel import pack_wire_pallas, unpack_wire_pallas
from repro.kernels.wire_pack.ops import (
    WIRE_DTYPES,
    pack_wire,
    unpack_wire,
    wire_itemsize,
)
from repro.kernels.wire_pack.ref import pack_wire_ref, unpack_wire_ref

SETTINGS = dict(max_examples=20, deadline=None)

# measured worst-case relative round-trip error per wire dtype, with margin:
# bf16 keeps 8 mantissa bits (~2^-8 relative), fp16 11 (~2^-11)
ROUNDTRIP_RTOL = {"fp32": 0.0, "bf16": 2 ** -7, "fp16": 2 ** -10}


def _complex_block(seed, shape):
    kr, ki = jax.random.split(jax.random.PRNGKey(seed))
    return jax.lax.complex(
        jax.random.normal(kr, shape), jax.random.normal(ki, shape)
    ).astype(jnp.complex64)


@pytest.mark.parametrize("wire", sorted(WIRE_DTYPES))
@pytest.mark.parametrize(
    "shape",
    [
        (8, 8),  # even x even
        (7, 9),  # odd x odd
        (6, 5),  # even x odd (rfft-ish half-spectrum column count)
        (3, 16, 33),  # batched, half-spectrum columns (n2=64 -> nf=33)
        (64,),  # flat
    ],
)
def test_roundtrip_shapes_and_accuracy(wire, shape):
    z = _complex_block(0, shape)
    w = pack_wire(z, wire, substrate="jnp")
    assert w.shape == (2,) + shape
    assert w.dtype == WIRE_DTYPES[wire]
    assert jnp.dtype(w.dtype).itemsize == wire_itemsize(wire)
    back = unpack_wire(w, z.dtype, substrate="jnp")
    assert back.shape == z.shape and back.dtype == z.dtype
    if wire == "fp32":
        assert bool(jnp.all(back == z))
    else:
        rel = float(jnp.linalg.norm(back - z) / jnp.linalg.norm(z))
        assert rel <= ROUNDTRIP_RTOL[wire], (wire, rel)


@pytest.mark.parametrize("wire", sorted(WIRE_DTYPES))
@pytest.mark.parametrize("L", [1, 17, 1024, 1025, 4096])
def test_pallas_matches_jnp(wire, L):
    """The Pallas kernels (interpret mode on CPU) are bit-identical to the
    jnp oracle — same casts, fused tiling only."""
    z = _complex_block(1, (L,))
    wj = pack_wire(z, wire, substrate="jnp")
    wp = pack_wire(z, wire, substrate="pallas", interpret=True)
    assert wp.shape == wj.shape and wp.dtype == wj.dtype
    np.testing.assert_array_equal(np.asarray(wp), np.asarray(wj))
    bj = unpack_wire(wj, z.dtype, substrate="jnp")
    bp = unpack_wire(wp, z.dtype, substrate="pallas", interpret=True)
    np.testing.assert_array_equal(np.asarray(bp), np.asarray(bj))


def test_pallas_batched_block_shapes():
    """Rank > 1 payloads flatten through the 1-D kernels and come back in
    the original layout."""
    z = _complex_block(2, (3, 7, 9))
    wp = pack_wire(z, "bf16", substrate="pallas", interpret=True)
    assert wp.shape == (2, 3, 7, 9)
    bp = unpack_wire(wp, z.dtype, substrate="pallas", interpret=True)
    wj = pack_wire(z, "bf16", substrate="jnp")
    np.testing.assert_array_equal(np.asarray(wp), np.asarray(wj))
    np.testing.assert_array_equal(
        np.asarray(bp), np.asarray(unpack_wire(wj, z.dtype, substrate="jnp"))
    )


def test_kernel_entry_points_direct():
    """The raw kernel wrappers (pre shape plumbing) honor padding: non-block
    multiples round-trip unchanged."""
    L = 1500  # not a multiple of DEFAULT_BLOCK=1024
    re = jax.random.normal(jax.random.PRNGKey(3), (L,))
    im = jax.random.normal(jax.random.PRNGKey(4), (L,))
    w = pack_wire_pallas(re, im, wire_dtype=jnp.bfloat16, interpret=True)
    assert w.shape == (2, L) and w.dtype == jnp.bfloat16
    r2, i2 = unpack_wire_pallas(w, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(r2), np.asarray(re.astype(jnp.bfloat16).astype(jnp.float32))
    )
    np.testing.assert_array_equal(
        np.asarray(i2), np.asarray(im.astype(jnp.bfloat16).astype(jnp.float32))
    )


def test_bad_substrate_rejected():
    z = _complex_block(5, (8,))
    with pytest.raises(ValueError, match="substrate"):
        pack_wire(z, "bf16", substrate="cuda")


def test_fp16_saturation_is_visible():
    """fp16's 65504 max turns large payloads non-finite — the property the
    plan layer's precision guard relies on to demote fp16 plans."""
    z = (jnp.ones((8,)) * 1e6).astype(jnp.complex64)
    back = unpack_wire(pack_wire(z, "fp16", substrate="jnp"), substrate="jnp")
    assert bool(jnp.all(jnp.isinf(jnp.real(back))))
    bf = unpack_wire(pack_wire(z, "bf16", substrate="jnp"), substrate="jnp")
    assert bool(jnp.all(jnp.isfinite(jnp.real(bf))))  # bf16 keeps fp32 range


if HAVE_HYPOTHESIS:

    @hypothesis.given(
        n1=st.integers(1, 12),
        n2=st.integers(1, 40),
        batched=st.booleans(),
        wire=st.sampled_from(sorted(WIRE_DTYPES)),
        seed=st.integers(0, 2 ** 16),
    )
    @hypothesis.settings(**SETTINGS)
    def test_roundtrip_property(n1, n2, batched, wire, seed):
        shape = (2, n1, n2) if batched else (n1, n2)
        z = _complex_block(seed, shape)
        for substrate in ("jnp", "pallas"):
            w = pack_wire(z, wire, substrate=substrate, interpret=True)
            assert w.shape == (2,) + shape
            back = unpack_wire(w, z.dtype, substrate=substrate, interpret=True)
            if wire == "fp32":
                assert bool(jnp.all(back == z))
            else:
                nz = float(jnp.linalg.norm(z))
                rel = float(jnp.linalg.norm(back - z)) / max(nz, 1e-30)
                assert rel <= ROUNDTRIP_RTOL[wire], (wire, rel)

else:  # keep the absence visible as a skip, not a silent non-collection

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_roundtrip_property():
        pass

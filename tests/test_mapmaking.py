"""Herschel-style multi-observation map-making (repro.core.mapmaking).

The prox layer's flagship non-l1 scenario: dithered exposures through one
shared compressed optic recover jointly under the TV prior and co-add into
one map.  Pins: the factored per-frame operator view matches the shared-op
view, the planned path matches local at 1e-5, and the recovered map's PSNR
is golden-pinned — with the TV-vs-l1 gap asserted so the prior is shown to
be load-bearing, not decorative.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mapmaking import (
    build_mapmaking_plan,
    build_mapmaking_problem,
    coadd,
    frame_operator,
    mapmaking_metrics,
    solve_mapmaking,
)
from repro.data.synthetic import extended_emission

SIZE = 16
SHIFTS = [0, 1, SIZE, SIZE + 1]  # 2x2 dither pattern on the raster


@pytest.fixture(scope="module")
def problem():
    sky = extended_emission(jax.random.PRNGKey(7), SIZE, SIZE, n_sources=3)
    return build_mapmaking_problem(
        jax.random.PRNGKey(11), sky, SHIFTS, blur_order=1.0, subsample=0.5,
        sensing="romberg", blur_kind="gaussian",
    )


def test_build_validation():
    with pytest.raises(ValueError, match="sky map"):
        build_mapmaking_problem(jax.random.PRNGKey(0), jnp.zeros((2, 8, 8)), [0])
    with pytest.raises(ValueError, match="offset"):
        build_mapmaking_problem(jax.random.PRNGKey(0), jnp.zeros((8, 8)), [])


def test_frames_are_shifted_skies(problem):
    flat = problem.sky.reshape(-1)
    for f, s in enumerate(problem.shifts):
        np.testing.assert_array_equal(
            np.asarray(problem.deblur.image[f].reshape(-1)),
            np.asarray(jnp.roll(flat, s)),
        )
    assert problem.deblur.y.shape == (len(SHIFTS), problem.deblur.op.m)


def test_frame_operator_factored_view(problem):
    """A_f = P (C B S_f) composed via shift circulants equals the shared
    operator applied to the shifted sky — the identity that lets the whole
    stack share one planned operator."""
    flat = problem.sky.reshape(-1)
    for f, s in enumerate(problem.shifts):
        a = frame_operator(problem, f).matvec(flat)
        b = problem.deblur.op.matvec(jnp.roll(flat, s))
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
        np.testing.assert_allclose(np.asarray(problem.deblur.y[f]),
                                   np.asarray(b), atol=1e-5)


def test_coadd_unshifts_and_averages(problem):
    """co-adding the *true* shifted stack returns the sky exactly (the
    unshift must invert the raster roll, including wrap)."""
    n = SIZE * SIZE
    z_true = problem.deblur.image.reshape(len(SHIFTS), n)
    np.testing.assert_allclose(
        np.asarray(coadd(problem, z_true)), np.asarray(problem.sky), atol=1e-6
    )
    m = mapmaking_metrics(problem, z_true)
    assert float(m["psnr_db"]) > 100.0
    # batch axes broadcast through coadd
    z_b = jnp.stack([z_true, z_true])
    assert coadd(problem, z_b).shape == (2, SIZE, SIZE)


def test_default_plan_is_tv(problem):
    pl = build_mapmaking_plan(problem)
    assert "prox=tv[16x16" in pl.config.describe()
    pl_l1 = build_mapmaking_plan(problem, prox=None)
    assert "prox=" not in pl_l1.config.describe()


def test_mapmaking_golden_psnr(problem):
    """Golden pin (sky key 7, problem key 11, 600 CPADMM iterations,
    alpha=1e-4): TV map PSNR recorded 47.8 dB vs l1 20.8 dB.  The band is
    wide enough for cross-platform float drift, two-sided so suspicious
    improvements get a human look, and the TV-over-l1 gap is the point."""
    z_tv, m_tv = solve_mapmaking(problem, method="cpadmm", iters=600,
                                 alpha=1e-4)
    psnr_tv = float(m_tv["psnr_db"])
    assert 44.0 < psnr_tv < 52.0, psnr_tv
    pl_l1 = build_mapmaking_plan(problem, prox=None)
    _, m_l1 = solve_mapmaking(problem, plan=pl_l1, method="cpadmm",
                              iters=600, alpha=1e-4)
    psnr_l1 = float(m_l1["psnr_db"])
    assert psnr_tv > psnr_l1 + 15.0, (psnr_tv, psnr_l1)


def test_mapmaking_planned_matches_local(problem):
    """The acceptance scenario: the TV-prior stack through the planned path
    (1-device mesh; the 8-device variant rides dist_progs/prox_prog.py)
    matches the local solve at 1e-5 and holds the golden PSNR."""
    from repro.dist.compat import make_mesh

    z_l, m_l = solve_mapmaking(problem, method="cpadmm", iters=600,
                               alpha=1e-4)
    pl = build_mapmaking_plan(problem, make_mesh((1,), ("model",)), rfft=True)
    z_d, m_d = solve_mapmaking(problem, plan=pl, method="cpadmm", iters=600,
                               alpha=1e-4)
    rel = float(jnp.linalg.norm(z_d - z_l) / (jnp.linalg.norm(z_l) + 1e-30))
    assert rel <= 1e-5, rel
    assert 44.0 < float(m_d["psnr_db"]) < 52.0


def test_extended_emission_statistics():
    sky = extended_emission(jax.random.PRNGKey(7), 32, 32, n_sources=3)
    assert float(sky.min()) > 0.0 and float(sky.max()) <= 1.0
    # gradient-sparse, not value-sparse: almost no zero pixels, few edges
    img = sky
    edges = (jnp.abs(jnp.roll(img, -1, 0) - img) > 1e-6).mean()
    assert float(edges) < 0.5
    assert float((sky > 0).mean()) == 1.0

"""Checkpoint/fault-tolerance unit tests (mesh-elastic path is covered by
tests/dist_progs/train_prog.py)."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ckpt


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, (4, 3)),
        "nested": {"b": jnp.arange(5), "c": [jnp.ones(2), jnp.zeros((2, 2))]},
    }


def test_save_restore_roundtrip(tmp_path):
    tree = _tree()
    ckpt.save(str(tmp_path), 7, tree)
    step, restored = ckpt.restore(str(tmp_path), None, jax.eval_shape(lambda: tree))
    assert step == 7
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        tree,
        restored,
    )


def test_latest_and_retention(tmp_path):
    tree = _tree()
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, tree, keep=3)
    assert ckpt.latest_step(str(tmp_path)) == 5
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(kept) == 3  # pruned to the newest 3


def test_corruption_detected(tmp_path):
    tree = _tree()
    path = ckpt.save(str(tmp_path), 1, tree)
    # flip bytes in the arrays file
    arrs = os.path.join(path, "arrays.npz")
    data = bytearray(open(arrs, "rb").read())
    data[len(data) // 2] ^= 0xFF
    open(arrs, "wb").write(bytes(data))
    with pytest.raises(Exception):
        ckpt.restore(str(tmp_path), 1, jax.eval_shape(lambda: tree))


def test_atomic_publish_no_partial_dirs(tmp_path):
    tree = _tree()
    ckpt.save(str(tmp_path), 1, tree)
    names = os.listdir(tmp_path)
    assert all(not n.startswith(".tmp") for n in names), names


def test_restore_specific_step(tmp_path):
    t1, t2 = _tree(1), _tree(2)
    ckpt.save(str(tmp_path), 1, t1)
    ckpt.save(str(tmp_path), 2, t2)
    step, restored = ckpt.restore(str(tmp_path), 1, jax.eval_shape(lambda: t1))
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(t1["a"]))


# ---------------------------------------------------------------------------
# step enumeration: numeric, never lexical (step_9 vs step_10 vs step_100)
# ---------------------------------------------------------------------------


def _unpad(ckpt_dir, step):
    """Rewrite a saved checkpoint dir to the unpadded legacy name, e.g.
    step_0000000009 -> step_9 (older layouts / foreign writers)."""
    src = os.path.join(ckpt_dir, f"step_{step:010d}")
    dst = os.path.join(ckpt_dir, f"step_{step}")
    os.rename(src, dst)
    return dst


def test_unpadded_step_names_order_numerically(tmp_path):
    """Regression: a lexical sort makes step_9 > step_10 > step_100, so
    restore(latest) picked step_9 and pruning deleted the newest dirs."""
    tree = _tree()
    for s in (9, 10, 100):
        ckpt.save(str(tmp_path), s, tree, keep=100)
        _unpad(str(tmp_path), s)
    assert ckpt.latest_step(str(tmp_path)) == 100
    step, _ = ckpt.restore(str(tmp_path), None, jax.eval_shape(lambda: tree))
    assert step == 100
    # restore by explicit number resolves the unpadded dir too
    step, _ = ckpt.restore(str(tmp_path), 9, jax.eval_shape(lambda: tree))
    assert step == 9


def test_prune_keeps_numerically_newest_across_paddings(tmp_path):
    """Mixed padded/unpadded dirs: lexically 'step_9' sorts after
    'step_0000000010', so the old prune deleted the *newer* step 10."""
    tree = _tree()
    ckpt.save(str(tmp_path), 9, tree, keep=100)
    _unpad(str(tmp_path), 9)
    ckpt.save(str(tmp_path), 10, tree, keep=1)
    names = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
    assert names == ["step_0000000010"], names
    assert ckpt.latest_step(str(tmp_path)) == 10


def test_prune_never_touches_step_being_published(tmp_path):
    """Saving a numerically-older step after newer ones exist (restart from
    an early checkpoint) must not prune the step it just wrote."""
    tree = _tree()
    ckpt.save(str(tmp_path), 100, tree, keep=1)
    path5 = ckpt.save(str(tmp_path), 5, tree, keep=1)
    assert os.path.isdir(path5), "just-published step_5 was pruned"
    step, _ = ckpt.restore(str(tmp_path), 5, jax.eval_shape(lambda: tree))
    assert step == 5


def test_non_numeric_step_dirs_are_ignored(tmp_path):
    tree = _tree()
    ckpt.save(str(tmp_path), 3, tree)
    os.makedirs(os.path.join(tmp_path, "step_backup"))
    assert ckpt.latest_step(str(tmp_path)) == 3
    ckpt.save(str(tmp_path), 4, tree, keep=1)  # prune must not crash on it
    assert ckpt.latest_step(str(tmp_path)) == 4

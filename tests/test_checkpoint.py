"""Checkpoint/fault-tolerance unit tests (mesh-elastic path is covered by
tests/dist_progs/train_prog.py)."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ckpt


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": jax.random.normal(k, (4, 3)),
        "nested": {"b": jnp.arange(5), "c": [jnp.ones(2), jnp.zeros((2, 2))]},
    }


def test_save_restore_roundtrip(tmp_path):
    tree = _tree()
    ckpt.save(str(tmp_path), 7, tree)
    step, restored = ckpt.restore(str(tmp_path), None, jax.eval_shape(lambda: tree))
    assert step == 7
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        tree,
        restored,
    )


def test_latest_and_retention(tmp_path):
    tree = _tree()
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, tree, keep=3)
    assert ckpt.latest_step(str(tmp_path)) == 5
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(kept) == 3  # pruned to the newest 3


def test_corruption_detected(tmp_path):
    tree = _tree()
    path = ckpt.save(str(tmp_path), 1, tree)
    # flip bytes in the arrays file
    arrs = os.path.join(path, "arrays.npz")
    data = bytearray(open(arrs, "rb").read())
    data[len(data) // 2] ^= 0xFF
    open(arrs, "wb").write(bytes(data))
    with pytest.raises(Exception):
        ckpt.restore(str(tmp_path), 1, jax.eval_shape(lambda: tree))


def test_atomic_publish_no_partial_dirs(tmp_path):
    tree = _tree()
    ckpt.save(str(tmp_path), 1, tree)
    names = os.listdir(tmp_path)
    assert all(not n.startswith(".tmp") for n in names), names


def test_restore_specific_step(tmp_path):
    t1, t2 = _tree(1), _tree(2)
    ckpt.save(str(tmp_path), 1, t1)
    ckpt.save(str(tmp_path), 2, t2)
    step, restored = ckpt.restore(str(tmp_path), 1, jax.eval_shape(lambda: t1))
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(t1["a"]))

"""Execution-plan layer (repro.ops): one driver stack, every backend.

Pins the ISSUE 4 contract:
  * ``plan(op)`` with no mesh is the identity lowering — every core matvec
    reproduced bit-exactly, and the drivers unchanged.
  * ``plan(op, mesh)`` lowers ista / fista / cpadmm onto the sharded
    four-step transforms; ``solve`` / ``solve_until`` / ``solve_checkpointed``
    match the single-device solver to 1e-5 relative error (the in-process
    1-device-mesh variant of tests/dist_progs/ista_prog.py).
  * ``make_dist_cpadmm`` survives as a deprecation shim with identical
    output to the plan route.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import RecoveryProblem, densify, solve, solve_checkpointed, solve_until
from repro.core.circulant import PartialCirculant, gaussian_circulant
from repro.data.synthetic import paper_regime, sparse_signal
from repro.dist.compat import make_mesh
from repro.dist.fft import layout_2d, unlayout_2d
from repro.dist.recovery import make_dist_cpadmm
from repro.ops import ExecutionPlan, PlanConfig, RecoveryOperator, plan, plan_from_parts

N1, N2 = 32, 16
N = N1 * N2
ALPHA, RHO, SIGMA = 1e-4, 0.01, 0.01


def _problem(batch=()):
    x_true = sparse_signal(jax.random.PRNGKey(0), N, paper_regime(N)[1], batch=batch)
    C = gaussian_circulant(jax.random.PRNGKey(1), N, normalize=True)
    m = paper_regime(N)[0]
    omega = jnp.sort(jax.random.permutation(jax.random.PRNGKey(2), N)[:m])
    op = PartialCirculant(C, omega.astype(jnp.int32))
    return RecoveryProblem(op=op, y=op.matvec(x_true), x_true=x_true)


def _rel(got, want):
    got, want = jnp.asarray(got), jnp.asarray(want)
    return float(jnp.linalg.norm(got - want) / (jnp.linalg.norm(want) + 1e-30))


# ---------------------------------------------------------------------------
# local plans: the identity lowering, bit-exact
# ---------------------------------------------------------------------------


def test_local_plan_reproduces_every_core_matvec_bit_exactly():
    prob = _problem()
    x = jax.random.normal(jax.random.PRNGKey(3), (N,))
    ops = [prob.op, prob.op.circ, densify(prob.op)]
    for op in ops:
        assert isinstance(op, RecoveryOperator)
        pl = plan(op)
        assert isinstance(pl, ExecutionPlan) and not pl.is_distributed
        assert pl.operator is op  # the identity lowering, by construction
        np.testing.assert_array_equal(
            np.asarray(pl.matvec(x)), np.asarray(op.matvec(x))
        )
        y = op.matvec(x)
        np.testing.assert_array_equal(
            np.asarray(pl.rmatvec(y)), np.asarray(op.rmatvec(y))
        )


def test_local_plan_drivers_bit_exact():
    """solve(plan=local_plan) is the same computation as solve()."""
    prob = _problem()
    pl = plan(prob.op)
    for method in ("ista", "fista", "cpadmm"):
        x0, _ = solve(prob, method, iters=40, record_every=40,
                      alpha=ALPHA, rho=RHO, sigma=SIGMA)
        x1, _ = solve(prob, method, iters=40, record_every=40,
                      alpha=ALPHA, rho=RHO, sigma=SIGMA, plan=pl)
        np.testing.assert_array_equal(np.asarray(x0), np.asarray(x1))


def test_local_plan_pallas_tail_matches_jnp():
    """tail='pallas' on the local backend: the fused cpadmm_tail kernel
    (interpret mode on CPU) reproduces the jnp stepper."""
    prob = _problem()
    iters = 25  # interpret-mode Pallas per iteration: keep the scan short
    x_jnp, _ = solve(prob, "cpadmm", iters=iters, record_every=iters,
                     alpha=ALPHA, rho=RHO, sigma=SIGMA)
    x_pal, _ = solve(prob, "cpadmm", iters=iters, record_every=iters,
                     alpha=ALPHA, rho=RHO, sigma=SIGMA,
                     plan=plan(prob.op, tail="pallas"))
    assert _rel(x_pal, x_jnp) <= 1e-5


# ---------------------------------------------------------------------------
# distributed plans on a 1-device mesh (fast lane; 8 devices in dist_progs/)
# ---------------------------------------------------------------------------

# (method, iters) — fista runs to convergence: its momentum transiently
# amplifies the four-step-FFT rounding noise mid-trajectory, and the 1e-5
# contract is about the *recovered signal*, not a mid-flight iterate.
DIST_CASES = [("ista", 300), ("fista", 800), ("cpadmm", 300)]


@pytest.mark.parametrize("method,iters", DIST_CASES)
@pytest.mark.parametrize("rfft", [False, True])
def test_dist_plan_solve_matches_core(method, iters, rfft):
    prob = _problem()
    mesh = make_mesh((1,), ("model",))
    pl = plan(prob.op, mesh, n1=N1, n2=N2, rfft=rfft)
    x_ref, _ = solve(prob, method, iters=iters, record_every=iters,
                     alpha=ALPHA, rho=RHO, sigma=SIGMA)
    x_dist, tr = solve(prob, method, iters=iters, record_every=iters,
                       alpha=ALPHA, rho=RHO, sigma=SIGMA, plan=pl)
    rel = _rel(x_dist, x_ref)
    assert rel <= 1e-5, f"{method} rfft={rfft}: {rel:.2e}"
    # distributed runs now get the core drivers' metric traces
    assert jnp.isfinite(tr.objective).all() and jnp.isfinite(tr.mse).all()


@pytest.mark.parametrize("method", ["ista", "cpadmm"])
def test_dist_plan_solve_until_matches_core(method):
    """Tolerance-stopped *distributed* recovery — previously impossible."""
    prob = _problem()
    mesh = make_mesh((1,), ("model",))
    pl = plan(prob.op, mesh, n1=N1, n2=N2, rfft=True)
    kw = dict(tol=1e-7, max_iters=3000, alpha=ALPHA, rho=RHO, sigma=SIGMA)
    x_ref, used_ref = solve_until(prob, method, **kw)
    x_dist, used = solve_until(prob, method, plan=pl, **kw)
    assert _rel(x_dist, x_ref) <= 1e-5
    assert int(used) > 0 and int(used_ref) > 0


@pytest.mark.parametrize("method", ["ista", "cpadmm"])
def test_dist_plan_solve_checkpointed_restarts(method):
    """Checkpoint/restart of a distributed solve: resuming from the first
    saved state reproduces the uninterrupted run exactly, and both match
    the single-device result."""
    prob = _problem()
    mesh = make_mesh((1,), ("model",))
    pl = plan(prob.op, mesh, n1=N1, n2=N2, rfft=True)
    kw = dict(iters=300, chunk=100, alpha=ALPHA, rho=RHO, sigma=SIGMA)
    saves = []
    x_full, _ = solve_checkpointed(
        prob, method, plan=pl, save_cb=lambda s, st: saves.append((s, st)), **kw
    )
    assert [s for s, _ in saves] == [100, 200, 300]
    # sharded-layout state leaves: (n1, n2), not flat (momentum scalars aside)
    assert all(
        leaf.shape[-2:] == (N1, N2)
        for leaf in jax.tree.leaves(saves[0][1])
        if leaf.ndim >= 2
    )
    x_resumed, _ = solve_checkpointed(prob, method, plan=pl, restore=saves[0], **kw)
    np.testing.assert_array_equal(np.asarray(x_full), np.asarray(x_resumed))
    x_ref, _ = solve_checkpointed(prob, method, **kw)
    assert _rel(x_full, x_ref) <= 1e-5


def test_dist_plan_batched_matches_core():
    """A leading batch rides the dist plan (replicated batch on a model-only
    mesh) with per-signal results matching the batched core solver."""
    B = 3
    prob = _problem(batch=(B,))
    mesh = make_mesh((1,), ("model",))
    pl = plan(prob.op, mesh, n1=N1, n2=N2, rfft=True)
    x_ref, _ = solve(prob, "cpadmm", iters=300, record_every=300,
                     alpha=ALPHA, rho=RHO, sigma=SIGMA)
    x_dist, _ = solve(prob, "cpadmm", iters=300, record_every=300,
                      alpha=ALPHA, rho=RHO, sigma=SIGMA, plan=pl)
    assert x_dist.shape == (B, N)
    for b in range(B):
        assert _rel(x_dist[b], x_ref[b]) <= 1e-5


def test_dist_plan_mask_form_operator():
    """The planned operator is diag(mask) C on flat arrays: same normal
    equations as the m-subset form (the solver-equivalence workhorse)."""
    prob = _problem()
    mesh = make_mesh((1,), ("model",))
    pl = plan(prob.op, mesh, n1=N1, n2=N2)
    x = jax.random.normal(jax.random.PRNGKey(4), (N,))
    mask = jnp.zeros((N,)).at[prob.op.omega].set(1.0)
    want_mv = mask * prob.op.circ.matvec(x)
    got_mv = pl.operator.matvec(x)
    scale = float(jnp.max(jnp.abs(want_mv)))
    np.testing.assert_allclose(
        np.asarray(got_mv), np.asarray(want_mv), atol=1e-5 * scale
    )
    # A^T y on scattered measurements == rmatvec of the m-subset operator
    y_full = mask * prob.op.circ.matvec(x)
    want_rmv = prob.op.rmatvec(jnp.take(y_full, prob.op.omega))
    got_rmv = pl.operator.rmatvec(y_full)
    scale = float(jnp.max(jnp.abs(want_rmv)))
    np.testing.assert_allclose(
        np.asarray(got_rmv), np.asarray(want_rmv), atol=1e-5 * scale
    )
    np.testing.assert_allclose(
        float(pl.operator.operator_norm_bound()),
        float(prob.op.operator_norm_bound()),
        rtol=1e-6,
    )


@pytest.mark.parametrize("shape", [(32, 16), (31, 33)])
@pytest.mark.parametrize("rfft", [False, True])
def test_spectrum_layout_matches_distributed_fft(shape, rfft):
    """plan()'s direct spectrum re-layout (spectral.spectrum_layout_2d — no
    time-domain round trip) produces the same column block the four-step
    transform of the first column does, on even and odd extents."""
    from repro.dist.recovery import make_dist_spectrum
    from repro.ops import spectral

    n1, n2 = shape
    col = jax.random.normal(jax.random.PRNGKey(5), (n1 * n2,))
    mesh = make_mesh((1,), ("model",))
    want = make_dist_spectrum(mesh, rfft=rfft)(layout_2d(col, n1, n2))
    got = spectral.spectrum_layout_2d(
        jnp.fft.rfft(col), n1, n2, rfft=rfft, p=1
    )
    assert got.shape == want.shape
    scale = float(jnp.max(jnp.abs(want)))
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), atol=1e-5 * scale
    )


# ---------------------------------------------------------------------------
# deprecation shim
# ---------------------------------------------------------------------------


def test_make_dist_cpadmm_shim_warns_and_matches_plan_route():
    prob = _problem()
    C, omega = prob.op.circ, prob.op.omega
    mask = jnp.zeros((N,)).at[omega].set(1.0)
    mesh = make_mesh((1,), ("model",))
    iters = 150

    with pytest.warns(DeprecationWarning, match="make_dist_cpadmm is deprecated"):
        solver = make_dist_cpadmm(mesh, N1, N2, iters, fused=True, rfft=True)
    pl = plan(prob.op, mesh, n1=N1, n2=N2, rfft=True)
    z_shim = solver(
        pl.spec2d,
        layout_2d(mask, N1, N2),
        layout_2d(mask * C.matvec(prob.x_true), N1, N2),
        jnp.float32(ALPHA), jnp.float32(RHO), jnp.float32(SIGMA),
    )
    z_plan, _ = solve(prob, "cpadmm", iters=iters, record_every=iters,
                      alpha=ALPHA, rho=RHO, sigma=SIGMA, plan=pl)
    # identical computation; the shim's single outer jit fuses differently
    # than the eager chunked route, so "identical" means float32-roundoff
    # (an order tighter than the 1e-5 solver acceptance gate)
    assert _rel(unlayout_2d(z_shim), z_plan) <= 1e-6


def test_shim_rejects_unknown_batch_axis():
    mesh = make_mesh((1,), ("model",))
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="batch_axis"):
            make_dist_cpadmm(mesh, N1, N2, 10, batch_axis="data")


# ---------------------------------------------------------------------------
# validation / error surfaces
# ---------------------------------------------------------------------------


def test_unknown_method_error_lists_valid_methods():
    prob = _problem()
    with pytest.raises(ValueError, match="ista, fista, cpista, admm, padmm, cpadmm"):
        solve(prob, "newton")


def test_dist_plan_method_without_lowering_errors():
    prob = _problem()
    mesh = make_mesh((1,), ("model",))
    pl = plan(prob.op, mesh, n1=N1, n2=N2)
    with pytest.raises(ValueError, match="no distributed lowering"):
        solve(prob, "admm", plan=pl)


def test_plan_validation_errors():
    prob = _problem()
    mesh = make_mesh((1,), ("model",))
    with pytest.raises(ValueError, match="n1 \\* n2"):
        plan(prob.op, mesh, n1=7, n2=11)
    with pytest.raises(TypeError, match="circulant"):
        plan(densify(prob.op), mesh)
    with pytest.raises(ValueError, match="tail"):
        plan(prob.op, tail="cuda")


def test_plan_auto_factorization():
    prob = _problem()
    mesh = make_mesh((1,), ("model",))
    pl = plan(prob.op, mesh)  # N = 512 -> 16 x 32
    assert pl.n1 * pl.n2 == N and pl.n1 <= pl.n2
    x_ref, _ = solve(prob, "ista", iters=100, record_every=100, alpha=ALPHA)
    x_dist, _ = solve(prob, "ista", iters=100, record_every=100, alpha=ALPHA,
                      plan=pl)
    assert _rel(x_dist, x_ref) <= 1e-5


# ---------------------------------------------------------------------------
# PlanConfig API (ISSUE 6): one config object, four entry points, one
# validation site
# ---------------------------------------------------------------------------


def test_plan_config_is_frozen_and_hashable():
    cfg = PlanConfig(rfft=True, overlap=2, n1=N1, n2=N2)
    assert hash(cfg) == hash(PlanConfig(rfft=True, overlap=2, n1=N1, n2=N2))
    with pytest.raises(Exception):  # dataclasses.FrozenInstanceError
        cfg.rfft = False
    assert "rfft=on" in cfg.describe() and "overlap=2" in cfg.describe()


def test_plan_accepts_config_with_legacy_parity():
    """config=PlanConfig(...) builds the identical plan the legacy kwargs
    spell, at every entry point that takes knobs."""
    prob = _problem()
    mesh = make_mesh((1,), ("model",))
    cfg = PlanConfig(rfft=True, overlap=2, n1=N1, n2=N2)
    via_cfg = plan(prob.op, mesh, config=cfg)
    via_kw = plan(prob.op, mesh, rfft=True, overlap=2, n1=N1, n2=N2)
    assert via_cfg.config == via_kw.config == cfg
    x = jax.random.normal(jax.random.PRNGKey(6), (N,))
    np.testing.assert_array_equal(
        np.asarray(via_cfg.matvec(x)), np.asarray(via_kw.matvec(x))
    )


def test_plan_from_parts_accepts_config_with_legacy_parity():
    prob = _problem()
    mesh = make_mesh((1,), ("model",))
    donor = plan(prob.op, mesh, n1=N1, n2=N2)
    mask2d = layout_2d(jnp.zeros((N,)).at[prob.op.omega].set(1.0), N1, N2)
    cfg = PlanConfig(n1=N1, n2=N2)
    via_cfg = plan_from_parts(mesh, donor.spec2d, mask2d, config=cfg)
    via_kw = plan_from_parts(mesh, donor.spec2d, mask2d, n1=N1, n2=N2)
    assert via_cfg.config == via_kw.config == cfg


def test_build_plan_accepts_config_with_legacy_parity():
    from repro.launch import recover

    prob = _problem()
    cfg = PlanConfig(rfft=True, n1=N1, n2=N2)
    via_cfg = recover.build_plan(prob.op, "1", config=cfg)
    via_kw = recover.build_plan(prob.op, "1", n1=N1, rfft=True)
    assert via_cfg.config == via_kw.config == cfg


def test_build_deblur_plan_accepts_config_with_legacy_parity():
    from repro.core.deblur import build_deblur_plan, build_deblur_problem
    from repro.data.synthetic import starfield

    img = starfield(jax.random.PRNGKey(7), 16, 16, density=0.05, n_blobs=2)
    dp = build_deblur_problem(jax.random.PRNGKey(8), img, blur_order=3,
                              subsample=0.5, sensing="romberg")
    mesh = make_mesh((1,), ("model",))
    cfg = PlanConfig(rfft=True, n1=16, n2=16)
    via_cfg = build_deblur_plan(dp, mesh, config=cfg)
    via_kw = build_deblur_plan(dp, mesh, rfft=True, n1=16, n2=16)
    assert via_cfg.config == via_kw.config == cfg


def test_config_plus_legacy_knobs_is_an_error():
    prob = _problem()
    mesh = make_mesh((1,), ("model",))
    cfg = PlanConfig(n1=N1, n2=N2)
    with pytest.raises(ValueError, match=r"not both.*rfft"):
        plan(prob.op, mesh, config=cfg, rfft=True)
    with pytest.raises(ValueError, match="not both"):
        plan_from_parts(mesh, None, None, config=cfg, overlap=2)


def test_local_plan_rejects_distributed_knobs():
    """The single validation site: rfft/overlap/batch_axis without a mesh
    used to be silently ignored — now they refuse loudly."""
    prob = _problem()
    for bad in (dict(rfft=True), dict(overlap=4), dict(batch_axis="data")):
        with pytest.raises(ValueError, match="pass a mesh"):
            plan(prob.op, **bad)


def test_plan_from_parts_requires_concrete_factorization():
    mesh = make_mesh((1,), ("model",))
    with pytest.raises(ValueError, match="no operator to infer n"):
        plan_from_parts(mesh, None, None, config=PlanConfig(rfft=True))


def test_plan_config_validate_messages():
    with pytest.raises(ValueError, match="tail must be"):
        PlanConfig(tail="cuda").validate(distributed=False)
    with pytest.raises(ValueError, match="overlap"):
        PlanConfig(overlap=0).validate(distributed=True)
    with pytest.raises(ValueError, match="positive"):
        PlanConfig(n1=-4, n2=8).validate(distributed=True)


# ---------------------------------------------------------------------------
# make_dist_cpadmm deprecation endgame
# ---------------------------------------------------------------------------


def test_shim_warning_pins_removal_version():
    mesh = make_mesh((1,), ("model",))
    with pytest.warns(
        DeprecationWarning,
        match=r"make_dist_cpadmm is deprecated and will be removed in "
              r"repro 0\.2\.0",
    ):
        make_dist_cpadmm(mesh, N1, N2, 1)


def test_make_dist_cpadmm_not_exported_from_dist_package():
    import repro.dist as dist

    assert "make_dist_cpadmm" not in dist.__all__
    assert "make_dist_cpadmm" not in dir(dist)
    with pytest.raises(AttributeError, match="make_dist_cpadmm"):
        dist.make_dist_cpadmm
    # the lazy symbol table still serves everything that IS public
    assert dist.MODEL_AXIS == "model"
    assert dist.make_mesh is make_mesh
    assert callable(dist.dist_cpadmm_step)
    assert set(dist.__all__) >= {"layout_2d", "make_distributed_rfft",
                                 "rules_for_arch", "DistCpadmmParams"}


# ---------------------------------------------------------------------------
# wire-compressed collectives (ISSUE 8): wire_dtype on the plan layer
# ---------------------------------------------------------------------------


def test_local_plan_rejects_wire_dtype_loudly():
    """The single validation site refuses a demoted wire without a mesh —
    a local plan has no all-to-all to compress, and silently ignoring the
    knob would hide the 2x byte win the caller thinks they asked for."""
    prob = _problem()
    for wire in ("bf16", "fp16"):
        with pytest.raises(ValueError, match="no wire to compress"):
            plan(prob.op, wire_dtype=wire)
    # the message teaches the fix: it lists the valid values
    with pytest.raises(ValueError, match=r"valid values.*bf16.*fp16.*fp32"):
        PlanConfig(wire_dtype="bf16").validate(distributed=False)


def test_unknown_wire_dtype_lists_valid_values():
    prob = _problem()
    mesh = make_mesh((1,), ("model",))
    with pytest.raises(ValueError, match=r"wire_dtype must be one of.*bf16"):
        plan(prob.op, mesh, wire_dtype="int8")
    with pytest.raises(ValueError, match="wire_dtype must be one of"):
        PlanConfig(wire_dtype="fp64").validate(distributed=True)


def test_plan_config_describe_carries_wire_tag():
    cfg32 = PlanConfig(rfft=True, n1=N1, n2=N2)
    cfg16 = PlanConfig(rfft=True, n1=N1, n2=N2, wire_dtype="bf16")
    assert "wire=" not in cfg32.describe()  # fp32 keeps legacy strings
    assert "wire=bf16" in cfg16.describe()
    # the tag splits serve buckets: describe() must differ
    assert cfg32.describe() != cfg16.describe()


def test_plan_bf16_wire_passes_guard_and_solves():
    """bf16 wire survives the precision guard on a well-scaled operator and
    the solver lands within the documented wire error bound of fp32."""
    from repro.ops.plan import WIRE_ERROR_BOUND

    prob = _problem()
    mesh = make_mesh((1,), ("model",))
    pl16 = plan(prob.op, mesh, n1=N1, n2=N2, wire_dtype="bf16")
    assert pl16.wire_dtype == "bf16"
    assert "wire=bf16" in pl16.config.describe()
    pl32 = plan(prob.op, mesh, n1=N1, n2=N2)
    kw = dict(iters=300, record_every=300, alpha=ALPHA, rho=RHO, sigma=SIGMA)
    x32, _ = solve(prob, "cpadmm", plan=pl32, **kw)
    x16, _ = solve(prob, "cpadmm", plan=pl16, **kw)
    assert _rel(x16, x32) <= WIRE_ERROR_BOUND


def test_wire_dtype_config_and_legacy_kwarg_agree():
    prob = _problem()
    mesh = make_mesh((1,), ("model",))
    cfg = PlanConfig(n1=N1, n2=N2, wire_dtype="bf16")
    via_cfg = plan(prob.op, mesh, config=cfg)
    via_kw = plan(prob.op, mesh, n1=N1, n2=N2, wire_dtype="bf16")
    assert via_cfg.config == via_kw.config == cfg


def test_fp16_wire_overflow_triggers_fp32_fallback():
    """ISSUE 8 acceptance: fp16 must either meet the bound or demonstrably
    fall back.  A spectrum scaled past float16's 65504 max overflows the
    inverse-transpose payload, the probe error goes non-finite, and the
    guard demotes the plan to the fp32 wire with a RuntimeWarning."""
    from repro.core.circulant import Circulant

    prob = _problem()
    big = Circulant.from_first_col(prob.op.circ.col * 1e9)
    op_big = PartialCirculant(big, prob.op.omega)
    mesh = make_mesh((1,), ("model",))
    with pytest.warns(RuntimeWarning, match="failed the precision guard"):
        pl = plan(op_big, mesh, n1=N1, n2=N2, wire_dtype="fp16")
    assert pl.wire_dtype == "fp32"  # error-controlled: never silently wrong
    # the fallback plan is the fp32 twin, numerically identical to asking
    # for fp32 outright
    x = jax.random.normal(jax.random.PRNGKey(9), (N,))
    ref = plan(op_big, mesh, n1=N1, n2=N2).matvec(x)
    np.testing.assert_array_equal(np.asarray(pl.matvec(x)), np.asarray(ref))


# ---------------------------------------------------------------------------
# hierarchical (host, device) transform axis — validation + describe
# ---------------------------------------------------------------------------


def test_local_plan_rejects_hier_axes_loudly():
    """The single validation site refuses hier_axes without a mesh, in the
    valid-values-listed error style."""
    prob = _problem()
    with pytest.raises(ValueError, match="no mesh axes to factor"):
        plan(prob.op, hier_axes=(2, 2))
    with pytest.raises(ValueError, match=r"valid values: None or a \(H, D\)"):
        PlanConfig(hier_axes=(2, 2)).validate(distributed=False)


def test_malformed_hier_axes_rejected():
    for bad in ((2,), (2, 2, 2), (2, 0), (2.0, 2), "2x2"):
        with pytest.raises(ValueError, match="hier_axes must be a"):
            PlanConfig(hier_axes=bad).validate(distributed=True)


def test_inter_wire_without_hier_rejected():
    """inter_wire_dtype only names the DCN hop of the hierarchical exchange
    — accepting it on a flat plan would silently ignore the knob."""
    prob = _problem()
    mesh = make_mesh((1,), ("model",))
    with pytest.raises(ValueError, match="inter_wire_dtype"):
        plan(prob.op, mesh, n1=N1, n2=N2, inter_wire_dtype="bf16")
    with pytest.raises(ValueError, match="inter_wire_dtype must be one of"):
        PlanConfig(hier_axes=(2, 2), inter_wire_dtype="int8").validate(
            distributed=True
        )


def test_hier_axes_must_match_mesh_extents():
    """hier_axes=(H, D) is checked against the mesh's actual (host, device)
    extents, and the error names the valid value."""
    from repro.dist.compat import make_hier_mesh

    prob = _problem()
    mesh = make_hier_mesh(1, 1, 1)
    with pytest.raises(ValueError, match=r"valid value: hier_axes=\(1, 1\)"):
        plan(prob.op, mesh, n1=N1, n2=N2, hier_axes=(2, 2))
    # and a mesh without the (host, device) axes teaches the fix
    flat = make_mesh((1,), ("model",))
    with pytest.raises(ValueError, match="make_hier_mesh"):
        plan(prob.op, flat, n1=N1, n2=N2, hier_axes=(1, 1))


def test_hier_describe_tags_split_configs():
    base = PlanConfig(rfft=True, n1=N1, n2=N2)
    hier = PlanConfig(rfft=True, n1=N1, n2=N2, hier_axes=(2, 4),
                      axis_name=("host", "device"))
    tflat = PlanConfig(rfft=True, n1=N1, n2=N2, axis_name=("host", "device"))
    iw = PlanConfig(rfft=True, n1=N1, n2=N2, hier_axes=(2, 4),
                    axis_name=("host", "device"), inter_wire_dtype="bf16")
    assert "hier=" not in base.describe()
    assert "hier=2x4" in hier.describe()
    assert "hier=flat" in tflat.describe()  # factored axis, one flat a2a
    assert "inter_wire=bf16" in iw.describe()
    assert len({c.describe() for c in (base, hier, tflat, iw)}) == 4


def test_hier_config_round_trips_through_json():
    cfg = PlanConfig(rfft=True, n1=N1, n2=N2, hier_axes=(2, 4),
                     axis_name=("host", "device"), inter_wire_dtype="bf16")
    again = PlanConfig.from_dict(json.loads(json.dumps(cfg.to_dict())))
    assert again == cfg
    assert isinstance(again.hier_axes, tuple)
    assert isinstance(again.axis_name, tuple)


def test_hier_plan_solves_on_degenerate_mesh():
    """The 1x1 (host, device) mesh runs the full hier code path in the fast
    lane; the solve must match the flat plan bit-for-bit (no inter hop to
    demote, no intra shuffle to get wrong)."""
    from repro.dist.compat import make_hier_mesh

    prob = _problem()
    flat = plan(prob.op, make_mesh((1,), ("model",)), n1=N1, n2=N2, rfft=True)
    hier = plan(prob.op, make_hier_mesh(1, 1, 1), n1=N1, n2=N2, rfft=True,
                hier_axes=(1, 1))
    assert hier.hier and hier.axis_name == ("host", "device")
    kw = dict(iters=40, record_every=40, alpha=ALPHA, rho=RHO, sigma=SIGMA)
    xf, _ = solve(prob, "cpadmm", plan=flat, **kw)
    xh, _ = solve(prob, "cpadmm", plan=hier, **kw)
    assert jnp.array_equal(xf, xh)

"""Property tests for the batched recovery pipeline.

The contract (ISSUE 2 acceptance): a batch is nothing but B independent
solves sharing one operator —

  * batch-of-1 equals the unbatched run for every driver
    (``solve``, ``solve_until``, the fused distributed CPADMM),
  * a batch of B independent signals matches B sequential solves,
  * ``solve_until`` converges per signal: early finishers freeze with the
    same iteration count they would have used solo.

``solve`` comparisons are to 1e-6 (fixed iteration counts — deterministic
elementwise/FFT broadcasting).  ``solve_until`` comparisons allow the
iteration count to move by one: near the tolerance crossing the batched FFT
differs from the unbatched one by float ulps, which can flip the knife-edge
step; the recovered signals still agree to 1e-5.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    RecoveryProblem,
    partial_gaussian_circulant,
    solve,
    solve_until,
)
from repro.data.synthetic import paper_regime, sparse_signal
from repro.dist.compat import make_mesh
from repro.dist.fft import layout_2d, unlayout_2d
from repro.dist.recovery import make_dist_cpadmm, make_dist_spectrum

try:  # optional dev dep; CI installs it, the container may not have it
    import hypothesis
    import hypothesis.strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

TUNED = dict(alpha=1e-4, rho=0.01, sigma=0.01)


def _batched_problem(n=256, batch=(), seed=0):
    m, k = paper_regime(n)
    x = sparse_signal(jax.random.PRNGKey(seed), n, k, batch=batch)
    op = partial_gaussian_circulant(jax.random.PRNGKey(seed + 1), n, m, normalize=True)
    return RecoveryProblem(op=op, y=op.matvec(x), x_true=x)


def _rel(a, b):
    return float(jnp.linalg.norm(a - b) / (jnp.linalg.norm(b) + 1e-30))


# ---------------------------------------------------------------------------
# batch-of-1 == unbatched
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("method", ["ista", "fista", "cpadmm"])
def test_solve_batch_of_one_equals_unbatched(method):
    prob = _batched_problem(batch=(1,))
    single = RecoveryProblem(op=prob.op, y=prob.y[0], x_true=prob.x_true[0])
    kw = TUNED if method == "cpadmm" else dict(alpha=1e-4)
    xb, trb = solve(prob, method, iters=150, record_every=150, **kw)
    xs, trs = solve(single, method, iters=150, record_every=150, **kw)
    assert xb.shape == (1,) + xs.shape
    np.testing.assert_allclose(np.asarray(xb[0]), np.asarray(xs), atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(trb.mse[:, 0]), np.asarray(trs.mse), atol=1e-9
    )


@pytest.mark.parametrize("method", ["fista", "cpadmm"])
def test_solve_until_batch_of_one_equals_unbatched(method):
    prob = _batched_problem(batch=(1,))
    single = RecoveryProblem(op=prob.op, y=prob.y[0], x_true=prob.x_true[0])
    kw = TUNED if method == "cpadmm" else dict(alpha=1e-4)
    xb, itb = solve_until(prob, method, tol=1e-6, max_iters=2000, **kw)
    xs, its = solve_until(single, method, tol=1e-6, max_iters=2000, **kw)
    assert itb.shape == (1,) and its.shape == ()
    # counts can move by a few knife-edge dips (batched-vs-unbatched ulps);
    # the iterates themselves must agree
    assert abs(int(itb[0]) - int(its)) <= max(10, int(its) // 10)
    assert _rel(xb[0], xs) <= 1e-5


def test_fused_dist_cpadmm_batch_of_one_equals_unbatched():
    n1, n2 = 16, 16
    n = n1 * n2
    prob = _batched_problem(n=n, batch=(1,), seed=3)
    mask = jnp.zeros((n,)).at[prob.op.omega].set(1.0)
    pty_b = prob.op.project_back(prob.y)  # (1, n)

    spec_args = dict(fused=True, rfft=True)
    mesh_b = make_mesh((1, 1), ("data", "model"))
    spec_h = make_dist_spectrum(mesh_b, rfft=True)(layout_2d(prob.op.circ.col, n1, n2))
    scalars = (jnp.float32(1e-4), jnp.float32(0.01), jnp.float32(0.01))

    zb = make_dist_cpadmm(mesh_b, n1, n2, 200, batch_axis="data", **spec_args)(
        spec_h, layout_2d(mask, n1, n2), layout_2d(pty_b, n1, n2), *scalars
    )
    mesh_s = make_mesh((1,), ("model",))
    spec_s = make_dist_spectrum(mesh_s, rfft=True)(layout_2d(prob.op.circ.col, n1, n2))
    zs = make_dist_cpadmm(mesh_s, n1, n2, 200, **spec_args)(
        spec_s, layout_2d(mask, n1, n2), layout_2d(pty_b[0], n1, n2), *scalars
    )
    assert _rel(unlayout_2d(zb)[0], unlayout_2d(zs)) <= 1e-6


# ---------------------------------------------------------------------------
# batch of B == B sequential solves
# ---------------------------------------------------------------------------


def test_solve_batch_matches_sequential_solves():
    """Acceptance gate: B=8 batched == 8 sequential solves, in process."""
    B = 8
    prob = _batched_problem(batch=(B,), seed=5)
    xb, _ = solve(prob, "cpadmm", iters=200, record_every=200, **TUNED)
    for b in range(B):
        single = RecoveryProblem(op=prob.op, y=prob.y[b], x_true=prob.x_true[b])
        xs, _ = solve(single, "cpadmm", iters=200, record_every=200, **TUNED)
        assert _rel(xb[b], xs) <= 1e-6, b


def test_fused_dist_cpadmm_batch_matches_sequential_core():
    """B=8 through the batched+rfft distributed solver vs sequential core."""
    n1, n2 = 16, 16
    n = n1 * n2
    B, iters = 8, 250
    prob = _batched_problem(n=n, batch=(B,), seed=6)
    mask = jnp.zeros((n,)).at[prob.op.omega].set(1.0)

    mesh = make_mesh((1, 1), ("data", "model"))
    spec_h = make_dist_spectrum(mesh, rfft=True)(layout_2d(prob.op.circ.col, n1, n2))
    solver = make_dist_cpadmm(
        mesh, n1, n2, iters, fused=True, rfft=True, batch_axis="data"
    )
    z2d = solver(
        spec_h,
        layout_2d(mask, n1, n2),
        layout_2d(prob.op.project_back(prob.y), n1, n2),
        jnp.float32(TUNED["alpha"]),
        jnp.float32(TUNED["rho"]),
        jnp.float32(TUNED["sigma"]),
    )
    zb = unlayout_2d(z2d)
    for b in range(B):
        single = RecoveryProblem(op=prob.op, y=prob.y[b], x_true=prob.x_true[b])
        xs, _ = solve(single, "cpadmm", iters=iters, record_every=iters, **TUNED)
        assert _rel(zb[b], xs) <= 1e-5, b


def test_solve_until_freezes_converged_signals():
    """Per-signal convergence masks: once signal b converges at iteration
    t_b, its state stops updating — so the batch's answer for b must equal a
    *fixed* t_b-iteration solve exactly, and the per-signal counts must be
    close to the solo tolerance runs.  (Exact count equality is a knife
    edge: ADMM's relative change oscillates near tol, and batched-vs-solo
    float ulps can move the crossing by a few dips — the frozen-state
    property is the robust invariant.)"""
    B = 4
    prob = _batched_problem(batch=(B,), seed=7)
    xb, iters_b = solve_until(prob, "cpadmm", tol=1e-6, max_iters=3000, **TUNED)
    assert iters_b.shape == (B,)
    for b in range(B):
        single = RecoveryProblem(op=prob.op, y=prob.y[b], x_true=prob.x_true[b])
        t_b = int(iters_b[b])
        assert 50 <= t_b < 3000  # converged strictly inside the budget
        x_fixed, _ = solve(single, "cpadmm", iters=t_b, record_every=t_b, **TUNED)
        assert _rel(xb[b], x_fixed) <= 1e-6, b
        _, its = solve_until(single, "cpadmm", tol=1e-6, max_iters=3000, **TUNED)
        assert abs(t_b - int(its)) <= max(10, int(its) // 10), (b, t_b, int(its))
    # the batch did NOT run every signal to the slowest signal's count
    assert int(jnp.min(iters_b)) < int(jnp.max(iters_b))


# ---------------------------------------------------------------------------
# hypothesis-driven sizes (optional dep; CI always runs these)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @hypothesis.given(
        nblk=st.integers(2, 6), batch=st.integers(1, 4), seed=st.integers(0, 2**16)
    )
    @hypothesis.settings(max_examples=8, deadline=None)
    def test_batched_solve_property(nblk, batch, seed):
        n = nblk * 64
        prob = _batched_problem(n=n, batch=(batch,), seed=seed)
        xb, _ = solve(prob, "cpadmm", iters=80, record_every=80, **TUNED)
        for b in range(batch):
            single = RecoveryProblem(op=prob.op, y=prob.y[b], x_true=prob.x_true[b])
            xs, _ = solve(single, "cpadmm", iters=80, record_every=80, **TUNED)
            assert _rel(xb[b], xs) <= 1e-6, (n, batch, b)

else:  # keep the absence visible as a skip, not a silent non-collection

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_batched_solve_property():
        pass

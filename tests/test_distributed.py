"""Multi-device tests, each in a subprocess with 8 fake CPU devices so the
main pytest process keeps jax at 1 device (the dry-run rule)."""

import os
import subprocess
import sys

import pytest

PROGS = [
    "fft_prog.py",
    "recovery_prog.py",
    "fused_recovery_prog.py",
    "batched_recovery_prog.py",
    "ista_prog.py",
    "overlap_prog.py",
    "deblur_prog.py",
    "train_prog.py",
    "compression_prog.py",
    "autotune_prog.py",
    "serve_prog.py",
    "wire_prog.py",
    "hier_prog.py",
    "prox_prog.py",
]
HERE = os.path.dirname(__file__)
SRC = os.path.join(HERE, "..", "src")


@pytest.mark.slow  # subprocess + 8 fake devices: minutes, not seconds
@pytest.mark.parametrize("prog", PROGS)
def test_distributed_prog(prog):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(SRC)
    res = subprocess.run(
        [sys.executable, os.path.join(HERE, "dist_progs", prog)],
        env=env,
        capture_output=True,
        text=True,
        timeout=1200,
    )
    assert res.returncode == 0, f"{prog} failed:\n{res.stdout}\n{res.stderr}"
    assert "ALL OK" in res.stdout, res.stdout

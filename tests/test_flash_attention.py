"""Flash-attention Pallas kernel vs naive oracle and vs the model's chunked
attention (interpret mode)."""

import jax
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention, flash_hbm_bytes
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.models.attention import _attend_chunked


def _qkv(key, b, s, h, kh, d):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kh, d))
    v = jax.random.normal(ks[2], (b, s, kh, d))
    return q, k, v


@pytest.mark.parametrize("s", [256, 512, 768])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_ref(s, causal):
    q, k, v = _qkv(jax.random.PRNGKey(0), 2, s, 2, 2, 64)
    got = flash_attention(q, k, v, causal=causal)
    bh = lambda a: a.transpose(0, 2, 1, 3).reshape(-1, s, 64)
    want = flash_attention_ref(bh(q), bh(k), bh(v), causal=causal)
    want = want.reshape(2, 2, s, 64).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-4)


@pytest.mark.parametrize("gqa", [(4, 4), (4, 2), (8, 1)])
def test_flash_gqa_matches_model_attention(gqa):
    h, kh = gqa
    s, d = 512, 32
    q, k, v = _qkv(jax.random.PRNGKey(1), 2, s, h, kh, d)
    got = flash_attention(q, k, v, causal=True)
    want = _attend_chunked(q, k, v, causal=True, chunk=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-4)


def test_causal_tile_skip_exactness():
    """The diagonal KV-tile early exit must not change results."""
    q, k, v = _qkv(jax.random.PRNGKey(2), 1, 1024, 2, 2, 32)
    got = flash_attention(q, k, v, causal=True)
    bh = lambda a: a.transpose(0, 2, 1, 3).reshape(-1, 1024, 32)
    want = flash_attention_ref(bh(q), bh(k), bh(v), causal=True)
    want = want.reshape(1, 2, 1024, 32).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-4)


def test_analytic_traffic_much_smaller_than_scores():
    """The kernel's HBM model must be far below the score-materializing cost."""
    b, s, h, d = 16, 4096, 32, 128
    fused = flash_hbm_bytes(b, s, s, h, d)
    score_tiles = b * h * s * s * 4  # one fp32 materialization of scores
    assert fused * 10 < score_tiles

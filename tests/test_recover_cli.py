"""launch/recover CLI: method/mesh flags routed through the plan API.

In-process invocations of ``repro.launch.recover.main`` at tiny sizes — the
fast-lane coverage for the production launcher (the 8-device forms run via
``--fake-devices`` as a script; here the 1-device mesh exercises the same
plan routing).
"""

import jax
import pytest

from repro.launch import recover


def test_tol_mode_with_mesh_plan(capsys):
    recover.main([
        "--n", "512", "--batch", "2", "--method", "fista", "--iters", "80",
        "--tol", "1e-3", "--mesh", "1", "--rfft",
    ])
    out = capsys.readouterr().out
    assert "mesh=1 (plan API)" in out
    assert "per-signal iterations" in out
    assert "per-signal MSE" in out


def test_checkpointed_mode_resumes(tmp_path, capsys):
    args = [
        "--n", "512", "--batch", "2", "--method", "cpadmm", "--iters", "60",
        "--chunk", "30", "--mesh", "1", "--ckpt-dir", str(tmp_path / "ck"),
    ]
    recover.main(args)
    first = capsys.readouterr().out
    assert "per-signal MSE" in first and "resumed" not in first
    recover.main(args)  # latest checkpoint (iter 60) is picked up
    assert "resumed from iteration 60" in capsys.readouterr().out


def test_local_backend_default(capsys):
    recover.main([
        "--n", "512", "--batch", "1", "--method", "ista", "--iters", "40",
        "--tol", "1e-2",
    ])
    out = capsys.readouterr().out
    assert "plan API" not in out and "per-signal iterations" in out


def test_deblur_workload_checkpointed_with_mesh_plan(tmp_path, capsys):
    """--deblur routes through build_deblur_plan on a (data, model) mesh and
    reports per-frame PSNR after the checkpointed solve."""
    recover.main([
        "--deblur", "--batch", "2", "--size", "16", "--blur-order", "3",
        "--iters", "40", "--chunk", "20", "--mesh", "1x1", "--rfft",
        "--ckpt-dir", str(tmp_path / "ck"),
    ])
    out = capsys.readouterr().out
    assert "deblurring batch=2 frames of 16x16" in out
    assert "mesh=1x1 (plan API)" in out
    assert "PSNR" in out and "normalized MSE" in out


def test_deblur_workload_tol_mode_local(capsys):
    recover.main([
        "--deblur", "--batch", "1", "--size", "16", "--iters", "40",
        "--tol", "1e-2",
    ])
    out = capsys.readouterr().out
    assert "per-signal iterations" in out and "PSNR" in out


def test_deblur_tv_prior_with_mesh_plan(capsys):
    """--prior tv builds a TVProx on the frame grid and threads it through
    build_deblur_plan onto the mesh path."""
    recover.main([
        "--deblur", "--batch", "2", "--size", "16", "--blur-kind", "gaussian",
        "--blur-order", "1.0", "--prior", "tv", "--iters", "40",
        "--chunk", "20", "--mesh", "1x1",
    ])
    out = capsys.readouterr().out
    assert "prior=tv" in out and "PSNR" in out


def test_prior_flag_local_sparse_recovery(capsys):
    for prior in ("nonneg-l1", "wavelet"):
        recover.main([
            "--n", "256", "--batch", "1", "--method", "ista", "--iters", "40",
            "--tol", "1e-2", "--prior", prior,
        ])
        out = capsys.readouterr().out
        assert f"prior={prior}" in out and "per-signal" in out


def test_make_prior():
    from repro.ops.prox import NonNegL1Prox, TVProx, WaveletProx

    assert recover.make_prior("l1", 256) is None
    assert isinstance(recover.make_prior("nonneg-l1", 256), NonNegL1Prox)
    assert isinstance(recover.make_prior("wavelet", 256), WaveletProx)
    assert recover.make_prior("tv", 256) == TVProx(shape=(16, 16))
    assert recover.make_prior("tv", 0, size=8) == TVProx(shape=(8, 8))
    with pytest.raises(SystemExit, match="square"):
        recover.make_prior("tv", 200)


def test_method_error_lists_valid_methods(capsys):
    with pytest.raises(SystemExit):
        recover.main(["--method", "newton", "--n", "512"])
    err = capsys.readouterr().err
    assert "cpadmm" in err and "ista" in err and "fista" in err


def test_bad_mesh_spec_rejected():
    op = None  # build_plan validates the spec before touching the operator
    with pytest.raises(ValueError, match="--mesh"):
        recover.build_plan(op, "2x2x2")


def test_build_plan_shapes():
    from repro.core import partial_gaussian_circulant
    from repro.ops import ExecutionPlan

    op = partial_gaussian_circulant(jax.random.PRNGKey(0), 512, 256)
    pl = recover.build_plan(op, None)
    assert isinstance(pl, ExecutionPlan) and not pl.is_distributed
    pl = recover.build_plan(op, "1", rfft=True)
    assert pl.is_distributed and pl.rfft and pl.batch_axis is None
    pl = recover.build_plan(op, "1x1")
    assert pl.is_distributed and pl.batch_axis == "data"

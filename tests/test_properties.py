"""Hypothesis property tests on system invariants (assignment (c)).

These pin the algebraic contracts the solvers and substrate rely on —
anything here breaking means a silent correctness bug elsewhere.
"""

import pytest

hypothesis = pytest.importorskip("hypothesis")  # optional dev dep; CI installs it
import hypothesis.strategies as st  # noqa: E402
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.circulant import gaussian_circulant, romberg_circulant
from repro.core.soft_threshold import soft_threshold
from repro.models.layers import apply_rope, init_norm, rmsnorm

SETTINGS = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# soft-threshold: the proximal operator of ||.||_1 (paper Eq. 4)
# ---------------------------------------------------------------------------


@hypothesis.given(
    seed=st.integers(0, 2**16), gamma=st.floats(0.0, 3.0), n=st.integers(1, 200)
)
@hypothesis.settings(**SETTINGS)
def test_soft_threshold_is_nonexpansive_shrinkage(seed, gamma, n):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (n,)) * 3
    y = jax.random.normal(k2, (n,)) * 3
    sx, sy = soft_threshold(x, gamma), soft_threshold(y, gamma)
    # prox operators are firmly non-expansive
    assert float(jnp.linalg.norm(sx - sy)) <= float(jnp.linalg.norm(x - y)) + 1e-5
    # shrinkage: |sx| <= |x| elementwise, sign preserved or zeroed
    assert bool(jnp.all(jnp.abs(sx) <= jnp.abs(x) + 1e-6))
    assert bool(jnp.all((sx == 0) | (jnp.sign(sx) == jnp.sign(x))))
    # exact kill zone
    assert bool(jnp.all(sx[jnp.abs(x) <= gamma] == 0))


# ---------------------------------------------------------------------------
# circulant algebra is a ring homomorphism onto spectra
# ---------------------------------------------------------------------------


@hypothesis.given(n=st.integers(4, 128), seed=st.integers(0, 2**16))
@hypothesis.settings(**SETTINGS)
def test_spectrum_homomorphism(n, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    A = gaussian_circulant(k1, n)
    B = gaussian_circulant(k2, n)
    # product of circulants -> product of spectra
    np.testing.assert_allclose(
        np.asarray(A.compose(B).spec), np.asarray(A.spec * B.spec),
        rtol=1e-3, atol=1e-2 * float(jnp.max(jnp.abs(A.spec)) * jnp.max(jnp.abs(B.spec))),
    )
    # commutativity (circulants always commute)
    x = jax.random.normal(jax.random.fold_in(k1, 9), (n,))
    np.testing.assert_allclose(
        np.asarray(A.matvec(B.matvec(x))),
        np.asarray(B.matvec(A.matvec(x))),
        atol=2e-2 * max(1.0, float(jnp.max(jnp.abs(x)))) * float(A.operator_norm() * B.operator_norm()) / n,
    )


@hypothesis.given(n=st.integers(8, 128), seed=st.integers(0, 2**16))
@hypothesis.settings(**SETTINGS)
def test_parseval_for_romberg(n, seed):
    """Unit-spectrum sensing is an isometry: ||Cx|| == ||x||."""
    C = romberg_circulant(jax.random.PRNGKey(seed), n)
    x = jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(seed), 1), (n,))
    np.testing.assert_allclose(
        float(jnp.linalg.norm(C.matvec(x))), float(jnp.linalg.norm(x)), rtol=1e-4
    )


@hypothesis.given(n=st.integers(4, 100), seed=st.integers(0, 2**16))
@hypothesis.settings(**SETTINGS)
def test_adjoint_identity(n, seed):
    """<Cx, y> == <x, C^T y> — the identity ISTA's gradient step relies on."""
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    C = gaussian_circulant(keys[0], n)
    x = jax.random.normal(keys[1], (n,))
    y = jax.random.normal(keys[2], (n,))
    lhs = float(jnp.dot(C.matvec(x), y))
    rhs = float(jnp.dot(x, C.rmatvec(y)))
    assert abs(lhs - rhs) <= 1e-3 * (abs(lhs) + abs(rhs) + 1.0)


# ---------------------------------------------------------------------------
# LASSO objective: solver output must not be worse than the zero vector
# ---------------------------------------------------------------------------


@hypothesis.given(seed=st.integers(0, 2**12))
@hypothesis.settings(max_examples=8, deadline=None)
def test_solver_beats_zero_solution(seed):
    from repro.core import RecoveryProblem, partial_gaussian_circulant, solve
    from repro.core.ista import lasso_objective
    from repro.data.synthetic import paper_regime, sparse_signal

    n = 128
    m, k = paper_regime(n)
    x = sparse_signal(jax.random.PRNGKey(seed), n, k)
    op = partial_gaussian_circulant(jax.random.PRNGKey(seed + 1), n, m, normalize=True)
    prob = RecoveryProblem(op=op, y=op.matvec(x), x_true=x)
    xh, _ = solve(prob, "cpadmm", iters=150, record_every=150, alpha=1e-4, rho=0.01, sigma=0.01)
    obj_zero = float(lasso_objective(op, prob.y, jnp.zeros_like(xh), 1e-4))
    obj_hat = float(lasso_objective(op, prob.y, xh, 1e-4))
    assert obj_hat < obj_zero


# ---------------------------------------------------------------------------
# substrate invariants
# ---------------------------------------------------------------------------


@hypothesis.given(
    s=st.integers(1, 32), dh=st.sampled_from([8, 16, 32]), seed=st.integers(0, 2**16)
)
@hypothesis.settings(**SETTINGS)
def test_rope_preserves_norms_and_relative_positions(s, dh, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, s, 2, dh))
    pos = jnp.broadcast_to(jnp.arange(s), (1, s))
    y = apply_rope(x, pos, 1e4)
    # rotation: per-position norms preserved
    np.testing.assert_allclose(
        np.asarray(jnp.linalg.norm(y, axis=-1)),
        np.asarray(jnp.linalg.norm(x, axis=-1)),
        rtol=2e-3,
    )
    # relative property: <rope(q,i), rope(k,j)> depends only on i - j
    if s >= 3:
        q = jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(seed), 1), (1, 1, 1, dh))
        k = jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(seed), 2), (1, 1, 1, dh))
        def dot_at(i, j):
            qi = apply_rope(q, jnp.full((1, 1), i), 1e4)
            kj = apply_rope(k, jnp.full((1, 1), j), 1e4)
            return float(jnp.sum(qi * kj))
        assert abs(dot_at(2, 1) - dot_at(1, 0)) < 1e-3


@hypothesis.given(d=st.sampled_from([8, 32, 128]), seed=st.integers(0, 2**16))
@hypothesis.settings(**SETTINGS)
def test_rmsnorm_output_scale(d, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (4, d)) * 10
    p = init_norm(d, jnp.float32)
    y = rmsnorm(p, x)
    rms = jnp.sqrt(jnp.mean(y * y, axis=-1))
    np.testing.assert_allclose(np.asarray(rms), 1.0, rtol=2e-2)


@hypothesis.given(seed=st.integers(0, 2**12))
@hypothesis.settings(max_examples=10, deadline=None)
def test_moe_combine_weights_normalized(seed):
    from repro.configs.registry import smoke_config
    from repro.models.moe import _routing

    cfg = smoke_config("deepseek_v3_671b")
    x = jax.random.normal(jax.random.PRNGKey(seed), (24, cfg.d_model))
    idx, gates, aux = _routing(
        {"router": jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(seed), 1),
                                     (cfg.d_model, cfg.n_experts)) * 0.02,
         "router_bias": jnp.zeros((cfg.n_experts,))},
        cfg, x,
    )
    np.testing.assert_allclose(np.asarray(jnp.sum(gates, axis=-1)), 1.0, atol=1e-3)
    assert idx.shape == (24, cfg.top_k)
    assert float(aux) >= 0.99  # balance loss >= 1 at (near-)uniform routing


def test_adamw_decreases_quadratic():
    from repro.optim import adamw

    cfg = adamw.AdamWConfig(lr_peak=0.1, warmup_steps=1, total_steps=100,
                            weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw.init(params, cfg)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    l0 = float(loss(params))
    for _ in range(50):
        grads = jax.grad(loss)(params)
        params, state, _ = adamw.update(params, grads, state, cfg)
    assert float(loss(params)) < l0 * 0.1

"""Validate the dry-run artifact set (skipped if the sweep hasn't been run).

These check the *deliverable*: every assigned (arch x shape x mesh) cell
compiled, recorded sane analysis numbers, and the roofline derivation holds.
"""

import glob
import json
import os

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")

pytestmark = pytest.mark.skipif(
    not glob.glob(os.path.join(ART, "*.json")),
    reason="dry-run artifacts not generated (run repro.launch.dryrun --all)",
)


def _cells():
    out = {}
    for p in glob.glob(os.path.join(ART, "*.json")):
        rec = json.load(open(p))
        out[(rec.get("arch"), rec.get("shape"), rec.get("mesh"))] = rec
    return out


def test_all_assigned_cells_present_and_ok():
    from repro.configs.registry import all_arch_ids, cells_for

    cells = _cells()
    missing, failed = [], []
    for arch in all_arch_ids():
        for shape in cells_for(arch):
            for mesh in ("single", "multipod"):
                rec = cells.get((arch, shape, mesh))
                if rec is None:
                    missing.append((arch, shape, mesh))
                elif not rec.get("ok"):
                    failed.append((arch, shape, mesh))
    assert not missing, f"missing cells: {missing}"
    assert not failed, f"failed cells: {failed}"


def test_cost_numbers_sane():
    from repro.configs.registry import SHAPES

    for key, rec in _cells().items():
        if not rec.get("ok"):
            continue
        w = rec["hlo_walk"]
        assert w["flops"] > 0, key
        assert w["bytes"] > 0, key
        # compiled flops must be at least the dense-model lower bound / devices
        seq, batch, kind = SHAPES[rec["shape"]]
        n_active = rec["params"]["active"]
        if kind == "train":
            lower = 6.0 * n_active * seq * batch * 0.5  # generous slack
            assert w["flops"] * rec["n_devices"] > lower * 0.05, key
        # memory analysis present
        assert rec["memory_analysis"].get("temp_size_in_bytes", 0) >= 0, key


def test_multipod_shards_pod_axis():
    """Multipod cells must use 512 devices and a 3-axis mesh."""
    for key, rec in _cells().items():
        if not rec.get("ok"):
            continue
        if rec["mesh"] == "multipod":
            assert rec["n_devices"] == 512, key
            assert rec["mesh_shape"] == [2, 16, 16], key
        else:
            assert rec["n_devices"] == 256, key


def test_train_cells_have_collectives():
    """Every sharded train cell must communicate (grad/TP reductions)."""
    for key, rec in _cells().items():
        if rec.get("ok") and rec["shape"] == "train_4k":
            total = sum(rec["hlo_walk"]["collective_bytes"].values())
            assert total > 1e6, (key, total)

"""Per-arch smoke tests: reduced configs, one forward + one train step on CPU,
asserting output shapes and finiteness (assignment requirement (f))."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import all_arch_ids, smoke_config
from repro.models import lm, steps
from repro.models.config import count_params
from repro.optim.adamw import AdamWConfig

BATCH, SEQ = 2, 32


def _batch_for(cfg, key):
    ks = jax.random.split(key, 3)
    b = {"tokens": jax.random.randint(ks[0], (BATCH, SEQ + 1), 0, cfg.vocab)}
    if cfg.n_img_tokens:
        b["img_embeds"] = (
            jax.random.normal(ks[1], (BATCH, cfg.n_img_tokens, cfg.d_model)) * 0.02
        )
    if cfg.is_encdec:
        b["frames"] = jax.random.normal(ks[2], (BATCH, 64, cfg.d_model)) * 0.02
    return b


@pytest.mark.parametrize("arch", all_arch_ids())
def test_forward_and_train_step(arch):
    cfg = smoke_config(arch)
    key = jax.random.PRNGKey(0)
    batch = _batch_for(cfg, jax.random.PRNGKey(1))

    opt_cfg = AdamWConfig(lr_peak=1e-3, warmup_steps=2, total_steps=10)
    state = steps.init_train_state(key, cfg, opt_cfg)

    # forward
    hidden, aux = lm.forward(
        state.params, cfg, batch["tokens"][:, :-1],
        img_embeds=batch.get("img_embeds"), frames=batch.get("frames"),
    )
    exp_s = SEQ + (cfg.n_img_tokens or 0)
    assert hidden.shape == (BATCH, exp_s, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(hidden.astype(jnp.float32))))

    # one jitted train step: loss AND gradients finite, params change
    train_step = jax.jit(steps.make_train_step(cfg, opt_cfg))
    new_state, metrics = train_step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"])), "NaN/inf gradients"
    assert float(metrics["loss"]) > 0
    # sanity: loss near log(vocab) at init (uniform predictions)
    assert float(metrics["loss"]) < np.log(cfg.vocab) + 2.0
    l0 = jax.tree.leaves(state.params)[0]
    l1 = jax.tree.leaves(new_state.params)[0]
    assert not np.allclose(np.asarray(l0), np.asarray(l1))


@pytest.mark.parametrize("arch", all_arch_ids())
def test_decode_step(arch):
    cfg = smoke_config(arch)
    if cfg.is_encdec:
        pytest.skip("enc-dec decode covered in test_whisper_decode")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    state = lm.init_decode_state(cfg, BATCH, max_len=16)
    decode = jax.jit(steps.make_decode_step(cfg))
    tok = jnp.zeros((BATCH, 1), jnp.int32)
    for _ in range(3):
        logits, state = decode(params, tok, state)
        assert logits.shape == (BATCH, cfg.vocab_padded)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
        tok = jnp.argmax(logits[:, : cfg.vocab], axis=-1)[:, None]


def test_whisper_decode():
    cfg = smoke_config("whisper_large_v3")
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    frames = jax.random.normal(jax.random.PRNGKey(1), (BATCH, 64, cfg.d_model)) * 0.02
    cross_kv = lm.encoder_forward(params, cfg, frames.astype(jnp.dtype(cfg.dtype)))
    state = lm.init_decode_state(cfg, BATCH, max_len=16, cross_kv=cross_kv)
    decode = jax.jit(steps.make_decode_step(cfg))
    tok = jnp.zeros((BATCH, 1), jnp.int32)
    logits, state = decode(params, tok, state)
    assert logits.shape == (BATCH, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", all_arch_ids())
def test_param_counts_positive(arch):
    from repro.configs.registry import full_config

    cfg = full_config(arch)
    counts = count_params(cfg)
    assert counts["total"] > 0
    assert 0 < counts["active"] <= counts["total"]


def test_full_param_counts_match_scale():
    """Full configs should land near their nominal parameter counts."""
    from repro.configs.registry import full_config

    # Expected totals follow the *assigned* configs (which for moonshot give
    # 28B — the assignment's 48L x 64e differs from the HF model's 27L).
    expect = {  # billions, tolerance
        "codeqwen15_7b": (8.2, 0.1),
        "granite_34b": (34, 0.1),
        "gemma_7b": (8.5, 0.1),
        "deepseek_v3_671b": (671, 0.05),
        "moonshot_v1_16b_a3b": (28.4, 0.1),
        "pixtral_12b": (12.3, 0.1),
        "xlstm_350m": (0.35, 0.25),
        "whisper_large_v3": (1.6, 0.15),
        "minitron_4b": (4.2, 0.1),
        "zamba2_1p2b": (1.2, 0.15),
    }
    for arch, (nominal, tol) in expect.items():
        total = count_params(full_config(arch))["total"] / 1e9
        assert abs(total - nominal) / nominal < tol, (arch, total, nominal)

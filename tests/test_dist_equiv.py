"""Distributed CPADMM == single-device CPADMM, in-process (fast lane).

The 8-device subprocess programs (tests/dist_progs/) are the real multi-device
exercise but run in the ``slow`` lane.  This test pins the same numerical
contract cheaply: the ``repro.dist.recovery`` solver on a 1-device mesh must
reproduce the ``repro.core.solvers`` CPADMM iterate to tight relative error —
the sharded code path (shard_map, four-step FFT, spectral inverse) is fully
exercised; only the collective is trivial.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import RecoveryProblem, solve
from repro.core.circulant import PartialCirculant, gaussian_circulant
from repro.data.synthetic import paper_regime, sparse_signal
from repro.dist.compat import make_mesh
from repro.dist.fft import (
    freq_flat,
    half_to_full,
    layout_2d,
    make_distributed_fft,
    make_distributed_matvec,
    make_distributed_rfft,
    padded_rfft_len,
    rfft_len,
    unlayout_2d,
)
from repro.dist.recovery import make_dist_cpadmm, make_dist_spectrum

N1, N2 = 32, 16
N = N1 * N2
ITERS = 300
ALPHA, RHO, SIGMA = 1e-4, 0.01, 0.01


def _problem():
    x_true = sparse_signal(jax.random.PRNGKey(0), N, paper_regime(N)[1])
    C = gaussian_circulant(jax.random.PRNGKey(1), N, normalize=True)
    m = paper_regime(N)[0]
    omega = jnp.sort(jax.random.permutation(jax.random.PRNGKey(2), N)[:m])
    mask = jnp.zeros((N,)).at[omega].set(1.0)
    return x_true, C, omega, mask


def test_four_step_fft_matches_dense_fft():
    mesh = make_mesh((1,), ("model",))
    x = jax.random.normal(jax.random.PRNGKey(3), (N,))
    fft2d, ifft2d = make_distributed_fft(mesh, N1, N2)
    F = fft2d(layout_2d(x, N1, N2).astype(jnp.complex64))
    np.testing.assert_allclose(
        np.asarray(freq_flat(F)),
        np.asarray(jnp.fft.fft(x.astype(jnp.complex64))),
        rtol=1e-3,
        atol=1e-3,
    )
    back = jnp.real(ifft2d(F))
    np.testing.assert_allclose(
        np.asarray(unlayout_2d(back)), np.asarray(x), atol=1e-5
    )


def test_distributed_matvec_matches_operator():
    mesh = make_mesh((1,), ("model",))
    _, C, _, _ = _problem()
    x = jax.random.normal(jax.random.PRNGKey(4), (N,))
    fft2d, _ = make_distributed_fft(mesh, N1, N2)
    spec2d = fft2d(layout_2d(C.col, N1, N2).astype(jnp.complex64))
    mv = make_distributed_matvec(mesh)
    np.testing.assert_allclose(
        np.asarray(unlayout_2d(mv(spec2d, layout_2d(x, N1, N2)))),
        np.asarray(C.matvec(x)),
        atol=1e-4,
    )
    np.testing.assert_allclose(
        np.asarray(unlayout_2d(mv(spec2d, layout_2d(x, N1, N2), True))),
        np.asarray(C.rmatvec(x)),
        atol=1e-4,
    )


# ---------------------------------------------------------------------------
# rfft half-spectrum parity: new path vs old full-complex path vs jnp.fft,
# on odd and even n1 x n2 factorizations (the Hermitian bookkeeping's edge
# cases: odd column counts, Nyquist column present/absent).
# ---------------------------------------------------------------------------

RFFT_FACTORIZATIONS = [(32, 16), (16, 15), (15, 16), (15, 15), (8, 14)]


@pytest.mark.parametrize("n1,n2", RFFT_FACTORIZATIONS)
def test_rfft_matches_full_complex_and_reference(n1, n2):
    """Half-spectrum forward == full-complex forward == jnp.fft, 1e-5 rel."""
    n = n1 * n2
    mesh = make_mesh((1,), ("model",))
    x = jax.random.normal(jax.random.PRNGKey(11), (n,))
    rfft2d, _ = make_distributed_rfft(mesh, n1, n2)
    fft2d, _ = make_distributed_fft(mesh, n1, n2)

    Fh = rfft2d(layout_2d(x, n1, n2))
    assert Fh.shape == (n1, padded_rfft_len(n2, 1))
    full_from_half = freq_flat(half_to_full(Fh, n2))
    full_old = freq_flat(fft2d(layout_2d(x, n1, n2).astype(jnp.complex64)))
    ref = jnp.fft.fft(x.astype(jnp.complex64))

    scale = float(jnp.max(jnp.abs(ref)))
    np.testing.assert_allclose(
        np.asarray(full_from_half), np.asarray(full_old), atol=1e-5 * scale
    )
    np.testing.assert_allclose(
        np.asarray(full_from_half), np.asarray(ref), atol=1e-5 * scale
    )


@pytest.mark.parametrize("n1,n2", RFFT_FACTORIZATIONS)
def test_rfft_roundtrip_is_identity(n1, n2):
    n = n1 * n2
    mesh = make_mesh((1,), ("model",))
    x = jax.random.normal(jax.random.PRNGKey(12), (n,))
    rfft2d, irfft2d = make_distributed_rfft(mesh, n1, n2)
    back = irfft2d(rfft2d(layout_2d(x, n1, n2)))
    assert back.dtype == x.dtype  # real in, real out — no complex detour
    np.testing.assert_allclose(np.asarray(unlayout_2d(back)), np.asarray(x), atol=1e-5)


def test_rfft_half_spectrum_column_count():
    """The half layout keeps n2//2+1 columns (padded to the mesh size)."""
    assert rfft_len(16) == 9 and rfft_len(15) == 8
    assert padded_rfft_len(16, 8) == 16 and padded_rfft_len(30, 8) == 16
    assert padded_rfft_len(16, 1) == 9


@pytest.mark.parametrize("transpose", [False, True])
def test_rfft_matvec_matches_full_and_operator(transpose):
    mesh = make_mesh((1,), ("model",))
    _, C, _, _ = _problem()
    x = jax.random.normal(jax.random.PRNGKey(13), (N,))
    rfft2d, _ = make_distributed_rfft(mesh, N1, N2)
    spec_h = rfft2d(layout_2d(C.col, N1, N2))
    mv_r = make_distributed_matvec(mesh, rfft=True)
    fft2d, _ = make_distributed_fft(mesh, N1, N2)
    spec_full = fft2d(layout_2d(C.col, N1, N2).astype(jnp.complex64))
    mv_c = make_distributed_matvec(mesh)

    got_r = unlayout_2d(mv_r(spec_h, layout_2d(x, N1, N2), transpose))
    got_c = unlayout_2d(mv_c(spec_full, layout_2d(x, N1, N2), transpose))
    want = C.rmatvec(x) if transpose else C.matvec(x)
    scale = float(jnp.max(jnp.abs(want)))
    np.testing.assert_allclose(np.asarray(got_r), np.asarray(got_c), atol=1e-5 * scale)
    np.testing.assert_allclose(np.asarray(got_r), np.asarray(want), atol=1e-5 * scale)


# ---------------------------------------------------------------------------
# overlapped chunked-transpose pipeline: overlap=K must match the monolithic
# overlap=1 path to 1e-5 rel on odd/even factorizations (uneven chunk / pad
# edge cases), for fft and rfft, unbatched and batched over the data axis.
# ---------------------------------------------------------------------------

OVERLAP_FACTORIZATIONS = [(32, 16), (16, 15), (15, 16), (15, 15)]


def _rel(got, want):
    got, want = jnp.asarray(got), jnp.asarray(want)
    return float(jnp.linalg.norm(got - want) / (jnp.linalg.norm(want) + 1e-30))


@pytest.mark.parametrize("n1,n2", OVERLAP_FACTORIZATIONS)
@pytest.mark.parametrize("overlap", [2, 3])
def test_overlap_fft_matches_monolithic(n1, n2, overlap):
    n = n1 * n2
    mesh = make_mesh((1,), ("model",))
    x = layout_2d(jax.random.normal(jax.random.PRNGKey(21), (n,)), n1, n2)

    f1, i1 = make_distributed_fft(mesh, n1, n2, overlap=1)
    fk, ik = make_distributed_fft(mesh, n1, n2, overlap=overlap)
    F1, Fk = f1(x.astype(jnp.complex64)), fk(x.astype(jnp.complex64))
    assert _rel(Fk, F1) <= 1e-5
    assert _rel(ik(Fk), i1(F1)) <= 1e-5

    r1, ir1 = make_distributed_rfft(mesh, n1, n2, overlap=1)
    rk, irk = make_distributed_rfft(mesh, n1, n2, overlap=overlap)
    H1, Hk = r1(x), rk(x)
    assert Hk.shape == H1.shape
    assert _rel(Hk, H1) <= 1e-5
    assert _rel(irk(Hk), ir1(H1)) <= 1e-5


@pytest.mark.parametrize("n1,n2", [(32, 16), (15, 16)])
def test_overlap_batched_data_axis_matches_monolithic(n1, n2):
    """overlap=K under a leading data-axis batch: the chunk reassembly must
    broadcast over the batch dimension."""
    n, B = n1 * n2, 3
    mesh = make_mesh((1, 1), ("data", "model"))
    x = layout_2d(jax.random.normal(jax.random.PRNGKey(22), (B, n)), n1, n2)

    r1, ir1 = make_distributed_rfft(mesh, n1, n2, batch_axis="data", overlap=1)
    rk, irk = make_distributed_rfft(mesh, n1, n2, batch_axis="data", overlap=3)
    H1, Hk = r1(x), rk(x)
    assert Hk.shape == H1.shape == (B, n1, padded_rfft_len(n2, 1))
    assert _rel(Hk, H1) <= 1e-5
    assert _rel(irk(Hk), ir1(H1)) <= 1e-5

    f1, i1 = make_distributed_fft(mesh, n1, n2, batch_axis="data", overlap=1)
    fk, ik = make_distributed_fft(mesh, n1, n2, batch_axis="data", overlap=4)
    F1, Fk = f1(x.astype(jnp.complex64)), fk(x.astype(jnp.complex64))
    assert _rel(Fk, F1) <= 1e-5
    assert _rel(ik(Fk), i1(F1)) <= 1e-5


@pytest.mark.parametrize("rfft", [False, True])
def test_overlap_matvec_matches_monolithic(rfft):
    mesh = make_mesh((1,), ("model",))
    _, C, _, _ = _problem()
    x2d = layout_2d(jax.random.normal(jax.random.PRNGKey(23), (N,)), N1, N2)
    if rfft:
        spec = make_distributed_rfft(mesh, N1, N2)[0](layout_2d(C.col, N1, N2))
    else:
        spec = make_distributed_fft(mesh, N1, N2)[0](
            layout_2d(C.col, N1, N2).astype(jnp.complex64)
        )
    mv1 = make_distributed_matvec(mesh, rfft=rfft, overlap=1)
    mvk = make_distributed_matvec(mesh, rfft=rfft, overlap=4)
    for transpose in (False, True):
        assert _rel(mvk(spec, x2d, transpose), mv1(spec, x2d, transpose)) <= 1e-5


@pytest.mark.parametrize("rfft", [False, True])
def test_overlap_dist_cpadmm_matches_core_solver(rfft):
    """The overlapped solver hits the same 1e-5 acceptance gate as overlap=1."""
    x_true, C, omega, mask = _problem()
    op = PartialCirculant(C, omega.astype(jnp.int32))
    y = jnp.take(C.matvec(x_true), omega)
    x_ref, _ = solve(
        RecoveryProblem(op=op, y=y, x_true=x_true),
        "cpadmm", iters=ITERS, record_every=ITERS,
        alpha=ALPHA, rho=RHO, sigma=SIGMA,
    )

    mesh = make_mesh((1,), ("model",))
    spec = make_dist_spectrum(mesh, rfft=rfft)(layout_2d(C.col, N1, N2))
    solver = make_dist_cpadmm(mesh, N1, N2, ITERS, fused=True, rfft=rfft, overlap=4)
    z2d = solver(
        spec,
        layout_2d(mask, N1, N2),
        layout_2d(mask * C.matvec(x_true), N1, N2),
        jnp.float32(ALPHA),
        jnp.float32(RHO),
        jnp.float32(SIGMA),
    )
    rel = _rel(unlayout_2d(z2d), x_ref)
    assert rel <= 1e-5, f"overlap=4 rfft={rfft}: relative error {rel:.2e} > 1e-5"


@pytest.mark.parametrize("fused", [False, True])
def test_pallas_tail_matches_jnp_tail(fused):
    """tail='pallas' (fused cpadmm_tail kernel, interpret mode on CPU) must
    reproduce the default jnp tail on the same solve."""
    x_true, C, omega, mask = _problem()
    mesh = make_mesh((1,), ("model",))
    spec_h = make_dist_spectrum(mesh, rfft=True)(layout_2d(C.col, N1, N2))
    args = (
        spec_h,
        layout_2d(mask, N1, N2),
        layout_2d(mask * C.matvec(x_true), N1, N2),
        jnp.float32(ALPHA),
        jnp.float32(RHO),
        jnp.float32(SIGMA),
    )
    iters = 25  # interpret-mode Pallas per iteration: keep the scan short
    z_jnp = make_dist_cpadmm(mesh, N1, N2, iters, fused=fused, rfft=True)(*args)
    z_pal = make_dist_cpadmm(
        mesh, N1, N2, iters, fused=fused, rfft=True, tail="pallas"
    )(*args)
    assert _rel(z_pal, z_jnp) <= 1e-5


@pytest.mark.parametrize("fused", [False, True])
def test_rfft_dist_cpadmm_matches_core_solver(fused):
    """The half-spectrum solver hits the same 1e-5 gate as the full path."""
    x_true, C, omega, mask = _problem()
    y = jnp.take(C.matvec(x_true), omega)
    op = PartialCirculant(C, omega.astype(jnp.int32))
    x_ref, _ = solve(
        RecoveryProblem(op=op, y=y, x_true=x_true),
        "cpadmm", iters=ITERS, record_every=ITERS,
        alpha=ALPHA, rho=RHO, sigma=SIGMA,
    )

    mesh = make_mesh((1,), ("model",))
    spec_h = make_dist_spectrum(mesh, rfft=True)(layout_2d(C.col, N1, N2))
    solver = make_dist_cpadmm(mesh, N1, N2, ITERS, fused=fused, rfft=True)
    z2d = solver(
        spec_h,
        layout_2d(mask, N1, N2),
        layout_2d(mask * C.matvec(x_true), N1, N2),
        jnp.float32(ALPHA),
        jnp.float32(RHO),
        jnp.float32(SIGMA),
    )
    x_dist = unlayout_2d(z2d)
    rel = float(
        jnp.linalg.norm(x_dist - x_ref) / (jnp.linalg.norm(x_ref) + 1e-30)
    )
    assert rel <= 1e-5, f"rfft fused={fused}: relative error {rel:.2e} > 1e-5"


@pytest.mark.parametrize("fused", [False, True])
def test_dist_cpadmm_matches_core_solver(fused):
    """Acceptance gate: <= 1e-5 relative error vs core CPADMM, same problem."""
    x_true, C, omega, mask = _problem()
    y = jnp.take(C.matvec(x_true), omega)

    op = PartialCirculant(C, omega.astype(jnp.int32))
    prob = RecoveryProblem(op=op, y=y, x_true=x_true)
    x_ref, _ = solve(
        prob, "cpadmm", iters=ITERS, record_every=ITERS,
        alpha=ALPHA, rho=RHO, sigma=SIGMA,
    )

    mesh = make_mesh((1,), ("model",))
    spec2d = make_dist_spectrum(mesh)(layout_2d(C.col, N1, N2))
    solver = make_dist_cpadmm(mesh, N1, N2, ITERS, fused=fused)
    z2d = solver(
        spec2d,
        layout_2d(mask, N1, N2),
        layout_2d(mask * C.matvec(x_true), N1, N2),  # P^T y, full-length
        jnp.float32(ALPHA),
        jnp.float32(RHO),
        jnp.float32(SIGMA),
    )
    x_dist = unlayout_2d(z2d)

    rel = float(
        jnp.linalg.norm(x_dist - x_ref) / (jnp.linalg.norm(x_ref) + 1e-30)
    )
    assert rel <= 1e-5, f"fused={fused}: relative error {rel:.2e} > 1e-5"


# ---------------------------------------------------------------------------
# wire-compressed collectives (ISSUE 8): demoted transpose payloads
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rfft", [False, True])
def test_fp32_wire_is_bit_exact_with_legacy_path(rfft):
    """wire_dtype='fp32' short-circuits to the direct all_to_all — the
    compiled program must be the legacy one, bit for bit."""
    mesh = make_mesh((1,), ("model",))
    _, C, _, _ = _problem()
    x2d = layout_2d(jax.random.normal(jax.random.PRNGKey(31), (N,)), N1, N2)
    if rfft:
        spec = make_distributed_rfft(mesh, N1, N2)[0](layout_2d(C.col, N1, N2))
    else:
        spec = make_distributed_fft(mesh, N1, N2)[0](
            layout_2d(C.col, N1, N2).astype(jnp.complex64)
        )
    mv = make_distributed_matvec(mesh, rfft=rfft)
    mv32 = make_distributed_matvec(mesh, rfft=rfft, wire_dtype="fp32")
    for transpose in (False, True):
        np.testing.assert_array_equal(
            np.asarray(mv32(spec, x2d, transpose)),
            np.asarray(mv(spec, x2d, transpose)),
        )


@pytest.mark.parametrize("wire", ["bf16", "fp16"])
@pytest.mark.parametrize("rfft", [False, True])
def test_wire_matvec_within_guard_bound(rfft, wire):
    """Demoted-wire matvecs stay within the plan layer's precision bound —
    the quantity the plan() guard probes before accepting the plan."""
    from repro.ops.plan import WIRE_ERROR_BOUND

    mesh = make_mesh((1,), ("model",))
    _, C, _, _ = _problem()
    x2d = layout_2d(jax.random.normal(jax.random.PRNGKey(37), (N,)), N1, N2)
    if rfft:
        spec = make_distributed_rfft(mesh, N1, N2)[0](layout_2d(C.col, N1, N2))
    else:
        spec = make_distributed_fft(mesh, N1, N2)[0](
            layout_2d(C.col, N1, N2).astype(jnp.complex64)
        )
    mv32 = make_distributed_matvec(mesh, rfft=rfft)
    mvw = make_distributed_matvec(mesh, rfft=rfft, wire_dtype=wire)
    for transpose in (False, True):
        rel = _rel(mvw(spec, x2d, transpose), mv32(spec, x2d, transpose))
        assert 0 < rel <= WIRE_ERROR_BOUND, (wire, rfft, transpose, rel)


def test_bf16_wire_dist_cpadmm_within_guard_bound():
    """End-to-end: the bf16-wire CPADMM solve lands within the documented
    wire error bound of the fp32-wire solve (same seed, same iterates)."""
    from repro.ops.plan import WIRE_ERROR_BOUND

    x_true, C, omega, mask = _problem()
    mesh = make_mesh((1,), ("model",))
    spec_h = make_dist_spectrum(mesh, rfft=True)(layout_2d(C.col, N1, N2))
    args = (
        spec_h,
        layout_2d(mask, N1, N2),
        layout_2d(mask * C.matvec(x_true), N1, N2),
        jnp.float32(ALPHA),
        jnp.float32(RHO),
        jnp.float32(SIGMA),
    )
    z32 = make_dist_cpadmm(mesh, N1, N2, ITERS, rfft=True)(*args)
    zbf = make_dist_cpadmm(mesh, N1, N2, ITERS, rfft=True,
                           wire_dtype="bf16")(*args)
    rel = _rel(unlayout_2d(zbf), unlayout_2d(z32))
    assert rel <= WIRE_ERROR_BOUND, f"bf16 wire: rel {rel:.2e}"


# ---------------------------------------------------------------------------
# hierarchical two-stage transpose: on the 1-device (1 x 1) host x device
# mesh the exchange is degenerate (no inter-host hop), but the full hier
# code path — device-major specs, tuple axis ranks, reorder/reshape — runs
# and must match the flat single-axis transforms exactly.  The real H>1
# parity and per-tier byte pins live in tests/dist_progs/hier_prog.py.
# ---------------------------------------------------------------------------

HIER_FACTORIZATIONS = [(32, 16), (16, 15), (15, 16), (15, 15)]


def _hier_mesh_1dev():
    return make_mesh((1, 1, 1), ("data", "host", "device"))


@pytest.mark.parametrize("n1,n2", HIER_FACTORIZATIONS)
@pytest.mark.parametrize("overlap", [1, 2, 3])
def test_hier_fft_matches_flat(n1, n2, overlap):
    n = n1 * n2
    flat = make_mesh((1,), ("model",))
    hier = _hier_mesh_1dev()
    x = layout_2d(jax.random.normal(jax.random.PRNGKey(31), (n,)), n1, n2)

    f1, i1 = make_distributed_fft(flat, n1, n2, overlap=overlap)
    fh, ih = make_distributed_fft(
        hier, n1, n2, axis_name=("host", "device"), overlap=overlap, hier=True
    )
    F1, Fh = f1(x.astype(jnp.complex64)), fh(x.astype(jnp.complex64))
    assert _rel(Fh, F1) <= 1e-5
    assert _rel(ih(Fh), i1(F1)) <= 1e-5

    r1, ir1 = make_distributed_rfft(flat, n1, n2, overlap=overlap)
    rh, irh = make_distributed_rfft(
        hier, n1, n2, axis_name=("host", "device"), overlap=overlap, hier=True
    )
    H1, Hh = r1(x), rh(x)
    assert Hh.shape == H1.shape
    assert _rel(Hh, H1) <= 1e-5
    assert _rel(irh(Hh), ir1(H1)) <= 1e-5


@pytest.mark.parametrize("n1,n2", [(32, 16), (15, 16)])
def test_hier_batched_data_axis_matches_flat(n1, n2):
    n, B = n1 * n2, 3
    flat = make_mesh((1, 1), ("data", "model"))
    hier = _hier_mesh_1dev()
    x = layout_2d(jax.random.normal(jax.random.PRNGKey(32), (B, n)), n1, n2)

    r1, ir1 = make_distributed_rfft(flat, n1, n2, batch_axis="data", overlap=2)
    rh, irh = make_distributed_rfft(
        hier, n1, n2, axis_name=("host", "device"), batch_axis="data",
        overlap=2, hier=True,
    )
    H1, Hh = r1(x), rh(x)
    assert Hh.shape == H1.shape == (B, n1, padded_rfft_len(n2, 1))
    assert _rel(Hh, H1) <= 1e-5
    assert _rel(irh(Hh), ir1(H1)) <= 1e-5


@pytest.mark.parametrize("rfft", [False, True])
def test_hier_matvec_matches_flat(rfft):
    flat = make_mesh((1,), ("model",))
    hier = _hier_mesh_1dev()
    _, C, _, _ = _problem()
    x2d = layout_2d(jax.random.normal(jax.random.PRNGKey(33), (N,)), N1, N2)
    if rfft:
        spec = make_distributed_rfft(flat, N1, N2)[0](layout_2d(C.col, N1, N2))
    else:
        spec = make_distributed_fft(flat, N1, N2)[0](
            layout_2d(C.col, N1, N2).astype(jnp.complex64)
        )
    mv1 = make_distributed_matvec(flat, rfft=rfft)
    mvh = make_distributed_matvec(
        hier, rfft=rfft, axis_name=("host", "device"), hier=True
    )
    for transpose in (False, True):
        assert _rel(mvh(spec, x2d, transpose), mv1(spec, x2d, transpose)) <= 1e-5

"""Kernel-backed solver steps must match the jnp-backed steps exactly."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.admm import (
    CpadmmParams,
    cpadmm_init,
    cpadmm_setup,
    cpadmm_step,
)
from repro.core.circulant import partial_gaussian_circulant
from repro.core.ista import IstaParams, ista_init, ista_step
from repro.core.kernel_backend import cpadmm_step_pallas, ista_step_pallas
from repro.data.synthetic import paper_regime, sparse_signal


def _setup(n=256, seed=0):
    m, k = paper_regime(n)
    x = sparse_signal(jax.random.PRNGKey(seed), n, k)
    op = partial_gaussian_circulant(jax.random.PRNGKey(seed + 1), n, m, normalize=True)
    return op, op.matvec(x)


def test_ista_backends_agree():
    op, y = _setup()
    p = IstaParams(alpha=jnp.float32(1e-4), tau=jnp.float32(0.5))
    s_j = s_p = ista_init(op, y)
    for it in range(5):
        s_j = ista_step(op, y, s_j, p)
        s_p = ista_step_pallas(op, y, s_p, p)
        np.testing.assert_allclose(
            np.asarray(s_p.x), np.asarray(s_j.x), atol=5e-5,
            err_msg=f"diverged at iteration {it}",
        )


def test_cpadmm_backends_agree():
    op, y = _setup(seed=3)
    p = CpadmmParams(*(jnp.float32(v) for v in (1e-4, 0.01, 0.01, 1.0, 1.0)))
    const = cpadmm_setup(op, y, p)
    s_j = s_p = cpadmm_init(op, y)
    for it in range(5):
        s_j = cpadmm_step(op, const, s_j, p)
        s_p = cpadmm_step_pallas(op, const, s_p, p)
        for f in ("x", "v", "z", "mu", "nu"):
            np.testing.assert_allclose(
                np.asarray(getattr(s_p, f)),
                np.asarray(getattr(s_j, f)),
                atol=5e-5,
                err_msg=f"field {f} diverged at iteration {it}",
            )

"""Unit + property tests for circulant operator algebra (paper Sec. 4)."""

import pytest

hypothesis = pytest.importorskip("hypothesis")  # optional dev dep; CI installs it
import hypothesis.strategies as st  # noqa: E402
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.circulant import (
    Circulant,
    DenseOperator,
    compose_sensing_blur,
    densify,
    gaussian_circulant,
    moving_average_blur,
    partial_gaussian_circulant,
    random_omega,
    romberg_circulant,
)

SETTINGS = dict(max_examples=25, deadline=None)


def _rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------------------
# Representation & conventions
# ---------------------------------------------------------------------------


def test_first_row_convention_matches_paper():
    """Paper Sec. 4.2: A[i,j] = v[(j-i) mod n]."""
    row = jnp.asarray([1.0, 2.0, 3.0, 4.0, 5.0])
    C = Circulant.from_first_row(row)
    d = np.asarray(C.to_dense())
    n = 5
    v = np.asarray(row)
    for i in range(n):
        for j in range(n):
            assert d[i, j] == v[(j - i) % n]


def test_first_col_roundtrip():
    col = _rand(0, 9)
    C = Circulant.from_first_col(col)
    np.testing.assert_allclose(np.asarray(C.to_dense())[:, 0], col, rtol=1e-6)
    np.testing.assert_allclose(C.first_row, np.asarray(C.to_dense())[0], rtol=1e-6)


@hypothesis.given(n=st.integers(4, 257), seed=st.integers(0, 2**20))
@hypothesis.settings(**SETTINGS)
def test_matvec_matches_dense(n, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    C = gaussian_circulant(k1, n)
    x = jax.random.normal(k2, (n,))
    dense = np.asarray(C.to_dense())
    scale = max(1.0, float(np.abs(dense @ np.asarray(x)).max()))
    np.testing.assert_allclose(
        np.asarray(C.matvec(x)), dense @ np.asarray(x), atol=2e-4 * scale
    )
    np.testing.assert_allclose(
        np.asarray(C.rmatvec(x)), dense.T @ np.asarray(x), atol=2e-4 * scale
    )


@hypothesis.given(n=st.integers(4, 128), seed=st.integers(0, 2**20))
@hypothesis.settings(**SETTINGS)
def test_gram_compose_inverse(n, seed):
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    C = gaussian_circulant(keys[0], n)
    D = gaussian_circulant(keys[1], n)
    dc, dd = np.asarray(C.to_dense()), np.asarray(D.to_dense())
    atol = 1e-3 * max(1.0, float(np.abs(dc).max()) ** 2) * n
    np.testing.assert_allclose(np.asarray(C.gram().to_dense()), dc.T @ dc, atol=atol)
    np.testing.assert_allclose(
        np.asarray(C.compose(D).to_dense()), dc @ dd, atol=atol
    )
    # inverse of a well-conditioned shifted gram
    B = C.gram().add_scaled_identity(0.1, 1.0)
    np.testing.assert_allclose(
        np.asarray(B.inverse().to_dense()),
        np.linalg.inv(np.asarray(B.to_dense())),
        atol=1e-4,
    )


def test_operator_norm_exact():
    C = gaussian_circulant(jax.random.PRNGKey(7), 64)
    np.testing.assert_allclose(
        float(C.operator_norm()),
        np.linalg.norm(np.asarray(C.to_dense()), 2),
        rtol=1e-5,
    )


def test_transpose_spectrum():
    C = gaussian_circulant(jax.random.PRNGKey(3), 33)
    np.testing.assert_allclose(
        np.asarray(C.transpose().to_dense()), np.asarray(C.to_dense()).T, atol=1e-4
    )


def test_batched_matvec():
    C = gaussian_circulant(jax.random.PRNGKey(1), 32)
    xb = _rand(2, 4, 3, 32)
    out = C.matvec(xb)
    assert out.shape == (4, 3, 32)
    np.testing.assert_allclose(
        np.asarray(out[1, 2]), np.asarray(C.matvec(xb[1, 2])), rtol=1e-5, atol=1e-5
    )


# ---------------------------------------------------------------------------
# Partial circulant A = P C (paper Sec. 4.3)
# ---------------------------------------------------------------------------


@hypothesis.given(
    n=st.integers(8, 120), frac=st.floats(0.2, 0.9), seed=st.integers(0, 2**20)
)
@hypothesis.settings(**SETTINGS)
def test_partial_matches_dense(n, frac, seed):
    m = max(1, int(n * frac))
    op = partial_gaussian_circulant(jax.random.PRNGKey(seed), n, m)
    assert op.shape == (m, n)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (n,))
    ym = jax.random.normal(jax.random.PRNGKey(seed + 2), (m,))
    dense = np.asarray(op.to_dense())
    atol = 2e-4 * max(1.0, float(np.abs(dense).max())) * n
    np.testing.assert_allclose(np.asarray(op.matvec(x)), dense @ np.asarray(x), atol=atol)
    np.testing.assert_allclose(
        np.asarray(op.rmatvec(ym)), dense.T @ np.asarray(ym), atol=atol
    )


def test_project_back_scatter():
    op = partial_gaussian_circulant(jax.random.PRNGKey(0), 16, 5)
    y = jnp.arange(1.0, 6.0)
    full = op.project_back(y)
    assert full.shape == (16,)
    np.testing.assert_allclose(np.asarray(full[op.omega]), np.asarray(y))
    assert float(jnp.sum(jnp.abs(full))) == pytest.approx(float(jnp.sum(y)))


def test_omega_unique_sorted():
    om = random_omega(jax.random.PRNGKey(5), 100, 40)
    o = np.asarray(om)
    assert len(np.unique(o)) == 40
    assert (np.sort(o) == o).all()


def test_norm_bound_is_upper_bound():
    op = partial_gaussian_circulant(jax.random.PRNGKey(9), 96, 48)
    true = np.linalg.norm(np.asarray(op.to_dense()), 2)
    assert float(op.operator_norm_bound()) >= true - 1e-4


# ---------------------------------------------------------------------------
# Romberg random convolution (beyond-paper conditioning)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [16, 33, 128])
def test_romberg_is_orthogonal(n):
    C = romberg_circulant(jax.random.PRNGKey(11), n)
    d = np.asarray(C.to_dense())
    np.testing.assert_allclose(d.T @ d, np.eye(n), atol=1e-4)
    assert float(C.operator_norm()) == pytest.approx(1.0, abs=1e-5)


# ---------------------------------------------------------------------------
# Blur composition (paper Sec. 7)
# ---------------------------------------------------------------------------


def test_moving_average_blur_row():
    B = moving_average_blur(8, 3)
    d = np.asarray(B.to_dense())
    np.testing.assert_allclose(d[0], [1 / 3, 1 / 3, 1 / 3, 0, 0, 0, 0, 0], atol=1e-7)
    np.testing.assert_allclose(d.sum(axis=1), np.ones(8), atol=1e-6)  # row-stochastic


def test_blur_composition_is_product():
    key = jax.random.PRNGKey(2)
    C = gaussian_circulant(key, 32)
    B = moving_average_blur(32, 5)
    A = compose_sensing_blur(C, B)
    np.testing.assert_allclose(
        np.asarray(A.to_dense()),
        np.asarray(C.to_dense()) @ np.asarray(B.to_dense()),
        atol=1e-3,
    )


def test_compose_spectrum_is_exact_product():
    """Composition stores the pointwise product spectrum bit-exactly — no
    irfft->rfft round trip (what lets plan() shard composed spectra as-is)."""
    C = gaussian_circulant(jax.random.PRNGKey(2), 32)
    B = moving_average_blur(32, 5)
    np.testing.assert_array_equal(
        np.asarray(C.compose(B).spec), np.asarray(C.spec * B.spec)
    )


def test_moving_average_blur_validates_order():
    """order > n used to silently truncate (.at[:order].set clips) so the
    kernel no longer summed to 1; now it is a loud error."""
    with pytest.raises(ValueError, match="order"):
        moving_average_blur(8, 9)
    with pytest.raises(ValueError, match="order"):
        moving_average_blur(8, 0)
    with pytest.raises(ValueError, match="order"):
        moving_average_blur(8, -3)
    # order == n is the legal extreme: the full-window average
    B = moving_average_blur(8, 8)
    np.testing.assert_allclose(np.asarray(B.col), np.full(8, 1 / 8), atol=1e-7)
    for order in (1, 3, 8):
        s = float(moving_average_blur(8, order).col.sum())
        assert s == pytest.approx(1.0, abs=1e-6), order


def test_compose_rejects_size_mismatch():
    """n mismatch raises a shape error up front, not a cryptic spectral
    broadcast failure deep in the rfft algebra."""
    C = gaussian_circulant(jax.random.PRNGKey(0), 16)
    B = moving_average_blur(32, 3)
    with pytest.raises(ValueError, match="different sizes: n=16 vs n=32"):
        C.compose(B)
    with pytest.raises(ValueError, match="different signal lengths"):
        compose_sensing_blur(C, B)


# ---------------------------------------------------------------------------
# Memory-footprint claim (paper Fig. 3): O(n) vs O(n^2)
# ---------------------------------------------------------------------------


def test_footprint_linear_vs_quadratic():
    n = 1 << 10
    op = partial_gaussian_circulant(jax.random.PRNGKey(0), n, n // 2)
    circ_bytes = op.circ.col.nbytes + op.circ.spec.nbytes + op.omega.nbytes
    dense_bytes = densify(op).mat.nbytes
    # circulant rep must be >100x smaller at n=1024 and scale ~n vs ~n^2/2
    assert circ_bytes < dense_bytes / 100
    assert circ_bytes <= 16 * n + 64


def test_dense_operator_norm_bound_is_safe_upper_bound():
    op = DenseOperator(_rand(3, 20, 50))
    true = np.linalg.norm(np.asarray(op.mat), 2)
    bound = float(op.operator_norm_bound())
    assert true <= bound <= 4.0 * true  # valid and not absurdly loose

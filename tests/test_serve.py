"""Recovery-as-a-service dispatcher: scheduling, recycling, isolation.

The serving layer's contracts, each pinned deterministically via
``ManualClock`` and seeded workloads:

  * seeded arrivals are bit-for-bit reproducible,
  * a recycled slot computes exactly what a solo ``solve_until`` run
    would (<= 1e-5 relative — the ISSUE acceptance pin),
  * priority orders admission under contention,
  * deadline expiry returns a *flagged partial result*, never raises,
  * requests whose operator or plan config differ never share a batch.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.core import RecoveryProblem, partial_gaussian_circulant, solve_until
from repro.data.synthetic import paper_regime, sparse_signal
from repro.ops import PlanConfig
from repro.serve import (
    ManualClock,
    RecoveryRequest,
    RecoveryServer,
    operator_fingerprint,
    poisson_times,
    static_batch_serve,
    summarize,
    synthetic_workload,
)

N = 128
RHO = 0.01  # production launcher setting; converges well inside max_iters


def _op(seed=1, n=N):
    m, _ = paper_regime(n)
    return partial_gaussian_circulant(jax.random.PRNGKey(seed), n, m,
                                      normalize=True)


def _server(**kw):
    kw.setdefault("slots", 2)
    kw.setdefault("round_iters", 16)
    kw.setdefault("rho", RHO)
    kw.setdefault("sigma", RHO)
    kw.setdefault("clock", ManualClock())
    return RecoveryServer(**kw)


def _workload(op, n_requests, **kw):
    kw.setdefault("rate", 1000.0)
    kw.setdefault("tols", (1e-3, 1e-5))
    kw.setdefault("max_iters", 600)
    return synthetic_workload(op, n_requests, seed=7, **kw)


# -- determinism -----------------------------------------------------------
def test_poisson_arrivals_deterministic():
    a = poisson_times(3, 20, 50.0)
    b = poisson_times(3, 20, 50.0)
    np.testing.assert_array_equal(a, b)
    assert np.all(np.diff(a) > 0) and a[0] > 0
    with pytest.raises(ValueError):
        poisson_times(0, 4, 0.0)


def test_synthetic_workload_reproducible():
    op = _op()
    w1 = _workload(op, 5)
    w2 = _workload(op, 5)
    for r1, r2 in zip(w1, w2):
        assert r1.request_id == r2.request_id
        assert r1.tol == r2.tol and r1.arrival_time == r2.arrival_time
        np.testing.assert_array_equal(np.asarray(r1.y), np.asarray(r2.y))


# -- the acceptance pin: recycled slots match run-alone --------------------
def test_recycled_slot_matches_solo_solve():
    """6 requests through 2 slots forces recycling; every result —
    including recycled-lane ones — must match its solo solve_until run to
    1e-5 relative, with identical iteration counts."""
    op = _op()
    reqs = _workload(op, 6)
    srv = _server()
    results = srv.serve(reqs)
    assert len(results) == 6
    assert srv.stats()["total"]["recycled"] >= 4  # 6 reqs - 2 cold slots
    by_id = {r.request_id: r for r in reqs}
    for res in results:
        req = by_id[res.request_id]
        x_solo, used = solve_until(
            RecoveryProblem(op=op, y=req.y), "cpadmm", tol=req.tol,
            max_iters=req.max_iters, min_iters=req.min_iters,
            rho=RHO, sigma=RHO,
        )
        x_solo = np.asarray(x_solo)
        rel = np.linalg.norm(res.x - x_solo) / (np.linalg.norm(x_solo) + 1e-12)
        assert rel <= 1e-5, (res.request_id, rel)
        assert res.iterations == int(used), res.request_id
        assert res.converged


def test_static_baseline_serves_same_results():
    op = _op()
    reqs = _workload(op, 5)
    cont = _server().serve(reqs)
    stat = static_batch_serve(reqs, slots=2, round_iters=16, rho=RHO,
                              sigma=RHO, clock=ManualClock())
    assert sorted(r.request_id for r in stat) == \
        sorted(r.request_id for r in cont)
    cont_by_id = {r.request_id: r for r in cont}
    for r in stat:
        assert r.iterations == cont_by_id[r.request_id].iterations
        np.testing.assert_allclose(r.x, cont_by_id[r.request_id].x,
                                   rtol=1e-5, atol=1e-7)


# -- scheduling ------------------------------------------------------------
def test_priority_orders_admission_under_contention():
    """One slot, three same-arrival requests with distinct priorities:
    admission (and hence finish) order must be by descending priority."""
    op = _op()
    _, k = paper_regime(N)
    srv = _server(slots=1)
    for pri, rid in ((0, "low"), (2, "high"), (1, "mid")):
        x = sparse_signal(jax.random.PRNGKey(10 + pri), N, k)
        srv.submit(RecoveryRequest(
            request_id=rid, op=op, y=op.matvec(x), tol=1e-3,
            max_iters=200, priority=pri,
        ))
    results = srv.drain()
    # one slot: finish order IS admission order
    assert [r.request_id for r in results] == ["high", "mid", "low"]


def test_deadline_expiry_returns_flagged_partial():
    """A deadline that lapses mid-solve yields a flagged partial result —
    iterations short of the budget, never an exception; a deadline that
    lapses while queued yields a zero-iterate flagged result."""
    op = _op()
    _, k = paper_regime(N)

    def req(rid, deadline):
        x = sparse_signal(jax.random.PRNGKey(99), N, k)
        return RecoveryRequest(request_id=rid, op=op, y=op.matvec(x),
                               tol=1e-12, min_iters=50, max_iters=5000,
                               deadline=deadline)

    clock = ManualClock()
    srv = _server(slots=1, clock=clock)
    srv.submit(req("in-slot", deadline=0.5))
    srv.step()  # admitted, one round done, deadline still ahead
    clock.advance_to(1.0)
    results = srv.step()
    assert [r.request_id for r in results] == ["in-slot"]
    r = results[0]
    assert r.deadline_expired and not r.converged
    assert 0 < r.iterations < 5000
    assert np.any(np.asarray(r.x) != 0)  # partial iterate, not a zero stub

    srv2 = _server(slots=1, clock=ManualClock(t=3.0))
    srv2.submit(req("queued-expired", deadline=1.0))  # already past
    results2 = srv2.drain()
    r2 = results2[0]
    assert r2.deadline_expired and r2.iterations == 0
    assert r2.admitted_time is None
    assert not np.any(np.asarray(r2.x))


# -- bucket isolation ------------------------------------------------------
def test_distinct_operators_never_share_a_batch():
    """Same shapes, different spectra: content fingerprints differ, so the
    requests land in separate engines and each recovers against its own
    operator (solo-parity checked per result)."""
    op_a, op_b = _op(seed=1), _op(seed=2)
    assert operator_fingerprint(op_a) != operator_fingerprint(op_b)
    reqs = []
    for tag, op in (("a", op_a), ("b", op_b)):
        for r in _workload(op, 2):
            reqs.append(dataclasses.replace(
                r, request_id=f"{tag}-{r.request_id}"))
    srv = _server()
    results = srv.serve(reqs)
    assert srv.stats()["buckets"] == 2
    by_id = {r.request_id: r for r in reqs}
    for res in results:
        req = by_id[res.request_id]
        x_solo, _ = solve_until(
            RecoveryProblem(op=req.op, y=req.y), "cpadmm", tol=req.tol,
            max_iters=req.max_iters, min_iters=req.min_iters,
            rho=RHO, sigma=RHO,
        )
        x_solo = np.asarray(x_solo)
        rel = np.linalg.norm(res.x - x_solo) / (np.linalg.norm(x_solo) + 1e-12)
        assert rel <= 1e-5, (res.request_id, rel)


def test_plan_config_splits_buckets():
    """rfft vs full-complex plan configs must never share a batch: the
    bucket key embeds PlanConfig.describe(), so the keys differ even for
    the same operator and solver."""
    op = _op()
    base = _workload(op, 1)[0]
    r_full = dataclasses.replace(base, plan_config=PlanConfig())
    r_rfft = dataclasses.replace(
        base, plan_config=PlanConfig(rfft=True, n1=8, n2=16))
    srv = _server()
    assert srv.bucket_key(r_full) != srv.bucket_key(r_rfft)
    # methods split buckets too
    r_ista = dataclasses.replace(base, method="ista")
    assert srv.bucket_key(base) != srv.bucket_key(r_ista)


# -- metrics ---------------------------------------------------------------
def test_summarize_reports_throughput_and_percentiles():
    op = _op()
    reqs = _workload(op, 4)
    srv = _server()
    s = summarize(srv.serve(reqs))
    assert s["count"] == 4 and s["converged"] == 4 and s["expired"] == 0
    assert s["signals_per_sec"] > 0
    assert 0 <= s["p50_latency_s"] <= s["p99_latency_s"]
    assert summarize([]) == {"count": 0}


def test_wire_dtype_splits_buckets():
    """bf16-wire and fp32-wire requests must never share a lane: describe()
    carries the wire tag, so the bucket keys differ on that knob alone."""
    op = _op()
    base = _workload(op, 1)[0]
    cfg32 = PlanConfig(rfft=True, n1=8, n2=16)
    cfg16 = PlanConfig(rfft=True, n1=8, n2=16, wire_dtype="bf16")
    srv = _server()
    k32 = srv.bucket_key(dataclasses.replace(base, plan_config=cfg32))
    k16 = srv.bucket_key(dataclasses.replace(base, plan_config=cfg16))
    assert k32 != k16
    assert "wire=bf16" in k16 and "wire=" not in k32


def test_recycled_slots_with_bf16_wire_bucket_isolated():
    """A mixed fp32/bf16-wire stream splits into two engines and recycling
    happens inside each lane.  The fp32 lane keeps the exact 1e-5
    recycled-slot parity contract with its solo same-plan solve.  The bf16
    lane is parity *within the wire bound*: batched and solo programs
    differ by fp32-ulp scheduling noise, and the bf16 wire re-rounds those
    slightly different payloads, so trajectories may part by ~one wire ulp
    per transpose — bounded by the plan layer's guard, never silent
    corruption."""
    from repro.dist.compat import make_mesh
    from repro.ops.plan import WIRE_ERROR_BOUND

    op = _op()
    mesh = make_mesh((1,), ("model",))
    cfg32 = PlanConfig(rfft=True, n1=8, n2=16)
    cfg16 = PlanConfig(rfft=True, n1=8, n2=16, wire_dtype="bf16")
    reqs = []
    for tag, cfg in (("w32", cfg32), ("w16", cfg16)):
        for r in _workload(op, 3, tols=(1e-3,)):
            reqs.append(dataclasses.replace(
                r, request_id=f"{tag}-{r.request_id}", plan_config=cfg))
    srv = _server(mesh=mesh)
    results = srv.serve(reqs)
    assert len(results) == 6
    stats = srv.stats()
    assert stats["buckets"] == 2
    # 3 requests through 2 slots per lane: at least one recycle each
    assert all(s["recycled"] >= 1 for s in stats["per_bucket"].values())
    # recycled-lane parity per bucket: each result matches the solo
    # solve_until run *under the same plan* (the engine computes identical
    # iterates whichever slot/round admitted it); across plans, the bf16
    # result stays within the wire precision bound of the fp32 one
    from repro.ops import plan as plan_fn

    plans = {"w32": plan_fn(op, mesh, config=cfg32),
             "w16": plan_fn(op, mesh, config=cfg16)}
    assert plans["w16"].wire_dtype == "bf16"  # guard accepted the wire
    by_id = {r.request_id: r for r in reqs}
    solo = {}
    for res in results:
        req = by_id[res.request_id]
        lane = res.request_id.split("-")[0]
        x_solo, used = solve_until(
            RecoveryProblem(op=op, y=req.y), "cpadmm", tol=req.tol,
            max_iters=req.max_iters, min_iters=req.min_iters,
            rho=RHO, sigma=RHO, plan=plans[lane],
        )
        solo[res.request_id] = np.asarray(res.x)
        x_solo = np.asarray(x_solo)
        rel = np.linalg.norm(res.x - x_solo) / (np.linalg.norm(x_solo) + 1e-12)
        if lane == "w32":
            assert rel <= 1e-5, (res.request_id, rel)
            assert res.iterations == int(used), res.request_id
        else:
            assert rel <= 2 * WIRE_ERROR_BOUND, (res.request_id, rel)
        assert res.converged, res.request_id
    # across lanes: the bf16 answer deviates from the fp32 one by wire
    # noise (compounded over the solve), not by silent corruption
    for rid16, x16 in solo.items():
        if not rid16.startswith("w16"):
            continue
        x32 = solo["w32" + rid16[len("w16"):]]
        rel = np.linalg.norm(x16 - x32) / (np.linalg.norm(x32) + 1e-12)
        assert 0 < rel <= 2 * WIRE_ERROR_BOUND, (rid16, rel)


def test_hier_plan_splits_buckets():
    """Hierarchical and flat plans must never share a serve lane — the
    exchange strategy changes the compiled program, and the bucket key
    embeds PlanConfig.describe()'s hier=/inter_wire= tags.  All four
    configs on the same operator land in four distinct buckets."""
    op = _op()
    base = _workload(op, 1)[0]
    flat = PlanConfig(rfft=True, n1=8, n2=16)
    tflat = PlanConfig(rfft=True, n1=8, n2=16, axis_name=("host", "device"))
    hier = PlanConfig(rfft=True, n1=8, n2=16, axis_name=("host", "device"),
                      hier_axes=(2, 4))
    hier16 = PlanConfig(rfft=True, n1=8, n2=16, axis_name=("host", "device"),
                        hier_axes=(2, 4), inter_wire_dtype="bf16")
    srv = _server()
    keys = [
        srv.bucket_key(dataclasses.replace(base, plan_config=c))
        for c in (flat, tflat, hier, hier16)
    ]
    assert len(set(keys)) == 4, keys
    assert "hier=2x4" in keys[2] and "inter_wire=bf16" in keys[3]
    assert "hier=" not in keys[0] and "hier=flat" in keys[1]

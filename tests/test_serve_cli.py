"""launch/serve CLI: the serving launcher's flags, in-process at tiny sizes.

Fast-lane coverage for ``repro.launch.serve.main`` — the 8-device forms run
via ``--fake-devices`` as a script; here local engines and a 1-device mesh
exercise the same dispatch routing.
"""


from repro.launch import serve


def test_serve_local_with_static_comparison(capsys):
    serve.main([
        "--n", "256", "--requests", "5", "--slots", "2", "--rate", "500",
        "--max-iters", "300", "--compare-static",
    ])
    out = capsys.readouterr().out
    assert "serving 5 requests, n=256" in out
    assert "continuous:" in out and "signals/s" in out
    assert "recycled" in out
    assert "static baseline:" in out
    assert "continuous vs static:" in out


def test_serve_mesh_plan_with_deadlines(capsys):
    serve.main([
        "--n", "256", "--requests", "3", "--slots", "2", "--rate", "500",
        "--max-iters", "200", "--mesh", "1", "--rfft",
        "--deadline-slack", "60", "--priorities", "0", "1",
    ])
    out = capsys.readouterr().out
    assert "mesh=1 (plan API)" in out
    assert "expired 0" in out  # 60s slack: nothing expires at this size
    assert "buckets 1" in out

"""Paper Fig. 7: matvec schemes vs matrix size.

Paper compares Reference (row-loop), Circulant (shifted-row), CUDA(cuBLAS).
Here: XLA dense GEMV (the cuBLAS analogue), the FFT circulant path, and the
direct Pallas kernel in interpret mode (correctness-only on CPU — its
*structural* HBM-traffic advantage is reported analytically: window reads
O(bi+bj) per tile vs O(bi*bj)).

The distributed four-step matvec is timed in both spectrum layouts so the
rfft half-spectrum lever (PR 2) is visible in the perf trajectory: the
full-complex path moves n complex bins through two transposes per matvec,
the rfft path only the kept n//2+1 columns at half the local FFT flops."""

from __future__ import annotations

import jax

from .common import emit, pick, time_fn

SIZES = pick((1 << 10, 1 << 12, 1 << 14), (1 << 8,))
BLOCK = pick(128, 32)
DIST_N1N2 = pick((128, 128), (16, 16))


def main() -> None:
    import jax.numpy as jnp

    from repro.core import gaussian_circulant
    from repro.dist.compat import make_mesh
    from repro.dist.fft import (
        layout_2d,
        make_distributed_fft,
        make_distributed_matvec,
        make_distributed_rfft,
    )
    from repro.kernels.circulant_matvec.ref import circulant_matvec_fft_ref

    for n in SIZES:
        C = gaussian_circulant(jax.random.PRNGKey(0), n)
        x = jax.random.normal(jax.random.PRNGKey(1), (n,))
        dense = C.to_dense()

        f_dense = jax.jit(lambda A, v: A @ v)
        f_fft = jax.jit(circulant_matvec_fft_ref)
        t_dense = time_fn(f_dense, dense, x)
        t_fft = time_fn(f_fft, C.col, x)

        # structural traffic model (per tile of the direct TPU kernel)
        tile_reads_dense = BLOCK * BLOCK
        tile_reads_circ = 2 * BLOCK - 1
        emit(
            f"matvec_n{n}",
            t_fft,
            f"dense_us={t_dense:.0f};fft_us={t_fft:.0f};"
            f"speedup={t_dense / t_fft:.1f}x;"
            f"hbm_reads_per_tile_dense={tile_reads_dense};"
            f"hbm_reads_per_tile_circulant={tile_reads_circ};"
            f"traffic_ratio={tile_reads_dense / tile_reads_circ:.0f}x",
        )

    # distributed four-step matvec: full-complex vs rfft half-spectrum
    n1, n2 = DIST_N1N2
    n = n1 * n2
    mesh = make_mesh((1,), ("model",))
    C = gaussian_circulant(jax.random.PRNGKey(0), n)
    x2d = layout_2d(jax.random.normal(jax.random.PRNGKey(1), (n,)), n1, n2)
    col2d = layout_2d(C.col, n1, n2)

    fft2d, _ = make_distributed_fft(mesh, n1, n2)
    spec_full = fft2d(col2d.astype(jnp.complex64))
    mv_full = make_distributed_matvec(mesh)
    t_full = time_fn(mv_full, spec_full, x2d)

    rfft2d, _ = make_distributed_rfft(mesh, n1, n2)
    spec_half = rfft2d(col2d)
    mv_half = make_distributed_matvec(mesh, rfft=True)
    t_half = time_fn(mv_half, spec_half, x2d)

    # wire-compressed collectives (PR 8): same rfft path, bf16 payload on
    # both transposes — on one device the wire is free, so this row times
    # the pack/unpack overhead; the byte cut shows in the dryrun model
    mv_bf16 = make_distributed_matvec(mesh, rfft=True, wire_dtype="bf16")
    t_bf16 = time_fn(mv_bf16, spec_half, x2d)

    emit(
        f"matvec_dist_full_n{n}",
        t_full,
        f"spectrum_cols={n2};wire_cols={n2}",
    )
    emit(
        f"matvec_dist_rfft_n{n}",
        t_half,
        f"spectrum_cols={n2 // 2 + 1};wire_cols={n2 // 2 + 1};"
        f"vs_full={t_full / t_half:.2f}x",
    )
    emit(
        f"matvec_dist_rfft_bf16wire_n{n}",
        t_bf16,
        f"wire_bytes_per_elem=4;fp32_wire_bytes_per_elem=8;"
        f"pack_overhead_vs_fp32wire={t_bf16 / t_half:.2f}x",
    )


if __name__ == "__main__":
    main()

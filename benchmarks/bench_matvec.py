"""Paper Fig. 7: matvec schemes vs matrix size.

Paper compares Reference (row-loop), Circulant (shifted-row), CUDA(cuBLAS).
Here: XLA dense GEMV (the cuBLAS analogue), the FFT circulant path, and the
direct Pallas kernel in interpret mode (correctness-only on CPU — its
*structural* HBM-traffic advantage is reported analytically: window reads
O(bi+bj) per tile vs O(bi*bj))."""

from __future__ import annotations

import jax

from .common import emit, pick, time_fn

SIZES = pick((1 << 10, 1 << 12, 1 << 14), (1 << 8,))
BLOCK = pick(128, 32)


def main() -> None:
    from repro.core import gaussian_circulant
    from repro.kernels.circulant_matvec.ref import circulant_matvec_fft_ref

    for n in SIZES:
        C = gaussian_circulant(jax.random.PRNGKey(0), n)
        x = jax.random.normal(jax.random.PRNGKey(1), (n,))
        dense = C.to_dense()

        f_dense = jax.jit(lambda A, v: A @ v)
        f_fft = jax.jit(circulant_matvec_fft_ref)
        t_dense = time_fn(f_dense, dense, x)
        t_fft = time_fn(f_fft, C.col, x)

        # structural traffic model (per tile of the direct TPU kernel)
        tile_reads_dense = BLOCK * BLOCK
        tile_reads_circ = 2 * BLOCK - 1
        emit(
            f"matvec_n{n}",
            t_fft,
            f"dense_us={t_dense:.0f};fft_us={t_fft:.0f};"
            f"speedup={t_dense / t_fft:.1f}x;"
            f"hbm_reads_per_tile_dense={tile_reads_dense};"
            f"hbm_reads_per_tile_circulant={tile_reads_circ};"
            f"traffic_ratio={tile_reads_dense / tile_reads_circ:.0f}x",
        )


if __name__ == "__main__":
    main()

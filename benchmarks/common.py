"""Shared benchmark utilities: timing, CSV emission, problem builders."""

from __future__ import annotations

import time
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp

ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time (us) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def build_problem(n: int, seed: int = 0, sensing: str = "gaussian", normalize=True):
    from repro.core import RecoveryProblem, partial_gaussian_circulant, partial_romberg_circulant
    from repro.data.synthetic import paper_regime, sparse_signal

    m, k = paper_regime(n)
    x = sparse_signal(jax.random.PRNGKey(seed), n, k)
    if sensing == "gaussian":
        op = partial_gaussian_circulant(jax.random.PRNGKey(seed + 1), n, m, normalize=normalize)
    else:
        op = partial_romberg_circulant(jax.random.PRNGKey(seed + 1), n, m)
    return RecoveryProblem(op=op, y=op.matvec(x), x_true=x)

"""Shared benchmark utilities: timing, CSV emission, problem builders.

Smoke mode: when ``REPRO_BENCH_SMOKE`` is set (``benchmarks.run --smoke``),
``pick`` swaps every suite's problem sizes/iteration counts for tiny ones so
the whole harness finishes in seconds on a CI CPU.  Smoke numbers are not
perf data — they only prove every suite still runs end to end and give the
artifact pipeline something to archive each push.
"""

from __future__ import annotations

import json
import os
import time
from typing import Callable, List

import jax

SMOKE = os.environ.get("REPRO_BENCH_SMOKE", "") not in ("", "0")

ROWS: List[dict] = []

# benchmarks.run sets this before each suite's main() so rows carry their
# suite name into the JSON artifact (benchmarks/compare.py aggregates the
# regression gate per suite).
CURRENT_SUITE: str | None = None


def pick(full, smoke):
    """Suite knob: the full-size value, or the tiny one in smoke mode."""
    return smoke if SMOKE else full


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append(
        {
            "suite": CURRENT_SUITE or name.split("_", 1)[0],
            "name": name,
            "us_per_call": float(f"{us_per_call:.1f}"),
            "derived": derived,
        }
    )
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def write_json(path: str) -> None:
    """Dump every emitted row (structured) for the CI artifact."""
    with open(path, "w") as f:
        json.dump({"smoke": SMOKE, "rows": ROWS}, f, indent=1)


def time_fn(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-time (us) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def build_problem(n: int, seed: int = 0, sensing: str = "gaussian", normalize=True):
    from repro.core import RecoveryProblem, partial_gaussian_circulant, partial_romberg_circulant
    from repro.data.synthetic import paper_regime, sparse_signal

    m, k = paper_regime(n)
    x = sparse_signal(jax.random.PRNGKey(seed), n, k)
    if sensing == "gaussian":
        op = partial_gaussian_circulant(jax.random.PRNGKey(seed + 1), n, m, normalize=normalize)
    else:
        op = partial_romberg_circulant(jax.random.PRNGKey(seed + 1), n, m)
    return RecoveryProblem(op=op, y=op.matvec(x), x_true=x)

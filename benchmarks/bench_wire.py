"""Wire-precision sweep: wire_dtype x overlap K on the distributed matvec.

For every (wire dtype, K) cell this times one planned rfft matvec
round (two transpose all-to-alls) and reports

  * the measured per-call time — on the in-process one-device mesh the
    wire is free, so the fp32-relative column isolates the pack/unpack
    overhead the wire_pack path adds to the chunk pipeline;
  * the modeled production wire bytes per matvec (both transposes at the
    cs_dryrun shape), computed from the wire dtype's true itemsize — the
    2x byte cut bf16/fp16 buy on a real mesh; and
  * the relative matvec error vs the fp32 wire — the quantity the plan
    layer's precision guard bounds (repro.ops.plan.WIRE_ERROR_BOUND).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.wire_pack.ops import WIRE_DTYPES, wire_itemsize

from .common import emit, pick, time_fn

N1, N2 = pick((256, 256), (16, 16))
OVERLAPS = pick((1, 2, 4), (1, 2))

# production-shape wire model constants (mirrors launch/cs_dryrun defaults)
PROD_N1 = PROD_N2 = 4096
PROD_P = 16


def _prod_wire_bytes(wire_dtype: str) -> int:
    """Modeled all-to-all payload bytes of one production matvec (forward
    + inverse transpose) per device, at the wire dtype's true itemsize."""
    nf_pad = -(-(PROD_N2 // 2 + 1) // PROD_P) * PROD_P
    elem = 2 * wire_itemsize(wire_dtype)  # split-complex (re, im) planes
    return 2 * (PROD_N1 // PROD_P) * nf_pad * elem


def main() -> None:
    from repro.dist.compat import make_mesh
    from repro.dist.fft import (
        layout_2d,
        make_distributed_matvec,
        make_distributed_rfft,
    )

    mesh = make_mesh((1,), ("model",))
    n = N1 * N2
    key = jax.random.PRNGKey(0)
    x2d = layout_2d(jax.random.normal(key, (n,)), N1, N2)
    col2d = layout_2d(
        jax.random.normal(jax.random.PRNGKey(1), (n,)) / jnp.sqrt(n), N1, N2
    )
    rfwd, _ = make_distributed_rfft(mesh, N1, N2)
    spec_half = rfwd(col2d)

    ref = None
    for k in OVERLAPS:
        for wire in WIRE_DTYPES:
            mv = make_distributed_matvec(
                mesh, rfft=True, overlap=k, wire_dtype=wire
            )
            t = time_fn(mv, spec_half, x2d)
            out = mv(spec_half, x2d)
            if wire == "fp32" and k == OVERLAPS[0]:
                ref = out
            rel = float(
                jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref)
            )
            emit(
                f"wire_{wire}_n{n}_k{k}",
                t,
                f"prod_a2a_mb_per_matvec={_prod_wire_bytes(wire) / 1e6:.1f};"
                f"rel_err_vs_fp32={rel:.2e}",
            )


if __name__ == "__main__":
    main()

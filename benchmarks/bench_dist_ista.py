"""Distributed CPISTA/FISTA through the plan API (beyond-paper).

The unification benchmark: the same ``solve`` driver runs each method on
the core backend and on a 1-device mesh through ``repro.ops.plan`` (the
sharded four-step transforms with a trivial collective), plus the rfft
half-spectrum variant.  The plan-vs-core ratio is the overhead of the
planned lowering itself — the quantity the ops layer must keep near 1 —
and the rfft row tracks the half-spectrum win on the same path.

Rows: ``dist_ista_<method>_<backend>[_rfft]``.
"""

from __future__ import annotations

import jax

from benchmarks.common import build_problem, emit, pick, time_fn

N = pick(65536, 1024)
ITERS = pick(100, 10)


def main() -> None:
    from repro.core import solve
    from repro.dist.compat import make_mesh
    from repro.ops import plan

    prob = build_problem(N)
    mesh = make_mesh((1,), ("model",))
    plans = {
        "core": plan(prob.op),
        "plan": plan(prob.op, mesh),
        "plan_rfft": plan(prob.op, mesh, rfft=True),
    }
    for method in ("ista", "fista"):
        base_us = None
        for tag, pl in plans.items():
            def run():
                x, _ = solve(
                    prob, method, iters=ITERS, record_every=ITERS, plan=pl
                )
                return x

            us = time_fn(jax.jit(run))
            if base_us is None:
                base_us = us
            emit(
                f"dist_ista_{method}_{tag}",
                us,
                f"n={N},iters={ITERS},vs_core={us / base_us:.2f}x",
            )


if __name__ == "__main__":
    main()

"""Paper Fig. 8: recovery error (MSE) over time at fixed n — the ISTA-vs-ADMM
crossover.  ADMM traces include the inversion time offset, as in the paper."""

from __future__ import annotations

import time

import jax
import numpy as np

from .common import build_problem, emit, pick

N = pick(1 << 12, 1 << 8)
ITERS = pick(400, 40)
RECORD = pick(40, 10)


def main() -> None:
    from repro.core import solve

    prob = build_problem(N)

    results = {}
    for method, kw in (
        ("ista", dict(alpha=1e-4)),
        ("fista", dict(alpha=1e-4)),
        ("cpadmm", dict(alpha=1e-4, rho=0.01, sigma=0.01)),
    ):
        t0 = time.perf_counter()
        _, tr = solve(prob, method, iters=ITERS, record_every=RECORD, **kw)
        jax.block_until_ready(tr.mse)
        wall = time.perf_counter() - t0
        trace = np.asarray(tr.mse)
        results[method] = (wall, trace)
        # first recorded step at which the paper threshold is crossed
        below = np.nonzero(trace <= 1e-4)[0]
        first = (below[0] + 1) * RECORD if len(below) else -1
        emit(
            f"error_trace_{method}_n{N}",
            wall * 1e6,
            f"final_mse={trace[-1]:.2e};iters_to_1e-4={first};"
            f"trace={'|'.join(f'{v:.1e}' for v in trace[::2])}",
        )

    # the Fig. 8 observation: ISTA reaches loose targets sooner; ADMM/FISTA win at tight ones
    ista_t = results["ista"][1]
    admm_t = results["cpadmm"][1]
    emit(
        f"error_trace_crossover_n{N}",
        0.0,
        f"ista_first_mse={ista_t[0]:.2e};admm_first_mse={admm_t[0]:.2e};"
        f"ista_final={ista_t[-1]:.2e};admm_final={admm_t[-1]:.2e}",
    )


if __name__ == "__main__":
    main()

"""Paper Sec. 7 / Fig. 9: compressed deblurring of an astronomical image.

128x128 synthetic starfield (statistically matched to the paper's ~10%-lit
Abell-2744 frame), order-5 raster blur, m = n/2, CPADMM recovery.  Paper
criterion: original-vs-recovered MSE of order 1e-2 on [0,255]-scaled pixels,
i.e. normalized MSE of order 1e-4; we report normalized MSE directly.

Since ISSUE 5 the same solve also runs through the execution-plan layer
(``build_deblur_plan`` on a 1-device mesh — the sharded four-step transforms
with a trivial collective): the ``deblur_planned[_rfft]`` rows track the
overhead of the planned lowering vs the single-device path, full-complex vs
half-spectrum, on identical numerics (pinned at 1e-5 in tests/test_deblur.py).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from .common import emit, pick

H = W = pick(128, 32)
ITERS = pick(600, 40)


def main() -> None:
    from repro.core import RecoveryProblem, solve
    from repro.core.deblur import (
        blurred_observation,
        build_deblur_plan,
        build_deblur_problem,
        deblur_metrics,
    )
    from repro.data.synthetic import starfield
    from repro.dist.compat import make_mesh

    img = starfield(jax.random.PRNGKey(0), H, W, density=0.10, n_blobs=8)
    p = build_deblur_problem(
        jax.random.PRNGKey(1), img, blur_order=5, subsample=0.5, sensing="romberg"
    )
    prob = RecoveryProblem(op=p.op, y=p.y, x_true=p.image.reshape(-1))

    t0 = time.perf_counter()
    x, tr = solve(prob, "cpadmm", iters=ITERS, record_every=ITERS, alpha=1e-3, rho=0.01, sigma=0.01)
    jax.block_until_ready(x)
    wall = time.perf_counter() - t0

    m = deblur_metrics(p, x)
    blurred = blurred_observation(p)
    blurred_nmse = float(jnp.mean((blurred - p.image) ** 2) / jnp.mean(p.image**2))
    emit(
        f"deblur_{H}x{W}",
        wall * 1e6,
        f"normalized_mse={float(m['normalized_mse']):.2e};"
        f"mse={float(m['mse']):.2e};"
        f"blurred_nmse={blurred_nmse:.2e};"
        f"improvement={blurred_nmse / float(m['normalized_mse']):.0f}x;"
        f"err_over_mean_intensity={float(m['mean_abs_err_over_mean_intensity']):.4f};"
        f"iters={ITERS}",
    )

    # single vs planned, full-complex vs rfft: the plan-overhead rows
    mesh = make_mesh((1,), ("model",))
    for tag, rfft in (("planned", False), ("planned_rfft", True)):
        pl = build_deblur_plan(p, mesh, rfft=rfft)

        t0 = time.perf_counter()
        xp, _ = solve(prob, "cpadmm", iters=ITERS, record_every=ITERS,
                      alpha=1e-3, rho=0.01, sigma=0.01, plan=pl)
        jax.block_until_ready(xp)
        wall_p = time.perf_counter() - t0

        mp = deblur_metrics(p, xp)
        emit(
            f"deblur_{tag}_{H}x{W}",
            wall_p * 1e6,
            f"normalized_mse={float(mp['normalized_mse']):.2e};"
            f"vs_single={wall_p / wall:.2f}x;iters={ITERS}",
        )


if __name__ == "__main__":
    main()

"""Perf-trajectory gate: compare a fresh BENCH_smoke.json to the baseline.

First real consumer of the BENCH_* artifact channel: CI's bench-smoke job
runs every suite at tiny sizes, then this script fails the job when any
suite's geometric-mean time ratio vs the committed baseline exceeds the
threshold.  The geomean-per-suite aggregation (rather than per-row) keeps
the gate robust to single-row jitter on shared CI runners; rows faster than
``--min-us`` in the baseline are pure dispatch overhead and are skipped.

The baseline was produced on a different machine than the CI runner, so
every suite's raw ratio carries a common machine-speed factor.  The gate
therefore normalizes each suite's geomean by the *median* suite geomean
before thresholding: a uniformly slower runner passes, while one suite
regressing relative to the fleet fails.  (A regression touching literally
every suite at once is invisible to this gate by construction — that is
the price of a committed cross-machine baseline; the raw median is printed
so gross drift stays observable in the job log.)

New rows/suites (no baseline entry) pass — they start gating once the
baseline is regenerated.  Rows present in the baseline but missing from the
fresh run fail: a suite silently dropping a measurement is itself a
regression.

    python -m benchmarks.compare BENCH_smoke.json \
        [--baseline benchmarks/baseline_smoke.json] [--threshold 1.25]

Regenerate the baseline after an intentional perf change:

    python -m benchmarks.run --smoke --json benchmarks/baseline_smoke.json
"""

from __future__ import annotations

import argparse
import json
import math
import sys
from collections import defaultdict


def _suite_of(name: str, row: dict) -> str:
    return row.get("suite", name.split("_", 1)[0])


def load_rows(path: str) -> dict:
    """Parse one BENCH artifact, failing loudly (not with a KeyError
    traceback) on files that are not benchmarks/run.py output."""
    try:
        with open(path) as f:
            data = json.load(f)
    except OSError as e:
        raise SystemExit(f"compare: cannot read {path}: {e}")
    except ValueError as e:
        raise SystemExit(f"compare: {path} is not valid JSON: {e}")
    if not isinstance(data, dict) or not isinstance(data.get("rows"), list):
        raise SystemExit(
            f"compare: {path} has no 'rows' list — not a benchmarks/run.py "
            f"artifact?"
        )
    rows = {}
    for i, r in enumerate(data["rows"]):
        if not isinstance(r, dict) or "name" not in r or "us_per_call" not in r:
            raise SystemExit(
                f"compare: {path} rows[{i}] lacks 'name'/'us_per_call': {r!r}"
            )
        rows[r["name"]] = r
    return rows


def compare(baseline: dict, fresh: dict, threshold: float, min_us: float):
    """-> (per-suite geomean ratios, missing row names, missing suite names).

    A suite present in the baseline but absent from the fresh run is its own
    loud failure (not just N missing rows): that is what a suite being
    dropped from the runner registration looks like.
    """
    ratios = defaultdict(list)
    missing = []
    for name, base_row in baseline.items():
        new_row = fresh.get(name)
        if new_row is None:
            missing.append(name)  # vanished rows fail regardless of speed
            continue
        if base_row["us_per_call"] < min_us:
            continue  # dispatch-overhead row: pure jitter at smoke sizes
        ratios[_suite_of(name, base_row)].append(
            max(new_row["us_per_call"], 1e-3) / max(base_row["us_per_call"], 1e-3)
        )
    geo = {
        suite: math.exp(sum(math.log(r) for r in rs) / len(rs))
        for suite, rs in ratios.items()
    }
    base_suites = {_suite_of(n, r) for n, r in baseline.items()}
    fresh_suites = {_suite_of(n, r) for n, r in fresh.items()}
    return geo, missing, sorted(base_suites - fresh_suites)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("fresh", help="freshly produced BENCH_smoke.json")
    ap.add_argument("--baseline", default="benchmarks/baseline_smoke.json")
    ap.add_argument(
        "--threshold", type=float, default=1.25,
        help="fail when a suite's geomean time ratio exceeds this (1.25 = +25%%)",
    )
    ap.add_argument(
        "--min-us", type=float, default=200.0,
        help="skip baseline rows faster than this (dispatch-overhead noise)",
    )
    args = ap.parse_args(argv)

    geo, missing, missing_suites = compare(
        load_rows(args.baseline), load_rows(args.fresh), args.threshold, args.min_us
    )
    ratios = sorted(geo.values())
    machine = ratios[len(ratios) // 2] if ratios else 1.0  # median suite ratio
    print(f"machine-speed factor (median suite geomean): {machine:.2f}x")
    failed = False
    for suite in sorted(geo):
        ratio = geo[suite] / machine
        verdict = "OK" if ratio <= args.threshold else "REGRESSED"
        failed |= ratio > args.threshold
        print(f"{suite:20s} geomean {geo[suite]:5.2f}x  normalized {ratio:5.2f}x  {verdict}")
    if missing_suites:
        failed = True
        print(
            f"MISSING suites (in baseline, absent from fresh run — dropped "
            f"from the runner registration?): {missing_suites}"
        )
    if missing:
        failed = True
        print(f"MISSING rows (in baseline, absent from fresh run): {missing}")
    if failed:
        print(
            f"perf gate FAILED (threshold {args.threshold:.2f}x vs "
            f"{args.baseline})", file=sys.stderr,
        )
        sys.exit(1)
    print(f"perf gate OK (threshold {args.threshold:.2f}x)")


if __name__ == "__main__":
    main()

"""Beyond-paper: CS gradient compression as a cross-pod collective.

Reports the wire-byte reduction and decode fidelity for sparse/compressible
gradients at several compression ratios (DESIGN.md Sec. 4)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import emit, pick, time_fn

DIM = pick(1 << 14, 1 << 10)


def main() -> None:
    from repro.core.compression import (
        compress,
        compression_wire_bytes,
        decode,
        identity_wire_bytes,
        make_compressor,
    )

    k = DIM // 128
    support = jax.random.permutation(jax.random.PRNGKey(0), DIM)[:k]
    g = jnp.zeros((DIM,)).at[support].set(
        jax.random.normal(jax.random.PRNGKey(1), (k,))
    )

    for ratio in pick((4, 8, 16), (4, 8)):
        spec, st = make_compressor(jax.random.PRNGKey(7), DIM, ratio=ratio)
        y, e = compress(spec, st, g)
        gh = decode(spec, st, y)[:DIM]
        err = float(jnp.linalg.norm(gh - g) / jnp.linalg.norm(g))
        t_enc = time_fn(lambda: compress(spec, st, g)[0])
        t_dec = time_fn(lambda: decode(spec, st, y))
        emit(
            f"grad_compression_r{ratio}_n{DIM}",
            t_dec,
            f"wire_B={compression_wire_bytes(spec)};dense_B={identity_wire_bytes(DIM)};"
            f"reduction={identity_wire_bytes(DIM)/compression_wire_bytes(spec):.0f}x;"
            f"rel_decode_err={err:.3f};encode_us={t_enc:.0f};decode_us={t_dec:.0f}",
        )


if __name__ == "__main__":
    main()

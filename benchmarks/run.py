"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (paper-artifact mapping in
DESIGN.md Sec. 7).

    python -m benchmarks.run [--only <name>] [--smoke] [--json OUT.json]

``--smoke`` swaps every suite to tiny problem sizes (seconds on a CI CPU;
run-to-completion check, not perf data); ``--json`` additionally writes the
structured rows — CI uploads ``BENCH_smoke.json`` as the per-push artifact
that anchors the perf trajectory.
"""

from __future__ import annotations

import argparse
import os
import sys
import traceback

SUITE_NAMES = (
    "footprint",  # Fig. 3
    "admm_recovery",  # Fig. 4
    "ista_recovery",  # Fig. 5
    "throughput",  # Fig. 6
    "matvec",  # Fig. 7
    "error_trace",  # Fig. 8
    "deblur",  # Sec. 7 / Fig. 9
    "grad_compression",  # beyond-paper
    "batched_recovery",  # beyond-paper: data-axis batching amortization
    "overlap",  # beyond-paper: chunked-transpose overlap sweep
    "dist_ista",  # beyond-paper: plan-API distributed CPISTA/FISTA overhead
    "autotune",  # beyond-paper: cost-model plan autotuner vs hand-picked
    "serve",  # beyond-paper: continuous-batching dispatcher vs static batch
    "wire",  # beyond-paper: wire-compressed collective precision sweep
    "hier",  # beyond-paper: hierarchical two-stage transpose, per-tier bytes
    "prox",  # beyond-paper: pluggable-prior cost per solve + TV map-making
)


def _load_suites():
    """Import suite modules *after* the smoke env var is settled — their
    size constants are bound at import time via common.pick."""
    import importlib

    return {name: importlib.import_module(f"benchmarks.bench_{name}") for name in SUITE_NAMES}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="run a single suite")
    ap.add_argument("--smoke", action="store_true", help="tiny sizes (CI run-to-completion)")
    ap.add_argument("--json", default=None, help="also write rows to this JSON file")
    args = ap.parse_args()

    if args.smoke:
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    suites = _load_suites()
    from benchmarks import common

    print("name,us_per_call,derived")
    failed = []
    for name, mod in suites.items():
        if args.only and name != args.only:
            continue
        common.CURRENT_SUITE = name  # rows emitted from here tag this suite
        try:
            mod.main()
        except Exception:
            failed.append(name)
            traceback.print_exc()
    common.CURRENT_SUITE = None
    if args.json:
        common.write_json(args.json)
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()

"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (paper-artifact mapping in
DESIGN.md Sec. 7).  ``python -m benchmarks.run [--only <name>]``.
"""

from __future__ import annotations

import argparse
import sys
import traceback

from . import (
    bench_admm_recovery,
    bench_deblur,
    bench_error_trace,
    bench_footprint,
    bench_grad_compression,
    bench_ista_recovery,
    bench_matvec,
    bench_throughput,
)

SUITES = {
    "footprint": bench_footprint,  # Fig. 3
    "admm_recovery": bench_admm_recovery,  # Fig. 4
    "ista_recovery": bench_ista_recovery,  # Fig. 5
    "throughput": bench_throughput,  # Fig. 6
    "matvec": bench_matvec,  # Fig. 7
    "error_trace": bench_error_trace,  # Fig. 8
    "deblur": bench_deblur,  # Sec. 7 / Fig. 9
    "grad_compression": bench_grad_compression,  # beyond-paper
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="run a single suite")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    failed = []
    for name, mod in SUITES.items():
        if args.only and name != args.only:
            continue
        try:
            mod.main()
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()

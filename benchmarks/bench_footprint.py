"""Paper Fig. 3: memory footprint vs signal size — circulant O(n) vs dense O(n^2).

Reports live ``nbytes`` of the actual operator data structures (the paper
logs nvidia-smi; we log device buffer sizes, same quantity minus runtime
overhead).  The dense column is analytical above DENSE_LIMIT to avoid
allocating gigabytes on CI."""

from __future__ import annotations

import jax

from .common import emit, pick

DENSE_LIMIT = 1 << 13


def main() -> None:
    from repro.core import densify, partial_gaussian_circulant

    for logn in pick((10, 12, 14, 16, 18, 20), (8, 10)):
        n = 1 << logn
        m = n // 2
        op = partial_gaussian_circulant(jax.random.PRNGKey(0), n, m)
        circ_bytes = op.circ.col.nbytes + op.circ.spec.nbytes + op.omega.nbytes
        if n <= DENSE_LIMIT:
            dense_bytes = densify(op).mat.nbytes
            mode = "measured"
        else:
            dense_bytes = m * n * 4  # fp32, the paper's PISTA footprint
            mode = "analytical"
        # PADMM additionally stores the n x n inverse (Fig. 3's worst line)
        padmm_bytes = n * n * 4 + dense_bytes
        emit(
            f"footprint_n{n}",
            0.0,
            f"circulant_B={circ_bytes};dense_A_B={dense_bytes};"
            f"padmm_B={padmm_bytes};ratio={dense_bytes / circ_bytes:.0f};{mode}",
        )


if __name__ == "__main__":
    main()

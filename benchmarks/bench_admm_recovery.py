"""Paper Fig. 4: ADMM recovery time vs n — PADMM (dense) vs CPADMM (circulant),
with and without the initial inversion (the -I curves).

On this CPU container wall-clock ratios between the dense O(n^3)/O(n^2) path
and the FFT path are the same *asymptotic* story the paper measures on GPU;
absolute numbers are CPU-scale.  Success criterion: paper's MSE <= 1e-4."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from .common import build_problem, emit, pick, time_fn

SIZES = pick((1 << 10, 1 << 11, 1 << 12), (1 << 8,))
ITERS = pick(300, 20)
TUNED = dict(alpha=1e-4, rho=0.01, sigma=0.01)


def main() -> None:
    from repro.core import RecoveryProblem, densify, solve
    from repro.core.admm import dense_admm_setup

    for n in SIZES:
        prob = build_problem(n)
        dense_prob = RecoveryProblem(op=densify(prob.op), y=prob.y, x_true=prob.x_true)

        # --- inversion (setup) time
        t0 = time.perf_counter()
        jax.block_until_ready(
            dense_admm_setup(dense_prob.op, dense_prob.y, rho=0.01).B
        )
        t_inv_dense = (time.perf_counter() - t0) * 1e6

        t0 = time.perf_counter()
        from repro.core.admm import CpadmmParams, cpadmm_setup

        p = CpadmmParams(*(jnp.float32(v) for v in (1e-4, 0.01, 0.01, 1.0, 1.0)))
        jax.block_until_ready(cpadmm_setup(prob.op, prob.y, p).b_spec)
        t_inv_circ = (time.perf_counter() - t0) * 1e6

        # --- iteration time + recovery quality
        def run_dense():
            return solve(dense_prob, "admm", iters=ITERS, record_every=ITERS, alpha=1e-4, rho=0.01)[1].mse[-1]

        def run_circ():
            return solve(prob, "cpadmm", iters=ITERS, record_every=ITERS, **TUNED)[1].mse[-1]

        t_dense = time_fn(run_dense)
        t_circ = time_fn(run_circ)
        mse_d = float(run_dense())
        mse_c = float(run_circ())
        emit(
            f"admm_recovery_n{n}",
            t_circ,
            f"padmm_us={t_dense:.0f};cpadmm_us={t_circ:.0f};"
            f"padmm_inv_us={t_inv_dense:.0f};cpadmm_inv_us={t_inv_circ:.0f};"
            f"speedup={t_dense / t_circ:.1f}x;inv_speedup={t_inv_dense / t_inv_circ:.1f}x;"
            f"mse_padmm={mse_d:.1e};mse_cpadmm={mse_c:.1e}",
        )


if __name__ == "__main__":
    main()

"""Plan autotuner vs hand-picked defaults (beyond-paper).

The closing-the-loop benchmark for ``repro.ops.tune``: on the two smoke
workloads — batched CS recovery and multi-frame compressed-domain
deblurring — run the same CPADMM solve under (a) the hand-picked default
plan and (b) the autotuned plan (``tune="measure"``), and report both plus
the tuner's own cost: a cold tune (enumerate + score + measure) and a warm
cache hit (which must be microseconds — the production-run path).

Rows:
    autotune_recovery_default / autotune_recovery_tuned
    autotune_deblur_default   / autotune_deblur_tuned
    autotune_cold_tune        / autotune_warm_cache

The tuned rows' derived field carries the chosen config and the
tuned-vs-default ratio — the acceptance number ROADMAP quotes.
"""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, pick, time_fn

N = pick(65536, 1024)  # 256^2 full
BATCH = pick(4, 2)
ITERS = pick(50, 10)
SIZE = pick(128, 16)  # deblur frame extent
FRAMES = pick(4, 2)
CACHE_PATH = "artifacts/bench_plan_cache.json"


def _solve_us(prob, pl):
    from repro.core import solve

    def run():
        x, _ = solve(prob, "cpadmm", iters=ITERS, record_every=ITERS, plan=pl)
        return x

    return time_fn(jax.jit(run))


def main() -> None:
    from repro.core import RecoveryProblem, partial_gaussian_circulant
    from repro.core.deblur import build_deblur_plan, build_multiframe_deblur_problem
    from repro.data.synthetic import paper_regime, sparse_signal, starfield
    from repro.dist.compat import make_mesh
    from repro.ops import plan
    from repro.ops.tune import PlanCache

    # all tunes in this suite share the bench-local store (the deblur path
    # reaches the cache through the env var)
    os.environ["REPRO_PLAN_CACHE"] = CACHE_PATH
    cache = PlanCache()
    cache.clear()  # cold numbers must be cold
    mesh = make_mesh((1,), ("model",))

    # -- batched recovery ---------------------------------------------------
    m, k = paper_regime(N)
    x = sparse_signal(jax.random.PRNGKey(0), N, k, batch=(BATCH,))
    op = partial_gaussian_circulant(jax.random.PRNGKey(1), N, m, normalize=True)
    prob = RecoveryProblem(op=op, y=op.matvec(x), x_true=x)

    default_pl = plan(op, mesh)
    t0 = time.perf_counter()
    tuned_pl = plan(op, mesh, tune="measure", batch=BATCH)
    cold_us = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    plan(op, mesh, tune="measure", batch=BATCH)
    warm_us = (time.perf_counter() - t0) * 1e6

    d_us = _solve_us(prob, default_pl)
    t_us = _solve_us(prob, tuned_pl)
    emit("autotune_recovery_default", d_us, f"n={N},batch={BATCH},iters={ITERS}")
    emit(
        "autotune_recovery_tuned", t_us,
        f"vs_default={t_us / d_us:.2f}x,cfg={tuned_pl.config.describe().replace(' ', ';')}",
    )
    emit("autotune_cold_tune", cold_us, "enumerate+score+measure, empty cache")
    emit("autotune_warm_cache", warm_us, "cache hit: no scoring, no compiles")

    # -- multi-frame deblurring --------------------------------------------
    frames = jnp.stack([
        starfield(jax.random.PRNGKey(10 + i), SIZE, SIZE, density=0.05,
                  n_blobs=2)
        for i in range(FRAMES)
    ])
    dp = build_multiframe_deblur_problem(
        jax.random.PRNGKey(2), frames, blur_order=3, subsample=0.5,
        sensing="romberg",
    )
    dprob = RecoveryProblem(op=dp.op, y=dp.y,
                            x_true=frames.reshape(FRAMES, -1))
    d_pl = build_deblur_plan(dp, mesh)
    t_pl = build_deblur_plan(dp, mesh, tune="measure", batch=FRAMES)
    dd_us = _solve_us(dprob, d_pl)
    dt_us = _solve_us(dprob, t_pl)
    emit("autotune_deblur_default", dd_us,
         f"frames={FRAMES},size={SIZE},iters={ITERS}")
    emit(
        "autotune_deblur_tuned", dt_us,
        f"vs_default={dt_us / dd_us:.2f}x,cfg={t_pl.config.describe().replace(' ', ';')}",
    )


if __name__ == "__main__":
    main()

"""Paper Fig. 5: ISTA recovery time vs n — PISTA (dense) vs CPISTA (circulant)
vs the beyond-paper FISTA; plus the Romberg-sensing conditioning win."""

from __future__ import annotations


from .common import build_problem, emit, pick, time_fn

SIZES = pick((1 << 10, 1 << 12, 1 << 14), (1 << 8,))
ITERS = pick(300, 20)


def main() -> None:
    from repro.core import RecoveryProblem, densify, solve

    for n in SIZES:
        prob = build_problem(n)

        def run(p, method):
            return solve(p, method, iters=ITERS, record_every=ITERS, alpha=1e-4)[1].mse[-1]

        t_circ = time_fn(run, prob, "ista")
        mse_c = float(run(prob, "ista"))
        if n <= (1 << 12):  # dense matvec memory gets silly beyond this
            dense_prob = RecoveryProblem(op=densify(prob.op), y=prob.y, x_true=prob.x_true)
            t_dense = time_fn(run, dense_prob, "ista")
            speed = f"pista_us={t_dense:.0f};speedup={t_dense / t_circ:.1f}x;"
        else:
            speed = "pista_us=OOM-skip;"
        t_fista = time_fn(run, prob, "fista")
        mse_f = float(run(prob, "fista"))
        romberg = build_problem(n, sensing="romberg")
        mse_r = float(run(romberg, "ista"))
        emit(
            f"ista_recovery_n{n}",
            t_circ,
            f"cpista_us={t_circ:.0f};{speed}fista_us={t_fista:.0f};"
            f"mse_cpista={mse_c:.1e};mse_fista={mse_f:.1e};mse_romberg_ista={mse_r:.1e}",
        )


if __name__ == "__main__":
    main()

"""Beyond-paper: pluggable-prior cost — what swapping the prox costs per solve.

The ISSUE 10 prox layer keeps the paper's l1 soft threshold on the fused
lowering (prox=None / L1Prox are bit-identical, pinned in tests/test_prox.py)
and composes richer priors outside the fused kernels.  These rows measure
the price of that composability: a full CPADMM solve per prior at identical
iteration budgets, locally and through a planned 1-device mesh (where the
non-elementwise TV/wavelet priors take the hybrid core + global-tail
lowering — the overhead row tracks exactly the cost the tuner's cost model
must price).  The map-making row times the flagship TV scenario end to end
(recover the dithered stack + co-add) and reports the recovered map's PSNR.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from .common import emit, pick

N = pick(4096, 256)
BATCH = pick(4, 2)
ITERS = pick(400, 30)
MAP_SIZE = pick(32, 16)
MAP_ITERS = pick(600, 60)


def main() -> None:
    from repro.core import RecoveryProblem, partial_gaussian_circulant, solve
    from repro.core.mapmaking import (
        build_mapmaking_plan,
        build_mapmaking_problem,
        solve_mapmaking,
    )
    from repro.data.synthetic import extended_emission, paper_regime, sparse_signal
    from repro.dist.compat import make_mesh
    from repro.ops import plan
    from repro.ops.prox import L1Prox, NonNegL1Prox, TVProx, WaveletProx

    m, k = paper_regime(N)
    x_true = sparse_signal(jax.random.PRNGKey(0), N, k, batch=(BATCH,))
    op = partial_gaussian_circulant(jax.random.PRNGKey(1), N, m, normalize=True)
    prob = RecoveryProblem(op=op, y=op.matvec(x_true), x_true=x_true)
    side = int(round(N ** 0.5))
    assert side * side == N, N  # sizes above are chosen square for TV

    priors = (
        ("l1", L1Prox()),
        ("nonneg_l1", NonNegL1Prox()),
        ("tv", TVProx(shape=(side, side))),
        ("wavelet", WaveletProx()),
    )
    mesh = make_mesh((1,), ("model",))
    base_wall = None
    for name, prox in priors:
        for tag, pl in (("", plan(op, prox=prox)),
                        ("_planned", plan(op, mesh, prox=prox))):
            t0 = time.perf_counter()
            x, _ = solve(prob, "cpadmm", iters=ITERS, record_every=ITERS,
                         alpha=1e-3, rho=0.01, sigma=0.01, plan=pl)
            jax.block_until_ready(x)
            wall = time.perf_counter() - t0
            if base_wall is None:
                base_wall = wall  # the local l1 row anchors the ratios
            mse = float(jnp.mean((x - x_true) ** 2))
            emit(
                f"prox_{name}{tag}_n{N}",
                wall * 1e6,
                f"vs_l1={wall / base_wall:.2f}x;mse={mse:.2e};iters={ITERS}",
            )

    # flagship TV scenario: dithered map-making, solve + co-add, map PSNR
    sky = extended_emission(jax.random.PRNGKey(7), MAP_SIZE, MAP_SIZE,
                            n_sources=3)
    shifts = [0, 1, MAP_SIZE, MAP_SIZE + 1]
    mp = build_mapmaking_problem(jax.random.PRNGKey(11), sky, shifts,
                                 blur_order=1.0, subsample=0.5)
    for name, prox in (("tv", "tv"), ("l1", None)):
        pl = build_mapmaking_plan(mp, prox=prox)
        t0 = time.perf_counter()
        z, met = solve_mapmaking(mp, plan=pl, method="cpadmm",
                                 iters=MAP_ITERS, alpha=1e-4)
        jax.block_until_ready(met["map"])
        wall = time.perf_counter() - t0
        emit(
            f"mapmaking_{name}_{MAP_SIZE}x{MAP_SIZE}",
            wall * 1e6,
            f"map_psnr_db={float(met['psnr_db']):.1f};"
            f"frames={len(shifts)};iters={MAP_ITERS}",
        )


if __name__ == "__main__":
    main()

"""Hierarchical two-stage transpose: flat vs hier rows, per-tier wire bytes.

For the flat exchange and the hierarchical one (degenerate 1x1 (host,
device) mesh in-process — the collectives are free, so measured time
isolates the reshuffle/slice overhead the two-stage path adds) this times
one planned rfft matvec round and reports, per row,

  * the measured per-call time and the relative error vs the flat fp32
    path (zero for fp32 wires — the hier exchange is bit-exact);
  * the modeled production per-tier wire bytes per matvec at the cs_dryrun
    multi-host shape (n=4096^2 over H=2 hosts x D=8 devices): intra-host
    bytes ride ICI, and only the (H-1)/H cross-boundary fraction rides DCN
    — the flat row pays DCN for every byte (launch/roofline.DCN_BW model).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.wire_pack.ops import wire_itemsize

from .common import emit, pick, time_fn

N1, N2 = pick((256, 256), (16, 16))
OVERLAPS = pick((1, 4), (1, 2))

# production multi-host shape (mirrors launch/cs_dryrun's mh_* variants)
PROD_N1 = PROD_N2 = 4096
PROD_H, PROD_D = 2, 8
PROD_P = PROD_H * PROD_D


def _prod_tier_bytes(hier: bool, wire: str, inter_wire: str):
    """(ici_bytes, dcn_bytes) of one production matvec (fwd + inv
    transpose) per device.  Flat: one monolithic all-to-all whose every
    byte crosses the host boundary.  Hier: the full payload intra-host at
    ``wire`` plus the (H-1)/H cross-host fraction at ``inter_wire``."""
    nf_pad = -(-(PROD_N2 // 2 + 1) // PROD_P) * PROD_P
    elems = 2 * (PROD_N1 // PROD_P) * nf_pad  # both transposes
    if not hier:
        return 0, elems * 2 * wire_itemsize(wire)
    intra = elems * 2 * wire_itemsize(wire)
    inter = elems * (PROD_H - 1) // PROD_H * 2 * wire_itemsize(inter_wire)
    return intra, inter


def main() -> None:
    from repro.dist.compat import make_hier_mesh, make_mesh
    from repro.dist.fft import (
        layout_2d,
        make_distributed_matvec,
        make_distributed_rfft,
    )

    flat_mesh = make_mesh((1,), ("model",))
    hier_mesh = make_hier_mesh(1, 1, 1)
    n = N1 * N2
    x2d = layout_2d(jax.random.normal(jax.random.PRNGKey(0), (n,)), N1, N2)
    col2d = layout_2d(
        jax.random.normal(jax.random.PRNGKey(1), (n,)) / jnp.sqrt(n), N1, N2
    )
    spec_half = make_distributed_rfft(flat_mesh, N1, N2)[0](col2d)

    rows = (  # (tag, hier, wire, inter_wire)
        ("flat_fp32", False, "fp32", "fp32"),
        ("flat_bf16", False, "bf16", "fp32"),
        ("hier_fp32", True, "fp32", "fp32"),
        ("hier_inter_bf16", True, "fp32", "bf16"),
        ("hier_bf16", True, "bf16", "bf16"),
    )
    ref = None
    for k in OVERLAPS:
        for tag, hier, wire, inter in rows:
            if hier:
                mv = make_distributed_matvec(
                    hier_mesh, rfft=True, overlap=k, wire_dtype=wire,
                    axis_name=("host", "device"), hier=True,
                    inter_wire_dtype=inter,
                )
            else:
                mv = make_distributed_matvec(
                    flat_mesh, rfft=True, overlap=k, wire_dtype=wire
                )
            t = time_fn(mv, spec_half, x2d)
            out = mv(spec_half, x2d)
            if tag == "flat_fp32" and k == OVERLAPS[0]:
                ref = out
            rel = float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref))
            ici, dcn = _prod_tier_bytes(hier, wire, inter)
            emit(
                f"hier_{tag}_n{n}_k{k}",
                t,
                f"prod_ici_mb_per_matvec={ici / 1e6:.1f};"
                f"prod_dcn_mb_per_matvec={dcn / 1e6:.1f};"
                f"rel_err_vs_flat_fp32={rel:.2e}",
            )


if __name__ == "__main__":
    main()

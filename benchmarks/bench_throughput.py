"""Paper Fig. 6: algorithmic throughput (iterations/second) vs n for the four
solver variants (the paper's GPU/CPU plots collapse to this CPU's numbers;
the circulant-vs-dense gap is the portable part)."""

from __future__ import annotations


from .common import build_problem, emit, pick, time_fn

SIZES = pick((1 << 10, 1 << 12, 1 << 14), (1 << 8,))
ITERS = pick(100, 10)


def main() -> None:
    from repro.core import RecoveryProblem, densify, solve

    for n in SIZES:
        prob = build_problem(n)
        rows = {}

        def runner(p, method, **kw):
            return lambda: solve(p, method, iters=ITERS, record_every=ITERS, **kw)[0]

        t = time_fn(runner(prob, "ista", alpha=1e-4))
        rows["cpista"] = ITERS / (t / 1e6)
        t = time_fn(runner(prob, "cpadmm", alpha=1e-4, rho=0.01, sigma=0.01))
        rows["cpadmm"] = ITERS / (t / 1e6)
        if n <= (1 << 12):
            dense_prob = RecoveryProblem(op=densify(prob.op), y=prob.y, x_true=prob.x_true)
            t = time_fn(runner(dense_prob, "ista", alpha=1e-4))
            rows["pista"] = ITERS / (t / 1e6)
            t = time_fn(runner(dense_prob, "admm", alpha=1e-4, rho=0.01))
            rows["padmm"] = ITERS / (t / 1e6)
        derived = ";".join(f"{k}_iters_per_s={v:.0f}" for k, v in rows.items())
        emit(f"throughput_n{n}", 1e6 / rows["cpista"], derived)


if __name__ == "__main__":
    main()

"""Continuous-batching dispatcher vs static batching (beyond-paper).

The serving benchmark for ``repro.serve``: one Poisson stream of
heterogeneous recovery requests (mixed tolerances — the raggedness that
makes static batches drain to their stragglers) is served twice on the
wall clock, by

  (a) ``RecoveryServer`` — continuous batching, converged slots recycled
      to queued requests mid-run, and
  (b) ``static_batch_serve`` — fixed waves of ``SLOTS``, each run to its
      last straggler before the next wave is admitted,

over the *identical* seeded workload and the same ``BatchEngine``.  Rows
report per-signal service time; the derived fields carry the headline
serving numbers — signals/sec and p50/p99 latency — plus the recycled-slot
count and the continuous-vs-static throughput ratio (the acceptance number
ROADMAP quotes).

Rows:
    serve_continuous / serve_static / serve_speedup
"""

from __future__ import annotations

import jax

from benchmarks.common import emit, pick

N = pick(16384, 512)
REQS = pick(32, 8)
SLOTS = pick(8, 4)
RATE = pick(200.0, 200.0)  # arrivals/s: fast enough that a queue forms
MAX_ITERS = pick(2000, 800)
# 3:1 loose-to-tight mix: most requests finish fast, a few run long — the
# ragged regime where static waves drain to their stragglers
TOLS = (1e-3, 1e-3, 1e-3, 1e-6)
RHO = 0.01


def main() -> None:
    from repro.core.circulant import partial_gaussian_circulant
    from repro.data.synthetic import paper_regime
    from repro.serve import (
        RecoveryServer,
        WallClock,
        static_batch_serve,
        summarize,
        synthetic_workload,
    )

    m, _ = paper_regime(N)
    op = partial_gaussian_circulant(jax.random.PRNGKey(0), N, m,
                                    normalize=True)
    reqs = synthetic_workload(op, REQS, rate=RATE, seed=0, tols=TOLS,
                              max_iters=MAX_ITERS, min_iters=50)

    srv = RecoveryServer(slots=SLOTS, round_iters=32, rho=RHO, sigma=RHO,
                         clock=WallClock())
    srv.warmup(reqs[0])  # compile round/re-arm programs off the clock
    srv.clock = WallClock()  # re-zero so latencies start at arrival 0
    cont = summarize(srv.serve(reqs))
    recycled = srv.stats()["total"]["recycled"]

    # the static baseline reuses the same server's compiled engines, so the
    # comparison is pure scheduling discipline (waves vs recycling)
    stat = summarize(static_batch_serve(reqs, server=srv, clock=WallClock()))

    emit(
        "serve_continuous",
        1e6 / cont["signals_per_sec"],
        f"sig/s={cont['signals_per_sec']:.1f},p50={cont['p50_latency_s']:.3f}s,"
        f"p99={cont['p99_latency_s']:.3f}s,recycled={recycled}",
    )
    emit(
        "serve_static",
        1e6 / stat["signals_per_sec"],
        f"sig/s={stat['signals_per_sec']:.1f},p50={stat['p50_latency_s']:.3f}s,"
        f"p99={stat['p99_latency_s']:.3f}s",
    )
    speedup = cont["signals_per_sec"] / stat["signals_per_sec"]
    emit(
        "serve_speedup",
        1e6 / cont["signals_per_sec"],
        f"continuous_vs_static={speedup:.2f}x,n={N},reqs={REQS},slots={SLOTS}",
    )


if __name__ == "__main__":
    main()

"""Batched multi-signal recovery: per-signal amortization over the data axis.

The paper's workload is off-line recovery of *many* compressed signals
(Andrecut's GPU speedup comes precisely from recovering signals in
parallel).  This suite times one batched ``solve`` over B signals sharing a
single sensing operator against B sequential single-signal solves, and
reports the per-signal amortization curve — the headline number for the
batching lever in ROADMAP §Perf.

Also times the batched tolerance driver (``solve_until`` with per-signal
convergence masks): the batch finishes at the *slowest* signal's iteration
count, but early finishers freeze — the derived column records the
min/max per-signal iterations actually spent.
"""

from __future__ import annotations

import jax

from .common import build_problem, emit, pick, time_fn

N = pick(1 << 12, 1 << 8)
BATCHES = pick((1, 4, 8, 16), (1, 4))
ITERS = pick(300, 20)
TUNED = dict(alpha=1e-4, rho=0.01, sigma=0.01)


def _batched_problem(n, batch):
    from repro.core import RecoveryProblem
    from repro.data.synthetic import paper_regime, sparse_signal

    base = build_problem(n)
    k = paper_regime(n)[1]
    x = sparse_signal(jax.random.PRNGKey(7), n, k, batch=(batch,))
    return RecoveryProblem(op=base.op, y=base.op.matvec(x), x_true=x)


def main() -> None:
    from repro.core import solve, solve_until

    t_single = None
    for batch in BATCHES:
        prob = _batched_problem(N, batch)

        def run():
            return solve(prob, "cpadmm", iters=ITERS, record_every=ITERS, **TUNED)[0]

        t = time_fn(run)
        per_signal = t / batch
        if t_single is None:
            t_single = t
        emit(
            f"batched_recovery_n{N}_b{batch}",
            per_signal,
            f"total_us={t:.0f};per_signal_us={per_signal:.0f};"
            f"amortization={t_single * batch / t:.2f}x",
        )

    # tolerance-driven batch: per-signal convergence masks
    batch = BATCHES[-1]
    prob = _batched_problem(N, batch)

    def run_until():
        x, iters = solve_until(
            prob, "cpadmm", tol=pick(1e-6, 1e-3), max_iters=ITERS * 4, **TUNED
        )
        return x, iters

    t = time_fn(lambda: run_until()[0])
    iters = jax.device_get(run_until()[1])
    emit(
        f"batched_solve_until_n{N}_b{batch}",
        t / batch,
        f"total_us={t:.0f};iters_min={int(iters.min())};iters_max={int(iters.max())}",
    )


if __name__ == "__main__":
    main()

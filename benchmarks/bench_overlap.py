"""Overlap sweep: chunked-transpose FFT pipeline vs the monolithic transpose.

Two complementary measurements per K:

  * measured: wall time of the distributed rfft forward+inverse pair with
    ``overlap=K`` on the in-process mesh — on one device the collective is
    free, so this isolates the *overhead* of chunking (extra reshuffles,
    K small FFDs instead of one big one).  The overlap win itself cannot
    show on one host device; the dry-run models it on the production mesh.
  * modeled: the hidden-collective fraction at the production mesh shape
    (n=4096x4096, model=16, batch/device=1), same window model as
    ``repro.launch.cs_dryrun``: per chunk, min(a2a time, stage-1 HBM time)
    of the remaining K-1 chunks hides behind compute.
"""

from __future__ import annotations

import jax

from repro.kernels.wire_pack.ops import wire_itemsize

# bandwidths shared with the dry-run's roofline so the two models can
# never diverge
from repro.launch.roofline import HBM_BW, ICI_BW

from .common import emit, pick, time_fn

N1, N2 = pick((512, 512), (32, 16))
OVERLAPS = (1, 2, 4, 8)

# production-shape model constants (mirrors launch/cs_dryrun)
PROD_N1 = PROD_N2 = 4096
PROD_P = 16


def _hidden_fraction_model(k: int, wire_dtype: str = "fp32") -> float:
    """Hidden-collective fraction of one forward rfft transform at the
    production shape: (k-1)/k of the wire hides, capped by the stage-1
    local window (HBM-bound row-rfft of the device's block).  The payload
    itemsize comes from the configured wire dtype (2 real planes per
    complex element), not a hardcoded complex64."""
    nf_pad = -(-(PROD_N2 // 2 + 1) // PROD_P) * PROD_P
    elem_bytes = 2 * wire_itemsize(wire_dtype)  # split-complex (re, im)
    a2a_bytes = (PROD_N1 // PROD_P) * nf_pad * elem_bytes
    stage1_bytes = (PROD_N1 // PROD_P) * (PROD_N2 * 4 + nf_pad * 8)  # r + w
    wire_s = a2a_bytes / ICI_BW
    window_s = stage1_bytes / HBM_BW
    hidden = min((k - 1) / k * wire_s, window_s)
    return hidden / wire_s


def main() -> None:
    from repro.dist.compat import make_mesh
    from repro.dist.fft import layout_2d, make_distributed_rfft

    mesh = make_mesh((1,), ("model",))
    n = N1 * N2
    x = layout_2d(jax.random.normal(jax.random.PRNGKey(0), (n,)), N1, N2)

    t_mono = None
    for k in OVERLAPS:
        rfwd, rinv = make_distributed_rfft(mesh, N1, N2, overlap=k)
        roundtrip = jax.jit(lambda a: rinv(rfwd(a)))
        t = time_fn(roundtrip, x)
        t_mono = t if k == 1 else t_mono
        emit(
            f"overlap_rfft_n{n}_k{k}",
            t,
            f"chunk_overhead={t / t_mono:.2f}x;"
            f"prod_hidden_frac={_hidden_fraction_model(k):.2f}",
        )

    # wire-compressed variant of the same sweep: bf16 payload halves the
    # modeled wire time, so more of it hides at the same K (the measured
    # column again isolates pack+chunk overhead — one device, free wire)
    for k in OVERLAPS:
        rfwd, rinv = make_distributed_rfft(
            mesh, N1, N2, overlap=k, wire_dtype="bf16"
        )
        roundtrip = jax.jit(lambda a: rinv(rfwd(a)))
        t = time_fn(roundtrip, x)
        emit(
            f"overlap_rfft_bf16wire_n{n}_k{k}",
            t,
            f"overhead_vs_fp32wire_k1={t / t_mono:.2f}x;"
            f"prod_hidden_frac={_hidden_fraction_model(k, 'bf16'):.2f}",
        )


if __name__ == "__main__":
    main()

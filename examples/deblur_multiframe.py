"""Multi-frame compressed deblurring: one batched solve for a frame stack.

    PYTHONPATH=src python examples/deblur_multiframe.py [--frames 4 --size 64]

Real astronomical pipelines hand over *stacks* of exposures observed through
the same optics (Herschel/PACS-style map-making), not lone frames.  This
example synthesizes F starfield frames, senses them all through one shared
blur+sensing operator A = P (C B), and recovers the whole stack with a
single batched CPADMM solve — the solvers broadcast over the leading frame
axis, so the per-frame cost amortizes exactly like the batched recovery
benchmark.  Per-frame PSNR / error metrics and PGM renders come out per
frame.
"""

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import RecoveryProblem, solve
from repro.core.deblur import (
    blurred_observation,
    build_multiframe_deblur_problem,
    deblur_metrics,
    recovered_image,
)
from repro.data.synthetic import starfield


def save_pgm(path: str, img) -> None:
    arr = np.asarray(jnp.clip(img, 0, 1) * 255).astype(np.uint8)
    h, w = arr.shape
    with open(path, "wb") as f:
        f.write(f"P5 {w} {h} 255\n".encode())
        f.write(arr.tobytes())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=4)
    ap.add_argument("--size", type=int, default=64)
    ap.add_argument("--iters", type=int, default=600)
    ap.add_argument("--blur-order", type=int, default=5)
    ap.add_argument("--out", default="artifacts/deblur_multiframe")
    args = ap.parse_args()

    frames = jnp.stack(
        [starfield(jax.random.PRNGKey(i), args.size, args.size, density=0.10, n_blobs=6)
         for i in range(args.frames)]
    )
    p = build_multiframe_deblur_problem(
        jax.random.PRNGKey(100), frames, blur_order=args.blur_order,
        subsample=0.5, sensing="romberg",
    )
    n = args.size * args.size
    print(f"{args.frames} frames of {args.size}x{args.size} (n={n}), "
          f"blur L={args.blur_order}, m={p.op.m}, one shared operator")

    prob = RecoveryProblem(
        op=p.op, y=p.y, x_true=frames.reshape(args.frames, -1)
    )
    t0 = time.time()
    x_hat, _ = solve(prob, "cpadmm", iters=args.iters,
                     record_every=max(1, args.iters // 4),
                     alpha=1e-3, rho=0.01, sigma=0.01)
    x_hat.block_until_ready()
    wall = time.time() - t0

    m = deblur_metrics(p, x_hat)
    print(f"recovered the whole stack in {wall:.1f}s / {args.iters} iters "
          f"({wall / args.frames:.1f}s per frame, one solve)")
    for f in range(args.frames):
        print(f"  frame {f}: PSNR {float(m['psnr_db'][f]):.1f} dB   "
              f"normalized MSE {float(m['normalized_mse'][f]):.2e}")

    os.makedirs(args.out, exist_ok=True)
    rec = recovered_image(p, x_hat)
    blur = blurred_observation(p)
    for f in range(args.frames):
        save_pgm(os.path.join(args.out, f"frame{f}_original.pgm"), frames[f])
        save_pgm(os.path.join(args.out, f"frame{f}_blurred.pgm"), blur[f])
        save_pgm(os.path.join(args.out, f"frame{f}_recovered.pgm"), rec[f])
    print(f"renders in {args.out}/frame*_{{original,blurred,recovered}}.pgm")


if __name__ == "__main__":
    main()

"""Multi-frame compressed deblurring, distributed, with checkpoint/restart.

    PYTHONPATH=src python examples/deblur_multiframe.py [--frames 4 --size 64]
        [--devices 8 --mesh 2x4 --rfft] [--method cpadmm|ista|fista]

Real astronomical pipelines hand over *stacks* of exposures observed through
the same optics (Herschel/PACS-style map-making), not lone frames.  This
example synthesizes F starfield frames, senses them all through one shared
blur+sensing operator A = P (C B), and recovers the whole stack with a
single batched solve — now lowered through ``build_deblur_plan`` onto a
(data, model) mesh: frames shard over the data axis, each frame's four-step
transforms over the model axis, and the composed spectrum spec(C)·spec(B)
is built and sharded exactly once.

The solve runs through ``solve_checkpointed`` like the production launcher:
it is killed halfway (simulated preemption), restarted from the latest
checkpoint, and the restarted result is verified bit-identical to an
uninterrupted run — the paper's three-hour Sec. 7 recovery as a preemptible
cluster job.  Per-frame PSNR / error metrics and PGM renders come out per
frame as before.
"""

import argparse
import os
import time

if __name__ == "__main__":  # XLA_FLAGS must land before jax imports
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=4)
    ap.add_argument("--size", type=int, default=64)
    ap.add_argument("--iters", type=int, default=600)
    ap.add_argument("--chunk", type=int, default=100)
    ap.add_argument("--blur-order", type=int, default=5)
    ap.add_argument("--method", default="cpadmm",
                    choices=("cpadmm", "ista", "fista"),
                    help="every method runs distributed through the plan")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N fake XLA host devices (0 = real devices)")
    ap.add_argument("--mesh", default=None,
                    help="'M' (model axis) or 'DxM' (data x model); "
                         "default: single-device plan")
    ap.add_argument("--rfft", action="store_true",
                    help="half-spectrum transforms (half the wire bytes)")
    ap.add_argument("--overlap", type=int, default=1,
                    help="chunked-transpose overlap factor K")
    ap.add_argument("--out", default="artifacts/deblur_multiframe")
    args = ap.parse_args()
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}"
        )

import jax  # noqa: E402  (after XLA_FLAGS)
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.ckpt import checkpoint as ckpt  # noqa: E402
from repro.core import RecoveryProblem, solve_checkpointed  # noqa: E402
from repro.core.deblur import (  # noqa: E402
    blurred_observation,
    build_deblur_plan,
    build_multiframe_deblur_problem,
    deblur_metrics,
    recovered_image,
)
from repro.core.solvers import make_stepper  # noqa: E402
from repro.data.synthetic import starfield  # noqa: E402
from repro.launch.recover import parse_mesh  # noqa: E402


def save_pgm(path: str, img) -> None:
    arr = np.asarray(jnp.clip(img, 0, 1) * 255).astype(np.uint8)
    h, w = arr.shape
    with open(path, "wb") as f:
        f.write(f"P5 {w} {h} 255\n".encode())
        f.write(arr.tobytes())


def main():
    frames = jnp.stack(
        [starfield(jax.random.PRNGKey(i), args.size, args.size, density=0.10, n_blobs=6)
         for i in range(args.frames)]
    )
    p = build_multiframe_deblur_problem(
        jax.random.PRNGKey(100), frames, blur_order=args.blur_order,
        subsample=0.5, sensing="romberg",
    )
    n = args.size * args.size
    mesh, batch_axis = parse_mesh(args.mesh)
    pl = build_deblur_plan(p, mesh, rfft=args.rfft, overlap=args.overlap,
                           batch_axis=batch_axis)
    print(f"{args.frames} frames of {args.size}x{args.size} (n={n}), "
          f"blur L={args.blur_order}, m={p.op.m}, one shared operator"
          + (f"; mesh={args.mesh} (plan API)" if args.mesh else ""))

    prob = RecoveryProblem(
        op=p.op, y=p.y, x_true=frames.reshape(args.frames, -1)
    )
    kw = dict(alpha=1e-3, rho=0.01, sigma=0.01, plan=pl, chunk=args.chunk)
    ckdir = os.path.join(args.out, "ckpt")
    import shutil

    shutil.rmtree(ckdir, ignore_errors=True)  # stale steps would win "latest"

    def save(step, state):
        ckpt.save(ckdir, step, jax.device_get(state))

    # --- first half of the budget, checkpointing every chunk, then "die"
    half = max(args.chunk, (args.iters // 2) // args.chunk * args.chunk)
    t0 = time.time()
    solve_checkpointed(prob, args.method, iters=half, save_cb=save, **kw)
    print(f"  -- simulated preemption after iter {half}: restarting --")

    # --- restart from the latest checkpoint and run out the full budget
    shape = jax.eval_shape(make_stepper(prob, args.method, **{
        k: v for k, v in kw.items() if k != "chunk"}).init)
    step_no, state = ckpt.restore(ckdir, None, shape)
    assert step_no == half, step_no
    x_hat, _ = solve_checkpointed(
        prob, args.method, iters=args.iters, save_cb=save,
        restore=(step_no, state), **kw,
    )
    x_hat.block_until_ready()
    wall = time.time() - t0

    # --- uninterrupted reference: the restarted stack must be bit-identical
    x_ref, _ = solve_checkpointed(prob, args.method, iters=args.iters, **kw)
    identical = bool((x_hat == x_ref).all())
    print(f"restart-vs-uninterrupted bit-identical: {identical}")
    assert identical

    m = deblur_metrics(p, x_hat)
    print(f"recovered the whole stack in {wall:.1f}s / {args.iters} iters "
          f"({wall / args.frames:.1f}s per frame, one solve + one restart)")
    for f in range(args.frames):
        print(f"  frame {f}: PSNR {float(m['psnr_db'][f]):.1f} dB   "
              f"normalized MSE {float(m['normalized_mse'][f]):.2e}")

    os.makedirs(args.out, exist_ok=True)
    rec = recovered_image(p, x_hat)
    blur = blurred_observation(p)
    for f in range(args.frames):
        save_pgm(os.path.join(args.out, f"frame{f}_original.pgm"), frames[f])
        save_pgm(os.path.join(args.out, f"frame{f}_blurred.pgm"), blur[f])
        save_pgm(os.path.join(args.out, f"frame{f}_recovered.pgm"), rec[f])
    print(f"renders in {args.out}/frame*_{{original,blurred,recovered}}.pgm")


if __name__ == "__main__":
    main()

"""End-to-end training driver: train a ~100M-param LM for a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py --arch xlstm-350m --steps 200

Uses the real substrate end to end: --arch picks any assigned architecture's
*smoke-scaled* config widened to ~100M params, the synthetic token pipeline
(deterministic per (seed, step) => restart never replays data), AdamW with
warmup-cosine, atomic checkpointing every --ckpt-every steps, and automatic
resume from the latest checkpoint.  Loss is expected to drop well below the
uniform baseline ln(vocab) within a few hundred steps.
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.ckpt import checkpoint as ckpt
from repro.configs.registry import smoke_config
from repro.data.synthetic import token_batch
from repro.models import steps as steps_mod
from repro.optim.adamw import AdamWConfig


def widen(cfg, d_model=512, n_layers=8, vocab=8192):
    """Scale a smoke config up to ~100M params for a real training demo."""
    heads = max(4, d_model // 128)
    return dataclasses.replace(
        cfg,
        d_model=d_model,
        n_layers=n_layers,
        n_heads=heads,
        n_kv_heads=heads if cfg.n_kv_heads == cfg.n_heads else max(1, heads // 4),
        d_ff=(0 if cfg.d_ff == 0 else d_model * 4),
        vocab=vocab,
        head_dim=0,
        loss_chunk=128,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="codeqwen1.5-7b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ckpt-dir", default="artifacts/train_lm_ckpt")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = widen(smoke_config(args.arch))
    from repro.models.config import count_params

    print(f"arch={cfg.name}  params~{count_params(cfg)['total']/1e6:.0f}M "
          f"vocab={cfg.vocab}  ln(V)={jnp.log(cfg.vocab):.2f}")

    opt_cfg = AdamWConfig(lr_peak=3e-4, warmup_steps=20, total_steps=args.steps)
    train_step = jax.jit(steps_mod.make_train_step(cfg, opt_cfg), donate_argnums=0)

    state = steps_mod.init_train_state(jax.random.PRNGKey(args.seed), cfg, opt_cfg)
    start = 0
    latest = ckpt.latest_step(args.ckpt_dir)
    if latest is not None:
        start, state = ckpt.restore(args.ckpt_dir, latest, jax.eval_shape(lambda: state))
        print(f"resumed from checkpoint step {start}")

    t0 = time.time()
    for step in range(start, args.steps):
        batch = {"tokens": token_batch(args.seed, step, 0, args.batch, args.seq, cfg.vocab)}
        state, metrics = train_step(state, batch)
        if (step + 1) % 20 == 0:
            toks = args.batch * args.seq * (step + 1 - start)
            print(f"step {step+1:4d}  loss {float(metrics['loss']):.3f}  "
                  f"acc {float(metrics['acc']):.3f}  "
                  f"gnorm {float(metrics['grad_norm']):.2f}  "
                  f"tok/s {toks/(time.time()-t0):.0f}")
        if (step + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, step + 1, jax.device_get(state))
    print("done")


if __name__ == "__main__":
    main()

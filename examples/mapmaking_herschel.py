"""Herschel-style multi-observation map-making under the TV prior.

    PYTHONPATH=src python examples/mapmaking_herschel.py [--frames 4 --size 32]
        [--devices 8 --mesh 2x4] [--prior tv|l1]

A space observatory scans the same sky patch at small pointing offsets
(dithering) and the ground segment fuses the exposures into one map.  Under
the paper's compressed-sensing telescope model each offset frame is the
*same* joint operator A = P (C B) applied to a shifted sky — shift
circulants compose into the circulant algebra like everything else
(``repro.core.mapmaking``) — so the whole stack recovers through ONE planned
operator with frames on the batch axis, then co-adds by unshifting:

    y_f = A roll(sky, s_f)      recover z_f jointly      map = mean_f roll(z_f, -s_f)

The blurred, shifted frames are not sparse point fields, so the paper's l1
soft threshold is the wrong prior here; the anisotropic TV prox
(``repro.ops.prox.TVProx``) recovers the map markedly better — the example
prints the PSNR table for both so the gap is a measurement, not a claim.
"""

import argparse
import os
import time

if __name__ == "__main__":  # XLA_FLAGS must land before jax imports
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=4)
    ap.add_argument("--size", type=int, default=32)
    ap.add_argument("--iters", type=int, default=400)
    ap.add_argument("--blur-sigma", type=float, default=1.5)
    ap.add_argument("--method", default="cpadmm",
                    choices=("cpadmm", "ista", "fista"))
    ap.add_argument("--prior", default="both", choices=("tv", "l1", "both"),
                    help="recovery prior; 'both' prints the comparison table")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N fake XLA host devices (0 = real devices)")
    ap.add_argument("--mesh", default=None,
                    help="'M' (model axis) or 'DxM' (data x model)")
    ap.add_argument("--out", default="artifacts/mapmaking")
    args = ap.parse_args()
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}"
        )

import jax  # noqa: E402  (after XLA_FLAGS)
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.mapmaking import (  # noqa: E402
    build_mapmaking_plan,
    build_mapmaking_problem,
    solve_mapmaking,
)
from repro.data.synthetic import extended_emission  # noqa: E402
from repro.launch.recover import parse_mesh  # noqa: E402


def save_pgm(path: str, img) -> None:
    arr = np.asarray(jnp.clip(img, 0, 1) * 255).astype(np.uint8)
    h, w = arr.shape
    with open(path, "wb") as f:
        f.write(f"P5 {w} {h} 255\n".encode())
        f.write(arr.tobytes())


def main():
    # extended dust/cloud emission, not a point field: gradient-sparse is the
    # regime where TV earns its keep (run --prior both and read the table)
    sky = extended_emission(jax.random.PRNGKey(7), args.size, args.size,
                            n_sources=3)
    # dither pattern: horizontal and vertical unit offsets around the pointing
    offsets = [0, 1, args.size, args.size + 1, 2, 2 * args.size]
    shifts = offsets[: args.frames]
    prob = build_mapmaking_problem(
        jax.random.PRNGKey(11), sky, shifts,
        blur_order=args.blur_sigma, subsample=0.5,
        sensing="romberg", blur_kind="gaussian",
    )
    mesh, _ = parse_mesh(args.mesh)
    print(f"{len(shifts)} dithered exposures of a {args.size}x{args.size} "
          f"sky, gaussian PSF sigma={args.blur_sigma}, m={prob.deblur.op.m}, "
          f"one shared operator"
          + (f"; mesh={args.mesh} (plan API)" if args.mesh else ""))

    priors = ("tv", "l1") if args.prior == "both" else (args.prior,)
    results = {}
    for prior in priors:
        pl = build_mapmaking_plan(
            prob, mesh, prox="tv" if prior == "tv" else None,
        )
        t0 = time.time()
        z_hat, m = solve_mapmaking(prob, plan=pl, method=args.method,
                                   iters=args.iters, alpha=1e-4)
        m["map"].block_until_ready()
        results[prior] = (m, time.time() - t0)

    print(f"\n  {'prior':<8} {'map PSNR':>10} {'map RMS':>10} {'wall':>8}")
    for prior, (m, wall) in results.items():
        print(f"  {prior:<8} {float(m['psnr_db']):>8.1f} dB "
              f"{float(m['rms']):>10.2e} {wall:>7.1f}s")

    os.makedirs(args.out, exist_ok=True)
    save_pgm(os.path.join(args.out, "sky_true.pgm"), sky)
    for prior, (m, _) in results.items():
        save_pgm(os.path.join(args.out, f"map_{prior}.pgm"), m["map"])
    print(f"\nrenders in {args.out}/{{sky_true,map_*}}.pgm")


if __name__ == "__main__":
    main()

"""Quickstart: recover a compressively-sensed sparse signal with CPADMM.

    PYTHONPATH=src python examples/quickstart.py

Reproduces the paper's core experiment (Sec. 6) at n=4096: a k-sparse signal
sensed by a partial circulant matrix at m = n/2 is recovered to the paper's
MSE <= 1e-4 threshold, with the operator stored as a single length-n vector.
"""

import jax

from repro.core import (
    PAPER_TARGET_MSE,
    RecoveryProblem,
    partial_gaussian_circulant,
    solve,
)
from repro.data.synthetic import paper_regime, sparse_signal


def main():
    n = 4096
    m, k = paper_regime(n)  # paper Sec. 6: m = n/2, k ~ n/10
    print(f"n={n}  measurements m={m}  sparsity k={k}")

    x_true = sparse_signal(jax.random.PRNGKey(0), n, k)
    op = partial_gaussian_circulant(jax.random.PRNGKey(1), n, m, normalize=True)
    y = op.matvec(x_true)

    # O(n) operator storage vs O(mn) dense (paper Fig. 3)
    circ_bytes = op.circ.col.nbytes + op.omega.nbytes
    print(f"sensing operator storage: {circ_bytes/1e3:.1f} kB "
          f"(dense would be {m*n*4/1e6:.1f} MB)")

    prob = RecoveryProblem(op=op, y=y, x_true=x_true)
    for method, iters, kw in (
        ("cpadmm", 400, dict(alpha=1e-4, rho=0.01, sigma=0.01)),
        ("fista", 800, dict(alpha=1e-4)),  # FISTA needs ~2x CPADMM's iters here
    ):
        x_hat, trace = solve(prob, method, iters=iters, record_every=iters // 4, **kw)
        mses = [f"{v:.2e}" for v in trace.mse]
        ok = "recovered" if float(trace.mse[-1]) < PAPER_TARGET_MSE else "NOT recovered"
        print(f"{method:8s} mse trace {mses}  -> {ok}")


if __name__ == "__main__":
    main()

"""End-to-end distributed recovery through the execution-plan layer: one
large signal sharded over the model axis via the four-step FFT, driven by
the *same* solver drivers as a single-device run, with checkpoint/restart.

    PYTHONPATH=src python examples/distributed_recovery.py [--devices 8]
        [--method cpadmm|ista|fista] [--overlap K] [--tail jnp|pallas]

This is the paper's workload as a *cluster job*: the same launcher logic
runs on a 256-chip pod by swapping the mesh (launch/mesh.py).  The example
forces N fake host devices, lowers the sensing operator onto them with
``repro.ops.plan``, recovers a 64k-sample signal with
``solve_checkpointed`` (any ``--method`` — distributed CPISTA/FISTA ride
the same plan), kills itself halfway (simulated preemption), and restarts
from the checkpoint — identical result to an uninterrupted run.
"""

import argparse
import os

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--n1", type=int, default=256)
    ap.add_argument("--n2", type=int, default=256)
    ap.add_argument("--method", default="cpadmm",
                    choices=("cpadmm", "ista", "fista"),
                    help="every method runs distributed through the plan")
    ap.add_argument("--rfft", action="store_true",
                    help="half-spectrum transforms (half the wire bytes)")
    ap.add_argument("--overlap", type=int, default=1,
                    help="chunked-transpose overlap factor K (1 = monolithic)")
    ap.add_argument("--tail", default="jnp", choices=("jnp", "pallas"),
                    help="elementwise iteration tail: XLA-fused jnp ops or "
                         "the fused cpadmm_tail Pallas kernel")
    args = ap.parse_args()
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={args.devices}"

import jax  # noqa: E402  (after XLA_FLAGS)
import jax.numpy as jnp  # noqa: E402

from repro.ckpt import checkpoint as ckpt  # noqa: E402
from repro.core import RecoveryProblem, solve_checkpointed  # noqa: E402
from repro.core.circulant import PartialCirculant, gaussian_circulant  # noqa: E402
from repro.data.synthetic import paper_regime, sparse_signal  # noqa: E402
from repro.dist.compat import make_mesh  # noqa: E402
from repro.ops import plan  # noqa: E402


def main():
    n1, n2 = args.n1, args.n2
    n = n1 * n2
    mesh = make_mesh((args.devices,), ("model",))
    m, k = paper_regime(n)
    print(f"n={n} over {args.devices} devices; m={m}, k={k}, "
          f"method={args.method}")

    x_true = sparse_signal(jax.random.PRNGKey(0), n, k)
    C = gaussian_circulant(jax.random.PRNGKey(1), n, normalize=True)
    omega = jnp.sort(jax.random.permutation(jax.random.PRNGKey(2), n)[:m])
    op = PartialCirculant(C, omega.astype(jnp.int32))
    prob = RecoveryProblem(op=op, y=op.matvec(x_true), x_true=x_true)

    # one call lowers the operator onto the mesh; the drivers are unchanged
    pl = plan(op, mesh, n1=n1, n2=n2, rfft=args.rfft,
              overlap=args.overlap, tail=args.tail)
    kw = dict(alpha=1e-4, rho=0.01, sigma=0.01, plan=pl, chunk=50)
    ckdir = "artifacts/dist_recovery_ckpt"
    import shutil

    shutil.rmtree(ckdir, ignore_errors=True)  # stale steps would win "latest"

    def report(step, state):
        ckpt.save(ckdir, step, jax.device_get(state))

    # --- run the first 100 iterations, checkpointing every chunk
    solve_checkpointed(prob, args.method, iters=100, save_cb=report, **kw)
    print("  -- simulated preemption after iter 100: restarting --")

    # --- restart from the latest checkpoint and run to 200
    from repro.core.solvers import make_stepper

    shape = jax.eval_shape(make_stepper(prob, args.method, **{
        k_: v for k_, v in kw.items() if k_ != "chunk"}).init)
    step_no, state = ckpt.restore(ckdir, None, shape)
    assert step_no == 100, step_no
    x_hat, mse = solve_checkpointed(
        prob, args.method, iters=200, save_cb=report,
        restore=(step_no, state), **kw,
    )

    # --- uninterrupted reference run: the restart must be bit-identical
    x_ref, _ = solve_checkpointed(prob, args.method, iters=200, **kw)
    identical = bool((x_hat == x_ref).all())
    print(f"restart-vs-uninterrupted bit-identical: {identical}")
    assert identical

    final = float(jnp.mean(mse))
    print(f"final MSE {final:.2e}  ({'OK' if final < 1e-4 else 'needs more iters'})")


if __name__ == "__main__":
    main()

"""End-to-end distributed recovery driver: one large signal sharded over the
model axis via the four-step FFT, with checkpoint/restart.

    PYTHONPATH=src python examples/distributed_recovery.py [--devices 8]

This is the paper's workload as a *cluster job*: the same launcher logic
runs on a 256-chip pod by swapping the mesh (launch/mesh.py).  The example
forces N fake host devices, recovers a 64k-sample signal distributed over
them, kills itself halfway (simulated preemption), and restarts from the
checkpoint — byte-identical result to an uninterrupted run.
"""

import argparse
import os

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--n1", type=int, default=256)
    ap.add_argument("--n2", type=int, default=256)
    ap.add_argument("--overlap", type=int, default=1,
                    help="chunked-transpose overlap factor K (1 = monolithic)")
    ap.add_argument("--tail", default="jnp", choices=("jnp", "pallas"),
                    help="elementwise iteration tail: XLA-fused jnp ops or "
                         "the fused cpadmm_tail Pallas kernel")
    args = ap.parse_args()
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={args.devices}"

import jax  # noqa: E402  (after XLA_FLAGS)
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.ckpt import checkpoint as ckpt  # noqa: E402
from repro.core.circulant import gaussian_circulant  # noqa: E402
from repro.data.synthetic import paper_regime, sparse_signal  # noqa: E402
from repro.dist.compat import make_mesh, shard_map  # noqa: E402
from repro.dist.fft import layout_2d, unlayout_2d  # noqa: E402
from repro.dist.recovery import (  # noqa: E402
    DistCpadmmParams,
    DistCpadmmState,
    dist_cpadmm_step,
    make_dist_spectrum,
)


def main():
    n1, n2 = args.n1, args.n2
    n = n1 * n2
    mesh = make_mesh((args.devices,), ("model",))
    m, k = paper_regime(n)
    print(f"n={n} over {args.devices} devices; m={m}, k={k}")

    x_true = sparse_signal(jax.random.PRNGKey(0), n, k)
    C = gaussian_circulant(jax.random.PRNGKey(1), n, normalize=True)
    omega = jnp.sort(jax.random.permutation(jax.random.PRNGKey(2), n)[:m])
    mask = jnp.zeros((n,)).at[omega].set(1.0)
    y_full = mask * C.matvec(x_true)

    spec2d = make_dist_spectrum(mesh)(layout_2d(C.col, n1, n2))
    mask2d = layout_2d(mask, n1, n2)
    y2d = layout_2d(y_full, n1, n2)

    p = DistCpadmmParams(*(jnp.float32(v) for v in (1e-4, 0.01, 0.01, 1.0, 1.0)))
    b_spec = (1.0 / (p.rho * (jnp.abs(spec2d) ** 2) + p.sigma)).astype(spec2d.dtype)
    d_diag = jnp.where(mask2d > 0, 1.0 / (1.0 + p.rho), 1.0 / p.rho)

    row = P("model", None)
    col = P(None, "model")

    def chunk_fn(spec, bs, dd, pty, state):
        def body(s, _):
            return dist_cpadmm_step(
                spec, bs, dd, pty, s, p, "model",
                overlap=args.overlap, tail=args.tail,
            ), None
        state, _ = jax.lax.scan(body, state, None, length=50)
        return state

    sm = shard_map(chunk_fn, mesh=mesh,
                   in_specs=(col, col, row, row, DistCpadmmState(*(row,) * 5)),
                   out_specs=DistCpadmmState(*(row,) * 5), check_vma=False)
    run_chunk = jax.jit(sm)

    zeros = jnp.zeros_like(y2d)
    state = DistCpadmmState(zeros, zeros, zeros, zeros, zeros)
    ckdir = "artifacts/dist_recovery_ckpt"

    # --- run 4 chunks, checkpoint each, "crash" after chunk 2
    for step in range(1, 5):
        state = run_chunk(spec2d, b_spec, d_diag, y2d, state)
        ckpt.save(ckdir, step * 50, jax.device_get(state))
        mse = float(jnp.mean((unlayout_2d(state.z) - x_true) ** 2))
        print(f"  iter {step*50:4d}  mse {mse:.2e}")
        if step == 2:
            print("  -- simulated preemption: restarting from checkpoint --")
            saved_step, state = ckpt.restore(ckdir, None, jax.eval_shape(lambda: state))
            assert saved_step == 100

    x_hat = unlayout_2d(state.z)
    final = float(jnp.mean((x_hat - x_true) ** 2))
    print(f"final MSE {final:.2e}  ({'OK' if final < 1e-4 else 'needs more iters'})")


if __name__ == "__main__":
    main()

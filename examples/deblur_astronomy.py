"""Compressed astronomical-image deblurring (paper Sec. 7, Fig. 9).

    PYTHONPATH=src python examples/deblur_astronomy.py [--size 128] [--iters 600]

Builds a synthetic starfield (the offline stand-in for the Abell-2744 Hubble
frame), blurs it with the paper's order-5 raster filter, sparse-samples the
blurred image at m = n/2, and jointly un-blurs + reconstructs with CPADMM
using the fact that A = P (C B) is still partial-circulant.  Saves PGM
renders of the original / blurred / recovered frames (viewable anywhere,
no image libraries needed).
"""

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import RecoveryProblem, solve
from repro.core.deblur import (
    blurred_observation,
    build_deblur_problem,
    deblur_metrics,
    recovered_image,
)
from repro.data.synthetic import starfield


def save_pgm(path: str, img) -> None:
    arr = np.asarray(jnp.clip(img, 0, 1) * 255).astype(np.uint8)
    h, w = arr.shape
    with open(path, "wb") as f:
        f.write(f"P5 {w} {h} 255\n".encode())
        f.write(arr.tobytes())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", type=int, default=128)
    ap.add_argument("--iters", type=int, default=600)
    ap.add_argument("--blur-order", type=int, default=5)
    ap.add_argument("--out", default="artifacts/deblur")
    args = ap.parse_args()

    img = starfield(jax.random.PRNGKey(0), args.size, args.size, density=0.10, n_blobs=8)
    p = build_deblur_problem(
        jax.random.PRNGKey(1), img, blur_order=args.blur_order,
        subsample=0.5, sensing="romberg",
    )
    n = img.size
    print(f"image {args.size}x{args.size} (n={n}), blur L={args.blur_order}, m={p.op.m}")

    prob = RecoveryProblem(op=p.op, y=p.y, x_true=img.reshape(-1))
    t0 = time.time()
    x_hat, trace = solve(prob, "cpadmm", iters=args.iters, record_every=max(1, args.iters // 6),
                         alpha=1e-3, rho=0.01, sigma=0.01)
    x_hat.block_until_ready()
    wall = time.time() - t0

    m = deblur_metrics(p, x_hat)
    print(f"recovered in {wall:.1f}s / {args.iters} iters")
    print(f"  normalized MSE      : {float(m['normalized_mse']):.2e} (paper: ~1e-4 order)")
    print(f"  abs err / mean int. : {float(m['mean_abs_err_over_mean_intensity']):.4f} "
          f"(paper: 0.0157)")
    os.makedirs(args.out, exist_ok=True)
    save_pgm(os.path.join(args.out, "original.pgm"), img)
    save_pgm(os.path.join(args.out, "blurred.pgm"), blurred_observation(p))
    save_pgm(os.path.join(args.out, "recovered.pgm"), recovered_image(p, x_hat))
    print(f"renders in {args.out}/{{original,blurred,recovered}}.pgm")


if __name__ == "__main__":
    main()
